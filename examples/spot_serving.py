"""Serving on preemptible capacity: batched decode with hibernate/resume of
in-flight requests when the spot market reclaims the instance.

Run:  PYTHONPATH=src python examples/spot_serving.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import init_params
from repro.serve import (
    Request,
    SpotServingScheduler,
    make_prefill_step,
    make_serve_step,
)


def main() -> None:
    cfg = get_smoke_config("deepseek_7b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompt_len, gen_tokens, batch = 16, 12, 4
    cache_len = prompt_len + gen_tokens

    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    step = jax.jit(make_serve_step(cfg))
    rng = np.random.default_rng(0)

    sched = SpotServingScheduler(batch_size=batch, hibernate=True)
    for i in range(10):
        sched.add(Request(i, prompt_len, gen_tokens))

    interrupted_once = False
    rounds = 0
    while len(sched.done) < 10 and rounds < 20:
        rounds += 1
        reqs = sched.fill_batch()
        b = len(reqs)
        prompts = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)),
                              jnp.int32)
        logits, state = prefill(params, prompts)
        tok = jnp.argmax(logits, -1)[:, None]
        for t in range(gen_tokens - 1):
            lg, state = step(params, tok, state)
            tok = jnp.argmax(lg[:, -1, :], -1)[:, None]
            if not interrupted_once and t == 5:
                print(f"[market] spot capacity reclaimed mid-batch: "
                      f"hibernating {b} requests (progress kept)")
                sched.interrupt()
                interrupted_once = True
                break
        else:
            sched.step(gen_tokens)
            continue

    st = sched.stats()
    print(f"served {st['done']}/10 requests over {rounds} batches; "
          f"{st['interruptions']} request interruptions (hibernate/resume)")
    assert st["done"] == 10


if __name__ == "__main__":
    main()
