"""Paper §VII-E — comparison of allocation algorithms under the synthetic
spot-market scenario (Table II hosts, Table III VM profiles, 2 000 VMs).

Reproduces the qualitative results of Figs. 14-15: First-Fit causes the most
spot interruptions, HLEM-VMP fewer, the adjusted HLEM-VMP fewest; HLEM has
the best average interruption time, adjusted the best maximum (vs HLEM).

Run:  PYTHONPATH=src python examples/market_comparison.py [--quick]
"""
import argparse
import copy
import time

from repro.core import (
    MarketSimulator,
    ScenarioConfig,
    SimConfig,
    make_policy,
    synthetic_scenario,
)

POLICIES = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
            "hlem-vmp-adjusted"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 policies only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=-0.5)
    args = ap.parse_args()

    policies = (["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]
                if args.quick else POLICIES)
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=args.seed))
    print(f"fleet: {len(hosts)} hosts | workload: {len(vms)} VMs "
          f"({sum(1 for v in vms if v.is_spot)} spot)")
    print(f"{'policy':20s} {'interrupts':>10s} {'avg_s':>8s} {'max_s':>8s} "
          f"{'finished':>9s} {'wall_s':>7s}")
    for name in policies:
        kwargs = {"alpha": args.alpha} if name == "hlem-vmp-adjusted" else {}
        sim = MarketSimulator(policy=make_policy(name, **kwargs),
                              config=SimConfig(record_timeline=False))
        for cap in hosts:
            sim.add_host(cap)
        for v in vms:
            sim.submit(copy.deepcopy(v))
        t0 = time.time()
        metrics = sim.run(until=2200.0)
        s = metrics.spot_stats(sim.vms)
        print(f"{name:20s} {s['interruptions']:10d} "
              f"{s['avg_interruption_time']:8.2f} "
              f"{s['max_interruption_time']:8.2f} "
              f"{s['spot_finished']:9d} {time.time()-t0:7.1f}")


if __name__ == "__main__":
    main()
