"""Paper §VII-E — comparison of allocation algorithms under the synthetic
spot-market scenario (Table II hosts, Table III VM profiles, 2 000 VMs).

Reproduces the qualitative results of Figs. 14-15: First-Fit causes the most
spot interruptions, HLEM-VMP fewer, the adjusted HLEM-VMP fewest; HLEM has
the best average interruption time, adjusted the best maximum (vs HLEM).

Each policy row also reports the $ consequences: total cost, savings vs an
all-on-demand execution, and wasted spend (terminated spot VMs pay for
partial work that delivers nothing).  By default spot bills at a flat
discount (``PriceModel.spot_discount``); with ``--market`` the dynamic
market engine runs underneath and spot bills at each pool's *realized
clearing price* instead.

The whole comparison is one :class:`~repro.api.ScenarioSpec` + a policy
loop: ``api.build`` materializes fresh engines/simulators per policy, so no
state can leak between rows (the paper's same-randomized-values
methodology for free).

Run:  PYTHONPATH=src python examples/market_comparison.py [--quick] [--market]
"""
import argparse
import time

from repro.api import (
    BidSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.core import ScenarioConfig, synthetic_scenario
from repro.market import cost_stats, realized_cost_stats

POLICIES = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
            "hlem-vmp-adjusted"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="3 policies only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--alpha", type=float, default=-0.5)
    ap.add_argument("--market", action="store_true",
                    help="attach the market engine (randomized bids; pick "
                         "the price regime with --regime); cost columns "
                         "then use realized clearing prices")
    ap.add_argument("--regime", default="volatile",
                    choices=["calm", "volatile", "correlated"])
    args = ap.parse_args()

    policies = (["first-fit", "hlem-vmp", "hlem-vmp-adjusted"]
                if args.quick else POLICIES)
    scenario = ScenarioSpec(
        workload="synthetic",
        regime=args.regime if args.market else None,
        n_pools=2, from_advisor=False,
        bid=(BidSpec("randomized", {"lo": 0.35, "hi": 1.0})
             if args.market else None))

    hosts, vms = synthetic_scenario(ScenarioConfig(seed=args.seed))
    n_spot = sum(1 for v in vms if v.is_spot)
    print(f"fleet: {len(hosts)} hosts | workload: {len(vms)} VMs "
          f"({n_spot} spot)"
          + (f" | market engine: {args.regime}" if args.market else ""))
    print(f"{'policy':20s} {'interrupts':>10s} {'avg_s':>8s} {'max_s':>8s} "
          f"{'finished':>9s} {'cost$':>8s} {'save%':>6s} {'waste$':>7s} "
          f"{'wall_s':>7s}")
    for name in policies:
        kwargs = {"alpha": args.alpha} if name == "hlem-vmp-adjusted" else {}
        sim = build(RunSpec(scenario=scenario,
                            policy=PolicySpec(name, kwargs)), args.seed)
        t0 = time.time()
        metrics = sim.run(until=2200.0)
        s = metrics.spot_stats(sim.vms)
        if args.market:
            c = realized_cost_stats(sim.vms.values(), sim.engine, sim.pool)
        else:
            c = cost_stats(sim.vms.values())
        print(f"{name:20s} {s['interruptions']:10d} "
              f"{s['avg_interruption_time']:8.2f} "
              f"{s['max_interruption_time']:8.2f} "
              f"{s['spot_finished']:9d} "
              f"{c['cost']:8.3f} {c['savings_pct']:6.1f} "
              f"{c['wasted_cost']:7.3f} {time.time()-t0:7.1f}")


if __name__ == "__main__":
    main()
