"""End-to-end driver: train a ~100M-parameter model for a few hundred steps
UNDER SIMULATED SPOT-MARKET PREEMPTIONS.

The paper's spot lifecycle drives the trainer: worker slices are spot VMs in
a MarketSimulator; interruptions trigger emergency checkpoints inside the
warning window and an elastic data-parallel re-mesh; resumptions scale back
up.  Global batch is invariant across rescales, so the loss curve is
comparable to an uninterrupted run.

Run:  PYTHONPATH=src python examples/elastic_training.py \
          [--steps 300] [--workers 8] [--d-model 512]
(8 CPU host devices are forced at startup for the elastic mesh.)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse          # noqa: E402
import tempfile          # noqa: E402

import numpy as np       # noqa: E402

from repro.elastic import ElasticTrainer, simulate_worker_availability  # noqa: E402
from repro.models.config import ArchConfig                              # noqa: E402
from repro.train.data import DataConfig                                 # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--d-model", type=int, default=768)
    ap.add_argument("--n-layers", type=int, default=10)
    ap.add_argument("--vocab", type=int, default=16384)
    ap.add_argument("--quick", action="store_true",
                    help="reduced model + fewer steps (CI-friendly)")
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()
    if args.quick:
        args.steps, args.d_model, args.n_layers, args.vocab = 120, 256, 4, 4096

    # defaults: ~110M params (10L x d768 x ff3072 + 16k vocab)
    cfg = ArchConfig(
        name="elastic-demo-100m", family="dense", n_layers=args.n_layers,
        d_model=args.d_model, n_heads=8, n_kv_heads=4,
        d_ff=4 * args.d_model, vocab=args.vocab, dtype="float32",
        attention_chunk=args.seq_len)
    print(f"model: {cfg.n_params()/1e6:.1f}M params")

    # spot-market-driven availability of the worker fleet
    events = simulate_worker_availability(
        n_workers=args.workers, horizon=float(args.steps), seed=args.seed,
        contention=1.5)
    churn = [e for e in events if e.time > 0]
    print(f"market timeline: {len(churn)} interruption/resume events")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="elastic_ckpt_")
    trainer = ElasticTrainer(
        cfg, DataConfig(batch=args.batch, seq_len=args.seq_len,
                        seed=args.seed),
        ckpt_dir, max_workers=args.workers, seed=args.seed)
    report = trainer.train_elastic(args.steps, churn,
                                   steps_per_sim_unit=1.0)

    print("\n=== elastic training report ===")
    print(f"steps run            : {report.steps_run}")
    print(f"mesh rescales        : {report.rescales}")
    print(f"emergency checkpoints: {report.emergency_saves}")
    print(f"restores             : {report.restores}")
    print(f"mesh history (step, data-parallel width): {report.mesh_history}")
    k = max(len(report.losses) // 10, 1)
    smooth = [float(np.mean(report.losses[i:i + k]))
              for i in range(0, len(report.losses), k)]
    print("loss curve (smoothed):",
          " ".join(f"{l:.3f}" for l in smooth))
    assert smooth[-1] < smooth[0], "training failed to reduce loss"
    print("OK: loss decreased across preemptions/rescales")


if __name__ == "__main__":
    main()
