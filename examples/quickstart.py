"""Quickstart — the paper's §VII-A minimal example, ported to repro.core.

One datacenter, one host.  A spot instance starts executing, a delayed
on-demand instance preempts it (HIBERNATE), and the spot instance resumes
once capacity frees up.  Prints the DynamicVm / SpotVm tables (paper
Figs. 5-6; the average interruption time of 22 s matches Fig. 6 exactly).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import (
    HlemVmp,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    dynamic_vm_table,
    make_on_demand,
    make_spot,
    resources,
    spot_vm_table,
    to_csv,
)


def main() -> None:
    # datacenter with a single 2-core host (Listing 3-4)
    sim = MarketSimulator(policy=HlemVmp(), config=SimConfig())
    sim.add_host(resources(2, 2048, 10_000, 1_000_000))

    # spot VM with HIBERNATE behavior (Listing 6)
    spot = make_spot(
        0, resources(2, 512, 1000, 10_000), duration=20.0,
        behavior=InterruptionBehavior.HIBERNATE,
        hibernation_timeout=100.0, waiting_timeout=100.0)

    # on-demand VM submitted with a 10 s delay (Listing 7)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), duration=22.0,
                        submit_time=10.0)

    # event listeners (Listing 10-11 analogue)
    sim.on("vm_interrupted", lambda sim, time, vm, kind, **kw: print(
        f"[{time:6.1f}s] spot vm {vm.id} interrupted ({kind})"))
    sim.on("vm_allocated", lambda sim, time, vm, host, resumed, **kw: print(
        f"[{time:6.1f}s] vm {vm.id} ({vm.vm_type.value}) -> host {host}"
        f"{' (resumed)' if resumed else ''}"))
    sim.on("vm_finished", lambda sim, time, vm, **kw: print(
        f"[{time:6.1f}s] vm {vm.id} finished"))

    sim.submit(spot)
    sim.submit(od)
    sim.run(until=200.0)  # simulation.terminateAt (Listing 2)

    print("\n=== DynamicVmTable (paper Fig. 5) ===")
    print(to_csv(dynamic_vm_table(sim.all_vms())))
    print("=== SpotVmTable (paper Fig. 6) ===")
    print(to_csv(spot_vm_table(sim.all_vms())))


if __name__ == "__main__":
    main()
