"""Dynamic market engine: price-driven interruption waves, price-gated
admission, multi-pool reallocation, realized-price cost accounting, and
fixed-seed determinism (PR 2 tentpole)."""
import copy

import numpy as np
import pytest

from repro.core import (
    FirstFit,
    HlemVmpAdjusted,
    HostPool,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    make_on_demand,
    make_spot,
    resources,
)
from repro.market import (
    MarketConfig,
    MarketEngine,
    OnDemandCapBid,
    PercentileBid,
    PoolConfig,
    RandomizedBid,
    assign_bids,
    make_bid_strategy,
    make_market,
    realized_cost_stats,
)

_EPS = 1e-9


class ScriptedProcess:
    """Price process stub: returns a scripted sequence, then holds the last
    value (ignores the utilization signal)."""

    def __init__(self, seq):
        self.seq = list(seq)
        self.last = self.seq[-1]

    def price(self, utilization: float) -> float:
        if self.seq:
            self.last = self.seq.pop(0)
        return self.last


def scripted_engine(*pool_price_seqs, tick=10.0) -> MarketEngine:
    pools = [PoolConfig(f"p{i}") for i in range(len(pool_price_seqs))]
    eng = MarketEngine(MarketConfig(pools, tick_interval=tick))
    eng.processes = [ScriptedProcess(s) for s in pool_price_seqs]
    return eng


def market_sim(engine, policy=None, **sim_kw):
    return MarketSimulator(
        policy=policy or FirstFit(),
        config=SimConfig(strict_invariants=True, **sim_kw),
        engine=engine)


# ---------------------------------------------------------------------------
# wave selection (vectorized registry)
# ---------------------------------------------------------------------------
def test_market_victims_matches_python_reference():
    pool = HostPool()
    pool.enable_market(3)
    rng = np.random.default_rng(0)
    for h in range(12):
        pool.add_host(resources(64, 131_072, 40_000, 1_600_000), pool=h % 3)
    vms = []
    for i in range(200):
        vm = make_spot(i, resources(1, 512, 10, 1000), 1e5,
                       bid=float(rng.uniform(0.1, 1.2)),
                       min_running_time=float(rng.choice([0.0, 40.0])))
        pool.place(vm, int(rng.integers(12)), now=0.0)
        vm.state = VmState.RUNNING
        vm.run_start = 0.0
        vms.append(vm)
    prices = np.array([0.3, 0.8, 0.05])
    for now in (0.0, 39.0, 41.0):
        vids, vpools = pool.market_victims(prices, now)
        want = sorted(
            v.id for v in vms
            if v.interruptible(now)
            and v.bid < prices[pool.pool_of[v.host]] - _EPS)
        assert sorted(vids.tolist()) == want
        assert all(int(vpools[k]) == int(pool.pool_of[vms[i].host])
                   for k, i in enumerate(vids.tolist()))


def test_wave_interrupts_only_bid_crossed_vms():
    # price path: cheap, spike to 0.6, cheap again
    eng = scripted_engine([0.1, 0.6, 0.1, 0.1, 0.1, 0.1], tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(8, 16_384, 10_000, 1_000_000))
    bids = (0.2, 0.5, 0.9)
    spots = [make_spot(i, resources(2, 2048, 1000, 10_000), 200.0,
                       hibernation_timeout=1000.0, bid=b)
             for i, b in enumerate(bids)]
    for v in spots:
        sim.submit(v)
    m = sim.run(until=400.0)
    # the t=10 spike crosses bids 0.2 and 0.5, spares 0.9
    assert spots[0].interruptions == 1
    assert spots[1].interruptions == 1
    assert spots[2].interruptions == 0
    assert [e.cause for e in m.interruption_events] == ["price-wave"] * 2
    assert len(m.wave_events) == 1
    w = m.wave_events[0]
    assert (w.time, w.pool, w.size) == (10.0, 0, 2)
    assert w.price == pytest.approx(0.6)
    # price drops at t=20: victims resume and everyone finishes
    assert all(v.state is VmState.FINISHED for v in spots)
    for v in spots[:2]:
        assert v.history[1].start == 20.0


def test_min_running_time_blocks_wave_selection():
    eng = scripted_engine([0.9] * 40, tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 300.0,
                   min_running_time=35.0, bid=0.5,
                   hibernation_timeout=1e6)
    sim.submit(vm)
    # admission: price is already above the bid at t=0, so the VM waits...
    m = sim.run(until=5.0)
    assert vm.state is VmState.WAITING
    # ...so give it a cheap window to start, then a permanent spike
    eng2 = scripted_engine([0.1, 0.9, 0.9, 0.9, 0.9, 0.9, 0.9], tick=10.0)
    sim2 = market_sim(eng2)
    sim2.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm2 = make_spot(0, resources(2, 2048, 1000, 10_000), 300.0,
                    min_running_time=35.0, bid=0.5,
                    hibernation_timeout=1e6)
    sim2.submit(vm2)
    m2 = sim2.run(until=200.0)
    # protected at the t=10/20/30 ticks, first interruptible tick is t=40
    assert vm2.interruptions >= 1
    assert m2.interruption_events[0].time == 40.0


def test_warning_time_delays_wave_commit():
    eng = scripted_engine([0.1, 0.8, 0.8, 0.8, 0.8, 0.8], tick=10.0)
    sim = market_sim(eng, warning_time=3.0)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 500.0, bid=0.4,
                   hibernation_timeout=1e6)
    sim.submit(vm)
    sim.run(until=50.0)
    assert vm.interruptions == 1
    # warning at t=10, commit (stop) at t=13
    assert vm.history[0] .stop == pytest.approx(13.0)
    assert vm.state is VmState.HIBERNATED


def test_price_gated_admission_waits_for_price_drop():
    """A spot VM whose bid is under the clearing price must queue even with
    free capacity, and the price *drop* must reopen it through the gain-log
    memo (regression: price drops don't release capacity, so without the
    flood the memo would skip the VM forever)."""
    eng = scripted_engine([0.8, 0.8, 0.8, 0.2, 0.2, 0.2], tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(8, 16_384, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 50.0, bid=0.4)
    # a second waiting VM ensures the batched-flush memo path is exercised
    vm2 = make_spot(1, resources(2, 2048, 1000, 10_000), 50.0, bid=0.3)
    sim.submit(vm)
    sim.submit(vm2)
    sim.run(until=200.0)
    assert vm.state is VmState.FINISHED
    assert vm2.state is VmState.FINISHED
    # placed exactly at the t=30 tick where the price fell to 0.2
    assert vm.history[0].start == 30.0
    assert vm2.history[0].start == 30.0


def test_hibernate_expire_resubmit_reallocation_chain():
    """Satellite: full hibernate → HIBERNATION_EXPIRE → resubmission →
    reallocation chain under price waves, across pools.

    Pool 0 spikes permanently at t=20; pool 1 stays cheap but is full until
    t=100.  The pool-0 spot VM hibernates at the spike, cannot reallocate
    while pool 1 is occupied, and reallocates into the *cheaper pool* the
    moment capacity frees there.  A second, shorter-timeout VM exhausts its
    hibernation window first and must TERMINATE via HIBERNATION_EXPIRE."""
    eng = scripted_engine(
        [0.1, 0.1] + [0.9] * 40,   # pool 0: cheap until the t=20 tick spikes
        [0.1] * 42,                # pool 1: always cheap
        tick=10.0)
    sim = market_sim(eng)
    h0 = sim.add_host(resources(4, 8192, 10_000, 1_000_000), pool=0)
    h1 = sim.add_host(resources(4, 8192, 10_000, 1_000_000), pool=1)
    # pool 1 fully occupied by an on-demand VM until t=100
    blocker = make_on_demand(10, resources(4, 8192, 10_000, 1_000_000),
                             100.0, pool=1)
    survivor = make_spot(0, resources(2, 2048, 1000, 10_000), 60.0,
                         bid=0.5, hibernation_timeout=500.0, pool=-1)
    expirer = make_spot(1, resources(2, 2048, 1000, 10_000), 60.0,
                        bid=0.5, hibernation_timeout=30.0, pool=0)
    for v in (blocker, survivor, expirer):
        sim.submit(v)
    m = sim.run(until=600.0)

    # both spot VMs started on the pool-0 host and hibernated at the t=20 spike
    for v in (survivor, expirer):
        assert v.history[0].host == h0
        assert v.history[0].stop == 20.0
        assert v.interruptions == 1
    assert m.wave_events and m.wave_events[0].time == 20.0
    # the short-timeout VM expired while pool 1 was still blocked
    assert expirer.state is VmState.TERMINATED
    assert expirer.hibernated_at == 20.0
    # the survivor resubmitted into the cheaper pool when the blocker finished
    assert survivor.state is VmState.FINISHED
    assert survivor.history[1].host == h1
    assert survivor.history[1].start == 100.0
    assert survivor.interruption_gaps() == [80.0]  # hibernated 20 → 100


# ---------------------------------------------------------------------------
# realized-price cost accounting
# ---------------------------------------------------------------------------
def test_realized_cost_integrates_clearing_price():
    eng = scripted_engine([0.5, 0.5, 0.5, 0.5, 0.5, 0.25, 0.25, 0.25,
                           0.25, 0.25, 0.25], tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 100.0, bid=1.0)
    od = make_on_demand(1, resources(2, 2048, 1000, 10_000), 100.0)
    sim.submit(vm)
    sim.submit(od)
    sim.run(until=300.0)
    assert vm.state is VmState.FINISHED and od.state is VmState.FINISHED
    # price is 0.5 on [0, 50), 0.25 afterwards; the VM ran [0, 100)
    want_integral = 50 * 0.5 + 50 * 0.25
    assert eng.price_integral(0, 0.0, 100.0) == pytest.approx(want_integral)
    from repro.market.pricing import PriceModel
    model = PriceModel()
    stats = realized_cost_stats(sim.vms.values(), eng, sim.pool, model)
    rate = model.rate(vm.demand)
    assert stats["spot_cost"] == pytest.approx(
        rate / 3600.0 * want_integral)
    # on-demand VM bills flat
    assert stats["cost"] == pytest.approx(
        stats["spot_cost"] + rate * 100.0 / 3600.0)
    assert stats["wasted_cost"] == 0.0


def test_realized_cost_caps_billing_at_the_bid():
    """A VM riding out a spike above its bid (protected by minimum running
    time) pays its bid for that stretch, never the clearing price."""
    # placed at 0.2, spikes to 0.9 at t=10 while min_running_time=35 protects
    # the VM; it is interrupted at the first eligible tick (t=40)
    eng = scripted_engine([0.2] + [0.9] * 30, tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 500.0, bid=0.5,
                   min_running_time=35.0,
                   behavior=InterruptionBehavior.TERMINATE)
    sim.submit(vm)
    sim.run(until=100.0)
    assert vm.state is VmState.TERMINATED
    assert vm.history[0].stop == 40.0
    # billed: 10s at 0.2, then 30s at min(0.9, bid=0.5)
    want = 10 * 0.2 + 30 * 0.5
    assert eng.price_integral(0, 0.0, 40.0, cap=0.5) == pytest.approx(want)
    from repro.market.pricing import PriceModel
    model = PriceModel()
    stats = realized_cost_stats(sim.vms.values(), eng, sim.pool, model)
    assert stats["spot_cost"] == pytest.approx(
        model.rate(vm.demand) / 3600.0 * want)
    # the lost partial work is wasted spend (TERMINATE behavior)
    assert stats["wasted_cost"] == stats["spot_cost"]


# ---------------------------------------------------------------------------
# bid strategies
# ---------------------------------------------------------------------------
def test_bid_strategies_seeded_and_bounded():
    vms = [make_spot(i, resources(1, 1024, 10, 1000), 10.0)
           for i in range(50)]
    vms.append(make_on_demand(99, resources(1, 1024, 10, 1000), 10.0))
    assign_bids(vms, OnDemandCapBid(fraction=0.8), seed=0)
    assert all(v.bid == pytest.approx(0.8) for v in vms if v.is_spot)
    assert vms[-1].bid == float("inf")  # on-demand untouched

    assign_bids(vms, RandomizedBid(lo=0.3, hi=0.9), seed=1)
    bids1 = [v.bid for v in vms if v.is_spot]
    assert all(0.3 <= b <= 0.9 for b in bids1)
    assert len(set(bids1)) > 1
    assign_bids(vms, RandomizedBid(lo=0.3, hi=0.9), seed=1)
    assert [v.bid for v in vms if v.is_spot] == bids1  # seeded replay

    strat = make_bid_strategy("percentile",
                              pool_cfg=PoolConfig("p", process="auction"),
                              seed=3, pct=80.0)
    assign_bids(vms, strat, seed=0)
    b = next(v.bid for v in vms if v.is_spot)
    hist = strat.history
    assert b == pytest.approx(float(np.percentile(hist, 80.0)))


# ---------------------------------------------------------------------------
# determinism: two identical runs are bit-identical
# ---------------------------------------------------------------------------
def _small_market_run(policy, seed=7):
    rng = np.random.default_rng(seed)
    mc = make_market("volatile", n_pools=2, seed=seed, tick_interval=20.0)
    eng = MarketEngine(mc)
    sim = MarketSimulator(policy=policy,
                          config=SimConfig(record_timeline=True),
                          engine=eng)
    for h in range(10):
        sim.add_host(resources(16, 32_768, 10_000, 400_000), pool=h % 2)
    vms = []
    for i in range(120):
        demand = resources(float(rng.choice([1, 2, 4])), 2048, 100, 10_000)
        t0 = float(rng.uniform(0.0, 300.0))
        if rng.random() < 0.6:
            vms.append(make_spot(i, demand, float(rng.uniform(50, 400)),
                                 hibernation_timeout=400.0,
                                 min_running_time=5.0, submit_time=t0))
        else:
            vms.append(make_on_demand(i, demand,
                                      float(rng.uniform(50, 400)),
                                      submit_time=t0))
    assign_bids(vms, RandomizedBid(lo=0.3, hi=1.0), seed=seed)
    for v in vms:
        sim.submit(v)
    m = sim.run(until=2000.0)
    cost = realized_cost_stats(sim.vms.values(), eng, sim.pool)
    return sim, m, cost


@pytest.mark.parametrize("policy_factory",
                         [FirstFit, lambda: HlemVmpAdjusted(alpha=-0.5)])
def test_market_run_bit_identical_across_runs(policy_factory):
    sim1, m1, c1 = _small_market_run(policy_factory())
    sim2, m2, c2 = _small_market_run(policy_factory())
    assert m1.interruption_events == m2.interruption_events
    assert m1.wave_events == m2.wave_events
    assert m1.price_series == m2.price_series
    assert m1.timeline == m2.timeline
    assert m1.allocations == m2.allocations
    assert m1.resubmissions == m2.resubmissions
    assert m1.spot_stats(sim1.vms) == m2.spot_stats(sim2.vms)
    assert m1.market_stats() == m2.market_stats()
    assert c1 == c2  # realized cost, exact float equality
    for v1, v2 in zip(sim1.all_vms(), sim2.all_vms()):
        assert v1.state is v2.state
        assert [(h.host, h.start, h.stop) for h in v1.history] == \
               [(h.host, h.start, h.stop) for h in v2.history]


def test_unbounded_run_terminates_with_price_gated_queue():
    """run() without a horizon must return even when the only remaining
    state is a spot VM whose bid never clears (the tick chain must not
    keep itself alive forever on queued-only state)."""
    eng = scripted_engine([0.9], tick=10.0)   # holds 0.9 forever
    sim = market_sim(eng)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 50.0, bid=0.3)
    sim.submit(vm)
    sim.run()  # until=inf
    assert vm.state is VmState.WAITING


def test_out_of_range_pool_fails_fast_at_add_host():
    eng = scripted_engine([0.5], tick=10.0)   # 1 pool
    sim = market_sim(eng)
    with pytest.raises(AssertionError, match="out of range"):
        sim.add_host(resources(4, 8192, 10_000, 1_000_000), pool=2)


def test_cap_and_randomized_strategies_inherit_pool_od_rate():
    cfg = PoolConfig("p", on_demand_rate=2.0)
    cap = make_bid_strategy("on-demand-cap", pool_cfg=cfg, fraction=1.0)
    assert cap.bids(1, np.random.default_rng(0))[0] == pytest.approx(2.0)
    rnd = make_bid_strategy("randomized", pool_cfg=cfg, lo=0.5, hi=1.0)
    bids = rnd.bids(100, np.random.default_rng(0))
    assert bids.min() >= 1.0 and bids.max() <= 2.0


def test_tick_chain_rearms_after_idle_for_late_submissions():
    """Once all work finishes the tick chain stops; a VM submitted *after*
    that must not be admitted against frozen prices — submit() re-arms the
    chain, the price re-clears, and the VM places at the fresh price."""
    # price 0.9 through the first phase (ticks at t=0..50, after which the
    # chain goes idle), 0.1 once ticking resumes
    eng = scripted_engine([0.9] * 6 + [0.1], tick=10.0)
    sim = market_sim(eng)
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    first = make_spot(0, resources(2, 2048, 1000, 10_000), 50.0, bid=1.0)
    sim.submit(first)
    sim.run(until=150.0)
    assert first.state is VmState.FINISHED
    ticks_phase1 = len(sim.metrics.price_series)
    # chain is now idle-dead; a low-bid VM arrives later
    late = make_spot(1, resources(2, 2048, 1000, 10_000), 40.0, bid=0.3,
                     submit_time=200.0)
    sim.submit(late)
    sim.run(until=400.0)
    # ticking resumed at t=200, re-cleared to 0.1 < bid, VM ran to completion
    assert len(sim.metrics.price_series) > ticks_phase1
    assert late.state is VmState.FINISHED
    assert late.history[0].start == 200.0


def test_gain_log_stays_bounded_under_price_oscillation():
    """Price drops flood the gain log every tick; with empty resubmission
    queues the flush must still compact it, or a long volatile run leaks
    O(ticks x hosts) entries."""
    eng = scripted_engine([0.9, 0.1] * 600, tick=10.0)  # drop every other tick
    sim = market_sim(eng)
    n_hosts = 40
    for _ in range(n_hosts):
        sim.add_host(resources(8, 16_384, 10_000, 1_000_000))
    # one infinite-bid spot VM keeps the tick chain alive, queues stay empty
    vm = make_spot(0, resources(1, 1024, 100, 1000), 11_000.0)
    sim.submit(vm)
    sim.run(until=10_000.0)
    assert len(sim.metrics.price_series) == 1001  # chain ran the whole time
    assert len(sim.pool.gain_log) <= max(1024, 4 * n_hosts)


def test_engine_off_leaves_market_machinery_inert():
    sim = MarketSimulator(policy=FirstFit(),
                          config=SimConfig(strict_invariants=True))
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    vm = make_spot(0, resources(2, 2048, 1000, 10_000), 20.0, bid=0.01)
    sim.submit(vm)
    m = sim.run(until=100.0)
    # bid is ignored entirely without an engine: no gating, no waves
    assert vm.state is VmState.FINISHED
    assert vm.interruptions == 0
    assert not sim.pool.market_on
    assert m.price_series == [] and m.wave_events == []
