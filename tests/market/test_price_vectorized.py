"""Array-native market-state API (PR 5 tentpole).

Covers the four acceptance pillars:

* frozen golden series pin the legacy internally-drawing scalar processes
  (both ``shock_rho`` settings) bit-exactly;
* the scalar shared-shock oracle and the fused vectorized family step are
  bit-identical under one pre-drawn shock table — including full-simulation
  metrics equality (synthetic + trace + all three market regimes);
* ``jax.lax.scan`` offline simulation equals the numpy step loop;
* batched ``price_integrals`` equals scalar ``price_integral`` exactly and
  the historical bisect reference (``price_integral_ref``) numerically,
  including the bid-cap path.
"""
import json

import numpy as np
import pytest

from repro.market import (
    AUCTION_FAMILY,
    SMOOTHED_FAMILY,
    AuctionPrice,
    MarketConfig,
    MarketEngine,
    PoolConfig,
    PRICE_PROCESS_REGISTRY,
    SmoothedPrice,
    draw_shock_table,
    realized_cost_stats,
    register_price_process,
    regime_comparison,
    simulate_price_paths,
    simulated_price_fan,
)
from repro.market.engine import price_integral_ref

# ---------------------------------------------------------------------------
# golden series: the legacy internally-drawing path is regression-pinned
# (values recorded from the pre-PR5 implementation — bit-exact)
# ---------------------------------------------------------------------------
_GOLD_UTIL = [0.5, 0.757687, 0.89418, 0.845284, 0.633995, 0.359687,
              0.15137, 0.107019, 0.247493, 0.506726, 0.762795, 0.895267]
_GOLD_AUCTION_IID = [
    0.3225875476610137, 1.186546735955824, 1.5, 0.8074480028085262,
    0.44509993065515197, 0.17695033657335346, 0.18881678258476478,
    0.14870784380037932, 0.22139378369600796, 0.1705904984018538,
    1.2963025466388807, 1.081577283743385]
_GOLD_AUCTION_AR1 = [
    0.3212831536367835, 1.015984720179931, 1.5, 1.2707977385914417,
    0.5666788444174983, 0.20877682611406245, 0.1739687401004737,
    0.16349037014805837, 0.214390767972809, 0.2521737882713139,
    0.8887864495369335, 1.243594091111216]
_GOLD_SMOOTHED = [
    0.10500000000000001, 0.11025000000000001, 0.11576250000000002,
    0.12155062500000002, 0.12762815625000004, 0.13400956406250006,
    0.14071004226562506, 0.14774554437890633, 0.15513282159785166,
    0.16288946267774426, 0.17103393581163148, 0.17958563260221305]


@pytest.mark.parametrize("proc_factory,golden", [
    (lambda: AuctionPrice(on_demand_rate=1.5, shock_sigma=0.35, seed=11),
     _GOLD_AUCTION_IID),
    (lambda: AuctionPrice(on_demand_rate=1.5, shock_sigma=0.35,
                          shock_rho=0.75, seed=11), _GOLD_AUCTION_AR1),
    (lambda: SmoothedPrice(on_demand_rate=1.5, alpha=0.2, max_step=0.05),
     _GOLD_SMOOTHED),
])
def test_legacy_golden_series(proc_factory, golden):
    proc = proc_factory()
    got = [proc.price(float(u)) for u in _GOLD_UTIL]
    assert got == golden  # bit-exact


def test_smoothed_rejects_dead_seed_param():
    """The pre-PR5 dataclass silently swallowed an unused ``seed``; it is
    gone — direct construction fails, while the engine's uniform
    ``make_scalar(..., seed=...)`` boundary still accepts and discards it."""
    with pytest.raises(TypeError):
        SmoothedPrice(seed=3)
    p = SMOOTHED_FAMILY.make_scalar(on_demand_rate=2.0, seed=3, alpha=0.1)
    assert isinstance(p, SmoothedPrice) and p.alpha == 0.1
    # a pool spec smuggling 'seed' through process_kwargs fails fast
    with pytest.raises(TypeError):
        MarketEngine(MarketConfig([PoolConfig(
            "p", process="smoothed", process_kwargs={"seed": 5})]))


# ---------------------------------------------------------------------------
# scalar shared-shock oracle == fused family step (bit-identity)
# ---------------------------------------------------------------------------
def _mixed_auction_kwargs(n, rng):
    return [dict(on_demand_rate=float(rng.uniform(0.5, 2.0)),
                 shock_sigma=float(rng.uniform(0.1, 0.6)),
                 shock_rho=float(rng.choice([0.0, 0.5, 0.75])),
                 seed=int(i))
            for i, _ in enumerate(range(n))]


def test_auction_scalar_oracle_matches_family_step_bitwise():
    rng = np.random.default_rng(0)
    n, t = 7, 40
    kwargs = _mixed_auction_kwargs(n, rng)
    procs = [AuctionPrice(**kw) for kw in kwargs]
    state = AUCTION_FAMILY.init(kwargs)
    utils = rng.uniform(0.0, 1.1, (t, n))
    shocks = draw_shock_table([kw["seed"] for kw in kwargs], t)
    for k in range(t):
        state, p_vec = AUCTION_FAMILY.step(state, utils[k], shocks[k])
        p_sc = [proc.price(float(utils[k, i]), shock=float(shocks[k, i]))
                for i, proc in enumerate(procs)]
        assert p_vec.tolist() == p_sc  # bit-exact, every tick


def test_smoothed_scalar_oracle_matches_family_step_bitwise():
    rng = np.random.default_rng(1)
    n, t = 5, 60
    kwargs = [dict(on_demand_rate=float(rng.uniform(0.5, 2.0)),
                   alpha=float(rng.uniform(0.05, 0.4)),
                   max_step=float(rng.uniform(0.01, 0.1)))
              for _ in range(n)]
    procs = [SmoothedPrice(**kw) for kw in kwargs]
    state = SMOOTHED_FAMILY.init(kwargs)
    utils = rng.uniform(0.0, 1.0, (t, n))
    for k in range(t):
        state, p_vec = SMOOTHED_FAMILY.step(state, utils[k],
                                            np.zeros(n))
        p_sc = [proc.price(float(utils[k, i]), shock=0.0)
                for i, proc in enumerate(procs)]
        assert p_vec.tolist() == p_sc


def test_engine_shock_stream_matches_offline_table():
    """The engine's block-buffered per-pool draws equal the offline
    ``draw_shock_table`` streams tick for tick (shared-randomness
    contract)."""
    pools = [PoolConfig(f"p{i}", seed=10 + i) for i in range(3)]
    eng = MarketEngine(MarketConfig(pools))
    table = draw_shock_table([10, 11, 12], 150)
    got = np.stack([eng._draw_shocks() for _ in range(150)])
    assert np.array_equal(got, table)


# ---------------------------------------------------------------------------
# registry adapter: legacy object protocol keeps working by name
# ---------------------------------------------------------------------------
def test_legacy_registered_process_runs_through_adapter():
    calls = []

    @register_price_process("test-legacy-proc")
    class LegacyRamp:
        def __init__(self, on_demand_rate=1.0, seed=0, slope=0.01):
            self.rate = on_demand_rate + seed * 0 + 0.0
            self.slope = slope
            self.k = 0

        def price(self, utilization):
            self.k += 1
            calls.append(utilization)
            return min(self.slope * self.k, self.rate)

    try:
        entry = PRICE_PROCESS_REGISTRY.get("test-legacy-proc")
        assert entry.make_scalar(slope=0.5).price(0.3) == 0.5
        eng = MarketEngine(MarketConfig(
            [PoolConfig("a", process="test-legacy-proc",
                        process_kwargs={"slope": 0.2}),
             PoolConfig("b", process="auction", seed=4)]))

        class _StubPool:
            def pool_cpu_utilization(self):
                return np.array([0.4, 0.6])

        p1 = eng.tick(_StubPool(), 0.0).copy()
        p2 = eng.tick(_StubPool(), 60.0).copy()
        assert p1[0] == pytest.approx(0.2) and p2[0] == pytest.approx(0.4)
        assert 0.0 < p1[1] <= 1.0  # auction pool fused alongside
        # the adapter walk consumed the live utilization signal
        assert calls[-2:] == [0.4, 0.4] or 0.4 in calls
    finally:
        PRICE_PROCESS_REGISTRY.entries.pop("test-legacy-proc", None)


# ---------------------------------------------------------------------------
# scan == step loop
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("family,kwargs", [
    (AUCTION_FAMILY, dict(shock_sigma=0.4, shock_rho=0.6, seed=3)),
    (AUCTION_FAMILY, dict(shock_sigma=0.3, seed=5)),
    (SMOOTHED_FAMILY, dict(alpha=0.15, max_step=0.04)),
])
def test_scan_equals_step_loop(family, kwargs):
    pytest.importorskip("jax")
    rng = np.random.default_rng(2)
    n, t = 4, 50
    state = family.init([kwargs] * n)
    utils = rng.uniform(0.0, 1.0, (t, n))
    shocks = rng.standard_normal((t, n))
    p_np, s_np = simulate_price_paths(family, family.init([kwargs] * n),
                                      utils, shocks, backend="numpy")
    p_jax, s_jax = simulate_price_paths(family, state, utils, shocks,
                                        backend="jax")
    np.testing.assert_allclose(p_jax, p_np, rtol=1e-12, atol=0)
    for k in s_np:
        np.testing.assert_allclose(s_jax[k], s_np[k], rtol=1e-12, atol=0)


def test_scan_multi_path_fan_shapes_and_determinism():
    eng = MarketEngine(MarketConfig(
        [PoolConfig(f"p{i}", process="auction", seed=i,
                    process_kwargs={"shock_sigma": 0.4, "shock_rho": 0.5})
         for i in range(3)]))

    class _StubPool:
        def pool_cpu_utilization(self):
            return np.array([0.3, 0.5, 0.7])

    for k in range(6):
        eng.tick(_StubPool(), 60.0 * k)
    fan1 = simulated_price_fan(eng, n_ticks=8, n_paths=32, seed=9)
    fan2 = simulated_price_fan(eng, n_ticks=8, n_paths=32, seed=9)
    assert fan1.shape == (3, 8, 3)       # (quantiles, ticks, pools)
    assert np.array_equal(fan1, fan2)    # seeded, engine streams untouched
    assert np.all(fan1[0] <= fan1[1] + 1e-12)
    assert np.all(fan1[1] <= fan1[2] + 1e-12)
    if pytest.importorskip("jax"):
        fan_jax = simulated_price_fan(eng, n_ticks=8, n_paths=32, seed=9,
                                      backend="jax")
        np.testing.assert_allclose(fan_jax, fan1, rtol=1e-12, atol=0)


def test_price_fan_identical_across_engine_modes():
    """price_state() must reflect the *current* tick in both engine modes:
    the scalar oracle evolves the per-pool objects, not the packed groups,
    so the snapshot re-packs — a fan projected from either mode after
    identical ticks is identical (regression: scalar mode used to snapshot
    tick-0 state)."""
    def make(vectorized):
        eng = MarketEngine(MarketConfig(
            [PoolConfig(f"p{i}", process="auction", seed=i,
                        process_kwargs={"shock_sigma": 0.4,
                                        "shock_rho": 0.6})
             for i in range(3)], vectorized=vectorized))

        class _StubPool:
            def pool_cpu_utilization(self):
                return np.array([0.3, 0.5, 0.7])

        for k in range(50):
            eng.tick(_StubPool(), 60.0 * k)
        return eng

    vec, sca = make(True), make(False)
    assert np.array_equal(vec.price_history(), sca.price_history())
    for (_, _, sv), (_, _, ss) in zip(vec.price_state(), sca.price_state()):
        for key in sv:
            assert np.array_equal(sv[key], ss[key]), key
    fan_v = simulated_price_fan(vec, n_ticks=6, n_paths=16, seed=4)
    fan_s = simulated_price_fan(sca, n_ticks=6, n_paths=16, seed=4)
    assert np.array_equal(fan_v, fan_s)


def test_regime_comparison_scan_matches_scalar_claims():
    pytest.importorskip("jax")
    r = regime_comparison(n=600, seed=0)
    rs = regime_comparison(n=600, seed=0, use_scan=True)
    for k in r:
        assert rs[k] == pytest.approx(r[k], rel=1e-9)


# ---------------------------------------------------------------------------
# batched price integrals
# ---------------------------------------------------------------------------
class _ScriptedProcess:
    def __init__(self, seq):
        self.seq = list(seq)
        self.last = self.seq[-1]

    def price(self, utilization):
        if self.seq:
            self.last = self.seq.pop(0)
        return self.last


class _StubHostPool:
    def __init__(self, n_pools):
        self.n_pools = n_pools

    def pool_cpu_utilization(self):
        return np.full(self.n_pools, 0.5)


def _random_history_engine(n_pools=3, n_ticks=300, seed=0, tick=10.0):
    """Engine with a long scripted price history (also exercises the
    preallocated-history growth path past the initial 256 capacity)."""
    rng = np.random.default_rng(seed)
    pools = [PoolConfig(f"p{i}") for i in range(n_pools)]
    eng = MarketEngine(MarketConfig(pools, tick_interval=tick))
    eng.processes = [
        _ScriptedProcess(rng.uniform(0.05, 1.0, n_ticks).tolist())
        for _ in range(n_pools)]
    stub = _StubHostPool(n_pools)
    for k in range(n_ticks):
        eng.tick(stub, tick * k)
    return eng


def test_batched_integrals_match_scalar_and_reference():
    eng = _random_history_engine()
    rng = np.random.default_rng(3)
    b = 500
    t_end = 300 * 10.0
    pids = rng.integers(0, 3, b)
    t0s = rng.uniform(-50.0, t_end + 100.0, b)
    t1s = t0s + rng.uniform(-20.0, 400.0, b)     # includes t1 <= t0 rows
    caps = np.where(rng.random(b) < 0.3, np.inf,
                    rng.uniform(0.1, 1.0, b))
    batched = eng.price_integrals(pids, t0s, t1s, caps)
    for k in range(b):
        scalar = eng.price_integral(int(pids[k]), float(t0s[k]),
                                    float(t1s[k]), float(caps[k]))
        assert scalar == batched[k]  # exact: scalar delegates to the kernel
        ref = price_integral_ref(eng, int(pids[k]), float(t0s[k]),
                                 float(t1s[k]), float(caps[k]))
        assert batched[k] == pytest.approx(ref, rel=1e-12, abs=1e-12)


def test_integrals_edge_cases():
    eng = MarketEngine(MarketConfig([PoolConfig("p")]))
    # empty history: everything integrates to zero
    assert eng.price_integral(0, 0.0, 100.0) == 0.0
    assert eng.price_integrals([0], [0.0], [100.0]).tolist() == [0.0]
    eng.processes = [_ScriptedProcess([0.5, 0.25])]
    stub = _StubHostPool(1)
    eng.tick(stub, 10.0)
    eng.tick(stub, 20.0)
    # span entirely before the first tick prices at zero
    assert eng.price_integral(0, 0.0, 10.0) == 0.0
    # spans: [10,20) at 0.5, then 0.25 extends past the final tick
    assert eng.price_integral(0, 10.0, 30.0) == pytest.approx(
        10 * 0.5 + 10 * 0.25)
    assert eng.price_integral(0, 15.0, 18.0) == pytest.approx(3 * 0.5)
    assert eng.price_integral(0, 15.0, 18.0, cap=0.4) == pytest.approx(
        3 * 0.4)
    assert eng.price_integral(0, 50.0, 40.0) == 0.0
    # discount batched == scalar
    d = eng.discount_integrals([0], [10.0], [30.0], [0.4])
    assert d[0] == eng.discount_integral(0, 10.0, 30.0, 0.4)


def test_interleaving_legacy_and_shock_calls_stays_consistent():
    """Mixing the legacy internal-draw path and the shared-shock protocol
    on one scalar process must evolve one coherent state (regression: the
    packed cache used to ignore legacy steps)."""
    a = AuctionPrice(seed=0, shock_rho=0.6)
    a.price(0.5, shock=1.0)      # creates the packed cache
    a.price(0.5)                 # legacy step advances _log_shock
    # reference: one kernel step from a fresh pack of the *current* scalar
    # state (what the next shock call must evolve from)
    ref_state, ref_p = AUCTION_FAMILY.step(
        AUCTION_FAMILY.pack([a]), np.asarray([0.5]), np.asarray([0.5]))
    got = a.price(0.5, shock=0.5)
    assert got == float(ref_p[0])
    assert a._log_shock == float(ref_state["log_shock"][0])

    s = SmoothedPrice(alpha=0.3)
    s.price(0.8, shock=0.0)
    s.price(0.2)                 # legacy step moves the EWMA
    ref_state, ref_p = SMOOTHED_FAMILY.step(
        SMOOTHED_FAMILY.pack([s]), np.asarray([0.5]), np.asarray([0.0]))
    assert s.price(0.5, shock=0.0) == float(ref_p[0])
    assert s._u_smooth == float(ref_state["u_smooth"][0])


def test_history_views_are_read_only():
    eng = _random_history_engine(n_pools=2, n_ticks=10)
    for view in (eng.tick_times(), eng.price_history()):
        with pytest.raises(ValueError):
            view[...] = 0.0


def test_history_views_and_growth():
    eng = _random_history_engine(n_pools=2, n_ticks=700)
    assert eng.n_ticks == 700                 # grew past the 256 preallocation
    ts = eng.tick_times()
    assert ts.shape == (700,) and ts[1] - ts[0] == 10.0
    ph = eng.price_history()
    assert ph.shape == (2, 700)
    t, p = eng.price_series(1)
    assert np.array_equal(t, ts) and np.array_equal(p, ph[1])


# ---------------------------------------------------------------------------
# full-simulation bit-identity: fused vectorized tick vs scalar oracle walk
# ---------------------------------------------------------------------------
def _metrics_doc(sim, metrics):
    cost = realized_cost_stats(sim.vms.values(), sim.engine, sim.pool)
    return json.dumps({
        "price_series": metrics.price_series,
        "waves": [tuple(w) for w in map(
            lambda w: (w.time, w.pool, w.price, w.size),
            metrics.wave_events)],
        "interruptions": [(e.vm_id, e.time, e.host, e.kind, e.cause)
                          for e in metrics.interruption_events],
        "spot": metrics.spot_stats(sim.vms),
        "market": metrics.market_stats(),
        "cost": cost,
        "allocations": metrics.allocations,
        "resubmissions": metrics.resubmissions,
    }, sort_keys=True)


def _run_spec(spec_kwargs, until, vectorized, migration="none", seed=0):
    from repro.api import MigrationSpec, PolicySpec, RunSpec, ScenarioSpec
    from repro.api import build

    spec = RunSpec(scenario=ScenarioSpec(**spec_kwargs),
                   policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
                   migration=MigrationSpec(migration))
    sim = build(spec, seed=seed)
    sim.engine.use_vectorized = vectorized
    metrics = sim.run(until=until)
    return _metrics_doc(sim, metrics)


@pytest.mark.parametrize("regime", ["calm", "volatile", "correlated"])
def test_market_scenario_vectorized_equals_oracle(regime):
    kw = dict(workload="market", regime=regime,
              bid={"strategy": "randomized", "params": {"lo": 0.45}})
    mig = "gradient-aware" if regime == "volatile" else "none"
    assert (_run_spec(kw, 2400.0, True, migration=mig)
            == _run_spec(kw, 2400.0, False, migration=mig))


def test_synthetic_scenario_vectorized_equals_oracle():
    kw = dict(workload="synthetic", regime="volatile",
              bid={"strategy": "randomized", "params": {"lo": 0.45}})
    assert _run_spec(kw, 1500.0, True) == _run_spec(kw, 1500.0, False)


def test_trace_scenario_vectorized_equals_oracle():
    kw = dict(workload="trace", regime="volatile",
              workload_params={"n_machines": 40, "sim_days": 0.05,
                               "n_spot": 150})
    assert _run_spec(kw, None, True) == _run_spec(kw, None, False)


def test_subclass_with_overridden_price_is_not_fused():
    """A subclass inherits the ``family`` class attribute, but only the
    exact scalar class matches the packed kernel — an overridden price()
    must be honored in the default vectorized mode (regression: it used to
    be silently routed through the base family kernel)."""
    class Scripted(AuctionPrice):
        def price(self, u, shock=None):
            return 42.0

    class _StubPool:
        def pool_cpu_utilization(self):
            return np.array([0.5])

    for vectorized in (True, False):
        eng = MarketEngine(MarketConfig([PoolConfig("p")],
                                        vectorized=vectorized))
        eng.processes = [Scripted()]
        assert eng.tick(_StubPool(), 0.0)[0] == 42.0, vectorized


def test_config_flag_selects_oracle_path():
    cfg = MarketConfig([PoolConfig("p", process="auction")],
                       vectorized=False)
    assert MarketEngine(cfg).use_vectorized is False
    assert MarketEngine(MarketConfig([PoolConfig("p")])).use_vectorized
