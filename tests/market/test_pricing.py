"""Cost-accounting tests (beyond-paper pricing extension)."""
import numpy as np
import pytest

from repro.core import (
    FirstFit,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    make_on_demand,
    make_spot,
    resources,
)
from repro.market import PriceModel, cost_stats


def test_rate_linear_in_resources():
    pm = PriceModel()
    r1 = pm.rate(resources(1, 1024, 0, 0))
    r2 = pm.rate(resources(2, 2048, 0, 0))
    assert r2 == pytest.approx(2 * r1)


def test_spot_discount_applied():
    pm = PriceModel(spot_discount=0.3)
    sim = MarketSimulator(policy=FirstFit(), config=SimConfig())
    sim.add_host(resources(4, 8192, 10_000, 1_000_000))
    spot = make_spot(0, resources(2, 1024, 100, 10_000), 3600.0)
    od = make_on_demand(1, resources(2, 1024, 100, 10_000), 3600.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=7200.0)
    c_spot = pm.vm_cost(spot)
    c_od = pm.vm_cost(od)
    assert c_spot == pytest.approx(0.3 * c_od)
    s = cost_stats([spot, od], pm)
    assert s["savings"] == pytest.approx(0.7 * c_od)
    assert s["wasted_cost"] == 0.0


def test_terminated_spot_counts_as_waste():
    pm = PriceModel()
    sim = MarketSimulator(policy=FirstFit(), config=SimConfig())
    sim.add_host(resources(2, 2048, 10_000, 1_000_000))
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 5000.0,
                     behavior=InterruptionBehavior.TERMINATE)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 1000.0,
                        submit_time=100.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=10_000.0)
    assert spot.state is VmState.TERMINATED
    s = cost_stats(sim.all_vms(), pm)
    assert s["wasted_cost"] > 0.0
    assert s["wasted_cost"] == pytest.approx(pm.vm_cost(spot))
