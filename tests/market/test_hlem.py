"""HLEM scoring math: numpy oracle vs jitted JAX vs properties."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    hlem_scores_jax,
    hlem_scores_np,
    hlem_select_batch_jax,
    hlem_select_jax,
    hlem_select_np,
)

BIG = 3.4e38


@pytest.mark.parametrize("n", [2, 5, 33, 200])
@pytest.mark.parametrize("alpha", [0.0, -0.5, 0.7])
def test_np_vs_jax_scores(n, alpha):
    rng = np.random.default_rng(n)
    free = rng.uniform(0, 100, (n, 4))
    mask = rng.random(n) < 0.7
    spot = rng.uniform(0, 1, (n, 4))
    s_np = hlem_scores_np(free, mask, spot, alpha)
    s_jx = np.asarray(hlem_scores_jax(
        jnp.asarray(free, jnp.float32), jnp.asarray(mask),
        jnp.asarray(spot, jnp.float32), jnp.float32(alpha)))
    if mask.any():
        np.testing.assert_allclose(s_np[mask], s_jx[mask], rtol=2e-3,
                                   atol=2e-4)
        assert np.argmax(s_np) == np.argmax(s_jx)
    assert np.all(s_jx[~mask] <= -BIG / 2)


def test_select_consistency():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.integers(2, 50))
        free = rng.uniform(0, 10, (n, 4))
        mask = rng.random(n) < 0.5
        spot = rng.uniform(0, 1, (n, 4))
        i_np = hlem_select_np(free, mask, spot, -0.5)
        i_jx = int(hlem_select_jax(
            jnp.asarray(free, jnp.float32), jnp.asarray(mask),
            jnp.asarray(spot, jnp.float32), jnp.float32(-0.5)))
        assert i_np == i_jx


def test_batched_select_matches_loop():
    rng = np.random.default_rng(3)
    n, b = 40, 8
    free = jnp.asarray(rng.uniform(0, 10, (n, 4)), jnp.float32)
    masks = jnp.asarray(rng.random((b, n)) < 0.6)
    spot = jnp.asarray(rng.uniform(0, 1, (n, 4)), jnp.float32)
    batched = np.asarray(hlem_select_batch_jax(free, masks, spot,
                                               jnp.float32(-0.5)))
    for i in range(b):
        single = int(hlem_select_jax(free, masks[i], spot,
                                     jnp.float32(-0.5)))
        assert batched[i] == single


def test_score_scale_invariance_of_selection():
    """Min-max standardization makes selection invariant to per-dimension
    affine rescaling of free capacities."""
    rng = np.random.default_rng(11)
    free = rng.uniform(1, 9, (12, 4))
    mask = np.ones(12, bool)
    base = hlem_select_np(free, mask)
    scaled = free * np.array([10.0, 0.5, 3.0, 100.0])
    assert hlem_select_np(scaled, mask) == base


def test_alpha_zero_equals_unadjusted():
    rng = np.random.default_rng(5)
    free = rng.uniform(0, 10, (9, 4))
    mask = rng.random(9) < 0.8
    spot = rng.uniform(0, 1, (9, 4))
    np.testing.assert_allclose(
        hlem_scores_np(free, mask, spot, 0.0),
        hlem_scores_np(free, mask, None, 0.0))
