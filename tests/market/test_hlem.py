"""HLEM scoring math: numpy oracle vs jitted JAX vs properties."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    hlem_scores_batch_jax,
    hlem_scores_batch_np,
    hlem_scores_jax,
    hlem_scores_np,
    hlem_select_batch_jax,
    hlem_select_jax,
    hlem_select_np,
)
from repro.core.hlem import hlem_pick_np

BIG = 3.4e38


@pytest.mark.parametrize("n", [2, 5, 33, 200])
@pytest.mark.parametrize("alpha", [0.0, -0.5, 0.7])
def test_np_vs_jax_scores(n, alpha):
    rng = np.random.default_rng(n)
    free = rng.uniform(0, 100, (n, 4))
    mask = rng.random(n) < 0.7
    spot = rng.uniform(0, 1, (n, 4))
    s_np = hlem_scores_np(free, mask, spot, alpha)
    s_jx = np.asarray(hlem_scores_jax(
        jnp.asarray(free, jnp.float32), jnp.asarray(mask),
        jnp.asarray(spot, jnp.float32), jnp.float32(alpha)))
    if mask.any():
        np.testing.assert_allclose(s_np[mask], s_jx[mask], rtol=2e-3,
                                   atol=2e-4)
        assert np.argmax(s_np) == np.argmax(s_jx)
    assert np.all(s_jx[~mask] <= -BIG / 2)


def test_select_consistency():
    rng = np.random.default_rng(7)
    for _ in range(20):
        n = int(rng.integers(2, 50))
        free = rng.uniform(0, 10, (n, 4))
        mask = rng.random(n) < 0.5
        spot = rng.uniform(0, 1, (n, 4))
        i_np = hlem_select_np(free, mask, spot, -0.5)
        i_jx = int(hlem_select_jax(
            jnp.asarray(free, jnp.float32), jnp.asarray(mask),
            jnp.asarray(spot, jnp.float32), jnp.float32(-0.5)))
        assert i_np == i_jx


def test_batched_select_matches_loop():
    rng = np.random.default_rng(3)
    n, b = 40, 8
    free = jnp.asarray(rng.uniform(0, 10, (n, 4)), jnp.float32)
    masks = jnp.asarray(rng.random((b, n)) < 0.6)
    spot = jnp.asarray(rng.uniform(0, 1, (n, 4)), jnp.float32)
    batched = np.asarray(hlem_select_batch_jax(free, masks, spot,
                                               jnp.float32(-0.5)))
    for i in range(b):
        single = int(hlem_select_jax(free, masks[i], spot,
                                     jnp.float32(-0.5)))
        assert batched[i] == single


def test_score_scale_invariance_of_selection():
    """Min-max standardization makes selection invariant to per-dimension
    affine rescaling of free capacities."""
    rng = np.random.default_rng(11)
    free = rng.uniform(1, 9, (12, 4))
    mask = np.ones(12, bool)
    base = hlem_select_np(free, mask)
    scaled = free * np.array([10.0, 0.5, 3.0, 100.0])
    assert hlem_select_np(scaled, mask) == base


def test_alpha_zero_equals_unadjusted():
    rng = np.random.default_rng(5)
    free = rng.uniform(0, 10, (9, 4))
    mask = rng.random(9) < 0.8
    spot = rng.uniform(0, 1, (9, 4))
    np.testing.assert_allclose(
        hlem_scores_np(free, mask, spot, 0.0),
        hlem_scores_np(free, mask, None, 0.0))


# ---------------------------------------------------------------------------
# batched oracle (B VMs x n hosts in one pass)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,n", [(1, 5), (4, 33), (16, 200), (8, 64)])
def test_batch_np_rows_match_single_oracle(b, n):
    rng = np.random.default_rng(b * 100 + n)
    free = rng.uniform(0, 50, (n, 4))
    free[:, 3] = 7.0  # degenerate (zero-span) column among candidates
    masks = rng.random((b, n)) < 0.6
    masks[0] = False  # fully-masked row
    spot = rng.uniform(0, 1, (n, 4))
    alphas = np.where(rng.random(b) < 0.5, -0.5, 0.0)
    out = hlem_scores_batch_np(free, masks, spot, alphas)
    assert out.shape == (b, n)
    for i in range(b):
        want = hlem_scores_np(free, masks[i], spot, alphas[i])
        if masks[i].any():
            np.testing.assert_allclose(out[i][masks[i]], want[masks[i]],
                                       rtol=1e-12, atol=1e-12)
            assert np.argmax(out[i]) == np.argmax(want)
        assert np.all(np.isneginf(out[i][~masks[i]]))


def test_batch_jax_matches_batch_np():
    rng = np.random.default_rng(17)
    b, n = 6, 80
    free = rng.uniform(0, 20, (n, 4)).astype(np.float32)
    free[:, 2] = 3.0  # degenerate column
    masks = rng.random((b, n)) < 0.5
    spot = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    alphas = np.linspace(-0.9, 0.9, b).astype(np.float32)
    want = hlem_scores_batch_np(free, masks, spot, alphas)
    got = np.asarray(hlem_scores_batch_jax(
        jnp.asarray(free), jnp.asarray(masks), jnp.asarray(spot),
        jnp.asarray(alphas)))
    for i in range(b):
        if masks[i].any():
            np.testing.assert_allclose(got[i][masks[i]], want[i][masks[i]],
                                       rtol=1e-4, atol=1e-5)
            assert np.argmax(got[i]) == np.argmax(want[i])


@pytest.mark.parametrize("b", [1, 4, 9])
def test_batch_np_large_n_crossover(b):
    """Above BATCH_NP_N_CUTOVER the batched scorer routes rows through the
    compressed per-row oracle; both paths must agree on finiteness, values
    (to summation-order tolerance), and — for downstream allocation — the
    per-row argmax decision."""
    from repro.core.hlem import BATCH_NP_N_CUTOVER
    n = BATCH_NP_N_CUTOVER + 64
    rng = np.random.default_rng(b)
    free = rng.uniform(0, 50, (n, 4))
    free[:, 3] = 7.0  # degenerate column survives both paths
    masks = rng.random((b, n)) < 0.6
    masks[-1] = False  # fully-masked row
    spot = rng.uniform(0, 1, (n, 4))
    alphas = np.where(rng.random(b) < 0.5, -0.5, 0.0)
    routed = hlem_scores_batch_np(free, masks, spot, alphas)
    # routed rows are exactly the per-row oracle
    for i in range(b):
        want = hlem_scores_np(free, masks[i], spot, alphas[i])
        np.testing.assert_array_equal(routed[i], want)
    # and agree with the broadcast core across the crossover
    forced = hlem_scores_batch_np(free, masks, spot, alphas,
                                  n_cutover=10 ** 9)
    finite = np.isfinite(forced)
    assert np.array_equal(np.isfinite(routed), finite)
    np.testing.assert_allclose(routed[finite], forced[finite],
                               rtol=1e-9, atol=1e-12)
    for i in range(b):
        if masks[i].any():
            assert np.argmax(routed[i]) == np.argmax(forced[i])


def test_batch_np_just_below_cutover_uses_broadcast_core():
    """At n <= cutover the broadcast core is untouched (bit-for-bit) — the
    trace-scale flush depends on its exact numerics."""
    from repro.core.hlem import BATCH_NP_N_CUTOVER
    rng = np.random.default_rng(99)
    n, b = 64, 5
    assert n <= BATCH_NP_N_CUTOVER
    free = rng.uniform(0, 50, (n, 4))
    masks = rng.random((b, n)) < 0.6
    spot = rng.uniform(0, 1, (n, 4))
    auto = hlem_scores_batch_np(free, masks, spot, -0.5)
    forced = hlem_scores_batch_np(free, masks, spot, -0.5,
                                  n_cutover=10 ** 9)
    np.testing.assert_array_equal(auto, forced)


def test_fused_pick_matches_scores_argmax():
    rng = np.random.default_rng(23)
    for trial in range(50):
        n = int(rng.integers(2, 80))
        free = rng.uniform(0, 10, (n, 4))
        if trial % 3 == 0:
            free[:, 1] = 5.0                  # degenerate dim
        if trial % 7 == 0:
            free[:] = free[0]                 # all dims degenerate
        if trial % 5 == 0 and n >= 4:
            free[2] = free[1]                 # exact duplicate hosts (ties)
        mask = rng.random(n) < 0.6
        spot = rng.uniform(0, 1, (n, 4))
        alpha = float(rng.choice([0.0, -0.5, 0.7]))
        got = hlem_pick_np(free, mask, spot, alpha)
        if not mask.any():
            assert got == -1
        else:
            assert got == int(np.argmax(hlem_scores_np(free, mask, spot,
                                                       alpha)))
