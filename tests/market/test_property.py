"""Hypothesis property tests: simulator invariants under random workloads."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import (
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    make_on_demand,
    make_spot,
    make_policy,
    resources,
)

TERMINAL = {VmState.FINISHED, VmState.TERMINATED, VmState.FAILED}


def run_random_sim(seed, n_hosts, n_vms, policy_name, behavior, selector,
                   warning):
    rng = np.random.default_rng(seed)
    sim = MarketSimulator(
        policy=make_policy(policy_name),
        config=SimConfig(strict_invariants=True, warning_time=warning,
                         interruption_selector=selector))
    for _ in range(n_hosts):
        cpu = float(rng.choice([4, 8, 16]))
        sim.add_host(resources(cpu, cpu * 2048, 1_000, 100_000))
    for i in range(n_vms):
        cpu = float(rng.choice([1, 2, 4]))
        demand = resources(cpu, cpu * 1024, 100, 10_000)
        dur = float(rng.uniform(5, 60))
        t0 = float(rng.uniform(0, 80))
        if rng.random() < 0.5:
            sim.submit(make_spot(
                i, demand, dur, behavior=behavior,
                min_running_time=float(rng.uniform(0, 5)),
                hibernation_timeout=float(rng.uniform(20, 100)),
                waiting_timeout=float(rng.uniform(20, 100)),
                submit_time=t0))
        else:
            sim.submit(make_on_demand(
                i, demand, dur, waiting_timeout=float(rng.uniform(20, 100)),
                submit_time=t0, persistent=bool(rng.random() < 0.9)))
    sim.run(until=500.0)
    return sim


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    n_hosts=st.integers(1, 6),
    n_vms=st.integers(1, 40),
    policy_name=st.sampled_from(
        ["first-fit", "best-fit", "hlem-vmp", "hlem-vmp-adjusted"]),
    behavior=st.sampled_from(
        [InterruptionBehavior.HIBERNATE, InterruptionBehavior.TERMINATE]),
    selector=st.sampled_from(
        ["list_order", "best_fit_remaining", "max_progress"]),
    warning=st.sampled_from([0.0, 2.0]),
)
def test_simulation_invariants(seed, n_hosts, n_vms, policy_name, behavior,
                               selector, warning):
    sim = run_random_sim(seed, n_hosts, n_vms, policy_name, behavior,
                         selector, warning)
    # 1. host accounting consistent (strict_invariants already re-checked
    #    per event); final check:
    sim.pool.check_invariants()

    for vm in sim.all_vms():
        # 2. every VM reaches a terminal state by the horizon
        assert vm.state in TERMINAL, (vm.id, vm.state)
        # 3. execution intervals are well-formed, non-overlapping, ordered
        for itv in vm.history:
            assert itv.stop is not None and itv.stop >= itv.start - 1e-9
        for a, b in zip(vm.history, vm.history[1:]):
            assert b.start >= a.stop - 1e-9
        # 4. work conservation: executed time == duration for FINISHED,
        #    < duration (+eps) otherwise
        executed = sum(itv.stop - itv.start for itv in vm.history)
        if vm.state is VmState.FINISHED:
            assert executed == pytest.approx(vm.duration, abs=1e-6)
        else:
            assert executed <= vm.duration + 1e-6
        # 5. on-demand VMs are never interrupted by capacity reclamation
        if not vm.is_spot:
            assert vm.interruptions == 0
        # 6. minimum running time respected for capacity interruptions
    for ev in sim.metrics.interruption_events:
        vm = sim.vms[ev.vm_id]
        if ev.kind == "host-removed":
            continue
        # find the interval ending at the interruption
        for itv in vm.history:
            if itv.stop is not None and abs(itv.stop - ev.time) < 1e-9:
                assert itv.stop - itv.start >= vm.min_running_time - \
                    max(1e-9, 0.0) or vm.remaining <= 1e-9
                break

    # 7. interruption gaps non-negative
    for vm in sim.all_vms():
        for g in vm.interruption_gaps():
            assert g >= -1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_policies_see_identical_workload_and_all_terminate(seed):
    """Determinism: same seed -> same workload; every policy terminates it."""
    results = {}
    for pol in ["first-fit", "hlem-vmp"]:
        sim = run_random_sim(seed, 4, 25, pol,
                             InterruptionBehavior.HIBERNATE, "list_order",
                             0.0)
        results[pol] = sorted(
            (v.id, v.duration, v.submit_time) for v in sim.all_vms())
    assert results["first-fit"] == results["hlem-vmp"]


def test_determinism_same_seed_same_metrics():
    a = run_random_sim(42, 4, 30, "hlem-vmp-adjusted",
                       InterruptionBehavior.HIBERNATE, "list_order", 0.0)
    b = run_random_sim(42, 4, 30, "hlem-vmp-adjusted",
                       InterruptionBehavior.HIBERNATE, "list_order", 0.0)
    sa = a.metrics.spot_stats(a.vms)
    sb = b.metrics.spot_stats(b.vms)
    assert sa == sb
