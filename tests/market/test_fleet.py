"""Spot-fleet manager (PR 6 tentpole): config validation, planner-vs-oracle
equality, target-capacity convergence, the fallback ladder (same-pool →
cheaper-pool → on-demand → queue → scale-down), resilience metrics, and the
hibernate→resume→fallback-ladder composition chain."""
import numpy as np
import pytest

from repro.core import (
    FirstFit,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    dynamic_vm_table,
    make_spot,
    resources,
    to_json,
)
from repro.core.causes import InterruptionCause
from repro.market import (
    FaultEvent,
    FaultInjector,
    FleetConfig,
    MarketConfig,
    MarketEngine,
    PoolConfig,
    fleet_pool_capacity,
    fleet_pool_capacity_ref,
    make_fleet_manager,
    plan_replenish,
    plan_replenish_ref,
    validate_fleet_config,
)

BIG = resources(64, 131_072, 40_000, 1_600_000)


class ScriptedProcess:
    """Price process stub: scripted sequence, then holds the last value."""

    def __init__(self, seq):
        self.seq = list(seq)
        self.last = self.seq[-1]

    def price(self, utilization: float) -> float:
        if self.seq:
            self.last = self.seq.pop(0)
        return self.last


def scripted_engine(*pool_price_seqs, tick=10.0) -> MarketEngine:
    pools = [PoolConfig(f"p{i}") for i in range(len(pool_price_seqs))]
    eng = MarketEngine(MarketConfig(pools, tick_interval=tick))
    eng.processes = [ScriptedProcess(s) for s in pool_price_seqs]
    return eng


def fleet_sim(engine, fleet, faults=None):
    sim = MarketSimulator(policy=FirstFit(),
                          config=SimConfig(strict_invariants=True),
                          engine=engine, fleet=fleet, faults=faults)
    for p in range(engine.n_pools):
        sim.add_host(BIG, pool=p)
    return sim


# ---------------------------------------------------------------------------
# config validation (fail-fast satellite)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cfg_kw, n_pools, match", [
    ({"target_capacity": 0.0}, None, "target_capacity"),
    ({"unit_cpu": -1.0}, None, "unit_cpu"),
    ({"bid_fraction": 0.0}, None, "bid_fraction"),
    ({"pool_weights": (1.0, -0.5)}, None,
     "conflicting fleet pool_weights.*negative"),
    ({"pool_weights": (0.0, 0.0)}, None,
     "conflicting fleet pool_weights.*all zero"),
    ({"pool_weights": (1.0, 1.0, 1.0)}, 2, "3 entries for 2 pools"),
    ({"ladder": ()}, None, "at least one rung"),
    ({"ladder": (("teleport", 1),)}, None,
     "unknown fallback rung 'teleport'"),
    ({"ladder": (("pool:7", 1),)}, 4,
     r"names unknown pool 7 \(known pools: 0\.\.3\)"),
    ({"ladder": (("same-pool", 0),)}, None, "retry budget"),
    ({"backoff_base": 0.0}, None, "backoff_base"),
    ({"backoff_mult": 0.5}, None, "backoff_mult"),
    ({"backoff_cap": 30.0}, None, "backoff_cap"),
    ({"od_lease": 0.0}, None, "od_lease"),
])
def test_fleet_config_validation(cfg_kw, n_pools, match):
    with pytest.raises(ValueError, match=match):
        validate_fleet_config(FleetConfig(**cfg_kw), n_pools)


def test_unknown_strategy_lists_known():
    with pytest.raises(ValueError) as exc:
        make_fleet_manager(2, strategy="teleport-everything")
    msg = str(exc.value)
    assert "teleport-everything" in msg and "diversified" in msg


def test_pinned_rung_accepted():
    validate_fleet_config(FleetConfig(ladder=(("pool:2", 3),)), n_pools=4)


# ---------------------------------------------------------------------------
# planner == per-pool Python oracle (benchmarked pair)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("strategy",
                         ["diversified", "lowest-price", "single-pool"])
def test_plan_replenish_matches_reference_oracle(strategy):
    rng = np.random.default_rng(0)
    unit = 2.0
    for trial in range(60):
        n = int(rng.integers(1, 7))
        need = int(rng.integers(0, 24))
        cur = rng.integers(0, 6, size=n)
        weights = np.where(rng.random(n) < 0.2, 0.0, rng.uniform(0.1, 3.0, n))
        if not weights.any():
            weights[0] = 1.0
        prices = np.round(rng.uniform(0.05, 1.2, n), 2)   # engineered ties
        bids = np.full(n, 0.6)
        free = np.round(rng.uniform(0.0, 30.0, n), 1)
        vec = plan_replenish(need, cur, weights, prices, bids, free, unit,
                             strategy)
        ref = plan_replenish_ref(need, cur, weights, prices, bids, free,
                                 unit, strategy)
        assert np.array_equal(vec, ref), (strategy, trial)
        assert vec.sum() <= need
        # never over-commit a pool's free CPU or an inadmissible pool
        for p in range(n):
            if vec[p]:
                assert prices[p] <= bids[p] + 1e-9 and weights[p] > 0
                assert vec[p] * unit <= free[p] + 1e-9


def test_plan_replenish_ref_rejects_unknown_strategy():
    with pytest.raises(ValueError, match="no reference walk"):
        plan_replenish_ref(1, [0], [1.0], [0.1], [0.6], [10.0], 2.0,
                           strategy="custom")


def test_fleet_pool_capacity_matches_reference():
    rng = np.random.default_rng(1)
    for _ in range(20):
        m = int(rng.integers(0, 400))
        vids = rng.permutation(10_000)[:m].astype(np.int64)
        registry = {
            "vid": vids,
            "pool": rng.integers(0, 5, size=m),
            "cpu": rng.uniform(1.0, 4.0, size=m),
        }
        fleet_vids = np.sort(rng.choice(10_000, size=200, replace=False))
        units, cpu = fleet_pool_capacity(registry, fleet_vids, 5)
        r_units, r_cpu = fleet_pool_capacity_ref(registry, fleet_vids, 5)
        assert np.array_equal(units, r_units)
        assert np.array_equal(cpu, r_cpu)     # bit-identical accumulation


# ---------------------------------------------------------------------------
# the manager: reach target, hold it, degrade through the ladder
# ---------------------------------------------------------------------------
def test_fleet_reaches_and_holds_target():
    eng = scripted_engine([0.1] * 60, [0.1] * 60, tick=10.0)
    fleet = make_fleet_manager(2, target_capacity=8.0, unit_cpu=2.0)
    sim = fleet_sim(eng, fleet)
    m = sim.run(until=100.0)

    assert m.fleet_launches == 4
    # diversified over uniform weights: 2 units per pool
    units, cpu = fleet_pool_capacity(
        sim.pool.market_registry(), np.sort(fleet.slot_vid), 2)
    assert units.tolist() == [2, 2] and cpu.tolist() == [4.0, 4.0]
    # first sample is the pre-launch shortfall, then the fleet holds target
    assert m.fleet_samples[0] == (0.0, 0.0, 8.0)
    assert all(s[1] == 8.0 for s in m.fleet_samples[1:])
    rs = m.resilience_stats()
    assert rs["time_below_target"] == 10.0      # one tick of ramp-up
    assert rs["shortfall_area"] == 80.0         # 8 CPU × 10 s
    assert rs["fallback_counts"] == {"launch": 4}
    # the billing contract bills closed spans only: every VM is still
    # running (open interval) at end-of-run, so realized cost is zero
    rs_full = m.resilience_stats(sim.vms, sim.engine, sim.pool)
    assert rs_full["fleet_spot_cost"] == 0.0
    assert rs_full["od_spill_cost"] == 0.0


def test_fallback_ladder_same_pool_then_cheaper_pool():
    # pool 0 cheap for 5 ticks then permanently above the bid; pool 1 stays
    # admissible — the ladder must walk same-pool (burn budget) → cheaper
    eng = scripted_engine([0.1] * 5 + [10.0] * 60, [0.2] * 65, tick=10.0)
    fleet = make_fleet_manager(
        2, strategy="single-pool", target_capacity=4.0, unit_cpu=2.0,
        pool_weights=(1.0, 0.5),
        ladder=(("same-pool", 1), ("cheaper-pool", 1)),
        backoff_base=10.0, backoff_mult=1.0, backoff_cap=10.0)
    sim = fleet_sim(eng, fleet)
    m = sim.run(until=200.0)

    # both slots launched in pool 0, were reclaimed by the wave at t=50,
    # burned the same-pool rung (price 10 > bid 0.6), then landed in pool 1
    wave = [e for e in m.interruption_events
            if e.cause == InterruptionCause.PRICE_WAVE]
    assert len(wave) == 2 and all(e.time == 50.0 for e in wave)
    assert m.fallback_counts == {"launch": 2, "same-pool": 2,
                                 "cheaper-pool": 2}
    assert fleet.slot_pool.tolist() == [1, 1]
    units, _ = fleet_pool_capacity(
        sim.pool.market_registry(), np.sort(fleet.slot_vid), 2)
    assert units.tolist() == [0, 2]
    # capacity dipped during the episode and recovered
    assert any(s[1] == 0.0 for s in m.fleet_samples)
    assert m.fleet_samples[-1][1] == 4.0
    assert m.fleet_launches == 4     # 2 initial + 2 ladder relaunches
    # realized billing: the two reclaimed pool-0 incarnations are closed
    # intervals [0, 50) at price 0.1; the pool-1 relaunches are still open
    rs = m.resilience_stats(sim.vms, sim.engine, sim.pool)
    assert rs["fleet_spot_cost"] == pytest.approx(2 * 0.1 * 50 / 3600)


def test_on_demand_fallback_lease_and_return_to_spot():
    eng = scripted_engine([0.1, 0.1] + [10.0] * 19 + [0.1] * 60, tick=10.0)
    fleet = make_fleet_manager(
        1, target_capacity=2.0, unit_cpu=2.0,
        ladder=(("on-demand", 1), ("queue", 99)),
        backoff_base=10.0, backoff_mult=1.0, backoff_cap=10.0, od_lease=50.0)
    sim = fleet_sim(eng, fleet)
    m = sim.run(until=260.0)

    # the spot VM died at the t=20 spike → the ladder's on-demand rung
    # bridged 50s (price-blind), the lease expired, the slot idled fresh
    # until the price fell at t=210, then returned to spot
    assert m.od_spill_launches == 1 and len(m.fleet_od_ids) == 1
    assert m.fallback_counts["on-demand"] == 1
    assert m.fleet_launches == 2        # initial spot + post-lease spot
    od = sim.vms[m.fleet_od_ids[0]]
    assert od.state is VmState.FINISHED
    assert od.history[0].start == 20.0 and od.history[0].stop == 70.0
    spot2 = sim.vms[m.fleet_spot_ids[-1]]
    assert spot2.state is VmState.RUNNING
    assert spot2.history[0].start == 210.0
    rs = m.resilience_stats(sim.vms, sim.engine, sim.pool)
    assert rs["od_spill_cost"] == pytest.approx(1.0 * 50 / 3600)


def test_scale_down_retires_slots_and_lowers_target():
    eng = scripted_engine([0.1, 0.1] + [10.0] * 60, tick=10.0)
    fleet = make_fleet_manager(1, target_capacity=4.0, unit_cpu=2.0,
                               ladder=(("scale-down", 1),))
    sim = fleet_sim(eng, fleet)
    m = sim.run(until=300.0)

    assert m.fleet_slots_retired == 2
    assert fleet.effective_target() == 0.0
    assert not fleet.wants_tick()
    assert m.fallback_counts == {"launch": 2, "scale-down": 2}
    # the sample at the kill tick still measures against the pre-retirement
    # target — the fleet had not yet chosen to shrink
    assert (20.0, 0.0, 4.0) in m.fleet_samples


def test_exhausted_ladder_retires():
    # one rung, budget 1, permanently inadmissible: try once, then retire
    eng = scripted_engine([0.1, 0.1] + [10.0] * 60, tick=10.0)
    fleet = make_fleet_manager(1, target_capacity=2.0, unit_cpu=2.0,
                               ladder=(("same-pool", 1),),
                               backoff_base=10.0, backoff_mult=1.0,
                               backoff_cap=10.0)
    sim = fleet_sim(eng, fleet)
    m = sim.run(until=300.0)
    assert m.fallback_counts == {"launch": 1, "same-pool": 1}
    assert m.fleet_slots_retired == 1
    assert not fleet.wants_tick()


# ---------------------------------------------------------------------------
# composition: hibernate → resume → fallback ladder (chaos chain satellite)
# ---------------------------------------------------------------------------
def _chain_run():
    eng = scripted_engine([0.1] * 60, [0.3] * 60, tick=10.0)
    fi = FaultInjector([FaultEvent("storm", 30.0, pools=(0,),
                                   magnitude=1.0)], 2)
    fleet = make_fleet_manager(
        2, strategy="single-pool", target_capacity=4.0, unit_cpu=2.0,
        pool_weights=(1.0, 0.5),
        ladder=(("same-pool", 2), ("cheaper-pool", 2)),
        backoff_base=10.0, backoff_mult=1.0, backoff_cap=10.0)
    sim = fleet_sim(eng, fleet, faults=fi)
    # a per-VM workload spot VM shares pool 0 with the fleet: the storm
    # hibernates it (behavior) while terminating the fleet's slots
    wl = make_spot(10_000, resources(2, 2048, 100, 1000), 100.0, bid=0.9,
                   pool=0, hibernation_timeout=1e6,
                   behavior=InterruptionBehavior.HIBERNATE)
    sim.submit(wl)
    m = sim.run(until=200.0)
    return sim, m, wl


def test_hibernate_resume_fallback_chain():
    sim, m, wl = _chain_run()
    storm = [e for e in m.interruption_events
             if e.cause == InterruptionCause.FAULT_STORM]
    # the storm took every pool-0 resident: the workload VM + both slots
    assert {e.vm_id for e in storm} == {10_000} | set(m.fleet_spot_ids[:2])
    assert {e.kind for e in storm} == {"hibernate", "terminate"}
    # per-VM resilience: hibernated, then resumed in the same tick's flush
    # (pool 0 still clears below its bid) and finished
    assert wl.interruptions == 1 and len(wl.history) == 2
    assert wl.history[1].start == 30.0
    assert wl.state is VmState.FINISHED
    # fleet resilience: the same-pool rung relaunched both slots at the
    # storm tick (pool 0 is still admissible — the storm was capacity
    # reclamation, not a price event)
    assert m.fallback_counts == {"launch": 2, "same-pool": 2}
    assert m.fleet_launches == 4
    assert m.fleet_samples[-1][1] == 4.0
    rs = m.resilience_stats()
    assert rs["faults_fired"] == 1
    assert rs["faults"][0]["kind"] == "storm"
    # dipped at t=30, recovered by t=40 → 10s recovery
    assert rs["faults"][0]["recovery_s"] == pytest.approx(10.0)
    assert not rs["faults"][0]["censored"]


def test_chain_two_run_bit_identity():
    sim1, m1, _ = _chain_run()
    sim2, m2, _ = _chain_run()
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))
    assert m1.interruption_events == m2.interruption_events
    assert m1.fleet_samples == m2.fleet_samples
    assert m1.fallback_counts == m2.fallback_counts
    assert m1.fault_records == m2.fault_records
