"""Trace generation, CSV roundtrip, and trace-driven simulation."""
import numpy as np
import pytest

from repro.core import VmState, make_policy
from repro.market import (
    TraceConfig,
    generate_trace,
    load_trace,
    simulate_trace,
    write_trace_csv,
)


@pytest.fixture(scope="module")
def small_cfg():
    return TraceConfig(seed=3, n_machines=12, sim_days=0.03, n_spot=40,
                       load_per_machine=40.0, spot_durations_h=(0.2, 0.4))


@pytest.fixture(scope="module")
def trace(small_cfg):
    return generate_trace(small_cfg)


def test_trace_structure(trace, small_cfg):
    adds = [e for e in trace.machine_events if e[2] == "add"]
    assert len(adds) >= small_cfg.n_machines
    kinds = {e[7] for e in trace.task_events}
    assert kinds == {"od", "spot"}
    times = [e[0] for e in trace.task_events]
    assert times == sorted(times)


def test_csv_roundtrip(trace, tmp_path):
    write_trace_csv(trace, str(tmp_path))
    tr2 = load_trace(str(tmp_path))
    assert len(tr2.machine_events) == len(trace.machine_events)
    assert len(tr2.task_events) == len(trace.task_events)
    assert tr2.task_events[0][0] == pytest.approx(trace.task_events[0][0])


def test_simulate_trace_runs_and_interrupts(trace, small_cfg):
    sim, metrics = simulate_trace(
        trace, policy=make_policy("hlem-vmp-adjusted"), cfg=small_cfg)
    stats = metrics.spot_stats(sim.vms)
    assert len(sim.vms) == len(trace.task_events)
    assert stats["interruptions"] > 0          # contended by construction
    sim.pool.check_invariants()


def test_same_seed_same_trace(small_cfg):
    a = generate_trace(small_cfg)
    b = generate_trace(small_cfg)
    assert a.task_events == b.task_events
    assert a.machine_events == b.machine_events
