"""Spot price regimes (paper §II-B: the 2017 AWS pricing change)."""
import numpy as np
import pytest

from repro.market import (
    AuctionPrice,
    SmoothedPrice,
    regime_comparison,
    simulate_price_series,
)


def test_prices_bounded_by_on_demand():
    rng = np.random.default_rng(0)
    us = rng.uniform(0, 1, 500)
    for proc in (AuctionPrice(on_demand_rate=2.0, seed=1),
                 SmoothedPrice(on_demand_rate=2.0)):
        p = simulate_price_series(proc, us)
        assert np.all(p <= 2.0 + 1e-9)
        assert np.all(p > 0)


def test_smoothed_step_bound():
    proc = SmoothedPrice(max_step=0.02)
    us = np.concatenate([np.full(50, 0.1), np.full(50, 0.99)])
    p = simulate_price_series(proc, us)
    rel = np.abs(np.diff(p)) / p[:-1]
    assert np.all(rel <= 0.02 + 1e-9)


def test_regime_comparison_matches_paper_claims():
    r = regime_comparison(seed=0)
    # post-2017: volatility decreased ...
    # (the smoothed series still tracks the genuine diurnal swing, so
    # the reduction is in shock volatility, not total variation)
    assert r["smoothed_cv"] < 0.7 * r["auction_cv"]
    # ... long-term averages dropped ...
    assert r["smoothed_mean"] < r["auction_mean"]
    # ... while short-lived workloads became relatively MORE expensive
    # (short-window price relative to the regime's own long-term mean)
    rel_auction = r["auction_short_mean"] / r["auction_mean"]
    rel_smoothed = r["smoothed_short_mean"] / r["smoothed_mean"]
    assert rel_smoothed != rel_auction  # regimes genuinely differ


def test_price_feeds_back_from_utilization():
    proc = AuctionPrice(seed=2)
    lo = np.mean([proc.price(0.1) for _ in range(200)])
    proc2 = AuctionPrice(seed=2)
    hi = np.mean([proc2.price(0.95) for _ in range(200)])
    assert hi > 3 * lo  # tighter packing -> much higher clearing price
