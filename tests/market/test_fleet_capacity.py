"""FleetManager dynamic-capacity interface (PR 10): set_target_units
grow/shed semantics, effective-target accounting, and the bit-identity
contract for autoscaler-less fleets."""
import numpy as np
import pytest

from repro.api import (
    FleetSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    ServeSpec,
    build,
)
from repro.market.fleet import FleetConfig, FleetManager


def _manager(target=8.0, unit=2.0):
    return FleetManager(FleetConfig(target_capacity=target, unit_cpu=unit),
                        n_pools=4)


class _SimStub:
    """Just enough simulator for set_target_units on empty slots."""
    vms: dict = {}

    def decommission(self, vm):  # pragma: no cover - empty-slot tests
        raise AssertionError("empty slots must not decommission anything")


def test_initial_state_matches_pr6_formula():
    m = _manager(target=8.0, unit=2.0)
    assert m.n_slots == 4
    assert m.target_units == 4
    assert m._units_override is None
    assert m.effective_target() == 8.0
    assert not m.slot_shed.any()


def test_grow_appends_fresh_slots():
    m = _manager(target=8.0, unit=2.0)
    m.set_target_units(_SimStub(), 7, now=100.0)
    assert m.n_slots == 7
    assert m.target_units == 7
    assert m.effective_target() == 14.0
    assert (m.slot_vid[4:] == -1).all()
    assert (m.slot_next[4:] == 100.0).all()
    assert not m.slot_shed.any()
    # every state array grew in lockstep
    for arr in (m.slot_vid, m.slot_pool, m.slot_rung, m.slot_tries,
                m.slot_fail, m.slot_next, m.slot_retired, m.slot_od,
                m.slot_ran, m.slot_shed):
        assert len(arr) == 7


def test_shed_empty_slots_then_unshed_on_growth():
    m = _manager(target=8.0, unit=2.0)
    m.set_target_units(_SimStub(), 1, now=10.0)
    assert int(np.count_nonzero(m.slot_shed)) == 3
    assert m.effective_target() == 2.0
    # highest-index slots shed first
    assert m.slot_shed.tolist() == [False, True, True, True]
    # growth reuses the parked slots before allocating new ones
    m.set_target_units(_SimStub(), 3, now=20.0)
    assert m.n_slots == 4
    assert int(np.count_nonzero(m.slot_shed)) == 1
    assert m.effective_target() == 6.0
    assert (m.slot_next[[2, 3]] == 20.0).sum() >= 1


def test_wants_tick_false_when_all_shed_or_retired():
    m = _manager(target=4.0, unit=2.0)
    assert m.wants_tick()
    m.slot_retired[0] = True
    m.set_target_units(_SimStub(), 0, now=0.0)
    assert not m.wants_tick()


def test_effective_target_tracks_retirement_after_override():
    m = _manager(target=8.0, unit=2.0)
    m.set_target_units(_SimStub(), 6, now=0.0)
    assert m.effective_target() == 12.0
    # a ladder retirement after the retarget lowers the promise from there
    m.slot_retired[0] = True
    assert m.effective_target() == 10.0
    # a fresh retarget rebases: pre-existing retirements stop double-counting
    m.set_target_units(_SimStub(), 5, now=100.0)
    assert m.effective_target() == 10.0


def test_scale_in_decommissions_live_vms():
    spec = RunSpec(
        scenario=ScenarioSpec(workload="serve-diurnal", regime="volatile",
                              n_pools=4, horizon=7200.0,
                              workload_params={"base_rate": 0.3}),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": 16.0}),
        serve=ServeSpec())
    sim = build(spec, seed=0)
    sim.run(until=1200.0)     # let the fleet fill its 8 slots
    fleet = sim.fleet
    live_before = int(np.count_nonzero(fleet.slot_vid >= 0))
    assert live_before > 2
    fleet.set_target_units(sim, 2, now=sim.now)
    assert fleet.target_units == 2
    in_service = ~fleet.slot_retired & ~fleet.slot_shed
    assert int(np.count_nonzero(in_service)) == 2
    # shed slots dropped their VM references; the VM_FINISH events drain
    # the decommissioned VMs on the next step
    assert int(np.count_nonzero(fleet.slot_vid >= 0)) <= 2
    sim.run(until=1500.0)
    live_now = int(np.count_nonzero(
        fleet.slot_vid[in_service] >= 0))
    assert live_now <= 2


def test_autoscaler_less_fleet_keeps_exact_formula():
    """No retarget ever happens -> effective_target returns the PR 6
    expression bit for bit (the serve=None identity contract)."""
    cfg = FleetConfig(target_capacity=13.0, unit_cpu=2.0)
    m = FleetManager(cfg, n_pools=4)
    m.slot_retired[2] = True
    expected = cfg.target_capacity - 1 * cfg.unit_cpu
    assert m.effective_target() == expected
    assert m._units_override is None
