"""Allocation policy unit tests (paper §VI + Algorithm 1)."""
import numpy as np
import pytest

from repro.core import (
    BestFit,
    FirstFit,
    HlemVmp,
    HlemVmpAdjusted,
    HostPool,
    WorstFit,
    clearing_mask,
    direct_mask,
    hlem_scores_np,
    hlem_select_np,
    hlem_weights_np,
    make_on_demand,
    make_spot,
    resources,
    rsdiff_np,
)


def pool_of(caps):
    p = HostPool()
    for c in caps:
        p.add_host(c)
    return p


def test_first_fit_takes_lowest_index():
    p = pool_of([resources(4, 4096, 100, 100)] * 3)
    vm = make_on_demand(0, resources(2, 1024, 10, 10), 10.0)
    hid, clearing = FirstFit().find_host(vm, p, 0.0, True)
    assert (hid, clearing) == (0, False)


def test_best_and_worst_fit():
    p = pool_of([resources(8, 8192, 100, 100),
                 resources(2, 8192, 100, 100),
                 resources(4, 8192, 100, 100)])
    vm = make_on_demand(0, resources(2, 1024, 10, 10), 10.0)
    assert BestFit().find_host(vm, p, 0.0, True)[0] == 1   # tightest
    assert WorstFit().find_host(vm, p, 0.0, True)[0] == 0  # most headroom


def test_direct_and_clearing_masks():
    p = pool_of([resources(2, 2048, 100, 100)] * 2)
    spot = make_spot(0, resources(2, 1024, 10, 10), 100.0)
    spot.state = spot.state.__class__.RUNNING
    p.place(spot, 0)
    spot.run_start = 0.0
    from repro.core import VmState
    spot.state = VmState.RUNNING

    od = make_on_demand(1, resources(2, 1024, 10, 10), 10.0)
    d = direct_mask(od, p)
    c = clearing_mask(od, p, now=10.0)
    assert list(d) == [False, True]
    assert list(c) == [True, True]

    # not yet past min runtime -> host 0 not clearable (min_running_time is
    # snapshotted by the reclaim index at placement time, so re-place)
    p.release(spot)
    spot.min_running_time = 50.0
    p.place(spot, 0, now=0.0)
    spot.state = VmState.RUNNING
    c2 = clearing_mask(od, p, now=10.0)
    assert list(c2) == [False, True]


def test_rsdiff_filters_loaded_hosts():
    # Eq. 1-2: host with high CPU utilization relative to request is filtered
    used = np.array([7.0, 0.0])
    total = np.array([8.0, 8.0])
    rs = rsdiff_np(2.0, used, total, rc=0.95)
    assert rs[0] < 0 < rs[1]


def test_hlem_weights_normalized():
    rng = np.random.default_rng(0)
    free = rng.uniform(0, 100, (20, 4))
    mask = np.ones(20, bool)
    c_std, w = hlem_weights_np(free, mask)
    assert w.shape == (4,)
    assert np.all(w >= 0)
    assert np.isclose(w.sum(), 1.0)
    assert np.all((0.0 <= c_std) & (c_std <= 1.0 + 1e-9))


def test_hlem_degenerate_cases():
    # single candidate
    free = np.array([[1.0, 2.0, 3.0, 4.0], [5.0, 6.0, 7.0, 8.0]])
    mask = np.array([False, True])
    assert hlem_select_np(free, mask) == 1
    # no candidates
    assert hlem_select_np(free, np.zeros(2, bool)) == -1
    # identical hosts: any valid pick, scores equal
    free = np.ones((4, 4))
    scores = hlem_scores_np(free, np.ones(4, bool))
    assert np.allclose(scores[0], scores)


def test_hlem_prefers_most_free_host():
    # one dominant host in every dimension must win
    free = np.array([
        [10.0, 10_000, 100, 1_000],
        [80.0, 90_000, 900, 9_000],
        [20.0, 20_000, 200, 2_000],
    ])
    assert hlem_select_np(free, np.ones(3, bool)) == 1


def test_adjusted_hlem_penalizes_spot_heavy_hosts():
    p = pool_of([resources(8, 8192, 1000, 1000)] * 2)
    # load host 0 with a spot VM
    s = make_spot(0, resources(4, 4096, 500, 500), 100.0)
    from repro.core import VmState
    p.place(s, 0)
    s.state = VmState.RUNNING
    s.run_start = 0.0

    new_spot = make_spot(1, resources(2, 1024, 100, 100), 10.0)
    base = HlemVmp()
    adj = HlemVmpAdjusted(alpha=-0.9)
    hid_adj, _ = adj.find_host(new_spot, p, 1.0, False)
    assert hid_adj == 1  # spreads spot load away from host 0

    # with alpha=0 the adjusted policy reduces to the base policy
    adj0 = HlemVmpAdjusted(alpha=0.0)
    assert adj0.find_host(new_spot, p, 1.0, False)[0] == \
        base.find_host(new_spot, p, 1.0, False)[0]


def test_hlem_spot_clearing_candidate_list():
    """Algorithm 1 lines 8-10: when no host fits directly, score the
    spot-clearing list (on-demand only)."""
    p = pool_of([resources(2, 2048, 100, 100)] * 2)
    from repro.core import VmState
    for hid in range(2):
        s = make_spot(hid, resources(2, 1024, 10, 10), 100.0)
        p.place(s, hid)
        s.state = VmState.RUNNING
        s.run_start = 0.0
    od = make_on_demand(5, resources(2, 1024, 10, 10), 10.0)
    hid, clearing = HlemVmp().find_host(od, p, 10.0, True)
    assert hid >= 0 and clearing

    spot = make_spot(6, resources(2, 1024, 10, 10), 10.0)
    hid2, clearing2 = HlemVmp().find_host(spot, p, 10.0, True)
    assert hid2 == -1 and not clearing2
