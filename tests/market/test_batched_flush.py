"""Batched allocation engine vs the per-VM reference path.

The batched resubmission flush (``SimConfig.flush_mode="batched"``) and the
incremental host accounting must be *decision-identical* to the legacy
one-VM-at-a-time loop: same allocations, same interruption counts, same
execution histories on a seeded trace.  These tests are the contract that
lets the hot path evolve without changing simulation semantics."""
import numpy as np
import pytest

from repro.core import (
    HostPool,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    make_on_demand,
    make_policy,
    make_spot,
    resources,
)
from repro.market import TraceConfig, generate_trace, simulate_trace

POLICIES = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
            "hlem-vmp-adjusted"]


def _histories(sim):
    return sorted(
        (v.id, v.state.value,
         tuple((i.host, i.start, i.stop) for i in v.history))
        for v in sim.all_vms())


def _run_trace(flush_mode, strict=False, policy="hlem-vmp-adjusted"):
    cfg = TraceConfig(seed=3, n_machines=12, sim_days=0.05, n_spot=60,
                      load_per_machine=25.0, spot_durations_h=(0.5, 1.0))
    tr = generate_trace(cfg)
    sim, metrics = simulate_trace(
        tr, policy=make_policy(policy), cfg=cfg,
        sim_config=SimConfig(record_timeline=False, flush_mode=flush_mode,
                             strict_invariants=strict))
    return sim, metrics


@pytest.mark.parametrize("policy", ["hlem-vmp-adjusted", "first-fit"])
def test_batched_flush_identical_to_per_vm_on_trace(policy):
    sim_a, m_a = _run_trace("per_vm", policy=policy)
    sim_b, m_b = _run_trace("batched", policy=policy)
    assert m_a.allocations == m_b.allocations
    assert m_a.resubmissions == m_b.resubmissions
    assert m_a.interruption_count() == m_b.interruption_count()
    assert m_a.spot_stats(sim_a.vms) == m_b.spot_stats(sim_b.vms)
    # full allocation decisions: every execution interval on the same host at
    # the same times
    assert _histories(sim_a) == _histories(sim_b)


def test_batched_flush_with_strict_invariants():
    """The incremental caches survive a full seeded trace with per-event
    from-scratch cross-checks (HostPool.check_invariants(now))."""
    sim, metrics = _run_trace("batched", strict=True)
    assert metrics.allocations > 0
    sim.pool.check_invariants(sim.now)


def _random_sim(seed, flush_mode, warning):
    rng = np.random.default_rng(seed)
    sim = MarketSimulator(
        policy=make_policy("hlem-vmp-adjusted"),
        config=SimConfig(flush_mode=flush_mode, warning_time=warning,
                         strict_invariants=True))
    for _ in range(4):
        cpu = float(rng.choice([4, 8, 16]))
        sim.add_host(resources(cpu, cpu * 2048, 1_000, 100_000))
    for i in range(60):
        cpu = float(rng.choice([1, 2, 4]))
        demand = resources(cpu, cpu * 1024, 100, 10_000)
        dur = float(rng.uniform(5, 60))
        t0 = float(rng.uniform(0, 80))
        if rng.random() < 0.5:
            sim.submit(make_spot(
                i, demand, dur, behavior=InterruptionBehavior.HIBERNATE,
                min_running_time=float(rng.uniform(0, 5)),
                hibernation_timeout=float(rng.uniform(20, 100)),
                waiting_timeout=float(rng.uniform(20, 100)), submit_time=t0))
        else:
            sim.submit(make_on_demand(
                i, demand, dur, waiting_timeout=float(rng.uniform(20, 100)),
                submit_time=t0))
    sim.run(until=400.0)
    return sim


@pytest.mark.parametrize("seed", [0, 7, 42])
@pytest.mark.parametrize("warning", [0.0, 2.0])
def test_batched_flush_identical_on_random_workloads(seed, warning):
    a = _random_sim(seed, "per_vm", warning)
    b = _random_sim(seed, "batched", warning)
    assert a.metrics.spot_stats(a.vms) == b.metrics.spot_stats(b.vms)
    assert a.metrics.allocations == b.metrics.allocations
    assert _histories(a) == _histories(b)


# ---------------------------------------------------------------------------
# find_hosts_batch / find_first_direct vs per-VM find_host at a fixed state
# ---------------------------------------------------------------------------
def _loaded_pool(seed=0, n_hosts=12, n_running=25):
    rng = np.random.default_rng(seed)
    pool = HostPool()
    for _ in range(n_hosts):
        cpu = float(rng.choice([4, 8, 16]))
        pool.add_host(resources(cpu, cpu * 2048, 1_000, 100_000))
    placed = []
    for i in range(n_running):
        cpu = float(rng.choice([1, 2]))
        vm = make_spot(1000 + i, resources(cpu, cpu * 1024, 50, 5_000), 100.0)
        for hid in rng.permutation(pool.n):
            if pool.fits(hid, vm.demand):
                pool.place(vm, int(hid), now=0.0)
                vm.state = VmState.RUNNING
                vm.run_start = 0.0
                placed.append(vm)
                break
    return pool


@pytest.mark.parametrize("policy_name", POLICIES)
def test_find_hosts_batch_matches_per_vm(policy_name):
    pool = _loaded_pool()
    policy = make_policy(policy_name)
    rng = np.random.default_rng(1)
    vms = []
    for i in range(16):
        cpu = float(rng.choice([1, 2, 4, 8]))
        vms.append(make_on_demand(i, resources(cpu, cpu * 1024, 50, 5_000),
                                  10.0))
    batch = policy.find_hosts_batch(vms, pool, now=5.0)
    for b, vm in enumerate(vms):
        hid, clearing = policy.find_host(vm, pool, 5.0,
                                         allow_spot_clearing=False)
        assert int(batch[b]) == hid, (policy_name, b)
        assert not clearing
        assert policy.find_direct(vm, pool) == hid


@pytest.mark.parametrize("policy_name", POLICIES)
def test_find_first_direct_matches_scan(policy_name):
    pool = _loaded_pool(seed=2)
    policy = make_policy(policy_name)
    rng = np.random.default_rng(3)
    vms = [make_on_demand(i, resources(float(rng.choice([2, 4, 16])),
                                       2048.0, 50, 5_000), 10.0)
           for i in range(10)]
    b, hid = policy.find_first_direct(vms, pool)
    # reference: first VM whose per-VM direct search succeeds
    want_b, want_hid = len(vms), -1
    for j, vm in enumerate(vms):
        h = policy.find_direct(vm, pool)
        if h >= 0:
            want_b, want_hid = j, h
            break
    assert (b, hid) == (want_b, want_hid)


# ---------------------------------------------------------------------------
# incremental accounting invariants under adversarial pool operations
# ---------------------------------------------------------------------------
def test_pool_cache_invariants_under_churn():
    rng = np.random.default_rng(9)
    pool = HostPool(capacity_hint=2)  # force growth
    running = []
    now = 0.0
    for step in range(300):
        now += float(rng.uniform(0, 3))
        op = rng.random()
        if op < 0.25 or pool.n < 2:
            pool.add_host(resources(float(rng.choice([4, 8, 16])),
                                    16_384, 1_000, 100_000))
        elif op < 0.55:
            cpu = float(rng.choice([1, 2]))
            vm = make_spot(10_000 + step,
                           resources(cpu, cpu * 512, 10, 1_000), 50.0,
                           min_running_time=float(rng.choice([0.0, 5.0])))
            hids = [h for h in range(pool.n) if pool.fits(h, vm.demand)]
            if hids:
                pool.place(vm, int(rng.choice(hids)), now=now)
                vm.state = VmState.RUNNING
                vm.run_start = now
                running.append(vm)
        elif op < 0.8 and running:
            vm = running.pop(int(rng.integers(len(running))))
            pool.release(vm)
        elif op < 0.9 and running:
            vm = running[int(rng.integers(len(running)))]
            vm.state = VmState.INTERRUPTING
            pool.mark_uninterruptible(vm)
        else:
            # capacity updates only grow here: check_invariants (like the
            # seed's) asserts used <= total, and shrinking under residents
            # would trip it by design
            hid = int(rng.integers(pool.n))
            pool.update_host(hid, resources(
                float(rng.choice([32, 64])), 32_768, 2_000, 200_000))
        pool.refresh_reclaim(now)
        pool.check_invariants(now)


def test_gain_log_monotone_and_epoch_stamped():
    pool = HostPool()
    e0 = pool.epoch
    h = pool.add_host(resources(8, 8192, 100, 100))
    assert pool.epoch > e0
    pos = pool.gain_pos()
    vm = make_on_demand(1, resources(2, 1024, 10, 10), 5.0)
    pool.place(vm, h)
    assert pool.gain_pos() == pos  # placements are not gains
    pool.release(vm)
    assert pool.gained_since(pos) == [h]


def test_gain_log_compaction_preserves_absolute_positions():
    pool = HostPool()
    h = pool.add_host(resources(8, 8192, 100, 100))
    vm = make_on_demand(1, resources(2, 1024, 10, 10), 5.0)
    for _ in range(10):
        pool.place(vm, h)
        pool.release(vm)
    pos = pool.gain_pos()
    pool.place(vm, h)
    pool.release(vm)  # one gain after pos
    pool.compact_gain_log(pos)
    assert pool.gained_since(pos) == [h]          # suffix survives
    assert pool.gained_since(0) == [h]            # pre-base positions clamp
    assert pool.gain_pos() == pos + 1             # absolute positions stable
    assert len(pool.gain_log) == 1                # prefix dropped


# ---------------------------------------------------------------------------
# incremental timeline counters vs the legacy full-scan oracle
# ---------------------------------------------------------------------------
def test_incremental_timeline_matches_full_scan_oracle():
    """Metrics.record_state is the O(V) oracle; the engine's incremental
    state counters must agree with it at every point of a seeded run."""
    from repro.core import Metrics
    rng = np.random.default_rng(11)
    sim = MarketSimulator(
        policy=make_policy("hlem-vmp-adjusted"),
        config=SimConfig(record_timeline=True, warning_time=1.0))
    for _ in range(3):
        sim.add_host(resources(8, 16_384, 1_000, 100_000))
    for i in range(50):
        cpu = float(rng.choice([1, 2, 4]))
        demand = resources(cpu, cpu * 1024, 10, 1_000)
        dur = float(rng.uniform(5, 40))
        t0 = float(rng.uniform(0, 80))
        if rng.random() < 0.5:
            sim.submit(make_spot(
                i, demand, dur, behavior=InterruptionBehavior.HIBERNATE,
                min_running_time=2.0,
                hibernation_timeout=30.0, waiting_timeout=50.0,
                submit_time=t0))
        else:
            sim.submit(make_on_demand(i, demand, dur, waiting_timeout=50.0,
                                      submit_time=t0))
    # step the clock and compare counters against a fresh full scan each step
    for t in np.linspace(5.0, 300.0, 60):
        sim.run(until=float(t))
        oracle = Metrics()
        oracle.record_state(sim.now, sim.vms)
        oracle_counts = oracle.timeline[-1][1:]
        assert tuple(sim.metrics.state_counts[1:]) == oracle_counts, t
    # and the recorded timeline's final sample agrees with the oracle
    if sim.metrics.timeline:
        assert sim.metrics.timeline[-1][1:] == oracle_counts
