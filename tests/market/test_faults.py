"""Market fault injection (PR 6): event validation, seeded schedule
determinism, per-kind fault mechanics through the PRICE_TICK machinery
(crunch bias, spike bias, pool outage, correlated storm), empty-injector
bit-identity, and the chaos-determinism contract (two identical runs under
injected faults are bit-identical)."""
import json

import numpy as np
import pytest

from repro.api import FaultSpec, FleetSpec, PolicySpec, RunSpec, ScenarioSpec, build
from repro.core import (
    FirstFit,
    MarketSimulator,
    SimConfig,
    VmState,
    dynamic_vm_table,
    make_spot,
    resources,
    to_json,
)
from repro.core.causes import InterruptionCause
from repro.market import (
    FaultEvent,
    FaultInjector,
    MarketConfig,
    MarketEngine,
    PoolConfig,
    make_fault_injector,
    make_market,
    storm_victims,
)

BIG = resources(64, 131_072, 40_000, 1_600_000)
SMALL = resources(2, 2048, 1000, 10_000)


class ScriptedProcess:
    """Price process stub: scripted sequence, then holds the last value."""

    def __init__(self, seq):
        self.seq = list(seq)
        self.last = self.seq[-1]

    def price(self, utilization: float) -> float:
        if self.seq:
            self.last = self.seq.pop(0)
        return self.last


def scripted_engine(*pool_price_seqs, tick=10.0) -> MarketEngine:
    pools = [PoolConfig(f"p{i}") for i in range(len(pool_price_seqs))]
    eng = MarketEngine(MarketConfig(pools, tick_interval=tick))
    eng.processes = [ScriptedProcess(s) for s in pool_price_seqs]
    return eng


def fault_sim(engine, faults, **sim_kw):
    return MarketSimulator(
        policy=FirstFit(),
        config=SimConfig(strict_invariants=True, **sim_kw),
        engine=engine, faults=faults)


# ---------------------------------------------------------------------------
# event validation + schedule
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("event, match", [
    (FaultEvent("meteor", 0.0), "unknown fault kind"),
    (FaultEvent("storm", -1.0, magnitude=0.5), "t0 must be >= 0"),
    (FaultEvent("pool-outage", 0.0, duration=-5.0), "duration must be >= 0"),
    (FaultEvent("storm", 0.0, pools=(0, 7), magnitude=0.5),
     r"unknown pool\(s\) \[7\] \(known pools: 0\.\.3\)"),
    (FaultEvent("storm", 0.0, magnitude=0.0), "storm fraction"),
    (FaultEvent("storm", 0.0, magnitude=1.5), "storm fraction"),
    (FaultEvent("capacity-crunch", 0.0, magnitude=0.0), "utilization bias"),
])
def test_fault_event_validation(event, match):
    with pytest.raises(ValueError, match=match):
        FaultInjector([event], n_pools=4)


def test_injector_sorts_schedule_and_coerces_dicts():
    fi = FaultInjector(
        [{"kind": "storm", "t0": 500.0, "magnitude": 0.5},
         FaultEvent("pool-outage", 100.0, 60.0, (1,))], n_pools=2)
    assert [e.kind for e in fi.events] == ["pool-outage", "storm"]
    assert fi.pending()
    started, ended = fi.begin_tick(100.0)
    assert [e.kind for _, e in started] == ["pool-outage"]
    assert ended == []
    # the outage ends inside the 160-tick; the storm starts at 500
    started, ended = fi.begin_tick(160.0)
    assert started == [] and ended == [0]
    started, _ = fi.begin_tick(500.0)
    assert [e.kind for _, e in started] == ["storm"]
    assert not fi.pending()


def test_bias_windows_sum_active_events():
    fi = FaultInjector(
        [FaultEvent("capacity-crunch", 100.0, 100.0, (0,), 0.2),
         FaultEvent("capacity-crunch", 150.0, 100.0, None, 0.1),
         FaultEvent("price-spike", 100.0, 50.0, (1,), 2.0)], n_pools=2)
    assert fi.util_bias(50.0) is None             # nothing active yet
    assert np.allclose(fi.util_bias(100.0), [0.2, 0.0])
    assert np.allclose(fi.util_bias(160.0), [0.3, 0.1])   # windows overlap
    assert fi.util_bias(300.0) is None            # all windows closed
    assert np.allclose(fi.shock_bias(120.0), [0.0, 2.0])
    assert fi.shock_bias(150.0) is None           # [t0, t1) half-open


def test_storm_victims_lowest_bids_first():
    registry = {
        "vid": np.array([10, 11, 12, 13, 20], dtype=np.int64),
        "pool": np.array([0, 0, 0, 0, 1], dtype=np.int64),
        "bid": np.array([0.9, 0.3, 0.5, 0.3, 0.7]),
    }
    # pool 0: ceil(0.5 * 4) = 2 victims, lowest bids (ties by vid)
    v = storm_victims(registry, (0,), 0.5)
    assert v.tolist() == [11, 13]
    # all pools: pool 1 contributes ceil(0.5 * 1) = 1
    v = storm_victims(registry, (0, 1), 0.5)
    assert v.tolist() == [11, 13, 20]
    assert storm_victims(registry, (0,), 0.0001).tolist() == [11]  # ceil >= 1
    empty = {k: a[:0] for k, a in registry.items()}
    assert storm_victims(empty, (0,), 0.5).size == 0


# ---------------------------------------------------------------------------
# scenario generators
# ---------------------------------------------------------------------------
def test_builtin_scenarios_compile_and_random_storms_are_seeded():
    for name in ("storm", "pool-outage", "price-spike", "capacity-crunch"):
        fi = make_fault_injector(name, 4, 14400.0, 60.0, 0)
        assert fi.events and all(e.kind in name or e.kind == "storm"
                                 for e in fi.events)
    scripted = make_fault_injector(
        "scripted", 4, 14400.0, 60.0, 0,
        events=[{"kind": "storm", "t0": 120.0, "magnitude": 0.5},
                FaultEvent("pool-outage", 60.0, 30.0, (1,))])
    assert [e.kind for e in scripted.events] == ["pool-outage", "storm"]
    a = make_fault_injector("random-storms", 4, 14400.0, 60.0, seed=3)
    b = make_fault_injector("random-storms", 4, 14400.0, 60.0, seed=3)
    c = make_fault_injector("random-storms", 4, 14400.0, 60.0, seed=4)
    assert a.events == b.events            # pre-drawn schedule is seeded
    assert a.events != c.events
    with pytest.raises(ValueError, match="unknown fault scenario"):
        make_fault_injector("meteor-shower", 4, 14400.0, 60.0, 0)


# ---------------------------------------------------------------------------
# price-path faults compose with the engine tick (not bypass it)
# ---------------------------------------------------------------------------
def _twin_engines(seed=0, n_pools=3):
    mk = lambda: MarketEngine(make_market(  # noqa: E731
        "volatile", n_pools=n_pools, seed=seed, tick_interval=60.0))
    return mk(), mk()


def _tick_pool(eng):
    from repro.core import HostPool
    pool = HostPool()
    pool.enable_market(eng.n_pools)
    for p in range(eng.n_pools):
        pool.add_host(BIG, pool=p)
    return pool


def test_price_spike_bias_raises_only_affected_pools():
    base, spiked = _twin_engines()
    pool_b, pool_s = _tick_pool(base), _tick_pool(spiked)
    bias = np.array([0.0, 4.0, 0.0])
    hit = False
    for k in range(20):
        pb = base.tick(pool_b, 60.0 * k)
        ps = spiked.tick(pool_s, 60.0 * k, shock_bias=bias)
        # unaffected pools share the identical shock draws → identical prices
        assert pb[0] == ps[0] and pb[2] == ps[2]
        hit = hit or ps[1] > pb[1]
    assert hit     # +4 sigma must lift the affected pool's price somewhere


def test_capacity_crunch_bias_raises_only_affected_pools():
    base, crunched = _twin_engines()
    pool_b, pool_c = _tick_pool(base), _tick_pool(crunched)
    bias = np.array([0.4, 0.0, 0.0])
    hit = False
    for k in range(20):
        pb = base.tick(pool_b, 60.0 * k)
        pc = crunched.tick(pool_c, 60.0 * k, util_bias=bias)
        assert pb[1] == pc[1] and pb[2] == pc[2]
        hit = hit or pc[0] > pb[0]
    assert hit


# ---------------------------------------------------------------------------
# simulator wiring: outage + storm lifecycles
# ---------------------------------------------------------------------------
def test_pool_outage_evicts_then_reactivates():
    eng = scripted_engine([0.1] * 60, [0.1] * 60, tick=10.0)
    fi = FaultInjector([FaultEvent("pool-outage", 20.0, 30.0, (0,))], 2)
    sim = fault_sim(eng, fi)
    h0 = sim.add_host(BIG, pool=0)
    sim.add_host(BIG, pool=1)
    vm = make_spot(0, SMALL, 100.0, bid=0.8, pool=0,
                   hibernation_timeout=1e6)
    sim.submit(vm)
    m = sim.run(until=300.0)

    # evicted at the window start through the ordinary interruption path
    ev = m.interruption_events[0]
    assert (ev.vm_id, ev.time, ev.kind) == (0, 20.0, "host-removed")
    assert ev.cause == InterruptionCause.FAULT_OUTAGE
    # pool-pinned → hibernates through the outage, resumes at the window
    # end on the reactivated host (ran 20s, so it finishes 80s later)
    assert vm.interruptions == 1
    assert [(i.host, i.start) for i in vm.history] == [(h0, 0.0), (h0, 50.0)]
    assert vm.state is VmState.FINISHED and vm.finish_time == 130.0
    assert sim.pool.active[h0]
    assert [r.kind for r in m.fault_records] == ["pool-outage"]
    assert m.fault_records[0].t1 == 50.0


def test_storm_reclaims_fraction_lowest_bids_first():
    eng = scripted_engine([0.01] * 60, [0.01] * 60, tick=10.0)
    fi = FaultInjector([FaultEvent("storm", 30.0, magnitude=0.5)], 2)
    sim = fault_sim(eng, fi)
    sim.add_host(BIG, pool=0)
    sim.add_host(BIG, pool=1)
    from repro.core import InterruptionBehavior
    vms = [make_spot(i, SMALL, 500.0, bid=0.2 + 0.1 * i, pool=i % 2,
                     behavior=InterruptionBehavior.TERMINATE)
           for i in range(4)]
    for v in vms:
        sim.submit(v)
    m = sim.run(until=100.0)

    # ceil(0.5 * 2) = 1 victim per pool, lowest bid each: vm 0 and vm 1
    storm_evs = [e for e in m.interruption_events
                 if e.cause == InterruptionCause.FAULT_STORM]
    assert [(e.vm_id, e.time, e.kind) for e in storm_evs] == \
        [(0, 30.0, "terminate"), (1, 30.0, "terminate")]
    assert vms[0].state is VmState.TERMINATED
    assert vms[1].state is VmState.TERMINATED
    assert vms[2].state is VmState.RUNNING
    assert vms[3].state is VmState.RUNNING
    # prices stayed far below every bid: the storm, not the wave, did this
    assert m.wave_events == []


# ---------------------------------------------------------------------------
# bit-identity contracts
# ---------------------------------------------------------------------------
def _seeded_market_run(faults, seed=7):
    rng = np.random.default_rng(seed)
    eng = MarketEngine(make_market("volatile", n_pools=2, seed=seed,
                                   tick_interval=20.0))
    sim = MarketSimulator(policy=FirstFit(),
                          config=SimConfig(record_timeline=True),
                          engine=eng, faults=faults)
    for h in range(6):
        sim.add_host(resources(16, 32_768, 10_000, 400_000), pool=h % 2)
    for i in range(60):
        demand = resources(float(rng.choice([1, 2, 4])), 2048, 100, 10_000)
        sim.submit(make_spot(i, demand, float(rng.uniform(50, 400)),
                             bid=float(rng.uniform(0.3, 1.0)),
                             hibernation_timeout=400.0,
                             submit_time=float(rng.uniform(0.0, 300.0))))
    m = sim.run(until=2000.0)
    return sim, m


def test_empty_injector_bit_identical_to_no_injector():
    """faults=FaultInjector(()) == faults=None: identical VM tables, events,
    prices, timeline — the fault layer is invisible until an event fires."""
    sim1, m1 = _seeded_market_run(faults=None)
    sim2, m2 = _seeded_market_run(faults=FaultInjector((), n_pools=2))
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))
    assert m1.interruption_events == m2.interruption_events
    assert m1.price_series == m2.price_series
    assert m1.timeline == m2.timeline
    assert m2.fault_records == []


def test_chaos_two_run_bit_identity():
    """The chaos-determinism contract: two identical fleet+faults runs at a
    fixed seed are bit-identical (VM tables, interruptions, fault records,
    capacity samples)."""
    spec = RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              n_pools=3, horizon=3600.0),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": 16.0}),
        faults=FaultSpec(scenario="storm",
                         params={"first": 600.0, "every": 600.0,
                                 "count": 3, "fraction": 0.5}))

    def one():
        sim = build(spec, seed=0)
        m = sim.run(until=3600.0)
        return sim, m

    sim1, m1 = one()
    sim2, m2 = one()
    assert m1.fault_records and m1.fleet_launches > 0   # chaos actually ran
    assert any(e.cause == InterruptionCause.FAULT_STORM
               for e in m1.interruption_events)
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))
    assert m1.interruption_events == m2.interruption_events
    assert m1.fault_records == m2.fault_records
    assert m1.fleet_samples == m2.fleet_samples
    assert m1.fallback_counts == m2.fallback_counts
    assert json.dumps(m1.resilience_stats(sim1.vms, sim1.engine, sim1.pool),
                      sort_keys=True) == \
        json.dumps(m2.resilience_stats(sim2.vms, sim2.engine, sim2.pool),
                   sort_keys=True)
