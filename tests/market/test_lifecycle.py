"""Spot lifecycle unit tests (paper Fig. 4 / §VII-A / §VII-B)."""
import numpy as np
import pytest

from repro.core import (
    FirstFit,
    HlemVmp,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    VmState,
    make_on_demand,
    make_spot,
    resources,
)


def two_slot_host_sim(policy=None, **sim_kw):
    sim = MarketSimulator(policy=policy or FirstFit(),
                          config=SimConfig(strict_invariants=True, **sim_kw))
    sim.add_host(resources(2, 2048, 10_000, 1_000_000))
    return sim


def test_restarting_interrupted_spot_matches_paper_example():
    """Reproduces the paper's RESTARTINGINTERRUPTEDSPOT timing: spot runs
    0-10, on-demand preempts 10-32, spot resumes 32-42, avg interruption 22 s
    (paper Fig. 6 shows exactly 22)."""
    sim = two_slot_host_sim(policy=HlemVmp())
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 20.0,
                     behavior=InterruptionBehavior.HIBERNATE,
                     hibernation_timeout=100.0)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 22.0,
                        submit_time=10.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=200.0)

    assert spot.state is VmState.FINISHED
    assert od.state is VmState.FINISHED
    assert spot.interruptions == 1
    assert [(h.start, h.stop) for h in spot.history] == [(0.0, 10.0),
                                                         (32.0, 42.0)]
    assert spot.average_interruption_time() == pytest.approx(22.0)


def test_terminate_behavior():
    sim = two_slot_host_sim()
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 50.0,
                     behavior=InterruptionBehavior.TERMINATE)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                        submit_time=5.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=100.0)
    assert spot.state is VmState.TERMINATED
    assert spot.interruptions == 1
    assert od.state is VmState.FINISHED


def test_minimum_running_time_blocks_interruption():
    sim = two_slot_host_sim()
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 50.0,
                     min_running_time=30.0,
                     behavior=InterruptionBehavior.TERMINATE)
    # od arrives at t=5 < min_running_time: spot must NOT be interrupted
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                        submit_time=5.0, persistent=False)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=100.0)
    assert spot.state is VmState.FINISHED
    assert spot.interruptions == 0
    assert od.state is VmState.FAILED  # non-persistent, could not be placed


def test_hibernation_timeout_terminates():
    sim = two_slot_host_sim()
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 50.0,
                     behavior=InterruptionBehavior.HIBERNATE,
                     hibernation_timeout=20.0)
    # long-running od keeps the host occupied past the hibernation timeout
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 500.0,
                        submit_time=5.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=600.0)
    assert spot.state is VmState.TERMINATED
    assert spot.hibernated_at == 5.0
    assert spot.interruptions == 1


def test_waiting_timeout_fails_persistent_request():
    sim = two_slot_host_sim()
    od1 = make_on_demand(0, resources(2, 512, 1000, 10_000), 500.0)
    od2 = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                         submit_time=1.0, waiting_timeout=30.0)
    sim.submit(od1)
    sim.submit(od2)
    sim.run(until=600.0)
    assert od2.state is VmState.FAILED
    assert od1.state is VmState.FINISHED


def test_persistent_request_fulfilled_when_capacity_frees():
    sim = two_slot_host_sim()
    od1 = make_on_demand(0, resources(2, 512, 1000, 10_000), 15.0)
    od2 = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                         submit_time=1.0, waiting_timeout=100.0)
    sim.submit(od1)
    sim.submit(od2)
    sim.run(until=200.0)
    assert od1.state is VmState.FINISHED
    assert od2.state is VmState.FINISHED
    assert od2.history[0].start == 15.0  # started when od1 freed the host


def test_warning_time_grace_period():
    """With warning_time=3, the victim keeps running 3 s after the signal."""
    sim = two_slot_host_sim(warning_time=3.0)
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 50.0,
                     behavior=InterruptionBehavior.HIBERNATE,
                     hibernation_timeout=1000.0)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                        submit_time=5.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=200.0)
    assert spot.history[0].stop == pytest.approx(8.0)   # 5 + warning 3
    assert od.history[0].start == pytest.approx(8.0)
    assert spot.state is VmState.FINISHED


def test_spot_finishing_during_warning_window():
    sim = two_slot_host_sim(warning_time=10.0)
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 12.0,
                     behavior=InterruptionBehavior.TERMINATE)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 10.0,
                        submit_time=5.0)
    sim.submit(spot)
    sim.submit(od)
    sim.run(until=200.0)
    # spot needed 12 s and the warning ends at 15 — it finishes, not terminates
    assert spot.state is VmState.FINISHED
    assert od.state is VmState.FINISHED


def test_spot_never_preempts_spot():
    sim = two_slot_host_sim()
    s1 = make_spot(0, resources(2, 512, 1000, 10_000), 50.0)
    s2 = make_spot(1, resources(2, 512, 1000, 10_000), 10.0, submit_time=5.0,
                   waiting_timeout=10.0)
    sim.submit(s1)
    sim.submit(s2)
    sim.run(until=200.0)
    assert s1.interruptions == 0
    assert s2.state is VmState.FAILED  # waited out, never preempted s1


def test_host_removal_interrupts_residents():
    sim = MarketSimulator(policy=FirstFit(),
                          config=SimConfig(strict_invariants=True))
    h0 = sim.add_host(resources(4, 4096, 10_000, 1_000_000))
    sim.add_host(resources(4, 4096, 10_000, 1_000_000))
    spot = make_spot(0, resources(2, 512, 1000, 10_000), 50.0,
                     behavior=InterruptionBehavior.HIBERNATE,
                     hibernation_timeout=1000.0)
    od = make_on_demand(1, resources(2, 512, 1000, 10_000), 50.0)
    sim.submit(spot)
    sim.submit(od)
    sim.schedule_host_remove(10.0, h0)
    sim.run(until=300.0)
    # both were on host 0; after removal they must migrate to host 1 and finish
    assert spot.state is VmState.FINISHED
    assert od.state is VmState.FINISHED
    assert spot.history[-1].host == 1
    assert od.history[-1].host == 1
