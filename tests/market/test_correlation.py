"""Association-measure properties (paper §VII-F)."""
import numpy as np
import pytest

from repro.market import (
    association_matrix,
    correlation_ratio,
    generate_advisor_dataset,
    pearson,
    theils_u,
)
from repro.market.advisor import KINDS


def test_theils_u_identity_and_independence():
    rng = np.random.default_rng(0)
    x = list(rng.integers(0, 4, 500))
    y = list(rng.integers(0, 4, 500))
    assert theils_u(x, x) == pytest.approx(1.0)
    assert theils_u(x, y) < 0.05
    assert 0.0 <= theils_u(x, y) <= 1.0


def test_theils_u_asymmetric_determinism():
    # y determines x fully, but not vice versa
    y = [0, 1, 2, 3] * 100
    x = [v % 2 for v in y]
    assert theils_u(x, y) == pytest.approx(1.0)
    assert theils_u(y, x) < 1.0


def test_correlation_ratio_bounds():
    rng = np.random.default_rng(1)
    cats = list(rng.integers(0, 3, 400))
    # values fully determined by category
    vals = np.asarray(cats, float) * 10.0
    assert correlation_ratio(cats, vals) == pytest.approx(1.0)
    # independent values
    assert correlation_ratio(cats, rng.normal(0, 1, 400)) < 0.2


def test_pearson_basic():
    x = np.arange(100, dtype=float)
    assert pearson(x, 2 * x + 1) == pytest.approx(1.0)
    assert pearson(x, -x) == pytest.approx(-1.0)
    assert pearson(x, np.zeros(100)) == 0.0


def test_advisor_analysis_recovers_paper_ordering():
    cols = generate_advisor_dataset(600, seed=1)
    am = association_matrix(cols, KINDS)
    row = am["interruption_band"]
    assert row["instance_type"] > row["family"] > row["category"]
    assert row["day"] < 0.15 and row["free_tier"] < 0.15
    # matrix diagonal is 1, all entries in [0, 1]
    for a in am:
        assert am[a][a] == 1.0
        for b in am[a]:
            assert -1e-9 <= am[a][b] <= 1.0 + 1e-9
