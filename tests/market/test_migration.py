"""Proactive cross-pool migration subsystem (PR 3 tentpole): bit-identity of
the ``none`` policy, MIGRATE_START/COMPLETE lifecycle (incl. interruption
mid-flight), anti-flapping hysteresis, planner-vs-oracle equality, adaptive
re-bidding determinism, and advisor-derived pool volatility."""
import copy

import numpy as np
import pytest

from repro.core import (
    FirstFit,
    HlemVmpAdjusted,
    HostPool,
    MarketSimulator,
    SimConfig,
    VmState,
    dynamic_vm_table,
    make_on_demand,
    make_spot,
    resources,
    to_json,
)
from repro.market import (
    MarketConfig,
    MarketEngine,
    MigrationConfig,
    MigrationPlanner,
    PoolConfig,
    RandomizedBid,
    RebidOnResume,
    TraceConfig,
    advisor_pool_volatility,
    assign_bids,
    generate_trace,
    make_market,
    make_migration_planner,
    plan_reference,
    simulate_trace,
)

_EPS = 1e-9


class ScriptedProcess:
    """Price process stub: scripted sequence, then holds the last value."""

    def __init__(self, seq):
        self.seq = list(seq)
        self.last = self.seq[-1]

    def price(self, utilization: float) -> float:
        if self.seq:
            self.last = self.seq.pop(0)
        return self.last


def scripted_engine(*pool_price_seqs, tick=10.0) -> MarketEngine:
    pools = [PoolConfig(f"p{i}") for i in range(len(pool_price_seqs))]
    eng = MarketEngine(MarketConfig(pools, tick_interval=tick))
    eng.processes = [ScriptedProcess(s) for s in pool_price_seqs]
    return eng


def mig_sim(engine, migration, policy=None, **sim_kw):
    return MarketSimulator(
        policy=policy or FirstFit(),
        config=SimConfig(strict_invariants=True, **sim_kw),
        engine=engine, migration=migration)


BIG = resources(64, 131_072, 40_000, 1_600_000)
SMALL = resources(2, 2048, 1000, 10_000)


# ---------------------------------------------------------------------------
# migration=none is bit-identical to main (no planner attached)
# ---------------------------------------------------------------------------
def _market_run(policy, migration, seed=7):
    rng = np.random.default_rng(seed)
    mc = make_market("volatile", n_pools=2, seed=seed, tick_interval=20.0)
    eng = MarketEngine(mc)
    sim = MarketSimulator(policy=policy,
                          config=SimConfig(record_timeline=True),
                          engine=eng, migration=migration)
    for h in range(10):
        sim.add_host(resources(16, 32_768, 10_000, 400_000), pool=h % 2)
    vms = []
    for i in range(120):
        demand = resources(float(rng.choice([1, 2, 4])), 2048, 100, 10_000)
        t0 = float(rng.uniform(0.0, 300.0))
        if rng.random() < 0.6:
            vms.append(make_spot(i, demand, float(rng.uniform(50, 400)),
                                 hibernation_timeout=400.0,
                                 min_running_time=5.0, submit_time=t0))
        else:
            vms.append(make_on_demand(i, demand, float(rng.uniform(50, 400)),
                                      submit_time=t0))
    assign_bids(vms, RandomizedBid(lo=0.3, hi=1.0), seed=seed)
    for v in vms:
        sim.submit(v)
    m = sim.run(until=2000.0)
    return sim, m


@pytest.mark.parametrize("policy_factory",
                         [FirstFit, lambda: HlemVmpAdjusted(alpha=-0.5)])
def test_migration_none_bit_identical_synthetic(policy_factory):
    """A ``none`` planner attached = no planner at all: identical VM tables
    (JSON), identical metrics, identical event series."""
    sim1, m1 = _market_run(policy_factory(), migration=None)
    sim2, m2 = _market_run(policy_factory(),
                           migration=make_migration_planner("none"))
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))
    assert m1.interruption_events == m2.interruption_events
    assert m1.wave_events == m2.wave_events
    assert m1.price_series == m2.price_series
    assert m1.timeline == m2.timeline
    assert m2.migration_events == [] and m2.migrations_planned == 0
    assert m2.migration_stats() == {
        "planned": 0, "started": 0, "completed": 0, "failed": 0,
        "downtime_s": 0.0, "predicted_saving": 0.0}


def test_migration_none_bit_identical_trace():
    """Trace runs (no engine → the planner can never fire) are unchanged by
    attaching it — full JSON equality of the VM table."""
    cfg = TraceConfig(seed=3, n_machines=20, sim_days=0.05, n_spot=60)
    tr = generate_trace(cfg)
    sim1, _ = simulate_trace(tr, cfg=cfg)
    sim2, _ = simulate_trace(tr, cfg=cfg,
                             migration=make_migration_planner("none"))
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))


# ---------------------------------------------------------------------------
# MIGRATE_START → MIGRATE_COMPLETE lifecycle
# ---------------------------------------------------------------------------
def test_migrate_lifecycle_chain():
    """Pool 0 clears high, pool 1 low: the resident spot VM is planned,
    leaves its host (MIGRATE_START), spends the downtime resident nowhere
    (reservation holds destination capacity), then arrives
    (MIGRATE_COMPLETE) with a via="migrate" interval and a cooldown stamp."""
    eng = scripted_engine([0.5] * 60, [0.1] * 60, tick=10.0)
    planner = make_migration_planner("greedy-cheapest", downtime=5.0,
                                     min_remaining=10.0, cooldown=100.0)
    sim = mig_sim(eng, planner)
    h0 = sim.add_host(BIG, pool=0)
    h1 = sim.add_host(BIG, pool=1)
    vm = make_spot(0, SMALL, 300.0, bid=0.8, hibernation_timeout=1e6)
    sim.submit(vm)
    m = sim.run(until=1000.0)

    assert vm.state is VmState.FINISHED
    assert vm.migrations == 1
    assert vm.interruptions == 0          # a migration is not an interruption
    assert [(i.host, i.via) for i in vm.history] == \
        [(h0, "start"), (h1, "migrate")]
    # planned at the t=10 tick (the t=0 tick precedes the submit), started
    # at t=10, arrived after the 5s downtime
    assert vm.history[0].stop == 10.0
    assert vm.history[1].start == 15.0
    assert vm.finish_time == pytest.approx(305.0)  # 10 ran + 5 down + 290
    assert vm.interruption_gaps() == []   # migrate gaps are not interruptions
    assert vm.migrate_cooldown_until == pytest.approx(115.0)
    assert (m.migrations_planned, m.migrations_started,
            m.migrations_completed, m.migrations_failed) == (1, 1, 1, 0)
    assert m.migration_downtime == pytest.approx(5.0)
    ev = m.migration_events[0]
    assert (ev.src_host, ev.dst_host, ev.src_pool, ev.dst_pool) == (h0, h1, 0, 1)
    assert ev.t_complete == 15.0 and not ev.failed
    assert sim.pool._reserved == {}       # reservation fully released
    stats = m.migration_stats(sim.vms, eng)
    # the remaining 290s ran on pool 1 at 0.1 vs 0.5 in pool 0
    assert stats["realized_saving"] == pytest.approx(290 * 0.4)


def test_interruption_during_migration():
    """The destination pool's price crosses the VM's bid during the flight:
    the arrival fails, the VM takes its interruption behavior (hibernate),
    and later resumes normally when the price falls back."""
    # pool 0 expensive (drives the migration), pool 1 cheap then spiking at
    # the t=20 tick — mid-flight for a migration started at t=10
    eng = scripted_engine([0.5] * 60,
                          [0.4, 0.1, 0.9, 0.9, 0.1] + [0.1] * 60, tick=10.0)
    planner = make_migration_planner("greedy-cheapest", downtime=15.0,
                                     min_remaining=10.0)
    sim = mig_sim(eng, planner)
    sim.add_host(BIG, pool=0)
    h1 = sim.add_host(BIG, pool=1)
    vm = make_spot(0, SMALL, 300.0, bid=0.8, hibernation_timeout=1e6)
    sim.submit(vm)
    m = sim.run(until=1000.0)

    # flight 1: planned at the t=10 tick, due t=25; the t=20 tick repriced
    # pool 1 to 0.9 > bid → failed arrival → hibernate → the same-event
    # flush resumes it on the still-clearing pool-0 host (gap 0)
    assert m.migration_events[0].failed
    assert m.migrations_failed == 1
    assert vm.interruptions == 1
    assert m.interruption_events[0].cause == "migration-failed"
    assert m.interruption_events[0].time == 25.0
    assert vm.history[1].via == "start"     # a resume, not a migration arrival
    assert (vm.history[1].host, vm.history[1].start) == (0, 25.0)
    # the failed flight's 15s of downtime counts as interruption time (the
    # resume is via="start", so the gap back to t=10 is not exempt)
    assert vm.interruption_gaps() == [15.0]
    # flight 2: pool 1 falls back to 0.1 at the t=40 tick → the planner
    # retries and this time the arrival commits.  Only the successful
    # flight's 15s count as migration downtime — the failed flight's 15s
    # already landed in the interruption gap (no double-count)
    assert m.migrations_started == 2 and m.migrations_completed == 1
    assert m.migration_downtime == pytest.approx(15.0)
    assert vm.state is VmState.FINISHED
    assert vm.migrations == 1
    assert vm.history[2].via == "migrate" and vm.history[2].host == h1
    assert sim.pool._reserved == {}


def test_hysteresis_prevents_flapping():
    """Price oscillation between two pools: without the cooldown the greedy
    chaser would bounce A→B→A every tick; the arrival stamp pins it."""
    osc0 = [0.6, 0.1] * 40    # pool 0 expensive on even ticks
    osc1 = [0.1, 0.6] * 40    # pool 1 expensive on odd ticks
    eng = scripted_engine(osc0, osc1, tick=10.0)
    planner = make_migration_planner("greedy-cheapest", downtime=2.0,
                                     min_remaining=10.0, cooldown=300.0)
    sim = mig_sim(eng, planner)
    h0 = sim.add_host(BIG, pool=0)
    sim.add_host(BIG, pool=1)
    vm = make_spot(0, SMALL, 400.0, bid=0.8, hibernation_timeout=1e6)
    sim.submit(vm)
    m = sim.run(until=310.0)
    # exactly one migration within the cooldown window, no A→B→A bounce
    assert vm.migrations == 1
    assert m.migrations_started == 1
    assert vm.history[0].host == h0
    assert len(vm.history) == 2 and vm.history[1].via == "migrate"


def test_migration_respects_pool_pin_and_min_running_time():
    eng = scripted_engine([0.5] * 30, [0.1] * 30, tick=10.0)
    planner = make_migration_planner("greedy-cheapest", downtime=2.0,
                                     min_remaining=10.0)
    sim = mig_sim(eng, planner)
    sim.add_host(BIG, pool=0)
    sim.add_host(BIG, pool=1)
    pinned = make_spot(0, SMALL, 200.0, bid=0.8, pool=0)
    protected = make_spot(1, SMALL, 200.0, bid=0.8, min_running_time=1e5)
    sim.submit(pinned)
    sim.submit(protected)
    sim.run(until=250.0)
    assert pinned.migrations == 0       # region-bound VMs never move
    assert protected.migrations == 0    # still under minimum running time


# ---------------------------------------------------------------------------
# planner: vectorized scoring == per-VM oracle
# ---------------------------------------------------------------------------
def _registry_fixture(m=300, n_pools=4, seed=0):
    pool = HostPool()
    pool.enable_market(n_pools)
    rng = np.random.default_rng(seed)
    n_hosts = 24
    for h in range(n_hosts):
        util_target = 0.5 + 0.1 * (h % n_pools)
        pool.add_host(resources((m / n_hosts) / util_target, 1e9, 1e9, 1e9),
                      pool=h % n_pools)
    for i in range(m):
        vm = make_spot(i, resources(1, 64, 1, 1), float(rng.uniform(100, 5000)),
                       bid=float(rng.uniform(0.1, 1.0)),
                       min_running_time=float(rng.choice([0.0, 200.0])),
                       pool=int(rng.choice([-1, -1, -1, 0])))
        vm.migrate_cooldown_until = float(rng.choice([0.0, 1e6]))
        pool.place(vm, i % n_hosts, now=0.0)
        vm.state = VmState.RUNNING
        vm.run_start = 0.0
    eng = MarketEngine(make_market("volatile", n_pools=n_pools, seed=seed,
                                   tick_interval=60.0))
    for k in range(6):
        pool.set_pool_prices(eng.tick(pool, 60.0 * k))
    return pool, eng


@pytest.mark.parametrize("policy", ["none", "greedy-cheapest",
                                    "gradient-aware", "risk-budgeted"])
def test_planner_matches_reference_oracle(policy):
    pool, eng = _registry_fixture()
    for inflight in (np.zeros(4, dtype=np.int64),
                     np.array([3, 0, 4, 1], dtype=np.int64)):
        planner = MigrationPlanner(MigrationConfig(
            policy=policy, min_remaining=50.0))
        vec = planner.plan(pool, eng, 360.0, inflight)
        ref = plan_reference(planner, pool, eng, 360.0, inflight)
        assert [(p.vm_id, p.dst_pool) for p in vec] == \
            [(p.vm_id, p.dst_pool) for p in ref]
        for a, b in zip(vec, ref):
            assert a.predicted_saving == pytest.approx(b.predicted_saving)
        if policy == "none":
            assert vec == []


def test_unknown_migration_policy_rejected():
    with pytest.raises(AssertionError, match="unknown migration policy"):
        MigrationConfig(policy="teleport")


# ---------------------------------------------------------------------------
# determinism: identical migration runs are bit-identical
# ---------------------------------------------------------------------------
def _gradient_run(seed=11):
    rng = np.random.default_rng(seed)
    mc = make_market("volatile", n_pools=3, seed=seed, tick_interval=20.0,
                     from_advisor=True)
    eng = MarketEngine(mc)
    planner = make_migration_planner("gradient-aware", downtime=10.0,
                                     cooldown=100.0, min_remaining=30.0,
                                     danger_margin=0.5, hysteresis=0.02)
    sim = MarketSimulator(policy=HlemVmpAdjusted(alpha=-0.5),
                          config=SimConfig(record_timeline=True,
                                           strict_invariants=True),
                          engine=eng, migration=planner)
    for h in range(9):
        sim.add_host(resources(16, 32_768, 10_000, 400_000), pool=h % 3)
    vms = []
    for i in range(90):
        demand = resources(float(rng.choice([1, 2, 4])), 2048, 100, 10_000)
        vms.append(make_spot(i, demand, float(rng.uniform(200, 1500)),
                             hibernation_timeout=1000.0,
                             submit_time=float(rng.uniform(0.0, 200.0))))
    assign_bids(vms, RandomizedBid(lo=0.3, hi=1.0), seed=seed)
    for v in vms:
        sim.submit(v)
    m = sim.run(until=3000.0)
    return sim, m


def test_migration_run_bit_identical_across_runs():
    sim1, m1 = _gradient_run()
    sim2, m2 = _gradient_run()
    assert m1.migration_events == m2.migration_events
    assert m1.interruption_events == m2.interruption_events
    assert m1.timeline == m2.timeline
    assert to_json(dynamic_vm_table(sim1.all_vms())) == \
        to_json(dynamic_vm_table(sim2.all_vms()))
    assert m1.migrations_completed == m2.migrations_completed
    # the run actually exercised the subsystem
    assert m1.migrations_started > 0


# ---------------------------------------------------------------------------
# adaptive re-bidding on hibernation (satellite)
# ---------------------------------------------------------------------------
def _rebid_run(rebid, seed=5):
    eng = scripted_engine([0.1, 0.6, 0.6, 0.1] + [0.1] * 40, tick=10.0)
    sim = MarketSimulator(policy=FirstFit(),
                          config=SimConfig(strict_invariants=True),
                          engine=eng, rebid=rebid)
    sim.add_host(BIG, pool=0)
    vms = [make_spot(i, SMALL, 200.0, bid=0.5, hibernation_timeout=1e6)
           for i in range(3)]
    for v in vms:
        sim.submit(v)
    m = sim.run(until=500.0)
    return sim, m, vms


def test_rebid_on_resume_off_by_default_and_deterministic():
    # off: bids never change
    _, _, vms_off = _rebid_run(rebid=None)
    assert all(v.bid == 0.5 for v in vms_off)
    assert all(v.interruptions == 1 for v in vms_off)

    # on: hibernation bumps the bid within [lo, hi], capped at on-demand
    hook = RebidOnResume(bump_lo=1.2, bump_hi=1.5, on_demand_rate=1.0, seed=3)
    _, _, vms_on = _rebid_run(rebid=hook)
    for v in vms_on:
        assert v.interruptions == 1
        assert 0.5 * 1.2 <= v.bid <= 0.5 * 1.5
    assert len({v.bid for v in vms_on}) == 3   # per-VM randomized draws

    # seeded determinism: an identical run re-draws identical bids
    _, _, vms_on2 = _rebid_run(rebid=RebidOnResume(
        bump_lo=1.2, bump_hi=1.5, on_demand_rate=1.0, seed=3))
    assert [v.bid for v in vms_on2] == [v.bid for v in vms_on]

    # the draw is keyed on interruption count: a later interruption of the
    # same VM draws a different bump
    vm = vms_on[0]
    first = hook.rebid(vm)
    vm.interruptions += 1
    assert hook.rebid(vm) != first


def test_rebid_caps_at_on_demand_rate():
    hook = RebidOnResume(bump_lo=3.0, bump_hi=4.0, on_demand_rate=1.0)
    vm = make_spot(0, SMALL, 10.0, bid=0.9)
    assert hook.rebid(vm) == 1.0


# ---------------------------------------------------------------------------
# risk signals
# ---------------------------------------------------------------------------
def test_risk_signals_from_price_history():
    from repro.market.risk import (bid_crossing_risk, price_gradients,
                                   price_volatility, projected_prices)

    eng = scripted_engine([0.1, 0.2, 0.3, 0.4, 0.5],   # linear ramp
                          [0.3] * 5, tick=10.0)        # flat
    pool = HostPool()
    pool.enable_market(2)
    pool.add_host(BIG, pool=0)
    pool.add_host(BIG, pool=1)
    for k in range(5):
        eng.tick(pool, 10.0 * k)
    grads = price_gradients(eng, window=5)
    assert grads[0] == pytest.approx(0.01)     # +0.1 per 10s tick
    assert grads[1] == pytest.approx(0.0)
    vol = price_volatility(eng, window=5)
    assert vol[0] > 0 and vol[1] == pytest.approx(0.0)
    # the regression line continues the ramp and holds the flat pool
    proj = projected_prices(eng, lead=10.0, window=5)
    assert proj[0] == pytest.approx(0.6)
    assert proj[1] == pytest.approx(0.3)
    # crossing risk is monotone in (projected - bid) and respects pools
    bids = np.array([0.55, 0.65, 0.55])
    pools = np.array([0, 0, 1])
    r = bid_crossing_risk(proj, vol, bids, pools)
    assert r[0] > r[1]          # same pool, lower bid → higher risk
    assert 0.0 <= r.min() and r.max() <= 1.0


# ---------------------------------------------------------------------------
# advisor-derived pool volatility (satellite)
# ---------------------------------------------------------------------------
def test_advisor_pool_volatility_deterministic_and_ordered():
    v1 = advisor_pool_volatility(4, seed=0)
    v2 = advisor_pool_volatility(4, seed=0)
    assert np.array_equal(v1, v2)
    assert v1.shape == (4,)
    # calm → spiky ordering by construction, inside the calibration anchors
    assert np.all(np.diff(v1) >= 0)
    assert np.all(v1 >= 0.12 - 1e-9) and np.all(v1 <= 0.60 + 1e-9)
    assert advisor_pool_volatility(4, seed=1)[0] != v1[0]  # seed-sensitive


def test_make_market_wires_advisor_volatility():
    mc = make_market("volatile", n_pools=3, seed=0, from_advisor=True)
    sigmas = [p.process_kwargs["shock_sigma"] for p in mc.pools]
    assert sigmas == sorted(sigmas)
    assert sigmas == advisor_pool_volatility(3, seed=0).tolist()
    # calm regime: volatility bounds the smoothed step size per pool
    mc_calm = make_market("calm", n_pools=3, seed=0, from_advisor=True)
    steps = [p.process_kwargs["max_step"] for p in mc_calm.pools]
    assert steps == [s / 9.0 for s in sigmas]
    with pytest.raises(AssertionError):
        make_market("volatile", n_pools=2, pool_volatility=[0.3],
                    from_advisor=False)
