"""Serving correctness: incremental decode == teacher-forced forward, and
interruption-aware request scheduling."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models.model import forward, init_params
from repro.serve import (
    Request,
    SpotServingScheduler,
    greedy_generate,
)

ARCHS = ["deepseek_7b", "falcon_mamba_7b", "hymba_1_5b",
         "granite_moe_3b_a800m", "starcoder2_15b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_teacher_forcing(arch):
    cfg = get_smoke_config(arch).replace(capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S, N = 2, 16, 6
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    gen = greedy_generate(cfg, params, prompt, N)
    full = jnp.concatenate([prompt, gen], axis=1)
    logits_full = forward(cfg, params, full)
    pred = jnp.argmax(logits_full[:, S - 1:S + N - 1, :], axis=-1)
    assert bool((pred == gen).all()), arch


def test_scheduler_hibernate_resume():
    s = SpotServingScheduler(batch_size=4, hibernate=True)
    for i in range(6):
        s.add(Request(i, 8, 10))
    batch = s.fill_batch()
    assert len(batch) == 4
    s.step(5)                      # halfway
    s.interrupt()                  # spot reclaimed
    st = s.stats()
    assert st["hibernated"] == 4 and st["running"] == 0
    batch2 = s.fill_batch()        # hibernated resume first
    assert {r.id for r in batch2[:4]} == {0, 1, 2, 3}
    assert all(r.generated == 5 for r in batch2[:4])  # progress kept
    s.step(5)
    assert len(s.done) == 4
    s.fill_batch()
    s.step(10)
    assert len(s.done) == 6
    assert s.stats()["interruptions"] == 4


def test_scheduler_terminate_requeues_from_scratch():
    s = SpotServingScheduler(batch_size=2, hibernate=False)
    for i in range(2):
        s.add(Request(i, 8, 10))
    s.fill_batch()
    s.step(7)
    s.interrupt()
    assert all(r.generated == 0 for r in s.queue)  # progress lost
