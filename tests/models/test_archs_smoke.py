"""Per-architecture smoke tests: reduced same-family config, one forward +
one train step + one decode step on CPU; shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models.model import (
    decode_step,
    forward,
    init_decode_state,
    init_params,
    param_specs,
)
from repro.train.train_step import init_train_state, make_train_step

B, S = 2, 32


def _tokens(cfg, key, b=B, s=S):
    if cfg.modality == "text":
        return jax.random.randint(key, (b, s), 0, cfg.vocab)
    return jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, key)
    logits = forward(cfg, params, _tokens(cfg, key))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, key):
    cfg = get_smoke_config(arch)
    state = init_train_state(cfg, key)
    # warmup=1 so the very first step has a non-zero learning rate
    step = jax.jit(make_train_step(
        cfg, lr_kwargs={"warmup": 1, "total": 100, "peak": 1e-2}))
    batch = {
        "tokens": _tokens(cfg, key),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab),
    }
    new_state, metrics = step(state, batch)
    new_state, metrics = step(new_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(new_state.step) == 2
    # params actually changed (compare full trees, not a single leaf)
    changed = any(
        not np.allclose(np.asarray(x, np.float32), np.asarray(y, np.float32))
        for x, y in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(new_state.params)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_decode_step(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(cfg, key)
    st = init_decode_state(cfg, B, cache_len=64)
    tok = _tokens(cfg, key, b=B, s=1)
    logits, st2 = decode_step(cfg, params, tok, st)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert int(st2.pos) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_metadata(arch):
    """The FULL configs are exercised via the dry-run only; here we validate
    their static metadata (param counts within 15% of published sizes)."""
    cfg = get_config(arch)
    published = {
        "phi_3_vision_4_2b": 4.2e9, "kimi_k2_1t_a32b": 1.0e12,
        "granite_moe_3b_a800m": 3.3e9, "musicgen_large": 3.3e9,
        "starcoder2_15b": 15e9, "deepseek_7b": 7e9,
        "internlm2_20b": 20e9, "llama3_405b": 405e9,
        "hymba_1_5b": 1.5e9, "falcon_mamba_7b": 7.3e9,
    }[arch]
    n = cfg.n_params()
    # modality archs: backbone-only counts exclude the stubbed frontend
    tol = 0.35 if cfg.modality != "text" else 0.15
    assert abs(n - published) / published < tol, (n, published)
    if cfg.is_moe:
        assert cfg.n_active_params() < 0.5 * n


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_matches_params(arch):
    from repro.models.sharding import is_spec_leaf
    cfg = get_smoke_config(arch)
    shapes = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
    specs = param_specs(cfg)
    flat_shapes = jax.tree.flatten(shapes)[0]
    flat_specs = jax.tree.flatten(specs, is_leaf=is_spec_leaf)[0]
    assert len(flat_shapes) == len(flat_specs)
    for sh, sp in zip(flat_shapes, flat_specs):
        assert len(sp) <= len(sh.shape) or len(sh.shape) == 0
