"""Live progress suppression (ISSUE 8 satellite): stderr counter/progress
lines are for humans at a terminal — suppressed when stderr is not a TTY
(CI, redirection) unless ``--force-progress`` overrides; always suppressed
under ``--json``."""
import argparse
import sys

import pytest

from repro.launch.market_sim import _progress_enabled, main


def _args(**kw):
    ns = argparse.Namespace(json=False, force_progress=False)
    for k, v in kw.items():
        setattr(ns, k, v)
    return ns


def _set_tty(monkeypatch, value: bool):
    monkeypatch.setattr(sys.stderr, "isatty", lambda: value, raising=False)


def test_progress_follows_tty(monkeypatch):
    _set_tty(monkeypatch, True)
    assert _progress_enabled(_args()) is True
    _set_tty(monkeypatch, False)
    assert _progress_enabled(_args()) is False


def test_force_progress_overrides_non_tty(monkeypatch):
    _set_tty(monkeypatch, False)
    assert _progress_enabled(_args(force_progress=True)) is True


def test_json_always_suppresses(monkeypatch):
    _set_tty(monkeypatch, True)
    assert _progress_enabled(_args(json=True)) is False
    assert _progress_enabled(_args(json=True, force_progress=True)) is False


def _counter_lines(capsys):
    return [ln for ln in capsys.readouterr().err.splitlines()
            if ln.startswith("# t=")]


COUNTER_ARGV = ["--market", "--regimes", "volatile", "--policy",
                "hlem-vmp-adjusted", "--until", "1800",
                "--counters-every", "600"]


def test_counter_lines_suppressed_without_tty(monkeypatch, capsys):
    _set_tty(monkeypatch, False)
    assert main(COUNTER_ARGV) == 0
    assert _counter_lines(capsys) == []


def test_counter_lines_restored_by_force_progress(monkeypatch, capsys):
    _set_tty(monkeypatch, False)
    assert main(COUNTER_ARGV + ["--force-progress"]) == 0
    assert len(_counter_lines(capsys)) > 0
