"""Launch-layer unit tests: shapes, skip rules, spec trees (1 device)."""
import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.launch.specs import SHAPES, batch_specs, cell_supported, rules_for


def test_shapes_table():
    assert SHAPES["train_4k"].kind == "train"
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].kind == "prefill"
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524_288


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_long_500k_skip_rule(arch):
    cfg = get_config(arch)
    ok, reason = cell_supported(cfg, SHAPES["long_500k"])
    if arch in ("falcon_mamba_7b", "hymba_1_5b"):
        assert ok, (arch, reason)
    else:
        assert not ok and "quadratic" in reason


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_all_other_shapes_supported(arch):
    cfg = get_config(arch)
    for name in ("train_4k", "prefill_32k", "decode_32k"):
        ok, _ = cell_supported(cfg, SHAPES[name])
        assert ok


def test_batch_specs_modality():
    vlm = get_config("phi_3_vision_4_2b")
    shapes, specs = batch_specs(vlm, SHAPES["train_4k"])
    assert len(shapes["tokens"].shape) == 3  # precomputed embeddings
    txt = get_config("deepseek_7b")
    shapes, specs = batch_specs(txt, SHAPES["train_4k"])
    assert len(shapes["tokens"].shape) == 2


def test_rules_for_overrides():
    llama = get_config("llama3_405b")
    r = rules_for(llama, SHAPES["train_4k"])
    assert r["fsdp"] == ("pod", "data")
    assert r["res_seq"] == "model"
    # decode: no sequence-parallel residual
    r2 = rules_for(llama, SHAPES["decode_32k"])
    assert "res_seq" not in r2
    small = get_config("deepseek_7b")
    assert rules_for(small, SHAPES["train_4k"]) == {}
