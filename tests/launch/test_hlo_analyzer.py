"""HLO analyzer correctness on known programs (single process, 1 device)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analyzer import analyze, parse_hlo
from repro.launch.hlo_stats import roofline_terms


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_exact():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        c, _ = jax.lax.scan(body, x, None, length=7)
        return c

    a = analyze(_hlo(f, jax.ShapeDtypeStruct((64, 64), jnp.float32)))
    assert a.flops == pytest.approx(7 * 2 * 64 ** 3, rel=0.01)


def test_nested_scan_multipliers():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return jnp.tanh(ci @ ci), None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, None
        c, _ = jax.lax.scan(outer, x, None, length=5)
        return c

    a = analyze(_hlo(f, jax.ShapeDtypeStruct((32, 32), jnp.float32)))
    assert a.flops == pytest.approx(15 * 2 * 32 ** 3, rel=0.01)


def test_plain_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b

    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    y = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    a = analyze(_hlo(f, x, y))
    assert a.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    # bytes >= inputs + output
    expect = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert a.hbm_bytes >= expect * 0.9


def test_dus_accumulation_not_overcounted():
    """Scan that stacks outputs (DUS pattern) must count slice traffic,
    not the full accumulation buffer per step."""
    def f(x):
        def body(c, _):
            c = c + 1.0
            return c, c
        _, ys = jax.lax.scan(body, x, None, length=1000)
        return ys

    a = analyze(_hlo(f, jax.ShapeDtypeStruct((128,), jnp.float32)))
    full_buffer_per_step = 1000 * 128 * 4 * 1000  # what overcounting gives
    assert a.hbm_bytes < full_buffer_per_step / 10


def test_roofline_terms_math():
    t = roofline_terms(flops=197e12 * 512, hbm_bytes=0, coll_bytes=0,
                       chips=512)
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["dominant"] == "compute_s"
    t2 = roofline_terms(flops=1, hbm_bytes=819e9 * 2, coll_bytes=0, chips=1)
    assert t2["memory_s"] == pytest.approx(2.0)
    assert t2["dominant"] == "memory_s"


def test_parse_hlo_finds_entry():
    comps, entry = parse_hlo(_hlo(lambda x: x * 2,
                                  jax.ShapeDtypeStruct((8,), jnp.float32)))
    assert entry is not None and entry in comps
