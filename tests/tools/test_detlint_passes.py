"""Cross-module detlint passes on seeded fixture trees."""
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]
FIXTURES = Path(__file__).resolve().parent / "fixtures"
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.detlint import run_lint  # noqa: E402
from tools.detlint.passes import (EventCoveragePass,  # noqa: E402
                                  RegistryCoveragePass,
                                  SpecRoundtripFieldsPass)


def run_pass(paths, pazz, tests_dir=None, root=REPO_ROOT):
    report = run_lint(paths=paths, root=root, rules=[], passes=[pazz],
                      tests_dir=tests_dir)
    return [f for f in report.findings if f.status == "new"]


# ---------------------------------------------------------------------------
# event-coverage
# ---------------------------------------------------------------------------
def test_event_coverage_flags_half_wired_kinds():
    found = run_pass([FIXTURES / "evtree"], EventCoveragePass())
    msgs = {(f.line, f.message.split(" — ")[0]) for f in found}
    assert (7, "EventKind.BETA has no PRIORITY entry") in msgs
    assert (7, "EventKind.BETA has no handler branch in simulator._dispatch") \
        in msgs
    assert any(m.startswith("EventKind.BETA is never pushed")
               for _, m in msgs)
    assert any(m.startswith("EventKind.GAMMA is never pushed")
               for _, m in msgs)
    # emit of a kind the LogEventKind enum does not declare
    mystery = [f for f in found if "mystery" in f.message]
    assert len(mystery) == 1 and mystery[0].line == 12
    assert mystery[0].path.endswith("repro/core/simulator.py")
    # declared log kind with no emit site
    orphan = [f for f in found if "'orphan'" in f.message]
    assert len(orphan) == 1 and orphan[0].line == 7
    assert orphan[0].path.endswith("repro/obs/eventlog.py")
    # ALPHA is fully wired: nothing about it
    assert not any("ALPHA" in f.message or "'alpha'" in f.message
                   for f in found)


def test_event_coverage_flags_missing_dispatch_trace_label(tmp_path):
    sim = FIXTURES / "evtree" / "repro" / "core" / "simulator.py"
    tree = tmp_path / "repro"
    (tree / "core").mkdir(parents=True)
    (tree / "core" / "events.py").write_text(
        (FIXTURES / "evtree" / "repro" / "core" / "events.py").read_text())
    (tree / "core" / "simulator.py").write_text(
        sim.read_text().replace('"dispatch/"', '"served/"'))
    found = run_pass([tmp_path], EventCoveragePass(), root=tmp_path)
    assert any("traced per-kind dispatch label" in f.message for f in found)


def test_event_coverage_real_tree_is_fully_wired():
    """All 25 LogEventKinds + 13 EventKinds in src/ are fully wired."""
    from repro.obs import LogEventKind
    from repro.core.events import EventKind, PRIORITY

    assert len(LogEventKind) == 25
    assert len(EventKind) == 13 and len(PRIORITY) == 13
    found = run_pass([REPO_ROOT / "src"], EventCoveragePass(),
                     tests_dir=REPO_ROOT / "tests")
    assert found == []


# ---------------------------------------------------------------------------
# registry-coverage
# ---------------------------------------------------------------------------
def _reg_findings(tmp_path, test_text):
    tests_dir = tmp_path / "tests"
    tests_dir.mkdir()
    (tests_dir / "test_ref.py").write_text(test_text)
    return run_pass([FIXTURES / "regtree"], RegistryCoveragePass(),
                    tests_dir=tests_dir)


def test_registry_coverage_duplicates_untested_and_loops(tmp_path):
    found = _reg_findings(
        tmp_path, 'NAMES = ["fixture-dup", "loop-a"]\n')
    dup = [f for f in found if "registered more than once" in f.message]
    assert len(dup) == 1 and "'fixture-dup'" in dup[0].message
    assert dup[0].line == 7                       # first site; second at 12
    assert ":12" in dup[0].message
    untested = sorted(f.message.split("'")[1] for f in found
                      if "not referenced by any test" in f.message)
    assert untested == ["fixture-untested", "loop-b"]
    # helper plumbing (name parameter) is not flagged as non-literal
    assert not any("non-literal" in f.message for f in found)


def test_registry_coverage_all_referenced(tmp_path):
    found = _reg_findings(
        tmp_path,
        'NAMES = ["fixture-dup", "fixture-untested", "loop-a", "loop-b"]\n')
    assert [f for f in found if "not referenced" in f.message] == []


def test_registry_coverage_real_tree_clean():
    found = run_pass([REPO_ROOT / "src"], RegistryCoveragePass(),
                     tests_dir=REPO_ROOT / "tests")
    assert found == []


def test_registry_coverage_flags_unwired_spec_anchor(tmp_path):
    """A spec anchor that stops referencing its registry is flagged."""
    tree = tmp_path / "repro"
    (tree / "api").mkdir(parents=True)
    (tree / "api" / "specs.py").write_text("# no registry imports here\n")
    (tree / "api" / "plugins.py").write_text(
        "from repro.api.registry import register_policy\n\n"
        "@register_policy('tmp-pol')\n"
        "def p():\n    return 0\n")
    tests_dir = tmp_path / "tests"
    tests_dir.mkdir()
    (tests_dir / "test_ref.py").write_text("USE = 'tmp-pol'\n")
    found = run_pass([tmp_path], RegistryCoveragePass(),
                     tests_dir=tests_dir, root=tmp_path)
    assert any("not constructible from a spec" in f.message for f in found)


# ---------------------------------------------------------------------------
# spec-roundtrip-fields
# ---------------------------------------------------------------------------
def test_spec_roundtrip_flags_dropped_field():
    found = run_pass([FIXTURES / "spec_bad.py"], SpecRoundtripFieldsPass())
    assert len(found) == 1
    f = found[0]
    assert f.line == 8
    assert "BrokenSpec.beta" in f.message
    assert "to_dict" in f.message and "from_dict" in f.message


def test_spec_roundtrip_real_tree_clean():
    found = run_pass([REPO_ROOT / "src"], SpecRoundtripFieldsPass())
    assert found == []
