"""detlint engine mechanics: baseline workflow, CLI, JSON output, self-lint."""
import json
import subprocess
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parents[2]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.detlint import default_passes, default_rules, run_lint  # noqa: E402
from tools.detlint.baseline import (baseline_counts, load_baseline,  # noqa: E402
                                    write_baseline)
from tools.detlint.cli import main as cli_main  # noqa: E402


def _violating_file(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("import time\n\n\ndef f():\n    return time.time()\n")
    return p


# ---------------------------------------------------------------------------
# baseline
# ---------------------------------------------------------------------------
def test_baseline_grandfathers_then_catches_new(tmp_path):
    p = _violating_file(tmp_path)
    rules = default_rules(ignore_scope=True)

    first = run_lint(paths=[p], root=tmp_path, rules=rules, passes=[])
    assert first.exit_code == 1 and len(first.new_findings) == 1

    bl = tmp_path / "baseline.json"
    write_baseline(bl, first.findings)
    counts = baseline_counts(load_baseline(bl))

    # same tree: the finding is baselined, gate passes
    second = run_lint(paths=[p], root=tmp_path, rules=rules, passes=[],
                      baseline_counts=counts)
    assert second.exit_code == 0
    assert [f.status for f in second.findings] == ["baselined"]

    # a NEW violation on top of the baselined one still fails
    p.write_text(p.read_text() + "\n\ndef g():\n    return time.monotonic()\n")
    third = run_lint(paths=[p], root=tmp_path, rules=rules, passes=[],
                     baseline_counts=counts)
    assert third.exit_code == 1
    assert len(third.new_findings) == 1
    assert "monotonic" in third.new_findings[0].message


def test_baseline_survives_line_shifts(tmp_path):
    """Fingerprints key on line text, not line numbers."""
    p = _violating_file(tmp_path)
    rules = default_rules(ignore_scope=True)
    first = run_lint(paths=[p], root=tmp_path, rules=rules, passes=[])
    bl = tmp_path / "baseline.json"
    write_baseline(bl, first.findings)
    # insert lines above the finding
    p.write_text("# a comment\n# another\n" + p.read_text())
    again = run_lint(paths=[p], root=tmp_path, rules=rules, passes=[],
                     baseline_counts=baseline_counts(load_baseline(bl)))
    assert again.exit_code == 0


def test_committed_baseline_is_empty():
    entries = load_baseline(REPO_ROOT / "tools" / "detlint" / "baseline.json")
    assert entries == []


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------
def test_cli_json_output_and_exit_codes(tmp_path, capsys):
    p = _violating_file(tmp_path)
    rc = cli_main([str(p), "--root", str(tmp_path), "--format", "json",
                   "--no-baseline", "--no-scope"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["tool"] == "detlint" and out["new"] == 1
    f = out["findings"][0]
    assert f["rule"] == "no-wallclock" and f["line"] == 5
    assert f["path"] == "x.py" and f["fingerprint"]


def test_cli_write_baseline_roundtrip(tmp_path, capsys):
    p = _violating_file(tmp_path)
    bl = tmp_path / "bl.json"
    rc = cli_main([str(p), "--root", str(tmp_path), "--baseline", str(bl),
                   "--write-baseline", "--no-scope"])
    assert rc == 0 and bl.is_file()
    rc = cli_main([str(p), "--root", str(tmp_path), "--baseline", str(bl),
                   "--no-scope"])
    capsys.readouterr()
    assert rc == 0


def test_cli_rules_filter(tmp_path, capsys):
    p = _violating_file(tmp_path)
    rc = cli_main([str(p), "--root", str(tmp_path), "--no-baseline",
                   "--no-scope", "--rules", "no-global-rng"])
    capsys.readouterr()
    assert rc == 0          # wallclock rule not selected


def test_cli_list_rules(capsys):
    rc = cli_main(["--list-rules"])
    out = capsys.readouterr().out
    assert rc == 0
    for rule_id in ("no-wallclock", "no-global-rng",
                    "no-unordered-float-accumulation", "jit-purity",
                    "dtype-discipline", "event-coverage",
                    "registry-coverage", "spec-roundtrip-fields"):
        assert rule_id in out


def test_module_entry_point_runs():
    """`python -m tools.detlint src/` is the CI gate invocation."""
    proc = subprocess.run(
        [sys.executable, "-m", "tools.detlint", "src/", "--format=json"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    doc = json.loads(proc.stdout)
    assert doc["new"] == 0


# ---------------------------------------------------------------------------
# self-lint: the repo's own source is clean
# ---------------------------------------------------------------------------
def test_self_lint_src_zero_non_baselined_findings():
    report = run_lint(
        paths=[REPO_ROOT / "src"],
        root=REPO_ROOT,
        rules=default_rules(),
        passes=default_passes(),
        baseline_counts=baseline_counts(
            load_baseline(REPO_ROOT / "tools" / "detlint" / "baseline.json")),
        tests_dir=REPO_ROOT / "tests",
    )
    assert report.new_findings == [], "\n".join(
        f.render() for f in report.new_findings)
    # the sweep ETA clock reads are justified inline suppressions
    suppressed = [f for f in report.findings if f.status == "suppressed"]
    assert all(f.justification for f in suppressed)
