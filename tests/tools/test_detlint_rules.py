"""Per-file detlint rules: paired good/bad fixtures with exact rule IDs,
line numbers, and suppression behavior."""
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[2]
FIXTURES = Path(__file__).resolve().parent / "fixtures"
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.detlint import default_passes, default_rules, run_lint  # noqa: E402


def lint(*names, rules=None, tests_dir=None):
    """Lint fixture files with scoping off (fixtures sit outside src/)."""
    report = run_lint(
        paths=[FIXTURES / n for n in names],
        root=REPO_ROOT,
        rules=rules if rules is not None else default_rules(ignore_scope=True),
        passes=[],
        tests_dir=tests_dir,
    )
    return report


def new_findings(report, rule=None):
    out = [f for f in report.findings if f.status == "new"]
    if rule is not None:
        out = [f for f in out if f.rule == rule]
    return out


# ---------------------------------------------------------------------------
# no-wallclock
# ---------------------------------------------------------------------------
def test_wallclock_bad_exact_lines():
    report = lint("wallclock_bad.py")
    found = new_findings(report, "no-wallclock")
    assert [(f.line, f.rule) for f in found] == [
        (8, "no-wallclock"), (9, "no-wallclock"), (10, "no-wallclock")]
    assert report.exit_code == 1
    assert "time.time" in found[0].message
    assert "time.perf_counter" in found[1].message     # alias resolved
    assert "datetime.datetime.now" in found[2].message


def test_wallclock_good_clean():
    report = lint("wallclock_good.py")
    assert new_findings(report) == []
    assert report.exit_code == 0


def test_wallclock_scoping_only_sim_paths(tmp_path):
    """Default scoping: obs/ and launch/ may read clocks, core/ may not."""
    code = "import time\n\ndef f():\n    return time.time()\n"
    for rel in ("src/repro/obs/clocky.py", "src/repro/launch/clocky.py",
                "src/repro/core/clocky.py"):
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(code)
    report = run_lint(paths=[tmp_path / "src"], root=tmp_path,
                      rules=default_rules(), passes=[])
    flagged = {f.path for f in new_findings(report, "no-wallclock")}
    assert flagged == {"src/repro/core/clocky.py"}


# ---------------------------------------------------------------------------
# no-global-rng
# ---------------------------------------------------------------------------
def test_rng_bad_exact_lines():
    found = new_findings(lint("rng_bad.py"), "no-global-rng")
    assert [f.line for f in found] == [9, 10, 11, 12]
    assert "random.random" in found[0].message
    assert "np.random.rand" in found[1].message
    assert "np.random.seed" in found[2].message


def test_rng_good_clean():
    assert new_findings(lint("rng_good.py")) == []


# ---------------------------------------------------------------------------
# no-unordered-float-accumulation
# ---------------------------------------------------------------------------
def test_unordered_bad_exact_lines():
    found = new_findings(lint("unordered_bad.py"),
                         "no-unordered-float-accumulation")
    assert [f.line for f in found] == [5, 6, 8]


def test_unordered_good_clean():
    assert new_findings(lint("unordered_good.py")) == []


# ---------------------------------------------------------------------------
# jit-purity
# ---------------------------------------------------------------------------
def test_jit_bad_exact_lines():
    found = new_findings(lint("jit_bad.py"), "jit-purity")
    assert [f.line for f in found] == [10, 11, 16, 28]
    assert "TRACE_LOG" in found[0].message
    assert "print" in found[1].message
    assert "_cache" in found[2].message
    assert "self" in found[3].message


def test_jit_good_clean():
    assert new_findings(lint("jit_good.py")) == []


# ---------------------------------------------------------------------------
# dtype-discipline
# ---------------------------------------------------------------------------
def test_dtype_bad_exact_lines():
    found = new_findings(lint("dtype_bad.py"), "dtype-discipline")
    assert [f.line for f in found] == [6, 7, 8]


def test_dtype_good_clean():
    assert new_findings(lint("dtype_good.py")) == []


def test_dtype_scoped_to_boundary_files(tmp_path):
    """Without --no-scope the rule only applies to the boundary modules."""
    p = tmp_path / "src" / "repro" / "api" / "free.py"
    p.parent.mkdir(parents=True)
    p.write_text("import numpy as np\nx = np.zeros(3)\n")
    report = run_lint(paths=[tmp_path / "src"], root=tmp_path,
                      rules=default_rules(), passes=[])
    assert new_findings(report, "dtype-discipline") == []


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------
def test_inline_suppressions_silence_with_justification():
    report = lint("suppressed.py")
    assert new_findings(report) == []
    sup = [f for f in report.findings if f.status == "suppressed"]
    assert {f.line for f in sup} == {6, 10}
    by_line = {f.line: f for f in sup}
    assert "progress display only" in by_line[6].justification
    assert by_line[10].rule == "no-wallclock"      # disable=all catches it


def test_unrelated_suppression_does_not_silence(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("import time\n"
                 "t = time.time()  # detlint: disable=no-global-rng\n")
    report = run_lint(paths=[p], root=tmp_path,
                      rules=default_rules(ignore_scope=True), passes=[])
    assert [f.rule for f in new_findings(report)] == ["no-wallclock"]


def test_disable_file_suppresses_everywhere(tmp_path):
    p = tmp_path / "x.py"
    p.write_text("# detlint: disable-file=no-wallclock\n"
                 "import time\n"
                 "a = time.time()\n"
                 "b = time.monotonic()\n")
    report = run_lint(paths=[p], root=tmp_path,
                      rules=default_rules(ignore_scope=True), passes=[])
    assert new_findings(report) == []
    assert len([f for f in report.findings if f.status == "suppressed"]) == 2


# ---------------------------------------------------------------------------
# parse errors fail closed
# ---------------------------------------------------------------------------
def test_syntax_error_is_a_finding(tmp_path):
    p = tmp_path / "broken.py"
    p.write_text("def f(:\n")
    report = run_lint(paths=[p], root=tmp_path,
                      rules=default_rules(), passes=default_passes())
    assert [f.rule for f in new_findings(report)] == ["parse-error"]
    assert report.exit_code == 1
