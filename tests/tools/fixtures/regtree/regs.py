"""Registry-coverage fixture: duplicate, untested, and loop registrations."""
from repro.api.registry import POLICY_REGISTRY, register_policy

LOOP_NAMES = ("loop-a", "loop-b")


@register_policy("fixture-dup")
def one():
    return 1


@register_policy("fixture-dup")          # line 12: duplicate registration
def two():
    return 2


@register_policy("fixture-untested")     # line 17: no test references it
def three():
    return 3


for _n in LOOP_NAMES:
    POLICY_REGISTRY.register(_n, object())


def register_dynamic(name):
    # helper plumbing: name is a parameter, not a registration site
    POLICY_REGISTRY.register(name, object())
