"""Good: every constructor pins its dtype."""
import numpy as np


def pack(n):
    prices = np.zeros(n, dtype=np.float64)
    caps = np.full(n, np.inf, dtype=np.float64)
    cols = np.asarray([1.0, 2.0], dtype=np.float64)
    like = np.zeros_like(prices)       # inherits dtype: fine
    return prices, caps, cols, like
