"""Bad: impure functions handed to jax tracing."""
import jax

TRACE_LOG = []
_cache = {}


@jax.jit
def leaky_step(x):
    TRACE_LOG.append(x)        # line 10: jit-purity (mutates closed-over list)
    print("stepping", x)       # line 11: jit-purity (I/O)
    return x * 2


def scan_body(carry, x):
    _cache[x] = carry          # line 16: jit-purity (writes closed-over dict)
    return carry + x, carry


def run(xs):
    return jax.lax.scan(scan_body, 0.0, xs)


class BadFamily:
    vectorized = True

    def step(self, state, util, shock):
        self.last_state = state    # line 28: jit-purity (writes through self)
        return state, state["p"]
