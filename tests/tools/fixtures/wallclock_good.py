"""Good: sim time comes from the event queue, not the wall."""


def advance(now: float, dt: float) -> float:
    return now + dt


def strftime_like(t: float) -> str:
    return f"{t:.3f}"
