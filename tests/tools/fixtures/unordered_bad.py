"""Bad: float accumulation over unordered set iteration."""


def total_cost(costs, extra):
    t = sum({round(c, 2) for c in costs})        # line 5: set comprehension
    u = sum(c * 2.0 for c in set(costs))         # line 6: genexp over set()
    acc = 0.0
    for c in set(costs) | set(extra):            # line 8: loop over set union
        acc += c
    return t + u + acc
