"""Good: pure traced functions — fresh state out, nothing mutated."""
import jax
import jax.numpy as jnp


@jax.jit
def pure_step(x):
    y = x * 2
    out = []                  # local list: fine
    out.append(y)             # mutating a local: fine
    return out[0]


def scan_body(carry, x):
    return carry + x, carry


def run(xs):
    return jax.lax.scan(scan_body, jnp.float64(0.0), xs)


class GoodFamily:
    vectorized = True

    def step(self, state, util, shock):
        nxt = {**state, "p": state["p"] * 0.5 + util}
        return nxt, nxt["p"]
