"""Bad: a *Spec dataclass whose round-trip drops a field."""
from dataclasses import dataclass


@dataclass(frozen=True)
class BrokenSpec:
    alpha: float = 0.5
    beta: float = 1.0          # line 8: spec-roundtrip-fields (missing below)

    def to_dict(self):
        return {"alpha": self.alpha}

    @classmethod
    def from_dict(cls, d):
        return cls(alpha=d["alpha"])
