"""Event-coverage fixture log vocabulary: one live kind, one orphan."""
import enum


class LogEventKind(str, enum.Enum):
    ALPHA = "alpha"
    ORPHAN = "orphan"   # line 7: declared but never emitted
