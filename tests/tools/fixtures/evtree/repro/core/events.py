"""Event-coverage fixture: one fully wired kind, two half-wired ones."""
import enum


class EventKind(enum.Enum):
    ALPHA = "alpha"
    BETA = "beta"     # line 7: no PRIORITY entry, no dispatch branch, no push
    GAMMA = "gamma"   # line 8: dispatched but never pushed


PRIORITY = {
    EventKind.ALPHA: 0,
    EventKind.GAMMA: 1,
}
