"""Event-coverage fixture simulator: handles ALPHA and GAMMA only."""
from .events import EventKind


class Sim:
    def _dispatch(self, ev):
        kind = ev.kind
        if kind is EventKind.ALPHA:
            self.queue.push(1.0, EventKind.ALPHA)
            self.events.emit(1.0, "alpha")
        elif kind is EventKind.GAMMA:
            self.events.emit(1.0, "mystery")   # line 12: undeclared log kind

    def _run_traced(self, ev):
        with self.tracer.span("dispatch/" + ev.kind.name):
            self._dispatch(ev)
