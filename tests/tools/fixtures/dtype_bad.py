"""Bad: dtype-less constructors at the packed-array boundary."""
import numpy as np


def pack(n):
    prices = np.zeros(n)               # line 6: dtype-discipline
    caps = np.full(n, np.inf)          # line 7: dtype-discipline
    cols = np.asarray([1.0, 2.0])      # line 8: dtype-discipline
    return prices, caps, cols
