"""Good: explicitly seeded Generators, threaded through."""
import random

import numpy as np


def draw(seed: int):
    rng = np.random.default_rng(seed)
    ss = np.random.SeedSequence(seed)
    local = random.Random(seed)
    return rng.standard_normal(3), ss.spawn(2), local.random()
