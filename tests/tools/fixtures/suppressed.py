"""Violations silenced by inline suppressions (justifications included)."""
import time


def stamp():
    return time.time()  # detlint: disable=no-wallclock — progress display only


def stamp_all():
    a = time.monotonic()  # detlint: disable=all — timing scratch
    return a
