"""Bad: global / legacy RNG entry points."""
import random

import numpy as np
from random import randint


def draw():
    a = random.random()        # line 9: no-global-rng
    b = np.random.rand(3)      # line 10: no-global-rng (legacy numpy)
    np.random.seed(0)          # line 11: no-global-rng (global seeding)
    c = randint(0, 10)         # line 12: no-global-rng (from-import)
    return a, b, c
