"""Bad: wall-clock reads on the sim path (every flagged line is exact)."""
import time
from datetime import datetime
from time import perf_counter as pc


def stamp():
    t0 = time.time()          # line 8: no-wallclock
    t1 = pc()                 # line 9: no-wallclock (aliased from-import)
    t2 = datetime.now()       # line 10: no-wallclock
    return t0, t1, t2
