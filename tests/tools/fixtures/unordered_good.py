"""Good: accumulation order pinned by sorting (or ordered sequences)."""


def total_cost(costs, extra):
    t = sum(sorted({round(c, 2) for c in costs}))
    u = sum(c * 2.0 for c in sorted(set(costs)))
    acc = 0.0
    for c in sorted(set(costs) | set(extra)):
        acc += c
    seen = {k: v for k, v in enumerate(costs)}   # dicts are insertion-ordered
    return t + u + acc + sum(seen.values())
