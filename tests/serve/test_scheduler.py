"""Unit tests for the per-VM SpotServingScheduler (PR 10 satellite):
add / fill_batch / step / interrupt / stats, including the
requeue-on-interrupt path the serving layer rides."""
import pytest

from repro.serve.scheduler import Request, SpotServingScheduler


def _req(i, tokens=10):
    return Request(id=i, prompt_len=8, target_tokens=tokens)


def test_add_queues_requests():
    s = SpotServingScheduler(batch_size=2)
    for i in range(3):
        s.add(_req(i))
    assert [r.id for r in s.queue] == [0, 1, 2]
    assert s.running == [] and s.done == []


def test_fill_batch_respects_batch_size():
    s = SpotServingScheduler(batch_size=2)
    for i in range(3):
        s.add(_req(i))
    s.fill_batch()
    assert [r.id for r in s.running] == [0, 1]
    assert [r.id for r in s.queue] == [2]
    assert all(r.state == "running" for r in s.running)


def test_step_advances_and_completes():
    s = SpotServingScheduler(batch_size=2)
    s.add(_req(0, tokens=3))
    s.add(_req(1, tokens=5))
    s.fill_batch()
    s.step(3)
    assert [r.id for r in s.done] == [0]
    assert [r.id for r in s.running] == [1]
    assert s.running[0].generated == 3
    s.step(2)
    assert [r.id for r in s.done] == [0, 1]
    assert s.running == []


def test_step_accepts_fractional_tokens():
    s = SpotServingScheduler(batch_size=1)
    s.add(_req(0, tokens=2))
    s.fill_batch()
    s.step(0.5)
    assert s.running[0].generated == pytest.approx(0.5)
    s.step(1.5)
    assert [r.id for r in s.done] == [0]


def test_completion_frees_slot_for_next_fill():
    s = SpotServingScheduler(batch_size=1)
    s.add(_req(0, tokens=1))
    s.add(_req(1, tokens=1))
    s.fill_batch()
    s.step(1)
    assert s.running == []      # step never refills on its own
    s.fill_batch()              # the serving loop refills each tick
    assert [r.id for r in s.running] == [1]
    s.step(1)
    assert [r.id for r in s.done] == [0, 1]


def test_interrupt_hibernate_keeps_progress():
    s = SpotServingScheduler(batch_size=2, hibernate=True)
    s.add(_req(0, tokens=10))
    s.fill_batch()
    s.step(4)
    s.interrupt()
    assert s.running == []
    assert [r.id for r in s.hibernated] == [0]
    assert s.hibernated[0].generated == 4
    assert s.hibernated[0].state == "hibernated"
    assert s.hibernated[0].interruptions == 1


def test_interrupt_requeue_resets_progress():
    s = SpotServingScheduler(batch_size=2, hibernate=False)
    s.add(_req(0, tokens=10))
    s.fill_batch()
    s.step(4)
    s.interrupt()
    assert s.running == [] and s.hibernated == []
    assert [r.id for r in s.queue] == [0]
    assert s.queue[0].generated == 0
    assert s.queue[0].state == "queued"
    assert s.queue[0].interruptions == 1


def test_resume_prefers_hibernated_over_queued():
    s = SpotServingScheduler(batch_size=1, hibernate=True)
    s.add(_req(0, tokens=10))
    s.fill_batch()
    s.step(4)
    s.interrupt()
    s.add(_req(1, tokens=10))
    s.fill_batch()
    # the hibernated request resumes before fresh queued work
    assert [r.id for r in s.running] == [0]
    assert s.running[0].generated == 4
    assert [r.id for r in s.queue] == [1]


def test_stats_counts_all_pools():
    s = SpotServingScheduler(batch_size=1, hibernate=True)
    for i in range(3):
        s.add(_req(i, tokens=2))
    s.fill_batch()
    s.step(1)       # 0 half done
    s.interrupt()   # 0 hibernated
    st = s.stats()
    assert st["queued"] == 2
    assert st["hibernated"] == 1
    assert st["running"] == 0
    assert st["done"] == 0
    assert st["interruptions"] == 1


def test_multiple_interruptions_accumulate():
    s = SpotServingScheduler(batch_size=1, hibernate=True)
    s.add(_req(0, tokens=100))
    for _ in range(3):
        s.fill_batch()
        s.step(1)
        s.interrupt()
    r = s.hibernated[0]
    assert r.interruptions == 3
    assert r.generated == 3     # progress survived every loss
