"""Demand-curve tests: shape, clamping, and pre-drawn determinism."""
import pytest

from repro.serve.demand import make_bursty, make_diurnal


def test_diurnal_peak_and_trough():
    rate = make_diurnal(base_rate=0.2, amplitude=0.1, period=86400.0)
    assert rate(0.0) == pytest.approx(0.2)
    assert rate(86400.0 / 4) == pytest.approx(0.3)       # peak
    assert rate(3 * 86400.0 / 4) == pytest.approx(0.1)   # trough


def test_diurnal_clamps_at_zero():
    rate = make_diurnal(base_rate=0.1, amplitude=0.5, period=3600.0)
    assert rate(3 * 3600.0 / 4) == 0.0


def test_diurnal_phase_shift():
    base = make_diurnal(base_rate=0.2, amplitude=0.1, period=3600.0)
    shifted = make_diurnal(base_rate=0.2, amplitude=0.1, period=3600.0,
                           phase=900.0)
    assert shifted(900.0) == pytest.approx(base(0.0))


@pytest.mark.parametrize("kwargs", [
    {"base_rate": -0.1}, {"amplitude": -1.0}, {"period": 0.0},
])
def test_diurnal_validation(kwargs):
    with pytest.raises(ValueError):
        make_diurnal(**kwargs)


def test_bursty_same_seed_is_bit_identical():
    a = make_bursty(horizon=36000.0, seed=7)
    b = make_bursty(horizon=36000.0, seed=7)
    ts = [i * 61.0 for i in range(500)]
    assert [a(t) for t in ts] == [b(t) for t in ts]


def test_bursty_seeds_differ():
    a = make_bursty(horizon=36000.0, seed=0)
    b = make_bursty(horizon=36000.0, seed=1)
    ts = [i * 61.0 for i in range(500)]
    assert [a(t) for t in ts] != [b(t) for t in ts]


def test_bursty_floor_is_base_rate():
    rate = make_bursty(base_rate=0.25, horizon=36000.0, seed=3)
    ts = [i * 17.0 for i in range(2000)]
    vals = [rate(t) for t in ts]
    assert min(vals) >= 0.25
    assert max(vals) > 0.25      # at least one spike is active somewhere


def test_bursty_evaluation_never_draws():
    """rate(t) is pure after construction: evaluation order is irrelevant."""
    rate = make_bursty(horizon=36000.0, seed=5)
    forward = [rate(t) for t in (0.0, 100.0, 200.0)]
    backward = [rate(t) for t in (200.0, 100.0, 0.0)]
    assert forward == backward[::-1]


@pytest.mark.parametrize("kwargs", [
    {"base_rate": -1.0}, {"spike_every": 0.0}, {"spike_alpha": 0.0},
    {"spike_duration": -5.0}, {"horizon": 0.0},
])
def test_bursty_validation(kwargs):
    with pytest.raises(ValueError):
        make_bursty(**kwargs)
