"""Autoscaler tests: registered policies, hysteresis/cooldown damping,
bound clamping, and config validation."""
import pytest

from repro.serve.autoscale import (
    AUTOSCALE_REGISTRY,
    Autoscaler,
    AutoscaleConfig,
    DemandSignals,
    make_autoscaler,
    validate_autoscale_config,
)


def _signals(t=0.0, rate=1.0, queue=0, p95=float("nan"), live=4, target=4,
             per_unit=0.5, ahead=0.0):
    return DemandSignals(t=t, rate_ewma=rate, queue_depth=queue,
                         p95_latency=p95, live_units=live,
                         target_units=target, unit_throughput=per_unit,
                         rate_ahead=ahead)


def test_registry_has_all_policies():
    for name in ("static", "target-tracking", "step",
                 "predictive-from-curve"):
        assert AUTOSCALE_REGISTRY.get(name) is not None


def test_unknown_policy_fails_fast():
    with pytest.raises(ValueError, match="unknown autoscale policy"):
        make_autoscaler("no-such-policy")


def test_static_holds_target():
    fn = AUTOSCALE_REGISTRY.get("static")
    assert fn(_signals(rate=99.0, target=4), AutoscaleConfig()) == 4


def test_target_tracking_scales_with_demand():
    fn = AUTOSCALE_REGISTRY.get("target-tracking")
    cfg = AutoscaleConfig(headroom=1.2)
    # 1.0 req/s * 1.2 headroom / 0.5 per unit = 2.4 -> ceil 3
    assert fn(_signals(rate=1.0, per_unit=0.5), cfg) == 3
    assert fn(_signals(rate=4.0, per_unit=0.5), cfg) == 10


def test_target_tracking_adds_queue_drain_surplus():
    fn = AUTOSCALE_REGISTRY.get("target-tracking")
    cfg = AutoscaleConfig(headroom=1.0, queue_drain=100.0)
    # steady 2 units + 100 queued / (0.5 * 100) = 2 extra
    assert fn(_signals(rate=1.0, queue=100, per_unit=0.5), cfg) == 4


def test_step_policy_thresholds():
    fn = AUTOSCALE_REGISTRY.get("step")
    cfg = AutoscaleConfig(step_units=2, queue_hi=4.0, queue_lo=0.5)
    up = _signals(queue=20, live=4, target=4)       # 5 per unit > hi
    hold = _signals(queue=8, live=4, target=4)      # 2 per unit, inside band
    down = _signals(queue=1, live=4, target=4)      # 0.25 per unit < lo
    assert fn(up, cfg) == 6
    assert fn(hold, cfg) == 4
    assert fn(down, cfg) == 2


def test_predictive_uses_curve_lookahead():
    fn = AUTOSCALE_REGISTRY.get("predictive-from-curve")
    cfg = AutoscaleConfig(headroom=1.0)
    # looks ahead: 3 req/s ahead beats 1 req/s now
    assert fn(_signals(rate=1.0, ahead=3.0, per_unit=0.5), cfg) == 6
    # but never provisions below measured demand
    assert fn(_signals(rate=3.0, ahead=1.0, per_unit=0.5), cfg) == 6


def test_decide_clamps_to_bounds():
    a = Autoscaler("target-tracking",
                   AutoscaleConfig(min_units=2, max_units=6, cooldown=0.0,
                                   hysteresis=0.0))
    assert a.decide(_signals(rate=100.0, target=4)) == 6
    assert a.decide(_signals(t=1e6, rate=0.0, target=4)) == 2


def test_decide_returns_none_on_no_change():
    a = Autoscaler("static", AutoscaleConfig(cooldown=0.0))
    assert a.decide(_signals(target=4)) is None


def test_hysteresis_suppresses_small_moves():
    cfg = AutoscaleConfig(hysteresis=0.25, cooldown=0.0, headroom=1.0,
                          max_units=100)
    a = Autoscaler("target-tracking", cfg)
    # desired 11 vs current 10: 10% move < 25% hysteresis -> suppressed
    assert a.decide(_signals(rate=5.5, per_unit=0.5, target=10)) is None
    # desired 16 vs current 10: 60% move clears the band
    assert a.decide(_signals(rate=8.0, per_unit=0.5, target=10)) == 16


def test_cooldown_rate_limits_changes():
    cfg = AutoscaleConfig(hysteresis=0.0, cooldown=600.0, headroom=1.0,
                          max_units=100)
    a = Autoscaler("target-tracking", cfg)
    assert a.decide(_signals(t=0.0, rate=5.0, per_unit=0.5, target=4)) == 10
    # 300 s later: inside the cooldown, even a big move is deferred
    assert a.decide(_signals(t=300.0, rate=20.0, per_unit=0.5,
                             target=10)) is None
    # 700 s later: cooldown expired, the move applies
    assert a.decide(_signals(t=700.0, rate=20.0, per_unit=0.5,
                             target=10)) == 40


def test_config_validation():
    validate_autoscale_config(AutoscaleConfig())
    for bad in (
            {"cadence": 0.0}, {"min_units": -1},
            {"max_units": 0, "min_units": 4}, {"hysteresis": 1.0},
            {"cooldown": -1.0}, {"headroom": 0.0}, {"ewma_alpha": 0.0},
            {"latency_window": 0.0}, {"queue_drain": 0.0}, {"lead": -1.0},
            {"step_units": 0}, {"queue_hi": 0.2, "queue_lo": 0.5}):
        with pytest.raises(ValueError):
            validate_autoscale_config(AutoscaleConfig(**bad))
