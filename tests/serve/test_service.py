"""End-to-end serving-scenario tests: the demand → queue → capacity closed
loop over the spec/build stack, requeue-on-interrupt through the simulator
lifecycle, determinism, and spec validation."""
import pytest

from repro.api import (
    AutoscaleSpec,
    ExperimentSpec,
    FaultSpec,
    FleetSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    ServeSpec,
    build,
    run_one,
)


def _serve_spec(workload="serve-diurnal", autoscale=None, horizon=7200.0,
                fleet_capacity=8.0, serve_params=None, **wl):
    return RunSpec(
        scenario=ScenarioSpec(workload=workload, regime="volatile",
                              n_pools=4, horizon=horizon,
                              workload_params=wl),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": fleet_capacity}),
        serve=ServeSpec(params=serve_params or {}),
        autoscale=autoscale)


def test_serve_run_serves_requests():
    row = run_one(_serve_spec(base_rate=0.3, amplitude=0.1), seed=0)
    assert row["requests_arrived"] > 0
    assert row["requests_done"] > 0
    assert row["requests_done"] <= row["requests_arrived"]
    assert row["p95_latency_s"] >= row["p50_latency_s"] > 0
    assert 0.0 <= row["slo_attainment"] <= 1.0
    assert row["cost_per_request"] >= 0.0


def test_serve_bursty_workload_runs():
    row = run_one(_serve_spec(workload="serve-bursty", spike_every=900.0),
                  seed=1)
    assert row["requests_arrived"] > 0


def test_serve_run_is_deterministic():
    spec = _serve_spec(
        workload="serve-bursty",
        autoscale=AutoscaleSpec("target-tracking",
                                params={"cadence": 600.0, "max_units": 16}))
    assert run_one(spec, seed=5) == run_one(spec, seed=5)


def test_autoscaler_changes_capacity():
    spec = _serve_spec(
        base_rate=0.6, amplitude=0.4, period=3600.0, fleet_capacity=4.0,
        autoscale=AutoscaleSpec("target-tracking",
                                params={"cadence": 300.0, "cooldown": 300.0,
                                        "max_units": 24}))
    sim = build(spec, seed=0)
    metrics = sim.run(until=7200.0)
    acted = [d for d in metrics.autoscale_decisions if d[1] != d[2]]
    assert acted, "target-tracking never moved the fleet target"
    # the fleet actually retargeted (the override path is live)
    assert sim.fleet._units_override is not None
    assert sim.fleet.target_units == acted[-1][2]


def test_static_baseline_never_moves():
    spec = _serve_spec(
        base_rate=0.6, amplitude=0.4,
        autoscale=AutoscaleSpec("static", params={"cadence": 300.0}))
    sim = build(spec, seed=0)
    metrics = sim.run(until=7200.0)
    assert all(old == new for (_, old, new) in metrics.autoscale_decisions)


def _faulted_spec(hibernate=True):
    """Matched capacity + a pool-outage storm: serving VMs reliably die
    while the backlog stays shallow enough that requeued requests finish
    again before the horizon."""
    return RunSpec(
        scenario=ScenarioSpec(workload="serve-diurnal", regime="volatile",
                              n_pools=4, horizon=14400.0,
                              workload_params={"base_rate": 0.2,
                                               "amplitude": 0.05}),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": 24.0}),
        faults=FaultSpec("storm"),
        serve=ServeSpec(params={"hibernate_requests": hibernate}))


def test_interrupted_vm_requeues_requests():
    sim = build(_faulted_spec(), seed=0)
    metrics = sim.run(until=14400.0)
    assert metrics.requests_requeued > 0
    # nothing vanished: every arrival is either served or still tracked
    outstanding = metrics.requests_arrived - metrics.requests_done
    assert outstanding >= 0
    assert sim.serve.queue_depth() + sum(
        len(s.running) for s in sim.serve._scheds.values()) == outstanding


def test_hibernate_keeps_progress_terminate_restarts():
    sims = {}
    for hib in (True, False):
        sim = build(_faulted_spec(hibernate=hib), seed=0)
        m = sim.run(until=14400.0)
        sims[hib] = m
        assert m.requests_requeued > 0
    # the same interrupts hit both runs; restart-from-scratch pays more
    # total latency than checkpointed resumption
    assert (sum(sims[False].request_latencies)
            > sum(sims[True].request_latencies))


def test_serve_spec_requires_demand_workload():
    with pytest.raises(ValueError, match="demand-providing workload"):
        RunSpec(scenario=ScenarioSpec(workload="market", regime="volatile"),
                policy=PolicySpec("first-fit"),
                fleet=FleetSpec(), serve=ServeSpec())


def test_demand_workload_requires_serve_spec():
    with pytest.raises(ValueError, match="add a serve spec"):
        RunSpec(scenario=ScenarioSpec(workload="serve-diurnal",
                                      regime="volatile"),
                policy=PolicySpec("first-fit"))


def test_autoscale_requires_serve_and_fleet():
    with pytest.raises(ValueError, match="needs a serve spec"):
        RunSpec(scenario=ScenarioSpec(workload="market", regime="volatile"),
                policy=PolicySpec("first-fit"), fleet=FleetSpec(),
                autoscale=AutoscaleSpec())
    with pytest.raises(ValueError, match="needs a fleet spec"):
        RunSpec(scenario=ScenarioSpec(workload="serve-diurnal",
                                      regime="volatile"),
                policy=PolicySpec("first-fit"), serve=ServeSpec(),
                autoscale=AutoscaleSpec())


def test_serve_spec_rejects_unknown_params():
    with pytest.raises(ValueError, match="unknown serve parameter"):
        ServeSpec(params={"nope": 1})
    with pytest.raises(ValueError, match="unknown autoscale policy"):
        AutoscaleSpec(policy="target-tracking", params={"nope": 1})


def test_run_spec_roundtrip_with_serve():
    spec = _serve_spec(
        autoscale=AutoscaleSpec("step", params={"step_units": 3}),
        serve_params={"tick": 120.0, "slots_per_vm": 8})
    d = spec.to_dict()
    assert RunSpec.from_dict(d).to_dict() == d
    assert d["serve"]["params"]["slots_per_vm"] == 8
    assert d["autoscale"]["policy"] == "step"


def test_experiment_autoscale_axis():
    exp = ExperimentSpec(
        scenario=ScenarioSpec(workload="serve-diurnal", regime="volatile",
                              horizon=3600.0),
        policies=(PolicySpec("first-fit"),), seeds=(0,),
        fleets=(FleetSpec(params={"target_capacity": 8.0}),),
        serve=ServeSpec(),
        autoscales=(None, AutoscaleSpec("static"),
                    AutoscaleSpec("target-tracking")))
    cells = exp.cells()
    assert len(cells) == 3
    assert cells[0].autoscale is None
    assert cells[1].autoscale.policy == "static"
    d = exp.to_dict()
    assert ExperimentSpec.from_dict(d).to_dict() == d


def test_serve_events_and_trace_record():
    spec = _serve_spec(base_rate=0.4, amplitude=0.2).replace(
        obs={"events": True, "trace": True})
    sim = build(spec, seed=0)
    sim.run(until=7200.0)
    kinds = set(sim.events.to_arrays()["kinds"])
    assert {"request-arrive", "request-done", "serve-sample"} <= kinds
    spans = {s[1] for s in sim.obs.spans}
    assert "tick/serve" in spans
