"""SLO/cost metric tests: percentiles, attainment, windowed error-budget
burn, and the cost-effectiveness helpers."""
import pytest

from repro.serve.slo import (
    cost_forecast,
    cost_per_request,
    error_budget_burn,
    latency_percentiles,
    slo_attainment,
)


def test_percentiles_empty_is_zero():
    assert latency_percentiles([]) == {"p50": 0.0, "p95": 0.0, "p99": 0.0}


def test_percentiles_values():
    lat = list(range(1, 101))     # 1..100
    pct = latency_percentiles(lat)
    assert pct["p50"] == pytest.approx(50.5)
    assert pct["p95"] == pytest.approx(95.05)
    assert pct["p99"] == pytest.approx(99.01)


def test_attainment():
    assert slo_attainment([], 1.0) == 1.0
    assert slo_attainment([0.5, 1.0, 2.0, 3.0], 1.0) == 0.5
    assert slo_attainment([0.1, 0.2], 1.0) == 1.0


def test_burn_rate_scales_with_budget():
    # 10% violations under a 95% objective = burn 2.0 (double budget)
    done = [float(i) for i in range(100)]
    lat = [2.0 if i < 10 else 0.5 for i in range(100)]
    burn = error_budget_burn(done, lat, threshold=1.0, objective=0.95,
                             window=1000.0, horizon=100.0)
    assert burn["burn_rate"] == pytest.approx(2.0)


def test_burn_empty_is_zero():
    burn = error_budget_burn([], [], 1.0, 0.95, 100.0, 1000.0)
    assert burn == {"burn_rate": 0.0, "max_window_burn": 0.0}


def test_max_window_burn_localizes_violations():
    # all violations inside the first 100 s window: that window burns at
    # 20.0 (100% violation / 5% budget) while the overall burn is diluted
    done = [float(i) for i in range(200)]
    lat = [2.0 if i < 100 else 0.5 for i in range(200)]
    burn = error_budget_burn(done, lat, threshold=1.0, objective=0.95,
                             window=100.0, horizon=200.0)
    assert burn["max_window_burn"] == pytest.approx(20.0)
    assert burn["burn_rate"] == pytest.approx(10.0)
    assert burn["max_window_burn"] > burn["burn_rate"]


def test_cost_per_request():
    assert cost_per_request(10.0, 100) == pytest.approx(0.1)
    assert cost_per_request(10.0, 0) == 0.0


def test_cost_forecast_linear():
    assert cost_forecast(5.0, 3600.0, 7200.0) == pytest.approx(10.0)
    assert cost_forecast(5.0, 0.0, 7200.0) == 0.0
