"""Self-contained HTML reports: zero external dependencies, inline SVG
charts, manifest header — for one recorded run and for a sweep report."""
import json

from repro.api import (
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.obs import (
    EventLog,
    render_report,
    render_sweep_report,
    report_summary_json,
    write_html_report,
)


def _run_log(seed=5, until=3600.0):
    sim = build(RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"),
        obs=ObsSpec(events=True)), seed)
    sim.run(until=until)
    return sim.events


def test_render_run_report_is_self_contained():
    html = render_report(_run_log(), manifest={"seed": 5,
                                               "spec_sha256": "abc123"})
    assert html.lower().startswith("<!doctype html>")
    assert "<svg" in html and "</svg>" in html
    # no external fetches: self-contained means offline-viewable (the SVG
    # xmlns URI is a namespace identifier, not a fetch)
    assert "<script" not in html and "<link" not in html
    assert "<img" not in html and "@import" not in html
    # manifest header present
    assert "abc123" in html
    # the headline sections
    assert "price" in html.lower()


def test_render_report_empty_log():
    html = render_report(EventLog(), title="Empty run")
    assert html.lower().startswith("<!doctype html>")
    assert "Empty run" in html


def test_write_html_report_run_and_path(tmp_path):
    log = _run_log()
    path = str(tmp_path / "run.html")
    out = write_html_report(log, path, manifest={"seed": 5})
    assert out == path
    text = open(path).read()
    assert "<svg" in text


def test_write_html_report_sweep_dict(tmp_path):
    report = {
        "name": "mini_sweep",
        "cells": [
            {"regime": "volatile", "policy": "hlem-vmp-adjusted",
             "migration": "none",
             "metrics": {"interruptions": {"mean": 120.0, "ci95": 8.0},
                         "realized_spot_cost": {"mean": 42.5,
                                                "ci95": 1.25}}},
            {"regime": "calm", "policy": "hlem-vmp-adjusted",
             "migration": "none",
             "metrics": {"interruptions": {"mean": 30.0, "ci95": 2.0},
                         "realized_spot_cost": {"mean": 21.0,
                                                "ci95": 0.5}}},
        ],
    }
    html = render_sweep_report(report)
    assert "<svg" in html and "volatile" in html and "calm" in html
    assert "120" in html
    path = str(tmp_path / "sweep.html")
    write_html_report(report, path)
    assert "<svg" in open(path).read()


def test_report_summary_json():
    doc = json.loads(report_summary_json(_run_log()))
    assert doc["events"] > 0
    assert "storms" in doc


def test_report_has_no_serve_section_without_serve_events():
    assert "Serving:" not in render_report(_run_log())


def test_report_renders_serve_section_for_serve_runs():
    from repro.api import AutoscaleSpec, FleetSpec, ServeSpec
    sim = build(RunSpec(
        scenario=ScenarioSpec(workload="serve-diurnal", regime="volatile",
                              n_pools=4, horizon=3600.0,
                              workload_params={"base_rate": 0.4}),
        policy=PolicySpec("first-fit"),
        fleet=FleetSpec(params={"target_capacity": 8.0}),
        serve=ServeSpec(),
        autoscale=AutoscaleSpec("target-tracking",
                                params={"cadence": 600.0, "max_units": 12}),
        obs=ObsSpec(events=True)), 0)
    sim.run(until=3600.0)
    html = render_report(sim.events, title="serve run")
    for section in ("arrival rate", "queue depth", "p95 latency",
                    "autoscaler target vs live"):
        assert section in html
    assert html.count("<svg") >= 6
