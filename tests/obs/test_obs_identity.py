"""Tracing is observation-only: at fixed (spec, seed) the metrics row must
be byte-identical whether the run carries no tracer, a constructed-but-off
ObsSpec, or a fully enabled tracer (spans + profile + counters).  This is
the PR-7 overhead contract's correctness half — the perf half lives in
``benchmarks/trace_scale.py`` (``obs/tracing_overhead``)."""
import json

import pytest

from repro.api import (
    FaultSpec,
    FleetSpec,
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
    run_one,
)

OBS_ON = ObsSpec(trace=True, profile=True, counters_every=600.0)


def _rows(spec_kwargs, seed, until):
    """The run's metrics JSON under: no obs / obs-off / obs-on."""
    out = []
    for obs in (None, ObsSpec(), OBS_ON):
        row = run_one(RunSpec(**spec_kwargs, obs=obs), seed, until=until)
        out.append(json.dumps(row, sort_keys=True))
    return out


def _market_kwargs(**overrides):
    kw = dict(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"))
    kw.update(overrides)
    return kw


def test_synthetic_identity():
    plain, off, on = _rows(
        dict(scenario=ScenarioSpec(workload="synthetic"),
             policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5})),
        seed=3, until=1500.0)
    assert plain == off == on


def test_market_migration_identity():
    plain, off, on = _rows(_market_kwargs(), seed=5, until=3600.0)
    assert plain == off == on


def test_fleet_faults_identity():
    plain, off, on = _rows(
        _market_kwargs(
            migration=MigrationSpec("none"),
            fleet=FleetSpec(strategy="diversified",
                            params={"target_capacity": 48.0}),
            faults=FaultSpec(scenario="storm")),
        seed=7, until=3600.0)
    assert plain == off == on


def test_off_spec_builds_plain_untraced_loop():
    # ObsSpec with everything off must not even construct a tracer: the
    # simulator gets NULL_TRACER and run() takes the plain loop
    sim = build(RunSpec(**_market_kwargs(), obs=ObsSpec()), 0)
    assert sim.obs.enabled is False
    sim_on = build(RunSpec(**_market_kwargs(), obs=OBS_ON), 0)
    assert sim_on.obs.enabled is True
    # one tracer instance shared by every subsystem
    assert sim_on.policy.tracer is sim_on.obs
    assert sim_on.engine.tracer is sim_on.obs
    assert sim_on.migration.tracer is sim_on.obs


def test_traced_runs_are_deterministic():
    # same spec + seed => identical deterministic view (sim-time ordering,
    # span names, counter values); wall-clock fields are excluded by design
    views = []
    for _ in range(2):
        sim = build(RunSpec(**_market_kwargs(), obs=OBS_ON), 11)
        sim.run(until=3600.0)
        views.append(json.dumps(sim.obs.deterministic_view(),
                                sort_keys=True, default=list))
    assert views[0] == views[1]
