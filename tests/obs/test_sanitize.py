"""Runtime determinism sanitizer (the dynamic twin of tools/detlint) and
the LogEventKind-derived validation vocabulary."""
import random
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.api import MigrationSpec, PolicySpec, RunSpec, ScenarioSpec
from repro.api.build import build, collect_row, run_one
from repro.obs import EVENT_KINDS, LogEventKind, SanitizerViolation, sanitized
from repro.obs import eventlog as eventlog_mod
from repro.obs import EventLog, validate_event_log

REPO_ROOT = Path(__file__).resolve().parents[2]


def _market_spec():
    return RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("first-fit"),
        migration=MigrationSpec("none"))


# ---------------------------------------------------------------------------
# sanitized() scope mechanics
# ---------------------------------------------------------------------------
def test_sanitized_blocks_wallclock_and_global_rng():
    with sanitized():
        with pytest.raises(SanitizerViolation, match="time.time"):
            time.time()
        with pytest.raises(SanitizerViolation, match="perf_counter"):
            time.perf_counter()
        with pytest.raises(SanitizerViolation, match="random.random"):
            random.random()
        with pytest.raises(SanitizerViolation, match="np.random.rand"):
            np.random.rand(2)
        with pytest.raises(SanitizerViolation, match="np.random.seed"):
            np.random.seed(0)


def test_sanitized_allows_seeded_generators():
    with sanitized():
        rng = np.random.default_rng(7)
        assert rng.standard_normal(3).shape == (3,)
        local = random.Random(7)
        assert 0.0 <= local.random() < 1.0


def test_sanitized_restores_on_exit_and_on_error():
    t_before = time.time
    with sanitized():
        assert time.time is not t_before
    assert time.time is t_before and isinstance(time.time(), float)
    with pytest.raises(RuntimeError, match="boom"):
        with sanitized():
            raise RuntimeError("boom")
    assert time.time is t_before
    assert isinstance(random.random(), float)
    assert np.random.rand(1).shape == (1,)


# ---------------------------------------------------------------------------
# the sim path really is clock/RNG free — and sanitizing changes nothing
# ---------------------------------------------------------------------------
def test_fixed_seed_market_run_survives_sanitizer():
    spec = _market_spec()
    plain = run_one(spec, seed=3, until=3600.0)
    sim = build(spec, 3)
    with sanitized():
        metrics = sim.run(until=3600.0)
    assert collect_row(sim, metrics, spec, 3) == plain


def test_cli_sanitize_flag_smoke():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.market_sim", "--market",
         "--regimes", "volatile", "--policy", "first-fit",
         "--until", "1800", "--sanitize"],
        cwd=REPO_ROOT, capture_output=True, text=True, timeout=300,
        env={**__import__("os").environ, "PYTHONPATH": "src"})
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "sanitized run ok" in proc.stdout


def test_sanitizer_catches_a_violation_in_sim_scope():
    """A deliberately planted clock read inside the sim scope raises."""
    sim = build(_market_spec(), 0)
    original = sim.run

    def tainted_run(until=None):
        time.time()                    # the planted violation
        return original(until=until)

    sim.run = tainted_run
    with pytest.raises(SanitizerViolation):
        with sanitized():
            sim.run(until=600.0)


# ---------------------------------------------------------------------------
# LogEventKind-derived validation (the runtime twin of event-coverage)
# ---------------------------------------------------------------------------
def test_event_kinds_tuple_is_derived_from_enum():
    assert EVENT_KINDS == tuple(k.value for k in LogEventKind)
    assert len(LogEventKind) == 25


def test_validation_fails_closed_on_dummy_kind(monkeypatch):
    """Validation keys on the enum itself: smuggling a dummy kind into the
    legacy EVENT_KINDS tuple does NOT make it validate."""
    monkeypatch.setattr(eventlog_mod, "EVENT_KINDS",
                        eventlog_mod.EVENT_KINDS + ("dummy-kind",))
    log = EventLog()
    log.emit(1.0, "dummy-kind", vm=1)
    problems = validate_event_log(log)
    assert any("unknown event kind 'dummy-kind'" in p for p in problems)
