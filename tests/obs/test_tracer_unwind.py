"""Tracer exception paths (ISSUE 8 satellite): when a handler raises
mid-span, the span stack must unwind to well-nested closure — the aborted
spans end normally (durations exact, child time still accumulated into
parents) with ``aborted`` marker args — and the truncated trace must still
export as schema-valid Chrome JSON."""
import pytest

from repro.api import (
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.obs import (
    NULL_TRACER,
    Tracer,
    chrome_trace,
    validate_chrome_trace,
)


def test_unwind_closes_all_open_spans():
    tr = Tracer(keep_records=True)
    tr.begin("a", "outer")
    tr.begin("a", "inner")
    tr.begin("b", "leaf")
    n = tr.unwind(42.0)
    assert n == 3
    assert tr._stack == []
    assert len(tr.spans) == 3
    # innermost closes first; every aborted span carries the marker args
    assert [s[1] for s in tr.spans] == ["leaf", "inner", "outer"]
    assert all(s[6] == {"aborted": True} for s in tr.spans)
    assert all(s[4] == 42.0 for s in tr.spans)
    # nesting stayed consistent: each parent's self time excludes children
    for _cat, _name, _t0, dur, _sim, self_dur, _args in tr.spans:
        assert 0.0 <= self_dur <= dur + 1e-12
    # idempotent on an empty stack
    assert tr.unwind(43.0) == 0


def test_unwind_custom_args_and_profile():
    tr = Tracer(keep_records=False, profile=True)
    tr.begin("x", "s")
    tr.unwind(1.0, args={"cause": "test"})
    assert tr._stack == []
    assert tr.profile()[("x", "s")][0] == 1


def test_null_tracer_unwind_noop():
    assert NULL_TRACER.unwind(0.0) == 0


def test_exception_mid_run_leaves_wellnested_trace():
    """A handler raising inside the traced event loop: the exception
    propagates, every open span is closed, and the truncated trace is
    schema-valid Chrome JSON."""
    sim = build(RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"),
        obs=ObsSpec(trace=True, profile=True)), 0)

    class Boom(RuntimeError):
        pass

    ticks = {"n": 0}
    orig_tick = sim.engine.tick

    def exploding_tick(*args, **kwargs):
        ticks["n"] += 1
        if ticks["n"] >= 5:
            raise Boom("injected mid-span failure")
        return orig_tick(*args, **kwargs)

    sim.engine.tick = exploding_tick
    with pytest.raises(Boom):
        sim.run(until=7200.0)
    # the stack unwound: nothing left open, spans recorded
    assert sim.obs._stack == []
    assert len(sim.obs.spans) > 0
    # at least one span carries the aborted marker (the dispatch frame
    # that was open when the handler blew up)
    assert any(s[6] == {"aborted": True} for s in sim.obs.spans)
    # truncated trace still exports schema-valid
    doc = chrome_trace(sim.obs)
    assert validate_chrome_trace(doc) == []
