"""Run manifests, ObsSpec validation/round-trip, and sweep manifest opt-in
(the default report stays manifest-free so byte-determinism holds)."""
import json

import pytest

from repro.api import (
    ExperimentSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    run_experiment,
)
from repro.obs import run_manifest, spec_hash

UNTIL = 600.0


# -- ObsSpec ------------------------------------------------------------------
def test_obs_spec_roundtrip():
    spec = ObsSpec(trace=True, profile=True, counters_every=300.0)
    assert ObsSpec.from_dict(spec.to_dict()) == spec
    run = RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                  policy=PolicySpec("first-fit"), obs=spec)
    again = RunSpec.from_dict(json.loads(run.to_json()))
    assert again.obs == spec and again == run
    # absent obs survives the round-trip as absent
    bare = RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                   policy=PolicySpec("first-fit"))
    assert RunSpec.from_dict(bare.to_dict()).obs is None


def test_obs_spec_enabled_and_validation():
    assert not ObsSpec().enabled
    assert ObsSpec(trace=True).enabled
    assert ObsSpec(profile=True).enabled
    assert ObsSpec(counters_every=60.0).enabled
    with pytest.raises(ValueError):
        ObsSpec(counters_every=0.0)
    with pytest.raises(ValueError):
        ObsSpec(counters_every=-1.0)
    with pytest.raises(ValueError):
        ObsSpec(counters_every="often")
    # mapping coercion, as for every other sub-spec
    run = RunSpec.from_dict({
        "scenario": {"workload": "synthetic"},
        "policy": {"name": "first-fit"},
        "obs": {"trace": True}})
    assert isinstance(run.obs, ObsSpec) and run.obs.trace


# -- manifest block -----------------------------------------------------------
def test_run_manifest_fields():
    m = run_manifest(spec_dict={"a": 1}, seed=7, duration_s=1.23456789,
                     extra={"resumed_cells": 2})
    assert m["manifest_version"] == 1
    assert m["seed"] == 7
    assert m["spec"] == {"a": 1}
    assert m["spec_hash"] == spec_hash({"a": 1})
    assert m["duration_s"] == 1.234568
    assert m["resumed_cells"] == 2
    assert m["versions"]["python"]
    # the repo is a git checkout, so the SHA resolves here
    assert m["git_sha"] is None or len(m["git_sha"]) == 40


def test_spec_hash_canonical():
    # key order must not matter; content must
    assert spec_hash({"a": 1, "b": 2}) == spec_hash({"b": 2, "a": 1})
    assert spec_hash({"a": 1}) != spec_hash({"a": 2})
    assert spec_hash(None) is None
    assert len(spec_hash({})) == 16


# -- sweep integration --------------------------------------------------------
def _mini():
    return ExperimentSpec(
        name="obs-mini",
        scenario=ScenarioSpec(workload="synthetic", horizon=UNTIL),
        policies=(PolicySpec("first-fit"),),
        seeds=(0, 1))


def test_sweep_manifest_opt_in():
    plain = run_experiment(_mini(), processes=0)
    assert "manifest" not in plain          # default stays byte-deterministic
    with_m = run_experiment(_mini(), processes=0, manifest=True)
    man = with_m["manifest"]
    assert man["spec"] == _mini().to_dict()
    assert man["spec_hash"] == spec_hash(_mini().to_dict())
    assert man["seed"] == [0, 1]
    assert man["duration_s"] > 0
    # the manifest is additive: cells are unchanged
    assert with_m["cells"] == plain["cells"]


def test_sweep_manifest_excluded_from_resume_matching(tmp_path):
    path = str(tmp_path / "rep.json")
    first = run_experiment(_mini(), processes=0, report_path=path,
                           manifest=True)
    # a resumed run must accept the manifest-bearing checkpoint and reuse
    # every cell (manifest compares by experiment + horizon only)
    second = run_experiment(_mini(), processes=0, report_path=path,
                            manifest=True)
    assert second["cells"] == first["cells"]
    assert second["manifest"]["resumed_cells"] == len(first["cells"])
