"""Event flight recorder: columnar log, round-trips, validation, and the
observation-only invariant — at fixed (spec, seed) the metrics row must be
byte-identical whether the run records the event log or not, and a log-off
run must still take the plain untraced loop (the PR 7 overhead contract
extended to ISSUE 8's recorder)."""
import json

import numpy as np
import pytest

from repro.api import (
    FaultSpec,
    FleetSpec,
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
    run_one,
)
from repro.obs import (
    EVENT_KINDS,
    NULL_RECORDER,
    EventLog,
    first_divergence,
    load_event_log,
    read_manifest,
    validate_event_log,
)

EVENTS_ON = ObsSpec(events=True)


def _market_kwargs(**overrides):
    kw = dict(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"))
    kw.update(overrides)
    return kw


# ---------------------------------------------------------------------------
# the log itself
# ---------------------------------------------------------------------------
def test_emit_and_columns():
    log = EventLog()
    log.emit(0.0, "submit", vm=1, a=0.5, aux="spot")
    log.emit(1.0, "start", vm=1, pool=2, host=7, a=0.5)
    log.emit(2.0, "interrupt", vm=1, pool=2, host=7, aux="price")
    assert len(log) == 3
    arr = log.to_arrays()
    assert arr["t"].tolist() == [0.0, 1.0, 2.0]
    assert [str(arr["kinds"][k]) for k in arr["kind"]] == [
        "submit", "start", "interrupt"]
    assert arr["vm"].tolist() == [1, 1, 1]
    assert arr["pool"].tolist() == [-1, 2, 2]
    # aux interning: "spot" and "price" present, None rows are -1
    assert log.aux_id("spot") >= 0 and log.aux_id("price") >= 0
    assert arr["aux"][1] == -1
    assert log.kind_id("never-emitted") == -1
    assert log.aux_id("never-emitted") == -1


def test_window_drops_out_of_range_events():
    log = EventLog(t_min=10.0, t_max=20.0)
    log.emit(5.0, "start", vm=1)
    log.emit(10.0, "start", vm=2)
    log.emit(19.9, "start", vm=3)
    log.emit(20.0, "start", vm=4)    # t_max is exclusive
    assert [r[2] for r in log.records()] == [2, 3]


@pytest.mark.parametrize("ext", ["ndjson", "npz"])
def test_round_trip(tmp_path, ext):
    log = EventLog()
    log.emit(0.0, "submit", vm=3, a=0.123456789012345, aux="spot")
    log.emit(0.5, "price-tick", pool=1, a=1.0 / 3.0)
    log.emit(1.5, "wave", pool=1, a=0.9, b=4.0)
    path = str(tmp_path / f"log.{ext}")
    log.save(path, manifest={"seed": 42})
    back = load_event_log(path)
    # bit-identity through the round-trip: exact tuple equality
    assert first_divergence(log, back) is None
    assert read_manifest(path) == {"seed": 42}
    assert validate_event_log(path) == []


def test_validate_catches_problems(tmp_path):
    log = EventLog()
    log.emit(5.0, "start", vm=1)
    log.emit(3.0, "no-such-kind", vm=2)      # time backwards + bad kind
    log.emit(4.0, "wave", a=float("inf"))    # non-finite payload
    problems = validate_event_log(log)
    assert any("unknown event kind" in p for p in problems)
    assert any("time goes backwards" in p for p in problems)
    assert any("not finite" in p for p in problems)
    # a real run's log is clean
    sim = build(RunSpec(**_market_kwargs(), obs=EVENTS_ON), 0)
    sim.run(until=1800.0)
    assert validate_event_log(sim.events) == []
    # and every recorded kind is in the public vocabulary
    assert set(sim.events.to_arrays()["kinds"]) <= set(EVENT_KINDS)


def test_null_recorder_is_inert():
    assert NULL_RECORDER.enabled is False
    NULL_RECORDER.emit(0.0, "start", vm=1)
    assert len(NULL_RECORDER) == 0
    assert list(NULL_RECORDER.records()) == []


# ---------------------------------------------------------------------------
# observation-only invariant (metrics byte-identity, three regimes)
# ---------------------------------------------------------------------------
def _rows(spec_kwargs, seed, until):
    out = []
    for obs in (None, ObsSpec(), EVENTS_ON):
        row = run_one(RunSpec(**spec_kwargs, obs=obs), seed, until=until)
        out.append(json.dumps(row, sort_keys=True))
    return out


def test_synthetic_identity():
    plain, off, on = _rows(
        dict(scenario=ScenarioSpec(workload="synthetic"),
             policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5})),
        seed=3, until=1500.0)
    assert plain == off == on


def test_market_migration_identity():
    plain, off, on = _rows(_market_kwargs(), seed=5, until=3600.0)
    assert plain == off == on


def test_fleet_faults_identity():
    plain, off, on = _rows(
        _market_kwargs(
            migration=MigrationSpec("none"),
            fleet=FleetSpec(strategy="diversified",
                            params={"target_capacity": 48.0}),
            faults=FaultSpec(scenario="storm")),
        seed=7, until=3600.0)
    assert plain == off == on


def test_events_only_spec_keeps_plain_loop():
    # events alone must NOT build a tracer: the simulator keeps NULL_TRACER
    # and run() takes the plain untraced loop — recording rides inside the
    # ordinary handlers
    sim = build(RunSpec(**_market_kwargs(), obs=EVENTS_ON), 0)
    assert sim.obs.enabled is False
    assert sim.events.enabled is True
    # one recorder shared by every subsystem
    assert sim.engine.events is sim.events
    assert sim.migration.events is sim.events
    # off spec leaves the inert singleton everywhere
    sim_off = build(RunSpec(**_market_kwargs(), obs=ObsSpec()), 0)
    assert sim_off.events is NULL_RECORDER
    assert sim_off.engine.events is NULL_RECORDER


def test_recorded_runs_are_deterministic():
    logs = []
    for _ in range(2):
        sim = build(RunSpec(**_market_kwargs(), obs=EVENTS_ON), 11)
        sim.run(until=3600.0)
        logs.append(sim.events)
    assert len(logs[0]) > 0
    assert first_divergence(logs[0], logs[1]) is None


def test_fleet_fault_kinds_recorded():
    sim = build(RunSpec(
        **_market_kwargs(
            migration=MigrationSpec("none"),
            fleet=FleetSpec(strategy="diversified",
                            params={"target_capacity": 48.0}),
            faults=FaultSpec(scenario="storm")),
        obs=EVENTS_ON), 7)
    sim.run(until=7200.0)
    kinds = set(str(k) for k in sim.events.to_arrays()["kinds"])
    assert "fault" in kinds
    assert "fleet-launch" in kinds
    assert "price-tick" in kinds
