"""Tracer mechanics (self-time, cadence, profile math — driven by a fake
clock so assertions are exact) and Chrome-trace schema validity for real
simulator runs."""
import json

import pytest

from repro.api import (
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.obs import (
    Tracer,
    chrome_trace,
    profile_report,
    profile_table,
    validate_chrome_trace,
    write_chrome_trace,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def tick(self, dt):
        self.t += dt


# -- span self-time -----------------------------------------------------------
def test_nested_span_self_time():
    clk = FakeClock()
    tr = Tracer(profile=True, clock=clk)
    tr.begin("outer", "parent")
    clk.tick(1.0)
    tr.begin("inner", "child")
    clk.tick(3.0)
    tr.end(sim_t=10.0)            # child: dur 3, self 3
    clk.tick(2.0)
    tr.end(sim_t=10.0)            # parent: dur 6, self 6 - 3 = 3
    spans = {(c, n): (dur, self_t)
             for c, n, _t0, dur, _sim, self_t, _a in tr.spans}
    assert spans[("inner", "child")] == (3.0, 3.0)
    assert spans[("outer", "parent")] == (6.0, 3.0)
    prof = tr.profile()
    assert prof[("outer", "parent")] == [1, 6.0, 3.0]
    assert prof[("inner", "child")] == [1, 3.0, 3.0]


def test_profile_only_mode_keeps_no_records():
    clk = FakeClock()
    tr = Tracer(keep_records=False, profile=True, clock=clk)
    for _ in range(100):
        tr.begin("cat", "site")
        clk.tick(0.5)
        tr.end(sim_t=0.0)
        tr.instant("cat", "mark", 0.0)
    assert tr.spans == [] and tr.instants == []
    assert tr.profile()[("cat", "site")] == [100, 50.0, 50.0]


def test_profile_table_math():
    clk = FakeClock()
    tr = Tracer(keep_records=False, profile=True, clock=clk)
    tr.begin("a", "hot")
    clk.tick(9.0)
    tr.end(0.0)
    tr.begin("b", "cold")
    clk.tick(1.0)
    tr.end(0.0)
    rows = profile_table(tr)
    assert [r["name"] for r in rows] == ["hot", "cold"]   # self desc
    assert rows[0]["self_pct"] == 90.0
    rep = profile_report(tr)
    assert rep["dominant"]["name"] == "hot"
    assert rep["total_self_ms"] == pytest.approx(10000.0)


# -- counters -----------------------------------------------------------------
def test_counter_cadence():
    clk = FakeClock()
    tr = Tracer(counters_every=100.0, clock=clk)
    seen = []
    tr.on_snapshot = lambda t, snap: seen.append(t)
    assert tr.counters_due(0.0)          # first boundary at t=0
    tr.counters.inc("x")
    tr.snapshot(0.0)
    assert not tr.counters_due(99.9)
    assert tr.counters_due(100.0)
    tr.snapshot(250.0, gauges={"g": 7})  # late snapshot re-anchors
    assert not tr.counters_due(299.0)
    assert tr.counters_due(300.0)
    assert seen == [0.0, 250.0]
    (t0, _w0, s0), (t1, _w1, s1) = tr.counters.series
    assert (t0, s0["x"]) == (0.0, 1)
    assert (t1, s1["g"]) == (250.0, 7)


def test_counters_every_validation():
    with pytest.raises(ValueError):
        Tracer(counters_every=0.0)
    with pytest.raises(ValueError):
        Tracer(counters_every=-5.0)


# -- chrome export ------------------------------------------------------------
def _traced_run(seed=3, until=2400.0):
    sim = build(RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"),
        obs=ObsSpec(trace=True, profile=True, counters_every=600.0)), seed)
    sim.run(until=until)
    return sim


def test_chrome_trace_schema_valid(tmp_path):
    sim = _traced_run()
    doc = write_chrome_trace(sim.obs, str(tmp_path / "t.json"),
                             manifest={"seed": 3})
    assert validate_chrome_trace(doc) == []
    reloaded = json.loads((tmp_path / "t.json").read_text())
    assert validate_chrome_trace(reloaded) == []
    assert reloaded["otherData"] == {"seed": 3}


def test_chrome_trace_dual_clock_tracks():
    doc = chrome_trace(_traced_run().obs)
    evs = doc["traceEvents"]
    pids = {e["pid"] for e in evs}
    assert pids == {1, 2}
    names = {(e["pid"], e["args"]["name"]) for e in evs
             if e.get("ph") == "M" and e["name"] == "process_name"}
    assert names == {(1, "wall-time"), (2, "sim-time")}
    # every span is mirrored on both clocks; sim-time spans carry wall_ms
    xs = [e for e in evs if e["ph"] == "X"]
    assert len([e for e in xs if e["pid"] == 1]) == \
        len([e for e in xs if e["pid"] == 2])
    assert all(e["dur"] == 0 and "wall_ms" in e["args"]
               for e in xs if e["pid"] == 2)
    # counter samples exist for the core live counters
    counter_names = {e["name"] for e in evs if e["ph"] == "C"}
    assert "events/total" in counter_names
    assert "gauge/queue_depth" in counter_names


def test_validator_catches_malformed_events():
    assert validate_chrome_trace({}) != []
    bad = {"traceEvents": [
        {"ph": "X", "pid": 1, "tid": 1, "name": "n", "cat": "c",
         "ts": -5.0, "dur": 1.0},
        {"ph": "??", "pid": 1, "tid": 1, "name": "n"},
        {"ph": "C", "pid": 9, "tid": 1, "name": "k", "ts": 0,
         "args": {"value": "not-a-number"}},
    ]}
    probs = validate_chrome_trace(bad)
    assert any("bad ts" in p for p in probs)
    assert any("unknown ph" in p for p in probs)
    assert any("not numeric" in p for p in probs)
    assert any("no process_name" in p for p in probs)


# -- expected instrumentation content -----------------------------------------
def test_trace_covers_subsystem_boundaries():
    tr = _traced_run().obs
    cats = {c for c, *_ in tr.spans}
    assert {"event-loop", "market-tick", "market-engine",
            "migration", "allocation"} <= cats
    names = {n for _c, n, *_ in tr.spans}
    assert "dispatch/price-tick" in names
    assert "plan/gradient-aware" in names
    c = tr.counters.values
    assert c["events/total"] > 0 and c["ticks"] > 0
    assert any(k.startswith("interruptions/") for k in c)
    assert c.get("migrations/planned", 0) == c.get("migrations/started", 0)
