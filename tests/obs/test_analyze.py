"""Market-risk analytics over the event log: storm detection, per-pool
risk series, per-VM lifecycles, cohort rollups — hand-built logs with
known answers, plus one real run for shape/consistency."""
import numpy as np
import pytest

from repro.api import (
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.obs import (
    EventLog,
    cohort_summary,
    interruption_intensity,
    pool_risk_series,
    serve_series,
    storm_intervals,
    victim_rate,
    vm_lifecycle,
)


def _burst_log():
    """10 interrupts in [1000, 1090] (a storm), 2 sparse ones later."""
    log = EventLog()
    for i in range(10):
        log.emit(1000.0 + 10.0 * i, "interrupt", vm=i, pool=0)
    log.emit(5000.0, "interrupt", vm=100, pool=0)
    log.emit(9000.0, "interrupt", vm=101, pool=0)
    return log


def test_interruption_intensity():
    t, inten = interruption_intensity(_burst_log(), window=600.0)
    assert t.size == 12
    # the 10th burst event sees all 10 in its window
    assert inten[9] == pytest.approx(10.0 / 600.0)
    # the isolated events see only themselves
    assert inten[-1] == pytest.approx(1.0 / 600.0)
    # empty log
    t0, i0 = interruption_intensity(EventLog())
    assert t0.size == 0 and i0.size == 0


def test_storm_intervals():
    storms = storm_intervals(_burst_log(), window=600.0,
                             threshold=5.0 / 600.0)
    assert len(storms) == 1
    s = storms[0]
    assert s["t0"] >= 1000.0 and s["t1"] <= 1090.0
    assert s["peak_intensity"] == pytest.approx(10.0 / 600.0)
    # nothing clears an impossible threshold
    assert storm_intervals(_burst_log(), threshold=1.0) == []


def test_pool_risk_series_occupancy_and_margin():
    log = EventLog()
    # two ticks at t=0 and t=60 for pool 0; bids admitted in between
    log.emit(0.0, "price-tick", pool=0, a=0.10)
    log.emit(0.0, "start", vm=1, pool=0, host=0, a=0.30)
    log.emit(10.0, "start", vm=2, pool=0, host=1, a=0.50)
    log.emit(30.0, "interrupt", vm=1, pool=0, host=0, a=0.30, aux="price")
    log.emit(60.0, "price-tick", pool=0, a=0.45)
    log.emit(60.0, "wave", pool=0, a=0.45, b=1.0)
    # pool 1 noise must not leak in
    log.emit(60.0, "price-tick", pool=1, a=9.9)
    rs = pool_risk_series(log, 0)
    assert rs["t"].tolist() == [0.0, 60.0]
    assert rs["price"].tolist() == [0.10, 0.45]
    # at t=0: vm1 started (events at the tick time count); at t=60: vm2
    # resident, vm1 interrupted
    assert rs["occupancy"].tolist() == [1.0, 1.0]
    assert rs["mean_bid"][0] == pytest.approx(0.30)
    assert rs["mean_bid"][1] == pytest.approx(0.40)
    assert rs["danger_margin"][1] == pytest.approx(0.40 - 0.45)
    assert rs["victims"].sum() == pytest.approx(1.0)


def test_migrations_move_occupancy_between_pools():
    log = EventLog()
    log.emit(0.0, "price-tick", pool=0, a=0.1)
    log.emit(0.0, "price-tick", pool=1, a=0.1)
    log.emit(0.0, "start", vm=1, pool=0, host=0, a=0.5)
    log.emit(10.0, "migrate-start", vm=1, pool=0, host=0, b=1.0)
    log.emit(40.0, "migrate-complete", vm=1, pool=1, host=5, aux="ok")
    log.emit(60.0, "price-tick", pool=0, a=0.1)
    log.emit(60.0, "price-tick", pool=1, a=0.1)
    assert pool_risk_series(log, 0)["occupancy"].tolist() == [1.0, 0.0]
    assert pool_risk_series(log, 1)["occupancy"].tolist() == [0.0, 1.0]


def test_victim_rate():
    log = EventLog()
    for k in range(4):
        log.emit(60.0 * k, "price-tick", pool=0, a=0.2)
    log.emit(120.0, "wave", pool=0, a=0.2, b=6.0)
    assert victim_rate(log) == pytest.approx(6.0 / 4.0)
    assert victim_rate(log, pool=1) == 0.0


def test_vm_lifecycle_and_cohort_summary():
    log = EventLog()
    log.emit(0.0, "submit", vm=1, a=0.4, aux="spot")
    log.emit(0.0, "start", vm=1, pool=0, host=0, a=0.4)
    log.emit(50.0, "interrupt", vm=1, pool=0, host=0, aux="price")
    log.emit(50.0, "hibernate", vm=1, a=0.4)
    log.emit(90.0, "resume", vm=1, pool=1, host=4, a=0.4)
    log.emit(200.0, "finish", vm=1, pool=1, host=4)
    log.emit(10.0, "submit", vm=2, aux="on-demand")   # noqa: emitted late
    life = vm_lifecycle(log, 1)
    assert [e["kind"] for e in life] == [
        "submit", "start", "interrupt", "hibernate", "resume", "finish"]
    assert life[2]["aux"] == "price"
    cs = cohort_summary(log)
    assert cs["n_vms"] == 2
    assert cs["final_states"] == {"finish": 1, "submit": 1}
    assert cs["interruptions"]["total"] == 1
    assert cs["interruptions"]["max"] == 1
    assert cs["migrations"]["total"] == 0
    assert cohort_summary(EventLog())["n_vms"] == 0


def test_real_run_consistency():
    sim = build(RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile"),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"),
        obs=ObsSpec(events=True)), 5)
    metrics = sim.run(until=3600.0)
    log = sim.events
    arr = log.to_arrays()
    # the log's interrupt count equals the metrics' interruption count
    n_interrupts = int((arr["kind"] == log.kind_id("interrupt")).sum())
    s = metrics.spot_stats(sim.vms)
    assert n_interrupts == s["interruptions"]
    # per-pool series aligns to that pool's tick count
    rs = pool_risk_series(log, 0)
    n_ticks = int(((arr["kind"] == log.kind_id("price-tick"))
                   & (arr["pool"] == 0)).sum())
    assert rs["t"].size == n_ticks
    assert np.isfinite(rs["price"]).all()
    cs = cohort_summary(log)
    assert cs["interruptions"]["total"] == s["interruptions"]


def test_serve_series_none_without_serve_events():
    assert serve_series(_burst_log()) is None


def test_serve_series_hand_built_log():
    log = EventLog()
    for i in range(4):
        t = 60.0 * (i + 1)
        log.emit(t, "request-arrive", a=2.0, b=0.5)
        log.emit(t, "serve-sample", a=float(i), b=3.0)
    log.emit(90.0, "request-done", a=10.0, b=240.0)
    log.emit(150.0, "request-done", a=30.0, b=240.0)
    log.emit(120.0, "autoscale", a=5.0, b=3.0, aux="target-tracking")
    sv = serve_series(log, window=1800.0)
    assert sv is not None
    assert sv["t"].tolist() == [60.0, 120.0, 180.0, 240.0]
    assert sv["depth"].tolist() == [0.0, 1.0, 2.0, 3.0]
    assert sv["rate"].tolist() == [0.5] * 4
    assert sv["live"].tolist() == [3.0] * 4
    # no completion yet at the first tick -> NaN; then the trailing p95
    # covers whatever finished so far
    assert np.isnan(sv["p95"][0])
    assert sv["p95"][1] == pytest.approx(10.0)
    assert sv["p95"][3] == pytest.approx(np.percentile([10.0, 30.0], 95))
    assert sv["scale_t"].tolist() == [120.0]
    assert sv["scale_units"].tolist() == [5.0]
