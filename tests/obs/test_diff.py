"""First-divergence diffing: the bit-identity debugging tool.

Acceptance pair (ISSUE 8): diffing PR 5's fused vectorized engine against
the scalar oracle at the same seed reports **zero divergence**, while a
deliberately perturbed run (one flipped bid) yields a correctly located
first-divergence event."""
import pytest

from repro.api import (
    BidSpec,
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    build,
)
from repro.obs import (
    EventLog,
    bisect_divergence,
    first_divergence,
    format_divergence,
)

EVENTS_ON = ObsSpec(events=True)


def _market_spec(**overrides):
    kw = dict(
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              bid=BidSpec("randomized", {"lo": 0.45})),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"),
        obs=EVENTS_ON)
    kw.update(overrides)
    return RunSpec(**kw)


def _attach(sim, log):
    """Swap a custom (e.g. windowed) recorder into every emit site."""
    sim.events = log
    if sim.engine is not None:
        sim.engine.events = log
    if sim.migration is not None:
        sim.migration.events = log
    if sim.fleet is not None:
        sim.fleet.events = log
    if sim.faults is not None:
        sim.faults.events_log = log


def _flip_one_bid(sim, after=300.0):
    """Perturb one spot VM's bid (the deliberate divergence); returns the
    VM and its submit time."""
    vm = min((v for v in sim.vms.values()
              if v.bid != float("inf") and v.submit_time >= after),
             key=lambda v: v.submit_time)
    vm.bid *= 1.01
    return vm


# ---------------------------------------------------------------------------
# streaming diff basics
# ---------------------------------------------------------------------------
def test_identical_and_diverging_iterables():
    a = [(0.0, "start", 1, 0, 0, 0.0, 0.0, None),
         (1.0, "finish", 1, 0, 0, 0.0, 0.0, None)]
    assert first_divergence(a, list(a)) is None
    b = [a[0], (1.0, "interrupt", 1, 0, 0, 0.0, 0.0, "price")]
    div = first_divergence(a, b, context=3)
    assert div.index == 1
    assert div.record_a[1] == "finish" and div.record_b[1] == "interrupt"
    assert div.time == 1.0
    assert div.context == [a[0]]


def test_one_stream_ends_early():
    a = [(0.0, "start", 1, 0, 0, 0.0, 0.0, None),
         (1.0, "finish", 1, 0, 0, 0.0, 0.0, None)]
    div = first_divergence(a, a[:1])
    assert div.index == 1
    assert div.record_a is not None and div.record_b is None
    assert "<stream ended>" in format_divergence(div)


def test_format_divergence_strings():
    assert "zero divergence" in format_divergence(None)
    a = [(0.0, "start", 1, 2, 3, 0.5, 0.0, "x")]
    div = first_divergence(a, [])
    text = format_divergence(div, label_a="A", label_b="B")
    assert "record #0" in text and "vm=1" in text and "pool=2" in text


# ---------------------------------------------------------------------------
# acceptance: vectorized engine vs scalar oracle — zero divergence
# ---------------------------------------------------------------------------
def test_vectorized_vs_scalar_oracle_zero_divergence():
    logs = []
    for vectorized in (True, False):
        sim = build(_market_spec(), 0)
        sim.engine.use_vectorized = vectorized
        sim.run(until=3600.0)
        logs.append(sim.events)
    assert len(logs[0]) > 100
    div = first_divergence(logs[0], logs[1])
    assert div is None, format_divergence(div, "vectorized", "oracle")


# ---------------------------------------------------------------------------
# acceptance: one flipped bid — divergence correctly located
# ---------------------------------------------------------------------------
def test_flipped_bid_divergence_located():
    sim_a = build(_market_spec(), 0)
    sim_b = build(_market_spec(), 0)
    flipped = _flip_one_bid(sim_b, after=300.0)
    sim_a.run(until=3600.0)
    sim_b.run(until=3600.0)
    div = first_divergence(sim_a.events, sim_b.events)
    assert div is not None
    # the first divergent record is exactly the perturbed VM's submit
    # event (it carries the bid in payload a) — nothing before it differs
    assert div.time == pytest.approx(flipped.submit_time)
    assert div.record_a[1] == "submit" and div.record_b[1] == "submit"
    assert div.record_a[2] == flipped.id and div.record_b[2] == flipped.id
    assert div.record_a[5] != div.record_b[5]      # the flipped bid
    assert len(div.context) == 5                    # shared prefix window


# ---------------------------------------------------------------------------
# windowed-rerun bisection
# ---------------------------------------------------------------------------
def test_bisect_divergence_narrows_to_flip():
    t_end = 2400.0

    def make_logs(t0, t1):
        out = []
        for perturb in (False, True):
            sim = build(_market_spec(obs=None), 0)
            if perturb:
                _flip_one_bid(sim, after=300.0)
            _attach(sim, EventLog(t_min=t0, t_max=t1))
            sim.run(until=t_end)
            out.append(sim.events)
        return out[0], out[1]

    # recover the true divergence time from one un-windowed reference pair
    a, b = make_logs(0.0, t_end)
    t_true = first_divergence(a, b).time

    div, (lo, hi) = bisect_divergence(make_logs, t_end, min_window=600.0)
    assert hi - lo <= 600.0 + 1e-9
    assert lo <= t_true < hi
    assert div is not None and div.time == pytest.approx(t_true)


def test_bisect_divergence_identical_runs():
    def make_logs(t0, t1):
        out = []
        for _ in range(2):
            sim = build(_market_spec(obs=None), 3)
            _attach(sim, EventLog(t_min=t0, t_max=t1))
            sim.run(until=1200.0)
            out.append(sim.events)
        return out[0], out[1]

    div, window = bisect_divergence(make_logs, 1200.0, min_window=600.0)
    assert div is None
