"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.hlem_score import hlem_score_pallas
from repro.kernels.ssm_scan import ssm_scan

def _rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# hlem_score
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n", [1, 3, 100, 512, 513, 2000])
@pytest.mark.parametrize("alpha", [0.0, -0.5])
def test_hlem_score_sweep(n, alpha):
    rng = _rng()
    free = jnp.asarray(rng.uniform(0, 100, (n, 4)), jnp.float32)
    mask = jnp.asarray(rng.random(n) < 0.7)
    spot = jnp.asarray(rng.uniform(0, 1, (n, 4)), jnp.float32)
    out = hlem_score_pallas(free, mask, spot, jnp.float32(alpha),
                            interpret=True)
    want = ref.hlem_score_ref(free, mask, spot, jnp.float32(alpha))
    m = np.asarray(mask)
    if m.any():
        np.testing.assert_allclose(np.asarray(out)[m], np.asarray(want)[m],
                                   rtol=1e-4, atol=1e-5)
        assert int(np.argmax(out)) == int(np.argmax(want))


def test_hlem_score_all_masked():
    rng = _rng()
    n = 64
    free = jnp.zeros((n, 4), jnp.float32)
    mask = jnp.zeros((n,), bool)
    spot = jnp.zeros((n, 4), jnp.float32)
    out = hlem_score_pallas(free, mask, spot, jnp.float32(0.0),
                            interpret=True)
    assert bool((out <= -1e37).all())


@pytest.mark.parametrize("b,n", [(1, 100), (4, 100), (3, 513), (8, 257)])
def test_hlem_score_batch_sweep(b, n):
    """Batched kernel (B VMs x n hosts in ONE pallas_call) vs the numpy
    batch oracle: <= 1e-5 on unmasked entries, including degenerate
    (zero-span) columns and a fully-masked row."""
    from repro.core.hlem import hlem_scores_batch_np
    from repro.kernels.hlem_score import hlem_score_pallas_batch
    rng = _rng()
    free = rng.uniform(0, 100, (n, 4)).astype(np.float32)
    free[:, 3] = 42.0  # degenerate column across every candidate set
    masks = rng.random((b, n)) < 0.7
    if b > 1:
        masks[0] = False  # fully-masked row -> all -big
    spot = rng.uniform(0, 1, (n, 4)).astype(np.float32)
    alphas = np.linspace(-0.5, 0.5, b).astype(np.float32)
    out = np.asarray(hlem_score_pallas_batch(
        jnp.asarray(free), jnp.asarray(masks), jnp.asarray(spot),
        jnp.asarray(alphas), interpret=True))
    want = hlem_scores_batch_np(free, masks, spot, alphas)
    assert out.shape == (b, n)
    for i in range(b):
        m = masks[i]
        if m.any():
            np.testing.assert_allclose(out[i][m], want[i][m], rtol=1e-4,
                                       atol=1e-5)
            assert int(np.argmax(out[i])) == int(np.argmax(want[i]))
        else:
            assert bool((out[i] <= -1e37).all())


def test_hlem_score_batch_consistent_with_single():
    """Each batch row must equal the single-VM kernel on the same mask."""
    from repro.kernels.hlem_score import hlem_score_pallas_batch
    rng = _rng()
    b, n = 5, 200
    free = jnp.asarray(rng.uniform(0, 10, (n, 4)), jnp.float32)
    masks = rng.random((b, n)) < 0.5
    spot = jnp.asarray(rng.uniform(0, 1, (n, 4)), jnp.float32)
    alpha = jnp.float32(-0.5)
    batch = np.asarray(hlem_score_pallas_batch(
        free, jnp.asarray(masks), spot,
        jnp.full((b,), -0.5, jnp.float32), interpret=True))
    for i in range(b):
        single = np.asarray(hlem_score_pallas(
            free, jnp.asarray(masks[i]), spot, alpha, interpret=True))
        np.testing.assert_allclose(batch[i], single, rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
CASES = [
    # b, h, hkv, tq, tk, dh, window, dtype
    (2, 4, 4, 128, 128, 64, None, jnp.float32),
    (1, 8, 2, 96, 96, 64, None, jnp.float32),      # GQA, ragged
    (1, 4, 2, 1, 200, 64, None, jnp.float32),      # decode tq=1
    (2, 4, 4, 128, 128, 64, 32, jnp.float32),      # sliding window
    (1, 2, 1, 64, 64, 128, None, jnp.bfloat16),
    (1, 5, 1, 70, 70, 16, 16, jnp.float32),        # odd heads (hymba-like)
]


@pytest.mark.parametrize("b,h,hkv,tq,tk,dh,window,dtype", CASES)
def test_flash_attention_sweep(b, h, hkv, tq, tk, dh, window, dtype):
    rng = _rng()
    q = jnp.asarray(rng.normal(0, 1, (b, h, tq, dh)), dtype)
    k = jnp.asarray(rng.normal(0, 1, (b, hkv, tk, dh)), dtype)
    v = jnp.asarray(rng.normal(0, 1, (b, hkv, tk, dh)), dtype)
    out = flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                          interpret=True)
    want = ref.mha_ref(q, k, v, window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(want, np.float32), atol=tol)


def test_flash_attention_noncausal():
    rng = _rng()
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 50, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 50, 32)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 50, 32)), jnp.float32)
    out = flash_attention(q, k, v, causal=False, block_q=32, block_k=32,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_chunked_ref_matches_dense():
    rng = _rng()
    q = jnp.asarray(rng.normal(0, 1, (1, 4, 257, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 257, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 257, 64)), jnp.float32)
    a = ref.mha_chunked_ref(q, k, v, chunk=64)
    b = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
SSM_CASES = [
    (2, 64, 128, 16, False, jnp.float32),
    (1, 100, 96, 16, True, jnp.float32),
    (1, 1, 64, 16, True, jnp.float32),      # decode single step
    (2, 64, 128, 16, False, jnp.bfloat16),
]


@pytest.mark.parametrize("b,t,dm,n,with_h0,dtype", SSM_CASES)
def test_ssm_scan_sweep(b, t, dm, n, with_h0, dtype):
    rng = _rng()
    x = jnp.asarray(rng.normal(0, 1, (b, t, dm)), dtype)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, t, dm)), dtype)
    a = jnp.asarray(-rng.uniform(0.1, 1, (dm, n)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (b, t, n)), dtype)
    c = jnp.asarray(rng.normal(0, 1, (b, t, n)), dtype)
    d = jnp.asarray(rng.normal(0, 1, (dm,)), jnp.float32)
    h0 = (jnp.asarray(rng.normal(0, 1, (b, dm, n)), jnp.float32)
          if with_h0 else None)
    y, hT = ssm_scan(x, dt, a, bb, c, d, h0, block_d=64, block_t=32,
                     interpret=True)
    yr, hTr = ref.ssm_scan_ref(x, dt, a, bb, c, d, h0)
    ytol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    htol = 5e-3 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=ytol)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=htol)


def test_ssm_chunked_equals_full():
    rng = _rng()
    """Running two chunks with carried state == one full scan."""
    b, t, dm, n = 1, 64, 64, 16
    x = jnp.asarray(rng.normal(0, 1, (b, t, dm)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, t, dm)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.1, 1, (dm, n)), jnp.float32)
    bb = jnp.asarray(rng.normal(0, 1, (b, t, n)), jnp.float32)
    c = jnp.asarray(rng.normal(0, 1, (b, t, n)), jnp.float32)
    d = jnp.asarray(rng.normal(0, 1, (dm,)), jnp.float32)
    y_full, h_full = ref.ssm_scan_ref(x, dt, a, bb, c, d)
    half = t // 2
    y1, h1 = ref.ssm_scan_ref(x[:, :half], dt[:, :half], a, bb[:, :half],
                              c[:, :half], d)
    y2, h2 = ref.ssm_scan_ref(x[:, half:], dt[:, half:], a, bb[:, half:],
                              c[:, half:], d, h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), atol=1e-4)


# ---------------------------------------------------------------------------
# ops dispatcher
# ---------------------------------------------------------------------------
def test_ops_impl_switch():
    rng = _rng()
    q = jnp.asarray(rng.normal(0, 1, (1, 2, 32, 16)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (1, 2, 32, 16)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (1, 2, 32, 16)), jnp.float32)
    a = ops.attention(q, k, v, impl="xla")
    b = ops.attention(q, k, v, impl="interp", block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)
