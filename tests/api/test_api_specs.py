"""Spec tree: JSON round-trip identity, construction-time validation, and
registry plug-in behavior."""
import json

import pytest

from repro.api import (
    BID_REGISTRY,
    BidSpec,
    ExperimentSpec,
    MIGRATION_REGISTRY,
    MigrationSpec,
    POLICY_REGISTRY,
    PolicySpec,
    PRICE_PROCESS_REGISTRY,
    RebidSpec,
    RunSpec,
    ScenarioSpec,
    WORKLOAD_REGISTRY,
    register_policy,
    register_workload,
)
from repro.core import FirstFit, make_policy


def _market_scenario() -> ScenarioSpec:
    return ScenarioSpec(workload="market", regime="volatile", n_pools=3,
                        tick_interval=30.0, from_advisor=False,
                        bid=BidSpec("randomized", {"lo": 0.45}),
                        horizon=1800.0)


SPECS = [
    BidSpec(),
    BidSpec("percentile", {"pct": 85.0}),
    PolicySpec("first-fit"),
    PolicySpec("hlem-vmp-adjusted", {"alpha": -0.4, "rc": 0.9}),
    MigrationSpec(),
    MigrationSpec("gradient-aware", {"downtime": 20.0, "hysteresis": 0.1}),
    RebidSpec(),
    RebidSpec(bump_lo=1.1, bump_hi=1.5),
    ScenarioSpec(workload="synthetic"),
    ScenarioSpec(workload="trace",
                 workload_params={"n_machines": 30, "sim_days": 0.05}),
    _market_scenario(),
    RunSpec(scenario=_market_scenario(),
            policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
            migration=MigrationSpec("risk-budgeted"),
            rebid=RebidSpec()),
    RunSpec(scenario=ScenarioSpec(workload="synthetic",
                                  sim_params={"interruption_selector":
                                              "max_progress"}),
            policy=PolicySpec("best-fit")),
    ExperimentSpec(
        name="grid",
        scenario=_market_scenario(),
        policies=(PolicySpec("first-fit"),
                  PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5})),
        migrations=(MigrationSpec(), MigrationSpec("gradient-aware")),
        regimes=("calm", "volatile"),
        seeds=(0, 1, 2),
        rebid=RebidSpec()),
    ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                   policies=(PolicySpec("first-fit"),),
                   seeds=(7,)),
]


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: type(s).__name__)
def test_dict_round_trip_identity(spec):
    d = spec.to_dict()
    clone = type(spec).from_dict(d)
    assert clone == spec
    # the dict itself must be JSON-pure (no spec objects smuggled through)
    assert json.loads(json.dumps(d)) == d


@pytest.mark.parametrize("spec", SPECS, ids=lambda s: type(s).__name__)
def test_json_round_trip_identity(spec):
    clone = type(spec).from_json(spec.to_json())
    assert clone == spec
    # serialization is canonical: round-tripping the JSON is a fixpoint
    assert clone.to_json() == spec.to_json()


def test_experiment_save_load(tmp_path):
    exp = SPECS[-2]
    path = tmp_path / "exp.json"
    exp.save(str(path))
    assert ExperimentSpec.load(str(path)) == exp


def test_experiment_cells_grid_order():
    exp = SPECS[-2]
    cells = exp.cells()
    assert len(cells) == 2 * 2 * 2  # regimes × policies × migrations
    assert [c.scenario.regime for c in cells[:4]] == ["calm"] * 4
    assert [c.policy.name for c in cells[:2]] == ["first-fit"] * 2
    assert [c.migration.policy for c in cells[:2]] == ["none",
                                                       "gradient-aware"]
    runs = list(exp.runs())
    assert len(runs) == len(cells) * len(exp.seeds)


# -- validation: fail fast at construction ----------------------------------
@pytest.mark.parametrize("factory, match", [
    (lambda: ScenarioSpec(workload="nope"), "unknown workload"),
    (lambda: ScenarioSpec(workload="synthetic", regime="wild"),
     "unknown regime"),
    (lambda: ScenarioSpec(workload="market"), "requires a market regime"),
    (lambda: ScenarioSpec(workload="synthetic", regime="calm", n_pools=0),
     "n_pools"),
    (lambda: ScenarioSpec(workload="synthetic", regime="calm",
                          tick_interval=0.0), "tick_interval"),
    (lambda: ScenarioSpec(workload="synthetic", horizon=-5.0), "horizon"),
    (lambda: ScenarioSpec(workload="synthetic", bid=BidSpec()),
     "needs a market engine"),
    (lambda: ScenarioSpec(workload="trace", regime="calm", bid=BidSpec()),
     "does not support bid"),
    (lambda: ScenarioSpec(workload="synthetic",
                          workload_params={"seed": 1}), "supplied by the"),
    (lambda: ScenarioSpec(workload="market", regime="calm",
                          workload_params={"n_pools": 2}),
     "supplied by the"),
    (lambda: ScenarioSpec(workload="synthetic",
                          workload_params={"typo": 1}), "unknown workload"),
    (lambda: ScenarioSpec(workload="synthetic",
                          sim_params={"typo": 1}), "unknown sim"),
    (lambda: PolicySpec("nope"), "unknown allocation policy"),
    (lambda: PolicySpec("first-fit", {"alpha": 1.0}),
     "unknown allocation policy 'first-fit' parameter"),
    (lambda: MigrationSpec("nope"), "unknown migration policy"),
    (lambda: MigrationSpec("gradient-aware", {"typo": 1}),
     "unknown migration policy"),
    (lambda: BidSpec("nope"), "unknown bid strategy"),
    (lambda: BidSpec("randomized", {"typo": 1}), "unknown bid strategy"),
    (lambda: RebidSpec(bump_lo=2.0, bump_hi=1.0), "bump"),
    (lambda: RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                     policy=PolicySpec("first-fit"),
                     migration=MigrationSpec("gradient-aware")),
     "requires a market engine"),
    (lambda: RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                     policy=PolicySpec("first-fit"), rebid=RebidSpec()),
     "re-bidding requires"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"), rebid=5),
     "rebid must be"),
    (lambda: RunSpec(scenario=_market_scenario(), policy="first-fit"),
     "policy must be"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=("first-fit",), seeds=(0,)),
     "policies must all be"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),),
                            migrations=("none",), seeds=(0,)),
     "migrations must all be"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(), seeds=(0,)), "at least one policy"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),), seeds=()),
     "at least one seed"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0, 0)), "duplicate seeds"),
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0,), regimes=("wild",)),
     "unknown regime"),
    # a bad grid *cell* fails at ExperimentSpec construction, not mid-sweep
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),),
                            migrations=(MigrationSpec("gradient-aware"),),
                            seeds=(0,)), "requires a market engine"),
])
def test_validation_fails_fast(factory, match):
    with pytest.raises(ValueError, match=match):
        factory()


# -- registries --------------------------------------------------------------
@pytest.mark.parametrize("registry, known", [
    (POLICY_REGISTRY, "hlem-vmp-adjusted"),
    (BID_REGISTRY, "randomized"),
    (MIGRATION_REGISTRY, "gradient-aware"),
    (PRICE_PROCESS_REGISTRY, "smoothed"),
    (WORKLOAD_REGISTRY, "synthetic"),
])
def test_registry_unknown_name_lists_known(registry, known):
    assert known in registry
    with pytest.raises(ValueError) as exc:
        registry.get("definitely-not-registered")
    msg = str(exc.value)
    assert "definitely-not-registered" in msg and known in msg
    assert registry.kind in msg


def test_register_custom_policy_plugs_into_specs():
    @register_policy("test-first-fit-clone")
    class FirstFitClone(FirstFit):
        name = "test-first-fit-clone"

    try:
        assert isinstance(make_policy("test-first-fit-clone"), FirstFitClone)
        spec = PolicySpec("test-first-fit-clone")
        assert PolicySpec.from_json(spec.to_json()) == spec
        with pytest.raises(ValueError, match="already registered"):
            register_policy("test-first-fit-clone")(FirstFitClone)
    finally:
        POLICY_REGISTRY.entries.pop("test-first-fit-clone")


def test_register_custom_workload_plugs_into_specs():
    from repro.core import resources, make_on_demand

    @register_workload("test-tiny")
    def _populate(sim, scenario, seed):
        sim.add_host(resources(8, 16_384, 5_000, 200_000))
        sim.submit(make_on_demand(0, resources(1, 1024, 100, 10_000), 50.0))

    try:
        from repro.api import build
        spec = RunSpec(scenario=ScenarioSpec(workload="test-tiny"),
                       policy=PolicySpec("first-fit"))
        sim = build(spec, seed=0)
        m = sim.run()
        assert m.allocations == 1
    finally:
        WORKLOAD_REGISTRY.entries.pop("test-tiny")


# ---------------------------------------------------------------------------
# PR 5 grid axes: bid strategies + workload-parameter ladders
# ---------------------------------------------------------------------------
def _grid_experiment(**kw) -> ExperimentSpec:
    base = dict(
        name="grid",
        scenario=_market_scenario(),
        policies=(PolicySpec("first-fit"),),
        seeds=(0, 1))
    base.update(kw)
    return ExperimentSpec(**base)


def test_bid_axis_fans_cells_and_round_trips():
    exp = _grid_experiment(
        bids=(BidSpec("randomized", {"lo": 0.35}),
              BidSpec("on-demand-cap", {"fraction": 0.8})))
    cells = exp.cells()
    assert len(cells) == 2
    assert [c.scenario.bid.strategy for c in cells] == [
        "randomized", "on-demand-cap"]
    # non-bid scenario fields are shared across the axis
    assert all(c.scenario.n_pools == 3 for c in cells)
    rt = ExperimentSpec.from_json(exp.to_json())
    assert rt == exp and rt.to_dict() == exp.to_dict()


def test_workload_grid_fans_cross_product_in_axis_order():
    exp = _grid_experiment(
        workload_grid={"fleet_scale": (1.0, 2.0),
                       "spot_submit_window": (300.0,)})
    cells = exp.cells()
    assert len(cells) == 2
    assert [c.scenario.workload_params["fleet_scale"] for c in cells] == \
        [1.0, 2.0]
    assert all(c.scenario.workload_params["spot_submit_window"] == 300.0
               for c in cells)
    rt = ExperimentSpec.from_json(exp.to_json())
    assert rt == exp
    assert rt.workload_grid == {"fleet_scale": (1.0, 2.0),
                                "spot_submit_window": (300.0,)}


def test_new_axes_nest_inside_the_pr4_grid_order():
    exp = _grid_experiment(
        regimes=("calm", "volatile"),
        migrations=(MigrationSpec(), MigrationSpec("gradient-aware")),
        bids=(BidSpec("randomized"), BidSpec("on-demand-cap")),
        workload_grid={"fleet_scale": (1.0, 2.0)})
    cells = exp.cells()
    assert len(cells) == 2 * 2 * 2 * 2
    key = [(c.scenario.regime, c.migration.policy, c.scenario.bid.strategy,
            c.scenario.workload_params["fleet_scale"]) for c in cells]
    # regime outermost, then migration, bid, workload innermost
    assert key[0] == ("calm", "none", "randomized", 1.0)
    assert key[1] == ("calm", "none", "randomized", 2.0)
    assert key[2] == ("calm", "none", "on-demand-cap", 1.0)
    assert key[4] == ("calm", "gradient-aware", "randomized", 1.0)
    assert key[8] == ("volatile", "none", "randomized", 1.0)


def test_inert_axes_keep_pr4_cells_and_dict_shape():
    exp = _grid_experiment()
    assert exp.bids is None and exp.workload_grid == {}
    d = exp.to_dict()
    assert d["bids"] is None and d["workload_grid"] == {}
    # pre-PR5 spec files (no bids / workload_grid keys) still load
    legacy = {k: v for k, v in d.items()
              if k not in ("bids", "workload_grid")}
    assert ExperimentSpec.from_dict(legacy) == exp


def test_grid_axis_validation_errors():
    with pytest.raises(ValueError, match="bids cannot be empty"):
        _grid_experiment(bids=())
    with pytest.raises(ValueError, match="cannot be empty"):
        _grid_experiment(workload_grid={"fleet_scale": ()})
    with pytest.raises(ValueError, match="exactly one place"):
        ExperimentSpec(
            name="x",
            scenario=_market_scenario().replace(
                workload_params={"fleet_scale": 1.0}),
            policies=(PolicySpec("first-fit"),),
            seeds=(0,),
            workload_grid={"fleet_scale": (1.0, 2.0)})
    # unknown workload param fails at construction, not in a worker
    with pytest.raises(ValueError, match="unknown workload"):
        _grid_experiment(workload_grid={"not_a_param": (1,)})
    # scalars and strings are spec errors, not raw TypeErrors or
    # silent per-character axes
    with pytest.raises(ValueError, match="list/tuple of values"):
        _grid_experiment(workload_grid={"fleet_scale": 2.0})
    with pytest.raises(ValueError, match="list/tuple of values"):
        _grid_experiment(workload_grid={"fleet_scale": "1.0"})
    # a bid axis over a regime-less scenario fails via cell validation
    with pytest.raises(ValueError, match="bid strategy needs a market"):
        ExperimentSpec(
            name="x",
            scenario=ScenarioSpec(workload="synthetic"),
            policies=(PolicySpec("first-fit"),),
            seeds=(0,),
            bids=(BidSpec("randomized"),))


def test_bid_axis_coerces_mappings():
    exp = _grid_experiment(bids=({"strategy": "on-demand-cap",
                                  "params": {"fraction": 0.9}},))
    assert exp.bids[0] == BidSpec("on-demand-cap", {"fraction": 0.9})


# ---------------------------------------------------------------------------
# PR 6 grid axes: fleet managers + fault injection
# ---------------------------------------------------------------------------
from repro.api import FaultSpec, FleetSpec  # noqa: E402
from repro.market import FAULT_REGISTRY, FLEET_STRATEGY_REGISTRY  # noqa: E402

FLEET_FAULT_SPECS = [
    FleetSpec(),
    FleetSpec("single-pool", {"target_capacity": 8.0,
                              "pool_weights": [1.0, 0.5],
                              "ladder": [["same-pool", 3], ["on-demand", 1]]}),
    FaultSpec(),
    FaultSpec("random-storms", {"rate_per_hour": 1.5, "fraction": 0.3}),
    RunSpec(scenario=_market_scenario(),
            policy=PolicySpec("first-fit"),
            fleet=FleetSpec(params={"target_capacity": 16.0}),
            faults=FaultSpec("storm", {"count": 2})),
    ExperimentSpec(
        name="resilience",
        scenario=_market_scenario(),
        policies=(PolicySpec("first-fit"),),
        fleets=(None, FleetSpec(params={"target_capacity": 16.0})),
        faults=FaultSpec("pool-outage", {"pool": 1}),
        seeds=(0, 1)),
]


@pytest.mark.parametrize("spec", FLEET_FAULT_SPECS,
                         ids=lambda s: type(s).__name__)
def test_fleet_fault_round_trip_identity(spec):
    d = spec.to_dict()
    assert type(spec).from_dict(d) == spec
    assert json.loads(json.dumps(d)) == d
    clone = type(spec).from_json(spec.to_json())
    assert clone == spec and clone.to_json() == spec.to_json()


@pytest.mark.parametrize("factory, match", [
    (lambda: FleetSpec("nope"), "unknown fleet strategy"),
    (lambda: FleetSpec(params={"typo": 1}),
     "unknown fleet strategy 'diversified' parameter"),
    (lambda: FleetSpec(params={"target_capacity": -1.0}),
     "target_capacity"),
    (lambda: FleetSpec(params={"pool_weights": [1.0, -1.0]}),
     "conflicting fleet pool_weights"),
    (lambda: FleetSpec(params={"ladder": [["teleport", 1]]}),
     "unknown fallback rung"),
    (lambda: FaultSpec("nope"), "unknown fault scenario"),
    (lambda: FaultSpec(params={"typo": 1}),
     "fault scenario 'storm' parameter"),
    (lambda: RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                     policy=PolicySpec("first-fit"), fleet=FleetSpec()),
     "fleet manager requires a market engine"),
    (lambda: RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                     policy=PolicySpec("first-fit"), faults=FaultSpec()),
     "fault injection requires a market engine"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"),
                     fleet=FleetSpec(params={"pool_weights": [1.0, 1.0]})),
     "2 entries for 3 pools"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"),
                     fleet=FleetSpec(params={"ladder": [["pool:9", 1]]})),
     "names unknown pool 9"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"),
                     faults=FaultSpec("pool-outage", {"pool": 7})),
     "unknown pool"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"), fleet="diversified"),
     "fleet must be"),
    (lambda: RunSpec(scenario=_market_scenario(),
                     policy=PolicySpec("first-fit"), faults=5),
     "faults must be"),
    (lambda: ExperimentSpec(scenario=_market_scenario(),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0,), fleets=()), "fleets cannot be empty"),
    (lambda: ExperimentSpec(scenario=_market_scenario(),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0,), fleets=("diversified",)),
     "fleets must all be"),
    (lambda: ExperimentSpec(scenario=_market_scenario(),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0,), faults=5), "faults must be"),
    # a fleet over an engine-less scenario fails via cell validation
    (lambda: ExperimentSpec(scenario=ScenarioSpec(workload="synthetic"),
                            policies=(PolicySpec("first-fit"),),
                            seeds=(0,), fleets=(FleetSpec(),)),
     "fleet manager requires a market engine"),
])
def test_fleet_fault_validation_fails_fast(factory, match):
    with pytest.raises(ValueError, match=match):
        factory()


@pytest.mark.parametrize("registry, known", [
    (FLEET_STRATEGY_REGISTRY, "diversified"),
    (FAULT_REGISTRY, "random-storms"),
])
def test_fleet_fault_registries_list_known_names(registry, known):
    assert known in registry
    with pytest.raises(ValueError) as exc:
        registry.get("definitely-not-registered")
    msg = str(exc.value)
    assert "definitely-not-registered" in msg and known in msg


def test_fleet_axis_fans_cells_and_round_trips():
    exp = _grid_experiment(
        fleets=(None, FleetSpec(params={"target_capacity": 8.0})),
        faults=FaultSpec("storm", {"count": 2}))
    cells = exp.cells()
    assert len(cells) == 2
    assert cells[0].fleet is None
    assert cells[1].fleet.params["target_capacity"] == 8.0
    # faults apply to every cell (the same seeded schedule per seed), so
    # fleet-vs-baseline cells stay comparable
    assert all(c.faults == exp.faults for c in cells)
    rt = ExperimentSpec.from_json(exp.to_json())
    assert rt == exp and rt.to_dict() == exp.to_dict()


def test_fleet_axis_nests_innermost():
    exp = _grid_experiment(
        bids=(BidSpec("randomized"), BidSpec("on-demand-cap")),
        fleets=(None, FleetSpec()))
    key = [(c.scenario.bid.strategy, c.fleet is not None)
           for c in exp.cells()]
    assert key == [("randomized", False), ("randomized", True),
                   ("on-demand-cap", False), ("on-demand-cap", True)]


def test_inert_fleet_axes_keep_prior_dict_shape():
    exp = _grid_experiment()
    d = exp.to_dict()
    assert d["fleets"] is None and d["faults"] is None
    # pre-PR6 spec files (no fleets / faults keys) still load
    legacy = {k: v for k, v in d.items() if k not in ("fleets", "faults")}
    assert ExperimentSpec.from_dict(legacy) == exp


def test_fleet_axis_coerces_mappings():
    exp = _grid_experiment(
        fleets=({"strategy": "lowest-price", "params": {}}, None),
        faults={"scenario": "price-spike", "params": {"magnitude": 1.5}})
    assert exp.fleets[0] == FleetSpec("lowest-price")
    assert exp.fleets[1] is None
    assert exp.faults == FaultSpec("price-spike", {"magnitude": 1.5})
