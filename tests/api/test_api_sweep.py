"""Sweep runner: deterministic reports, serial == multiprocessing, CI
aggregation math."""
import json
import math

import pytest

from repro.api import (
    BidSpec,
    ExperimentSpec,
    MigrationSpec,
    PolicySpec,
    RunSpec,
    ScenarioSpec,
    aggregate_rows,
    mean_ci95,
    run_experiment,
    run_one,
    write_report,
)
from repro.api.sweep import format_report, t_crit95

UNTIL = 1200.0


def _mini_experiment() -> ExperimentSpec:
    """3 seeds × 2 policies over the synthetic scenario (fast, no engine)."""
    return ExperimentSpec(
        name="mini",
        scenario=ScenarioSpec(workload="synthetic", horizon=UNTIL),
        policies=(PolicySpec("first-fit"),
                  PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5})),
        seeds=(0, 1, 2))


def test_mini_sweep_deterministic_report():
    exp = _mini_experiment()
    r1 = run_experiment(exp, processes=0)
    r2 = run_experiment(exp, processes=0)
    assert json.dumps(r1, sort_keys=True) == json.dumps(r2, sort_keys=True)
    assert r1["n_runs"] == 6
    assert [c["policy"] for c in r1["cells"]] == ["first-fit",
                                                  "hlem-vmp-adjusted"]
    for cell in r1["cells"]:
        assert cell["n_seeds"] == 3
        assert [row["seed"] for row in cell["rows"]] == [0, 1, 2]
        m = cell["metrics"]["interruptions"]
        assert m["n"] == 3
        assert m["min"] <= m["mean"] <= m["max"]
        # identifier keys never aggregate
        assert "seed" not in cell["metrics"]
        assert "policy" not in cell["metrics"]


def test_sweep_parallel_equals_serial():
    exp = _mini_experiment()
    serial = run_experiment(exp, processes=0)
    parallel = run_experiment(exp, processes=2)
    assert json.dumps(serial, sort_keys=True) == \
        json.dumps(parallel, sort_keys=True)


def test_sweep_rows_match_run_one():
    exp = _mini_experiment()
    report = run_experiment(exp, processes=0)
    cell = report["cells"][1]
    spec = RunSpec(scenario=exp.scenario, policy=exp.policies[1])
    assert cell["rows"][2] == run_one(spec, seed=2, until=UNTIL)


def test_sweep_report_json_artifact(tmp_path):
    exp = _mini_experiment()
    report = run_experiment(exp, processes=0)
    path = write_report(report, str(tmp_path / "report.json"))
    with open(path) as f:
        loaded = json.load(f)
    assert loaded == json.loads(json.dumps(report))
    # the embedded experiment spec round-trips from the artifact
    assert ExperimentSpec.from_dict(loaded["experiment"]) == exp
    assert "first-fit" in format_report(report)


def test_market_sweep_cells_fan_regimes_and_migrations():
    exp = ExperimentSpec(
        name="market-mini",
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              bid=BidSpec("randomized", {"lo": 0.45})),
        policies=(PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),),
        migrations=(MigrationSpec(), MigrationSpec("gradient-aware")),
        regimes=("calm", "volatile"),
        seeds=(0, 1))
    report = run_experiment(exp, until=900.0)
    assert [(c["regime"], c["migration"]) for c in report["cells"]] == [
        ("calm", "none"), ("calm", "gradient-aware"),
        ("volatile", "none"), ("volatile", "gradient-aware")]
    for cell in report["cells"]:
        assert {row["seed"] for row in cell["rows"]} == {0, 1}
        assert "realized_spot_cost" in cell["metrics"]


# -- aggregation math ---------------------------------------------------------
def test_mean_ci95_known_values():
    stats = mean_ci95([1.0, 2.0, 3.0])
    assert stats["mean"] == 2.0
    assert stats["n"] == 3
    # sd = 1, se = 1/sqrt(3), t(df=2) = 4.303
    assert stats["ci95"] == pytest.approx(4.303 / math.sqrt(3), abs=1e-6)
    assert stats["min"] == 1.0 and stats["max"] == 3.0


def test_mean_ci95_single_sample_has_zero_ci():
    stats = mean_ci95([5.0])
    assert stats == {"mean": 5.0, "ci95": 0.0, "min": 5.0, "max": 5.0,
                     "n": 1}


def test_t_crit_table():
    assert t_crit95(1) == pytest.approx(12.706)
    assert t_crit95(19) == pytest.approx(2.093)   # the >=20-seed sweeps
    # beyond the table: continuous at the boundary, no drop to 1.96
    assert t_crit95(31) == pytest.approx(t_crit95(30), abs=0.01)
    assert t_crit95(40) == pytest.approx(2.021, abs=0.005)
    assert t_crit95(10_000) == pytest.approx(1.96, abs=0.001)
    # monotone decreasing toward the normal limit
    assert t_crit95(30) > t_crit95(31) > t_crit95(60) > 1.96


def test_aggregate_rows_skips_identifiers_and_non_numeric():
    rows = [
        {"policy": "p", "regime": "calm", "migration": "none", "seed": 0,
         "interruptions": 4, "note": "x", "flag": True},
        {"policy": "p", "regime": "calm", "migration": "none", "seed": 1,
         "interruptions": 6, "note": "y", "flag": False},
    ]
    agg = aggregate_rows(rows)
    assert set(agg) == {"interruptions"}
    assert agg["interruptions"]["mean"] == 5.0


# ---------------------------------------------------------------------------
# PR 5: incremental report writing + crash resume + grid-axis metadata
# ---------------------------------------------------------------------------
def test_report_path_writes_final_report_atomically(tmp_path):
    exp = _mini_experiment()
    path = str(tmp_path / "report.json")
    report = run_experiment(exp, processes=0, report_path=path)
    with open(path) as f:
        on_disk = json.load(f)
    assert on_disk == json.loads(json.dumps(report))
    assert "partial" not in on_disk
    assert not (tmp_path / "report.json.tmp").exists()


def test_partial_report_resumes_and_matches_fresh_run(tmp_path, monkeypatch):
    exp = _mini_experiment()
    path = str(tmp_path / "report.json")
    fresh = run_experiment(exp, processes=0)

    # simulate a crash after the first completed cell: a partial file with
    # the prefix of the grid, marked partial
    partial = json.loads(json.dumps(fresh))
    partial["cells"] = partial["cells"][:1]
    partial["partial"] = True
    with open(path, "w") as f:
        json.dump(partial, f)

    calls = []
    import repro.api.sweep as sweep_mod
    real = sweep_mod._run_job

    def counting(job):
        calls.append(job)
        return real(job)

    monkeypatch.setattr(sweep_mod, "_run_job", counting)
    resumed = run_experiment(exp, processes=0, report_path=path)
    # only the second cell's seeds ran; the report is byte-identical
    assert len(calls) == len(exp.seeds)
    assert json.dumps(resumed, sort_keys=True) == \
        json.dumps(fresh, sort_keys=True)
    with open(path) as f:
        assert json.load(f) == json.loads(json.dumps(fresh))


def test_mismatched_partial_is_ignored(tmp_path, monkeypatch):
    exp = _mini_experiment()
    other = exp.replace(seeds=(5, 6, 7))
    path = str(tmp_path / "report.json")
    run_experiment(other, processes=0, report_path=path, until=UNTIL / 2)

    calls = []
    import repro.api.sweep as sweep_mod
    real = sweep_mod._run_job

    def counting(job):
        calls.append(job)
        return real(job)

    monkeypatch.setattr(sweep_mod, "_run_job", counting)
    report = run_experiment(exp, processes=0, report_path=path)
    assert len(calls) == len(exp.cells()) * len(exp.seeds)
    assert json.dumps(report, sort_keys=True) == json.dumps(
        run_experiment(exp, processes=0), sort_keys=True)


def test_resume_false_recomputes(tmp_path, monkeypatch):
    exp = _mini_experiment()
    path = str(tmp_path / "report.json")
    run_experiment(exp, processes=0, report_path=path)
    calls = []
    import repro.api.sweep as sweep_mod
    real = sweep_mod._run_job

    def counting(job):
        calls.append(job)
        return real(job)

    monkeypatch.setattr(sweep_mod, "_run_job", counting)
    run_experiment(exp, processes=0, report_path=path, resume=False)
    assert len(calls) == len(exp.cells()) * len(exp.seeds)


def test_grid_axis_cells_carry_identifying_metadata():
    exp = ExperimentSpec(
        name="axes",
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              bid=BidSpec("randomized", {"lo": 0.45})),
        policies=(PolicySpec("first-fit"),),
        bids=(BidSpec("randomized", {"lo": 0.45}),
              BidSpec("on-demand-cap", {"fraction": 0.7})),
        workload_grid={"fleet_scale": (0.5, 1.0)},
        seeds=(0,))
    report = run_experiment(exp, processes=0, until=600.0)
    assert [(c["bid"]["strategy"], c["workload_params"]["fleet_scale"])
            for c in report["cells"]] == [
        ("randomized", 0.5), ("randomized", 1.0),
        ("on-demand-cap", 0.5), ("on-demand-cap", 1.0)]
    # the full bid spec (params included) identifies the cell: two specs
    # sharing a strategy stay distinguishable
    assert report["cells"][2]["bid"]["params"] == {"fraction": 0.7}
    # inert axes add no cell keys (PR 4 report shape preserved)
    plain = run_experiment(_mini_experiment(), processes=0, until=600.0)
    assert all("bid" not in c and "workload_params" not in c
               for c in plain["cells"])


# ---------------------------------------------------------------------------
# PR 6: fleet axis + fault injection through the sweep runner
# ---------------------------------------------------------------------------
def _resilience_experiment() -> ExperimentSpec:
    from repro.api import FaultSpec, FleetSpec
    return ExperimentSpec(
        name="resilience-mini",
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              n_pools=2, horizon=1800.0),
        policies=(PolicySpec("first-fit"),),
        fleets=(None, FleetSpec(params={"target_capacity": 8.0})),
        faults=FaultSpec("storm", {"first": 600.0, "every": 600.0,
                                   "count": 2, "fraction": 0.5}),
        seeds=(0, 1))


def test_fleet_fault_sweep_parallel_equals_serial():
    """Chaos-determinism through the sweep runner: a fleet axis under
    injected storms produces byte-identical reports serial vs
    multiprocessing."""
    exp = _resilience_experiment()
    serial = run_experiment(exp, processes=0)
    parallel = run_experiment(exp, processes=2)
    assert json.dumps(serial, sort_keys=True) == \
        json.dumps(parallel, sort_keys=True)


def test_fleet_cells_carry_spec_and_resilience_metrics():
    exp = _resilience_experiment()
    report = run_experiment(exp, processes=0)
    baseline, fleet_cell = report["cells"]
    assert baseline["fleet"] is None
    assert fleet_cell["fleet"]["strategy"] == "diversified"
    # resilience columns appear only where a fleet manager ran
    assert "time_below_target_s" not in baseline["metrics"]
    for key in ("time_below_target_s", "shortfall_area", "mean_recovery_s",
                "faults_fired", "fleet_launches", "fleet_spot_cost"):
        assert key in fleet_cell["metrics"], key
    # every cell saw the same number of injected faults
    assert all(r["faults_fired"] == 2 for r in fleet_cell["rows"])
    # the report renders with fleet + recovery columns
    txt = format_report(report)
    assert "per-vm" in txt and "diversified" in txt and "below_tgt_s" in txt
    # inert-axis reports keep the old column set
    assert "below_tgt_s" not in format_report(
        run_experiment(_mini_experiment(), processes=0, until=600.0))
