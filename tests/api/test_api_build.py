"""Builder bit-identity: ``api.build(spec, seed)`` runs must equal
hand-wired ``MarketSimulator`` runs exactly (metrics JSON equality) at
fixed seed — for the synthetic scenario, the trace scenario, and the
engine-coupled market scenario across regimes and migration policies."""
import copy
import json

import pytest

from repro.api import (
    BidSpec,
    MigrationSpec,
    PolicySpec,
    RebidSpec,
    RunSpec,
    ScenarioSpec,
    build,
    collect_row,
    run_one,
)
from repro.core import (
    MarketScenarioConfig,
    MarketSimulator,
    ScenarioConfig,
    SimConfig,
    make_policy,
    market_scenario,
    synthetic_scenario,
)
from repro.market import (
    MarketEngine,
    RebidOnResume,
    TraceConfig,
    assign_bids,
    generate_trace,
    make_bid_strategy,
    make_market,
    make_migration_planner,
    simulate_trace,
)

UNTIL_MARKET = 2400.0


def _row_json(sim, metrics, spec, seed) -> str:
    return json.dumps(collect_row(sim, metrics, spec, seed), sort_keys=True)


# -- synthetic ----------------------------------------------------------------
def test_synthetic_bit_identity():
    seed, until = 3, 1500.0
    spec = RunSpec(
        scenario=ScenarioSpec(
            workload="synthetic",
            sim_params={"interruption_selector": "best_fit_remaining"}),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}))

    # hand-wired, exactly as launch/market_sim.py did before the API layer
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=seed))
    sim = MarketSimulator(
        policy=make_policy("hlem-vmp-adjusted", alpha=-0.5),
        config=SimConfig(record_timeline=False,
                         interruption_selector="best_fit_remaining"))
    for cap in hosts:
        sim.add_host(cap)
    for v in vms:
        sim.submit(copy.deepcopy(v))
    m = sim.run(until=until)

    api_sim = build(spec, seed)
    api_m = api_sim.run(until=until)
    assert _row_json(api_sim, api_m, spec, seed) == \
        _row_json(sim, m, spec, seed)
    assert api_m.interruption_events == m.interruption_events


# -- trace --------------------------------------------------------------------
def test_trace_bit_identity():
    seed = 5
    cfg = dict(n_machines=40, sim_days=0.05, n_spot=150)
    spec = RunSpec(
        scenario=ScenarioSpec(workload="trace", workload_params=cfg),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}))

    tcfg = TraceConfig(seed=seed, **cfg)
    sim, m = simulate_trace(generate_trace(tcfg),
                            policy=make_policy("hlem-vmp-adjusted"),
                            cfg=tcfg)

    api_sim = build(spec, seed)
    api_m = api_sim.run()
    assert _row_json(api_sim, api_m, spec, seed) == \
        _row_json(sim, m, spec, seed)
    assert api_m.allocations == m.allocations
    assert api_m.interruption_events == m.interruption_events


# -- market (engine-coupled) --------------------------------------------------
def _hand_market_row(policy_name, regime, seed, until, migration="none",
                     rebid=False, spec=None):
    """The exact pre-API wiring of launch/market_sim.run_market."""
    hosts, pool_ids, vms = market_scenario(
        MarketScenarioConfig(seed=seed, n_pools=4))
    mc = make_market(regime, n_pools=4, seed=seed, tick_interval=60.0,
                     from_advisor=True)
    engine = MarketEngine(mc)
    strat = make_bid_strategy("randomized", pool_cfg=mc.pools[0], seed=seed,
                              lo=0.45)
    assign_bids(vms, strat, seed=seed)
    planner = make_migration_planner(migration)
    rebid_hook = (RebidOnResume(on_demand_rate=mc.pools[0].on_demand_rate,
                                seed=seed) if rebid else None)
    sim = MarketSimulator(
        policy=make_policy(policy_name, alpha=-0.5),
        config=SimConfig(record_timeline=False),
        engine=engine, migration=planner, rebid=rebid_hook)
    for cap, pid in zip(hosts, pool_ids):
        sim.add_host(cap, pool=pid)
    for v in vms:
        sim.submit(v)
    m = sim.run(until=until)
    return _row_json(sim, m, spec, seed)


def _market_spec(regime, migration="none", rebid=False) -> RunSpec:
    return RunSpec(
        scenario=ScenarioSpec(workload="market", regime=regime,
                              bid=BidSpec("randomized", {"lo": 0.45})),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec(migration),
        rebid=RebidSpec() if rebid else None)


@pytest.mark.parametrize("regime", ["calm", "volatile", "correlated"])
def test_market_bit_identity_all_regimes(regime):
    seed = 0
    spec = _market_spec(regime)
    api_json = json.dumps(run_one(spec, seed, until=UNTIL_MARKET),
                          sort_keys=True)
    assert api_json == _hand_market_row("hlem-vmp-adjusted", regime, seed,
                                        UNTIL_MARKET, spec=spec)


@pytest.mark.parametrize("migration", ["none", "greedy-cheapest",
                                       "gradient-aware", "risk-budgeted"])
def test_market_bit_identity_all_migration_policies(migration):
    seed = 1
    spec = _market_spec("volatile", migration=migration)
    api_json = json.dumps(run_one(spec, seed, until=UNTIL_MARKET),
                          sort_keys=True)
    assert api_json == _hand_market_row(
        "hlem-vmp-adjusted", "volatile", seed, UNTIL_MARKET,
        migration=migration, spec=spec)


def test_market_bit_identity_with_rebid():
    seed = 2
    spec = _market_spec("volatile", migration="gradient-aware", rebid=True)
    api_json = json.dumps(run_one(spec, seed, until=UNTIL_MARKET),
                          sort_keys=True)
    assert api_json == _hand_market_row(
        "hlem-vmp-adjusted", "volatile", seed, UNTIL_MARKET,
        migration="gradient-aware", rebid=True, spec=spec)


# -- fresh state per build ----------------------------------------------------
def test_build_materializes_fresh_components_per_run():
    spec = _market_spec("volatile", migration="gradient-aware")
    sim1 = build(spec, seed=0)
    sim2 = build(spec, seed=0)
    assert sim1.engine is not sim2.engine
    assert sim1.migration is not sim2.migration
    assert sim1.policy is not sim2.policy
    # running one must not perturb the other: same decisions either way
    m1 = sim1.run(until=1200.0)
    sim3 = build(spec, seed=0)
    m3 = sim3.run(until=1200.0)
    assert json.dumps(collect_row(sim1, m1, spec, 0), sort_keys=True) == \
        json.dumps(collect_row(sim3, m3, spec, 0), sort_keys=True)
    assert m1.interruption_events == m3.interruption_events


def test_run_one_rows_are_wall_clock_free():
    spec = RunSpec(scenario=ScenarioSpec(workload="synthetic"),
                   policy=PolicySpec("first-fit"))
    row = run_one(spec, seed=0, until=400.0)
    assert "wall_s" not in row
    assert row == run_one(spec, seed=0, until=400.0)
