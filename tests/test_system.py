"""End-to-end behaviour tests for the full system.

Multi-device paths (elastic mesh, dry-run) run in subprocesses so the main
pytest process keeps the default single CPU device.
"""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
REPO = os.path.join(os.path.dirname(__file__), "..")


def _run(code: str, timeout=540, devices=8):
    env = dict(os.environ,
               PYTHONPATH=SRC,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}")
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


# ---------------------------------------------------------------------------
# market end-to-end: the paper's comparison on a reduced workload
# ---------------------------------------------------------------------------
def test_market_comparison_reduced():
    import copy

    from repro.core import (
        MarketSimulator, ScenarioConfig, SimConfig, make_policy,
        synthetic_scenario,
    )
    cfg = ScenarioConfig(seed=1)
    hosts, vms = synthetic_scenario(cfg)
    # reduce: every 4th VM, every 2nd host
    hosts = hosts[::2]
    vms = [v for i, v in enumerate(vms) if i % 4 == 0]
    results = {}
    for pol in ["first-fit", "hlem-vmp-adjusted"]:
        sim = MarketSimulator(policy=make_policy(pol),
                              config=SimConfig(record_timeline=False,
                                               strict_invariants=True))
        for cap in hosts:
            sim.add_host(cap)
        for v in vms:
            sim.submit(copy.deepcopy(v))
        m = sim.run(until=2200.0)
        results[pol] = m.spot_stats(sim.vms)
    # the adjusted policy should not interrupt more than first-fit
    assert (results["hlem-vmp-adjusted"]["interruptions"]
            <= results["first-fit"]["interruptions"])


# ---------------------------------------------------------------------------
# training end-to-end: checkpoint restart is bit-consistent with an
# uninterrupted run (exactly-once data consumption via the cursor)
# ---------------------------------------------------------------------------
def test_train_restart_continues_exactly(tmp_path):
    from repro.configs import get_smoke_config
    from repro.elastic import CheckpointManager
    from repro.train import (
        DataConfig, SyntheticDataset, init_train_state, make_train_step,
    )

    cfg = get_smoke_config("deepseek_7b").replace(dtype="float32")
    dcfg = DataConfig(batch=4, seq_len=24, seed=0)
    lr = {"warmup": 2, "total": 50, "peak": 1e-3}

    # uninterrupted run: 8 steps
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg, dcfg)
    step = jax.jit(make_train_step(cfg, lr_kwargs=lr))
    losses_a = []
    for _ in range(8):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, m = step(state, batch)
        losses_a.append(float(m["loss"]))

    # interrupted run: 4 steps, checkpoint, restore, 4 more
    cm = CheckpointManager(str(tmp_path), async_save=False)
    state_b = init_train_state(cfg, jax.random.PRNGKey(0))
    ds_b = SyntheticDataset(cfg, dcfg)
    losses_b = []
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in ds_b.next_batch().items()}
        state_b, m = step(state_b, batch)
        losses_b.append(float(m["loss"]))
    cm.save(state_b, 4, {"data_step": ds_b.step})
    del state_b

    template = jax.eval_shape(
        lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
    restored, meta = cm.restore(template)
    ds_c = SyntheticDataset(cfg, dcfg)
    ds_c.load_state_dict({"step": meta["data_step"], "seed": 0})
    for _ in range(4):
        batch = {k: jnp.asarray(v) for k, v in ds_c.next_batch().items()}
        restored, m = step(restored, batch)
        losses_b.append(float(m["loss"]))

    np.testing.assert_allclose(losses_a, losses_b, rtol=1e-5)


# ---------------------------------------------------------------------------
# elastic multi-device path (subprocess with 8 CPU devices)
# ---------------------------------------------------------------------------
def test_elastic_trainer_rescales():
    code = """
import tempfile
from repro.configs import get_smoke_config
from repro.elastic import ElasticTrainer, AvailabilityEvent
from repro.train.data import DataConfig

cfg = get_smoke_config('deepseek_7b')
events = [AvailabilityEvent(10.0, 4, 'interrupt'),
          AvailabilityEvent(20.0, 8, 'resume')]
with tempfile.TemporaryDirectory() as d:
    tr = ElasticTrainer(cfg, DataConfig(batch=8, seq_len=16, seed=0), d,
                        max_workers=8)
    rep = tr.train_elastic(total_steps=30, events=events)
    assert rep.steps_run == 30, rep.steps_run
    assert rep.emergency_saves >= 1
    widths = [w for _, w in rep.mesh_history]
    assert 4 in widths and 8 in widths
print('ELASTIC_OK')
"""
    r = _run(code)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# dry-run machinery smoke (subprocess, small 4x2 mesh, MoE arch)
# ---------------------------------------------------------------------------
def test_dryrun_machinery_small_mesh():
    code = """
import jax
from jax.sharding import Mesh
import numpy as np
from repro.configs import get_smoke_config
from repro.models.sharding import use_mesh
from repro.launch.specs import ShapeSpec, input_specs
from repro.train.train_step import make_train_step
from repro.launch.hlo_analyzer import analyze

cfg = get_smoke_config('granite_moe_3b_a800m')
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ('data', 'model'))
shape = ShapeSpec('mini_train', 'train', 64, 8)
with use_mesh(mesh):
    args = input_specs(cfg, shape)
    compiled = jax.jit(make_train_step(cfg),
                       donate_argnums=(0,)).lower(*args).compile()
    ana = analyze(compiled.as_text())
    assert ana.flops > 0
print('DRYRUN_OK')
"""
    r = _run(code)
    assert "DRYRUN_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# production dry-run results must be error-free once generated
# ---------------------------------------------------------------------------
def test_dryrun_results_if_present():
    import glob
    files = glob.glob(os.path.join(REPO, "results", "dryrun", "*.json"))
    if not files:
        pytest.skip("run PYTHONPATH=src python -m repro.launch.dryrun first")
    statuses = {}
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        statuses.setdefault(rec["status"], []).append(
            (rec["arch"], rec["shape"], rec["mesh"]))
    assert "error" not in statuses, statuses.get("error")
    # 10 archs x 4 shapes x 2 meshes = 80 cells; 8 full-attention archs skip
    # long_500k on both meshes = 16 skips
    assert len(statuses.get("ok", [])) >= 60
    assert len(statuses.get("skipped", [])) == 16
