"""Cluster-level HLEM-VMP job placement (the paper's algorithm as the
launcher's scheduler)."""
import numpy as np

from repro.elastic import ClusterScheduler, JobSpec


def test_jobs_placed_and_spread():
    cs = ClusterScheduler(n_slices=4, warning_s=0.0)
    for i in range(4):
        cs.submit(JobSpec(f"train-{i}", chips=128, hbm_gb=2048,
                          ici_gbps=10_000, host_ram_gb=6_000,
                          duration_h=2.0, preemptible=True))
    cs.run(until_h=0.01)
    placement = cs.placement()
    assert all(h >= 0 for h in placement.values())
    # adjusted HLEM spreads spot jobs across slices
    assert len(set(placement.values())) == 4


def test_reserved_job_preempts_spot():
    cs = ClusterScheduler(n_slices=1, warning_s=0.0)
    cs.submit(JobSpec("spot-a", chips=200, hbm_gb=3000, ici_gbps=20_000,
                      host_ram_gb=10_000, duration_h=10.0, preemptible=True))
    cs.submit(JobSpec("prod", chips=200, hbm_gb=3000, ici_gbps=20_000,
                      host_ram_gb=10_000, duration_h=1.0, preemptible=False),
              at=3600.0)  # after min_running_time
    cs.run(until_h=1.2)
    states = cs.states()
    assert states["prod"] in ("running", "finished")
    assert states["spot-a"] in ("hibernated", "waiting", "running")
    vm = cs._jobs["spot-a"]
    assert vm.interruptions >= 1
