"""Gradient compression (int8 + error feedback) and straggler mitigation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.elastic import (
    StragglerDetector,
    compress_tree,
    compressed_grad_combine,
    decompress_tree,
    dequantize_int8,
    init_error_feedback,
    masked_grad_mean,
    quantize_int8,
)


def test_quantize_dequantize_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 1, (64, 64)), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-7


def test_error_feedback_accumulates_residual():
    g = {"w": jnp.asarray([[0.001, 1.0], [-0.5, 0.002]], jnp.float32)}
    ef = init_error_feedback(g)
    out, ef2 = compressed_grad_combine(g, ef)
    # residual = corrected - dequant
    resid = g["w"] - out["w"]
    np.testing.assert_allclose(np.asarray(ef2["w"]), np.asarray(resid),
                               atol=1e-7)


def test_ef_sgd_converges_like_uncompressed():
    """Quadratic convergence with int8+EF gradients ~ matches full precision."""
    rng = np.random.default_rng(0)
    target = jnp.asarray(rng.normal(0, 1, (16, 16)), jnp.float32)

    def run(compressed):
        w = {"w": jnp.zeros((16, 16))}
        ef = init_error_feedback(w)
        for _ in range(200):
            g = {"w": 2 * (w["w"] - target)}
            if compressed:
                g, ef = compressed_grad_combine(g, ef)
            w = {"w": w["w"] - 0.05 * g["w"]}
        return float(jnp.mean((w["w"] - target) ** 2))

    full = run(False)
    comp = run(True)
    assert comp < 1e-3, comp
    assert comp < full * 10 + 1e-4


def test_compression_ratio_is_4x():
    x = jnp.zeros((1024,), jnp.float32)
    q, s = quantize_int8(x)
    assert q.dtype == jnp.int8  # 4x fewer bytes across the pod links


def test_masked_grad_mean_drops_stragglers():
    g = {"w": jnp.stack([jnp.full((4,), 1.0), jnp.full((4,), 3.0),
                         jnp.full((4,), 100.0)])}
    arrived = jnp.asarray([True, True, False])
    out = masked_grad_mean(g, arrived)
    np.testing.assert_allclose(np.asarray(out["w"]), np.full((4,), 2.0))


def test_masked_grad_mean_all_arrived():
    g = {"w": jnp.stack([jnp.full((2,), 1.0), jnp.full((2,), 2.0)])}
    out = masked_grad_mean(g, jnp.asarray([True, True]))
    np.testing.assert_allclose(np.asarray(out["w"]), np.full((2,), 1.5))


def test_straggler_detector_flags_persistent_slow_host():
    det = StragglerDetector(threshold=1.5, patience=2)
    for step in range(4):
        for h in range(4):
            det.observe(h, 1.0 if h != 3 else 3.0)
        flagged = det.stragglers()
    assert flagged == [3]


def test_straggler_detector_recovers():
    det = StragglerDetector(threshold=1.5, patience=2, alpha=1.0)
    for h in range(3):
        det.observe(h, 1.0)
    det.observe(0, 5.0)
    det.stragglers()
    det.observe(0, 1.0)  # back to normal -> strikes reset
    assert det.stragglers() == []
