"""Optimizer + train-step correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.train import (
    DataConfig,
    SyntheticDataset,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    init_train_state,
    lr_schedule,
    make_train_step,
)


def test_adamw_matches_manual_step():
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.1])}
    st = adamw_init(p)
    lr, b1, b2, eps, wd = 0.1, 0.9, 0.95, 1e-8, 0.0
    p2, st2 = adamw_update(p, g, st, lr=jnp.float32(lr), b1=b1, b2=b2,
                           eps=eps, weight_decay=wd)
    m = (1 - b1) * np.asarray(g["w"])
    v = (1 - b2) * np.asarray(g["w"]) ** 2
    mh, vh = m / (1 - b1), v / (1 - b2)
    want = np.asarray(p["w"]) - lr * mh / (np.sqrt(vh) + eps)
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-6)
    assert int(st2.step) == 1


@pytest.mark.parametrize("opt", ["adamw", "adafactor"])
def test_optimizers_converge_on_quadratic(opt):
    target = jnp.asarray(np.random.default_rng(0).normal(0, 1, (8, 8)),
                         jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    init, upd = ((adamw_init, adamw_update) if opt == "adamw"
                 else (adafactor_init, adafactor_update))
    st = init(params)
    loss_fn = lambda p: jnp.mean((p["w"] - target) ** 2)
    for i in range(300):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, st = upd(params, g, st, lr=jnp.float32(0.05))
    assert float(loss_fn(params)) < 0.02, float(loss_fn(params))


def test_lr_schedule_shape():
    assert float(lr_schedule(jnp.asarray(0), peak=1.0, warmup=10,
                             total=100)) == pytest.approx(0.0)
    assert float(lr_schedule(jnp.asarray(10), peak=1.0, warmup=10,
                             total=100)) == pytest.approx(1.0, abs=1e-3)
    end = float(lr_schedule(jnp.asarray(100), peak=1.0, warmup=10, total=100,
                            min_ratio=0.1))
    assert end == pytest.approx(0.1, abs=1e-3)


def test_grad_accum_equivalent_to_full_batch():
    cfg = get_smoke_config("deepseek_7b").replace(dtype="float32")
    key = jax.random.PRNGKey(0)
    state1 = init_train_state(cfg, key)
    state2 = init_train_state(cfg, key)
    ds = SyntheticDataset(cfg, DataConfig(batch=8, seq_len=16, seed=1))
    batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
    s1, m1 = jax.jit(make_train_step(cfg, grad_accum=1))(state1, batch)
    s2, m2 = jax.jit(make_train_step(cfg, grad_accum=4))(state2, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_loss_decreases_over_training():
    cfg = get_smoke_config("musicgen_large")
    # audio modality consumes embeddings; use text-like labels over vocab
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    ds = SyntheticDataset(cfg, DataConfig(batch=8, seq_len=32, seed=0))
    step = jax.jit(make_train_step(
        cfg, lr_kwargs={"warmup": 3, "total": 60, "peak": 3e-3}))
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in ds.next_batch().items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_data_pipeline_checkpointable_cursor():
    cfg = get_smoke_config("deepseek_7b")
    d1 = SyntheticDataset(cfg, DataConfig(batch=2, seq_len=8, seed=5))
    for _ in range(3):
        d1.next_batch()
    st = d1.state_dict()
    b_next = d1.next_batch()
    d2 = SyntheticDataset(cfg, DataConfig(batch=2, seq_len=8, seed=5))
    d2.load_state_dict(st)
    b_resumed = d2.next_batch()
    np.testing.assert_array_equal(b_next["tokens"], b_resumed["tokens"])
