"""Checkpoint manager: roundtrip, atomicity, GC, emergency saves."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.elastic import CheckpointManager
from repro.train import init_train_state


@pytest.fixture
def state():
    cfg = get_smoke_config("deepseek_7b")
    return init_train_state(cfg, jax.random.PRNGKey(0))


def test_roundtrip(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(state, 5, {"data_step": 17})
    template = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, meta = cm.restore(template)
    assert meta["step"] == 5 and meta["data_step"] == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_async_save_and_wait(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    cm.save(state, 1)
    cm.save(state, 2)
    cm.wait()
    assert cm.all_steps() == [1, 2]


def test_keep_n_gc(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), keep_n=2, async_save=False)
    for s in [1, 2, 3, 4]:
        cm.save(state, s)
    assert cm.all_steps() == [3, 4]


def test_atomicity_tmp_dirs_ignored(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(state, 1)
    # simulate a preemption mid-write: stale tmp dir + half-written step dir
    os.makedirs(tmp_path / "step_9.tmp")
    os.makedirs(tmp_path / "step_7")  # no meta.json -> incomplete
    assert cm.latest_step() == 1
    restored, meta = cm.restore(jax.tree.map(jnp.zeros_like, state))
    assert meta["step"] == 1


def test_emergency_save_is_synchronous(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), async_save=True)
    cm.save_on_warning(state, 3, {"data_step": 1})
    # must be on disk immediately, no wait() needed
    assert cm.latest_step() == 3
    with open(tmp_path / "step_3" / "meta.json") as f:
        assert json.load(f)["emergency"] is True


def test_leaf_count_mismatch_raises(tmp_path, state):
    cm = CheckpointManager(str(tmp_path), async_save=False)
    cm.save(state, 1)
    with pytest.raises(AssertionError, match="leaves"):
        cm.restore({"just_one": jnp.zeros(3)})
