from .mesh import available_devices, make_mesh, make_production_mesh
from .specs import SHAPES, ShapeSpec, cell_supported, input_specs, rules_for

__all__ = [k for k in dir() if not k.startswith("_")]
