"""Structural analyzer for optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE, which makes it
useless for scan-over-layers programs (a 126-layer model reports 1-layer
flops).  This analyzer parses the HLO module into computations, builds the
call graph (while bodies annotated with known_trip_count, fusions, calls,
reduce appliers), propagates trip-count multipliers from ENTRY, and attributes
three quantities to every computation:

  * flops             — from dot ops (2 x result_elems x contracted_elems)
  * hbm bytes         — operand+result bytes of top-level (fusion-boundary)
                        instructions; fusion internals excluded
  * collective bytes  — operand bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute

Totals are Σ per-computation x trip-multiplier — i.e. true per-device,
per-step costs for scanned/grad-accumulated programs.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    """All array components in a (possibly tuple) type string."""
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype = m.group(1)
        if dtype not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((dtype, dims))
    return out


def _type_elems(type_str: str) -> int:
    total = 0
    for _, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


def _first_dims(type_str: str) -> List[int]:
    s = _shape_list(type_str)
    return s[0][1] if s else []


@dataclass
class Instruction:
    name: str
    result_type: str
    opcode: str
    operands: List[str]
    raw: str


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    types: Dict[str, str] = field(default_factory=dict)  # instr name -> type


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*[^{]+\{")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s+([\w\-]+)\((.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


_COMMENT = re.compile(r"/\*.*?\*/")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        line = _COMMENT.sub("", line)
        if cur is None:
            m = _COMP_HEADER.match(line.strip()) if "{" in line else None
            if m and ("->" in line):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                # parse header params: `name: type` pairs
                hdr = line[line.find("(") + 1: line.rfind(")")]
                for pm in re.finditer(r"%?([\w\.\-]+)\s*:\s*([^,()]+(?:\([^)]*\))?)",
                                      hdr):
                    cur.types[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, rtype, opcode, rest = m.groups()
        # operand names: %tokens up to the closing paren of the call
        depth, end = 1, 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        call_args = rest[:end]
        operands = re.findall(r"%([\w\.\-]+)", call_args)
        instr = Instruction(name, rtype.strip(), opcode, operands, line)
        cur.instructions.append(instr)
        cur.types[name] = rtype.strip()
    return comps, entry


def _call_edges(comp: Computation) -> List[Tuple[str, float, str]]:
    """(callee, multiplier, kind) edges out of this computation."""
    edges = []
    for ins in comp.instructions:
        raw = ins.raw
        if ins.opcode == "while":
            trip = 1.0
            tm = _TRIP.search(raw)
            if tm:
                trip = float(tm.group(1))
            for key in ("body", "condition"):
                m = re.search(key + r"=%?([\w\.\-]+)", raw)
                if m:
                    edges.append((m.group(1), trip, "while"))
        else:
            for key in ("calls", "to_apply"):
                m = re.search(key + r"=%?([\w\.\-]+)", raw)
                if m:
                    edges.append((m.group(1), 1.0, ins.opcode))
            # conditionals: branch_computations={%a, %b}
            m = re.search(r"branch_computations=\{([^}]*)\}", raw)
            if m:
                for b in re.findall(r"%([\w\.\-]+)", m.group(1)):
                    edges.append((b, 1.0, "conditional"))
            for key in ("true_computation", "false_computation"):
                m = re.search(key + r"=%?([\w\.\-]+)", raw)
                if m:
                    edges.append((m.group(1), 1.0, "conditional"))
    return edges


_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "partition-id", "replica-id", "iota",
    # control-flow callers: their bodies are counted separately (with trip
    # multipliers); counting the caller's tuple operands would double-count
    "while", "call", "conditional",
    # loop-carried buffer copies are elided by XLA buffer assignment
    # (in-place while-loop state); counting them would dominate scan-heavy
    # programs with traffic that never happens on hardware
    "copy", "copy-start", "copy-done",
}
# ops whose callee computations are *inlined* (not real HBM-level comps)
_INLINE_CALLERS = {"fusion", "reduce", "map", "scatter", "select-and-scatter",
                   "sort", "reduce-window", "all-reduce", "reduce-scatter",
                   "custom-call"}


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    result_elems = 1
    for d in _first_dims(ins.result_type):
        result_elems *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.raw)
    if not m or not ins.operands:
        return 2.0 * result_elems  # degenerate
    lhs_type = comp.types.get(ins.operands[0], "")
    lhs_dims = _first_dims(lhs_type)
    contracted = 1
    if m.group(1):
        for idx in m.group(1).split(","):
            i = int(idx)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * result_elems * contracted


def _conv_flops(ins: Instruction, comp: Computation) -> float:
    # rough: 2 x result_elems x (kernel spatial x in_features)
    result_elems = 1
    for d in _first_dims(ins.result_type):
        result_elems *= d
    if len(ins.operands) >= 2:
        k_dims = _first_dims(comp.types.get(ins.operands[1], ""))
        k = 1
        for d in k_dims[:-1]:
            k *= d
        return 2.0 * result_elems * max(k, 1)
    return 2.0 * result_elems


def _operand_stored_bytes(name: str, comp: Computation,
                          trivial: Dict[str, str]) -> float:
    """Bytes of an operand at its STORED precision: looks through trivial
    convert-fusions to the original buffer."""
    seen = 0
    while name in trivial and seen < 4:
        name = trivial[name]
        seen += 1
    return _type_bytes(comp.types.get(name, ""))


def _instr_hbm_bytes(ins: Instruction, comp: Computation,
                     trivial: Dict[str, str] | None = None) -> float:
    """HBM traffic of one top-level instruction.

    Slicing/indexing ops move only the slice, not the buffer they index:
      dynamic-slice / slice / gather     -> result (+ negligible indices)
      dynamic-update-slice               -> 2 x update bytes (read-mod-write)
      scatter                            -> 2 x updates bytes
    Everything else: operands + result.
    """
    op = ins.opcode
    rbytes = _type_bytes(ins.result_type)
    if op in _ELEMENTWISE:
        # perfect producer-fusion model: an elementwise op's reads are
        # attributed to its producers' writes (TPU fuses these chains; the
        # CPU-lowered HLO leaves them top-level, which would double-count)
        return rbytes
    if op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * rbytes  # read slice + write result
    if op == "dynamic-update-slice":
        upd = (_type_bytes(comp.types.get(ins.operands[1], ""))
               if len(ins.operands) > 1 else rbytes)
        return 2.0 * upd
    if op == "scatter":
        upd = (_type_bytes(comp.types.get(ins.operands[2], ""))
               if len(ins.operands) > 2 else rbytes)
        return 2.0 * upd
    if trivial is not None and op in ("dot", "convolution"):
        # MXU reads operands at their stored precision (see _TRIVIAL_OPS)
        return rbytes + sum(_operand_stored_bytes(o, comp, trivial)
                            for o in ins.operands)
    return rbytes + sum(_type_bytes(comp.types.get(o, ""))
                        for o in ins.operands)


_SLICING = {"dynamic-slice", "slice", "gather"}

# fusions whose bodies contain only these ops are dtype/layout plumbing; on
# the TPU target they fuse into their consumer (the MXU reads the stored
# precision directly), so they carry no HBM traffic of their own.  The CPU
# backend materializes bf16->f32 copies of every weight before its f32-only
# matmuls — a lowering artifact the roofline model must not charge.
_TRIVIAL_OPS = {"parameter", "convert", "bitcast", "broadcast", "constant",
                "get-tuple-element", "tuple", "copy", "reshape", "transpose"}


def _trivial_fusions(comp: Computation,
                     comps: Dict[str, Computation]) -> Dict[str, str]:
    """fusion-instruction name -> its first operand, for fusions whose body
    is pure dtype/layout plumbing."""
    out = {}
    for ins in comp.instructions:
        if ins.opcode != "fusion":
            continue
        m = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
        body = comps.get(m.group(1)) if m else None
        if body and all(bi.opcode in _TRIVIAL_OPS
                        for bi in body.instructions):
            out[ins.name] = ins.operands[0] if ins.operands else ""
    return out

_ELEMENTWISE = {
    "convert", "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "select", "compare", "clamp", "negate", "exponential", "tanh", "cosine",
    "sine", "sqrt", "rsqrt", "is-finite", "and", "or", "not", "xor", "power",
    "abs", "floor", "ceil", "round-nearest-afz", "round-nearest-even", "log",
    "log-plus-one", "exponential-minus-one", "sign", "broadcast", "remainder",
    "shift-left", "shift-right-logical", "shift-right-arithmetic", "atan2",
    "expm1", "logistic", "cbrt", "erf", "reverse", "real", "imag",
}


def _fusion_hbm_bytes(ins: Instruction, comp: Computation,
                      comps: Dict[str, "Computation"]) -> float:
    """Fusion traffic with slice-aware parameter accounting: a fusion
    parameter consumed ONLY by slicing ops inside the body (the scan-over-
    layers weight-slice pattern) contributes the slice bytes, not the full
    buffer."""
    rbytes = _type_bytes(ins.result_type)
    m = re.search(r"calls=%?([\w\.\-]+)", ins.raw)
    body = comps.get(m.group(1)) if m else None
    if body is None:
        return rbytes + sum(_type_bytes(comp.types.get(o, ""))
                            for o in ins.operands)
    # body parameter name by index
    params: Dict[int, str] = {}
    for bi in body.instructions:
        if bi.opcode == "parameter":
            pm = re.search(r"parameter\((\d+)\)", bi.raw)
            if pm:
                params[int(pm.group(1))] = bi.name
    passthrough = {"bitcast", "reshape", "transpose", "copy"}
    # in-place accumulation: a fusion containing a dynamic-update-slice whose
    # result is the full fusion output returns the whole buffer but only
    # writes the update slice (XLA aliases the buffer); the buffer-typed
    # operand is the in-place destination.  (The DUS may be followed by
    # bitcasts/converts, so scan the body rather than only the root.)
    # element-count comparison: CPU lowering may round-trip the buffer
    # through f32 inside the fusion, so byte sizes differ across dtypes
    dus = None
    for bi in body.instructions:
        if bi.opcode == "dynamic-update-slice" and \
                _type_elems(bi.result_type) == _type_elems(ins.result_type):
            dus = bi
    dus_inplace = dus is not None
    if dus_inplace:
        upd = (_type_bytes(body.types.get(dus.operands[1], ""))
               if len(dus.operands) > 1 else 0)
        rbytes = 2.0 * upd
    total = rbytes
    result_elems = _type_elems(ins.result_type)
    for idx, op_name in enumerate(ins.operands):
        full = _type_bytes(comp.types.get(op_name, ""))
        if dus_inplace and _type_elems(
                comp.types.get(op_name, "")) == result_elems:
            continue  # aliased in-place destination buffer (any dtype)
        pname = params.get(idx)
        if pname is None:
            total += full
            continue
        # transitive: param -> (bitcast/reshape)* -> slicing ops only?
        frontier = [pname]
        sliced_bytes = 0.0
        only_sliced = True
        hops = 0
        while frontier and only_sliced and hops < 8:
            hops += 1
            nxt = []
            for fname in frontier:
                consumers = [bi for bi in body.instructions
                             if fname in bi.operands
                             and bi.opcode != "parameter"]
                for c in consumers:
                    if c.opcode in _SLICING:
                        sliced_bytes += _type_bytes(c.result_type)
                    elif c.opcode in passthrough:
                        nxt.append(c.name)
                    else:
                        only_sliced = False
            frontier = nxt
        if only_sliced and sliced_bytes > 0:
            total += sliced_bytes
        else:
            total += full
    return total


@dataclass
class HloAnalysis:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    per_comp: Dict[str, dict] = field(default_factory=dict)
    trip_multipliers: Dict[str, float] = field(default_factory=dict)

    def asdict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "collective_by_kind": dict(self.collective_by_kind),
            "collective_counts": dict(self.collective_counts),
        }


def analyze(text: str) -> HloAnalysis:
    comps, entry = parse_hlo(text)
    if entry is None:
        return HloAnalysis()

    # ---- propagate multipliers through the call DAG -------------------------
    inlined: set = set()
    edges: Dict[str, List[Tuple[str, float, str]]] = {
        c: _call_edges(comp) for c, comp in comps.items()}
    for cname, es in edges.items():
        for callee, m, kind in es:
            if kind in _INLINE_CALLERS and callee in comps:
                inlined.add(callee)
    # Kahn-style: callers before callees (HLO computations form a DAG)
    indeg = defaultdict(int)
    for cname, es in edges.items():
        for callee, _, _ in es:
            if callee in comps:
                indeg[callee] += 1
    mult = defaultdict(float)
    mult[entry] = 1.0
    queue = [c for c in comps if indeg[c] == 0]
    seen = set()
    while queue:
        c = queue.pop()
        if c in seen:
            continue
        seen.add(c)
        for callee, m, kind in edges.get(c, ()):
            if callee not in comps:
                continue
            mult[callee] += mult[c] * m
            indeg[callee] -= 1
            if indeg[callee] <= 0:
                queue.append(callee)

    out = HloAnalysis(trip_multipliers=dict(mult))
    for cname, comp in comps.items():
        cm = mult.get(cname, 0.0)
        if cm == 0.0:
            continue
        flops = 0.0
        hbm = 0.0
        coll = 0.0
        coll_kind: Dict[str, float] = {}
        coll_cnt: Dict[str, float] = {}
        trivial = _trivial_fusions(comp, comps)
        for ins in comp.instructions:
            if ins.opcode == "dot":
                flops += _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                flops += _conv_flops(ins, comp)
            base = next((c for c in _COLLECTIVES
                         if ins.opcode.startswith(c)), None)
            if base and not ins.opcode.endswith("-done"):
                # collectives move the STORED precision on the real target
                # (look through CPU-inserted bf16->f32 convert fusions)
                nbytes = sum(_operand_stored_bytes(o, comp, trivial)
                             for o in ins.operands)
                if nbytes == 0:
                    nbytes = _type_bytes(ins.result_type)
                coll += nbytes
                coll_kind[base] = coll_kind.get(base, 0.0) + nbytes
                coll_cnt[base] = coll_cnt.get(base, 0.0) + 1
            if cname not in inlined and ins.opcode not in _SKIP_BYTES_OPS:
                if ins.name in trivial:
                    pass  # dtype/layout plumbing: fuses into consumers on TPU
                elif ins.opcode == "fusion":
                    hbm += _fusion_hbm_bytes(ins, comp, comps)
                else:
                    hbm += _instr_hbm_bytes(ins, comp, trivial)
        out.per_comp[cname] = {
            "mult": cm, "flops": flops, "hbm_bytes": hbm,
            "collective_bytes": coll,
        }
        out.flops += cm * flops
        out.hbm_bytes += cm * hbm
        out.collective_bytes += cm * coll
        for k, v in coll_kind.items():
            out.collective_by_kind[k] = out.collective_by_kind.get(k, 0.0) + cm * v
        for k, v in coll_cnt.items():
            out.collective_counts[k] = out.collective_counts.get(k, 0.0) + cm * v
    return out
