"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real training on the available devices (CPU devices in this container;
TPU slices in production — same code path, bigger mesh).  Supports
checkpoint/restart, periodic + emergency checkpointing, and elastic rescale
driven by the spot-market simulator (--elastic).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..elastic import (
    CheckpointManager,
    ElasticTrainer,
    build_mesh,
    simulate_worker_availability,
)
from ..models.sharding import tree_shardings, use_mesh
from ..train.data import DataConfig, SyntheticDataset
from ..train.train_step import (
    init_train_state,
    make_train_step,
    train_state_specs,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--n-data", type=int, default=0,
                    help="data-parallel width (0 = all devices)")
    ap.add_argument("--elastic", action="store_true",
                    help="train under simulated spot interruptions")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dcfg = DataConfig(batch=args.batch, seq_len=args.seq_len, seed=args.seed)

    if args.elastic:
        n = args.n_data or len(jax.devices())
        events = simulate_worker_availability(n, horizon=args.steps,
                                              seed=args.seed)
        tr = ElasticTrainer(cfg, dcfg, args.ckpt_dir, max_workers=n,
                            seed=args.seed)
        rep = tr.train_elastic(args.steps, events)
        print(f"elastic run: steps={rep.steps_run} rescales={rep.rescales} "
              f"emergency_saves={rep.emergency_saves} restores={rep.restores}")
        print(f"final loss {rep.losses[-1]:.4f}")
        return 0

    n_data = args.n_data or len(jax.devices())
    mesh = build_mesh(n_data)
    ckpt = CheckpointManager(args.ckpt_dir, keep_n=3)
    dataset = SyntheticDataset(cfg, dcfg)

    with use_mesh(mesh):
        shardings = tree_shardings(train_state_specs(cfg))
        latest = ckpt.latest_step()
        if latest is not None:
            template = jax.eval_shape(
                lambda: init_train_state(cfg, jax.random.PRNGKey(args.seed)))
            state, meta = ckpt.restore(template, shardings=shardings)
            dataset.load_state_dict({"step": meta.get("data_step", 0),
                                     "seed": args.seed})
            print(f"restored from step {latest}")
        else:
            state = jax.device_put(
                init_train_state(cfg, jax.random.PRNGKey(args.seed)),
                shardings)
        step_fn = jax.jit(make_train_step(cfg), donate_argnums=(0,))

        t0 = time.time()
        for i in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in
                     dataset.next_batch().items()}
            state, metrics = step_fn(state, batch)
            step = int(state.step)
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"({(time.time()-t0)/max(i+1,1):.2f}s/step)", flush=True)
            if args.checkpoint_every and step % args.checkpoint_every == 0:
                ckpt.save(state, step, {"data_step": dataset.step})
        ckpt.save(state, int(state.step), {"data_step": dataset.step},
                  block=True)
        ckpt.wait()
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
