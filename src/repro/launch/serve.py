"""Serving launcher: batched decode with spot-interruption-aware request
scheduling (``python -m repro.launch.serve --arch <id> --smoke``)."""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_smoke_config
from ..models.model import init_params
from ..serve import (
    Request,
    SpotServingScheduler,
    make_prefill_step,
    make_serve_step,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--interrupt-at", type=int, default=0,
                    help="simulate a spot interruption after N decode steps")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    cache_len = args.prompt_len + args.gen_tokens

    prefill = jax.jit(make_prefill_step(cfg, cache_len))
    step = jax.jit(make_serve_step(cfg))

    sched = SpotServingScheduler(batch_size=args.batch, hibernate=True)
    for i in range(args.requests):
        sched.add(Request(i, args.prompt_len, args.gen_tokens))

    rng = np.random.default_rng(args.seed)
    t0 = time.time()
    decode_steps = 0
    while len(sched.done) < args.requests:
        batch_reqs = sched.fill_batch()
        if not batch_reqs:
            break
        b = len(batch_reqs)
        if cfg.modality == "text":
            prompts = jnp.asarray(
                rng.integers(0, cfg.vocab, (b, args.prompt_len)), jnp.int32)
        else:
            prompts = jnp.asarray(
                rng.normal(0, 1, (b, args.prompt_len, cfg.d_model)),
                jnp.float32)
        logits, state = prefill(params, prompts)
        tok = jnp.argmax(logits, axis=-1)[:, None]
        for t in range(args.gen_tokens - 1):
            if cfg.modality != "text":
                tok_in = jnp.zeros((b, 1, cfg.d_model), jnp.float32)
            else:
                tok_in = tok
            lg, state = step(params, tok_in, state)
            tok = jnp.argmax(lg[:, -1, :], axis=-1)[:, None]
            decode_steps += 1
            if args.interrupt_at and decode_steps == args.interrupt_at:
                print(f"[market] interruption after {decode_steps} decode "
                      f"steps — hibernating {b} in-flight requests")
                sched.interrupt()
                break
        else:
            sched.step(args.gen_tokens)
            continue
        # interrupted: resume on next fill_batch (hibernated first)
        args.interrupt_at = 0

    dt = time.time() - t0
    st = sched.stats()
    print(f"served {st['done']}/{args.requests} requests in {dt:.1f}s "
          f"({decode_steps} decode steps, {st['interruptions']} request "
          f"interruptions)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
