"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax initialization.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) data x model = 256 chips.
    Multi-pod: (2, 16, 16) pod x data x model = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh for tests / elastic rescale (e.g. (4,2) on 8 CPU
    devices with --xla_force_host_platform_device_count=8)."""
    return jax.make_mesh(shape, axes)


def available_devices() -> int:
    return len(jax.devices())
