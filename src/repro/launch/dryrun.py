import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below runs with 512 host-platform placeholder devices -------
import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from typing import Optional  # noqa: E402

import jax           # noqa: E402

from ..configs import ARCH_IDS, get_config          # noqa: E402
from ..models.sharding import use_mesh              # noqa: E402
from ..serve.engine import make_prefill_step, make_serve_step  # noqa: E402
from ..train.train_step import make_train_step      # noqa: E402
from .hlo_analyzer import analyze                    # noqa: E402
from .hlo_stats import roofline_terms                # noqa: E402
from .mesh import make_production_mesh               # noqa: E402
from .specs import SHAPES, cell_supported, input_specs, rules_for  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _mem_dict(mem) -> dict:
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(cost) -> dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    if not isinstance(cost, dict):
        return {}
    keep = {}
    for k, v in cost.items():
        if k in ("flops", "bytes accessed", "transcendentals") or \
                k.startswith("bytes accessed"):
            keep[k] = float(v)
    return keep


def model_flops(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (train), 2·N·D (fwd only); MoE uses active N."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: 1 token per sequence


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             donate: bool = True, hlo_dir: Optional[str] = None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind}

    ok, reason = cell_supported(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, shape)
    try:
        with use_mesh(mesh, rules):
            args = input_specs(cfg, shape)
            if shape.kind == "train":
                fn = make_train_step(cfg)
                donate_argnums = (0,) if donate else ()
            elif shape.kind == "prefill":
                fn = make_prefill_step(cfg, cache_len=shape.seq_len)
                donate_argnums = ()
            else:
                fn = make_serve_step(cfg)
                donate_argnums = (2,) if donate else ()

            jitted = jax.jit(fn, donate_argnums=donate_argnums)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = None
            try:
                mem = compiled.memory_analysis()
            except Exception:
                pass
            cost = {}
            try:
                cost = _cost_dict(compiled.cost_analysis())
            except Exception:
                pass
            hlo = compiled.as_text()
            if hlo_dir:
                import gzip
                os.makedirs(hlo_dir, exist_ok=True)
                with gzip.open(os.path.join(
                        hlo_dir,
                        f"{arch}__{shape_name}__{mesh_name}.hlo.gz"),
                        "wt") as f:
                    f.write(hlo)
            ana = analyze(hlo)  # per-device totals with loop trip multipliers

        chips = mesh.size
        # analyzer totals are per-device over the partitioned module; the
        # roofline formula takes globals, so multiply back by chip count.
        flops_global = ana.flops * chips
        hbm_global = ana.hbm_bytes * chips
        coll_global = ana.collective_bytes * chips
        mf = model_flops(cfg, shape)
        rec.update(
            status="ok",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=_mem_dict(mem) if mem else {},
            cost_analysis_body_once=cost,
            hlo_analysis=ana.asdict(),
            model_flops=mf,
            hlo_flops=flops_global,
            useful_flops_ratio=(mf / flops_global) if flops_global else None,
            roofline=roofline_terms(flops_global, hbm_global, coll_global,
                                    chips),
        )
    except Exception as e:  # a failure here is a bug in the system
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="architecture id or 'all'")
    ap.add_argument("--shape", default="all",
                    help="shape name or 'all'")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--save-hlo", action="store_true", default=True,
                    help="save gzipped optimized HLO next to results")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells with existing result files")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "pod2x16x16" if multi else "pod16x16"
                path = os.path.join(
                    args.out, f"{arch}__{shape}__{mesh_name}.json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    print(f"[cached] {arch} {shape} {mesh_name}: "
                          f"{prev.get('status')}")
                    failures += prev.get("status") == "error"
                    continue
                rec = run_cell(
                    arch, shape, multi,
                    hlo_dir=os.path.join(args.out, "hlo")
                    if args.save_hlo else None)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" compile={rec['compile_s']:.0f}s "
                             f"dom={r['dominant']} "
                             f"cmp={r['compute_s']*1e3:.2f}ms "
                             f"mem={r['memory_s']*1e3:.2f}ms "
                             f"col={r['collective_s']*1e3:.2f}ms")
                elif status == "error":
                    failures += 1
                    extra = " " + rec["error"][:160]
                elif status == "skipped":
                    extra = " " + rec["reason"][:80]
                print(f"[{status}] {arch} {shape} {mesh_name}{extra}",
                      flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
