"""Market simulation launcher (the paper's §VII experiments from the CLI).

  python -m repro.launch.market_sim --scenario synthetic --policy all
  python -m repro.launch.market_sim --scenario trace --machines 200
  python -m repro.launch.market_sim --market                 # price regimes
  python -m repro.launch.market_sim --market --regimes volatile --pools 3

``--market`` runs the dynamic market engine: multi-pool price clearing over
the §VII-E synthetic fleet, HLEM vs First-Fit under calm / volatile /
correlated-pool price regimes, reporting interruption counts, max
interruption duration, and realized spot cost (billed at clearing price).

``--migration=POLICY`` (or ``all``) attaches the proactive cross-pool
migration planner and reports migrations / downtime / savings next to the
interruption metrics:

  python -m repro.launch.market_sim --market --migration all
  python -m repro.launch.market_sim --market --migration gradient-aware \\
      --regimes volatile,correlated --rebid
"""
from __future__ import annotations

import argparse
import copy
import json
import time

from ..core import (
    MarketScenarioConfig,
    MarketSimulator,
    ScenarioConfig,
    SimConfig,
    dynamic_vm_table,
    make_policy,
    market_scenario,
    spot_vm_table,
    synthetic_scenario,
    to_csv,
)
from ..market import (
    MIGRATION_POLICIES,
    MarketEngine,
    REGIMES,
    RebidOnResume,
    TraceConfig,
    assign_bids,
    generate_trace,
    make_bid_strategy,
    make_market,
    make_migration_planner,
    realized_cost_stats,
    simulate_trace,
)

POLICY_SET = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
              "hlem-vmp-adjusted"]
MARKET_POLICY_SET = ["first-fit", "hlem-vmp-adjusted"]


def run_synthetic(policy_name: str, seed: int, until: float,
                  selector: str = "list_order", alpha: float = -0.5) -> dict:
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=seed))
    kwargs = {}
    if policy_name == "hlem-vmp-adjusted":
        kwargs["alpha"] = alpha
    policy = make_policy(policy_name, **kwargs)
    sim = MarketSimulator(policy=policy, config=SimConfig(
        record_timeline=False, interruption_selector=selector))
    for cap in hosts:
        sim.add_host(cap)
    for v in vms:
        sim.submit(copy.deepcopy(v))
    t0 = time.time()
    m = sim.run(until=until)
    stats = m.spot_stats(sim.vms)
    stats.update(policy=policy_name, wall_s=round(time.time() - t0, 1),
                 allocations=m.allocations, resubmissions=m.resubmissions)
    return stats


def run_market(policy_name: str, regime: str, seed: int, until: float = 14400.0,
               n_pools: int = 4, bid_strategy: str = "randomized",
               tick_interval: float = 60.0, alpha: float = -0.5,
               migration: str = "none", rebid: bool = False,
               from_advisor: bool = True) -> dict:
    """One engine-coupled run over the *market scenario* (regional demand
    humps, long-lived pool-flexible spot VMs — see
    :class:`repro.core.MarketScenarioConfig`): per-pool volatility from the
    synthetic Spot-Advisor dataset (``from_advisor``, on by default), seeded
    bids on every spot VM, price-driven interruption waves, realized-price
    cost accounting.  ``migration`` attaches a proactive cross-pool
    migration planner (``"none"`` is bit-identical to no planner);
    ``rebid`` switches on adaptive re-bidding on hibernation."""
    hosts, pool_ids, vms = market_scenario(
        MarketScenarioConfig(seed=seed, n_pools=n_pools))
    mc = make_market(regime, n_pools=n_pools, seed=seed,
                     tick_interval=tick_interval, from_advisor=from_advisor)
    engine = MarketEngine(mc)
    # randomized bids floored above the busy-fleet clearing base, so draws
    # span the at-risk band instead of the permanently-below-base region
    strat_kw = {"lo": 0.45} if bid_strategy == "randomized" else {}
    strat = make_bid_strategy(bid_strategy, pool_cfg=mc.pools[0], seed=seed,
                              **strat_kw)
    assign_bids(vms, strat, seed=seed)
    kwargs = {"alpha": alpha} if policy_name == "hlem-vmp-adjusted" else {}
    planner = make_migration_planner(migration)
    rebid_hook = (RebidOnResume(on_demand_rate=mc.pools[0].on_demand_rate,
                                seed=seed) if rebid else None)
    sim = MarketSimulator(policy=make_policy(policy_name, **kwargs),
                          config=SimConfig(record_timeline=False),
                          engine=engine, migration=planner,
                          rebid=rebid_hook)
    for cap, pid in zip(hosts, pool_ids):
        sim.add_host(cap, pool=pid)
    for v in vms:
        sim.submit(v)
    t0 = time.time()
    m = sim.run(until=until)
    wall = time.time() - t0
    s = m.spot_stats(sim.vms)
    ms = m.market_stats()
    migs = m.migration_stats(sim.vms, engine)
    cost = realized_cost_stats(sim.vms.values(), engine, sim.pool)
    return {
        "policy": policy_name,
        "regime": regime,
        "migration": migration,
        "interruptions": s["interruptions"],
        "price_interruptions": ms["price_interruptions"],
        "waves": ms["waves"],
        "max_wave_size": ms["max_wave_size"],
        "avg_interruption_time": s["avg_interruption_time"],
        "max_interruption_time": s["max_interruption_time"],
        "spot_finished": s["spot_finished"],
        "spot_terminated": s["spot_terminated"],
        "migrations": migs["completed"],
        "migrations_failed": migs["failed"],
        "migration_downtime_s": migs["downtime_s"],
        "predicted_saving": round(migs["predicted_saving"], 2),
        "realized_saving": round(migs["realized_saving"], 2),
        "realized_spot_cost": round(cost["spot_cost"], 4),
        "savings_pct": round(cost["savings_pct"], 1),
        "wasted_cost": round(cost["wasted_cost"], 4),
        "allocations": m.allocations,
        "wall_s": round(wall, 1),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=["synthetic", "trace"],
                    default="synthetic")
    ap.add_argument("--policy", default="all",
                    help="policy name or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--until", type=float, default=None,
                    help="horizon (s); default 3000, or 14400 in "
                         "--market mode (the four demand humps + drain)")
    ap.add_argument("--selector", default="list_order",
                    choices=["list_order", "best_fit_remaining",
                             "max_progress"])
    ap.add_argument("--alpha", type=float, default=-0.5)
    ap.add_argument("--machines", type=int, default=200)
    ap.add_argument("--spot", type=int, default=1000)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--json", action="store_true")
    # market-engine mode
    ap.add_argument("--market", action="store_true",
                    help="run the dynamic market engine across price regimes")
    ap.add_argument("--regimes", default="calm,volatile,correlated",
                    help="comma-separated subset of " + ",".join(REGIMES))
    ap.add_argument("--pools", type=int, default=4)
    ap.add_argument("--bid-strategy", default="randomized",
                    choices=["on-demand-cap", "percentile", "randomized"])
    ap.add_argument("--tick", type=float, default=60.0,
                    help="price tick interval (s)")
    ap.add_argument("--migration", default="none",
                    help="proactive migration policy, one of "
                         + ",".join(MIGRATION_POLICIES) + ", or 'all' to "
                         "compare every policy per regime")
    ap.add_argument("--rebid", action="store_true",
                    help="adaptive re-bidding on hibernation (Bhuyan-style)")
    ap.add_argument("--flat-volatility", action="store_true",
                    help="use the regime's hand-set volatility constant for "
                         "every pool instead of deriving per-pool sigmas "
                         "from the synthetic Spot-Advisor dataset")
    args = ap.parse_args(argv)

    if args.market:
        # the migration comparison varies the migration policy against the
        # paper's allocator; the allocator comparison (PR 2) spans both
        policies = ((MARKET_POLICY_SET if args.migration == "none"
                     else ["hlem-vmp-adjusted"])
                    if args.policy == "all" else [args.policy])
        migrations = (list(MIGRATION_POLICIES) if args.migration == "all"
                      else [args.migration])
        until = args.until if args.until is not None else 14400.0
        rows = []
        for regime in args.regimes.split(","):
            for p in policies:
                for mig in migrations:
                    rows.append(run_market(
                        p, regime, args.seed, until,
                        n_pools=args.pools,
                        bid_strategy=args.bid_strategy,
                        tick_interval=args.tick, alpha=args.alpha,
                        migration=mig, rebid=args.rebid,
                        from_advisor=not args.flat_volatility))
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            print(f"{'regime':11s} {'policy':18s} {'migration':15s} "
                  f"{'intr':>5s} {'waves':>5s} {'max_intr_s':>10s} "
                  f"{'migr':>5s} {'down_s':>7s} {'spot_cost':>9s} "
                  f"{'save%':>6s} {'waste':>7s}")
            for r in rows:
                print(f"{r['regime']:11s} {r['policy']:18s} "
                      f"{r['migration']:15s} "
                      f"{r['interruptions']:5d} {r['waves']:5d} "
                      f"{r['max_interruption_time']:10.1f} "
                      f"{r['migrations']:5d} "
                      f"{r['migration_downtime_s']:7.1f} "
                      f"{r['realized_spot_cost']:9.3f} "
                      f"{r['savings_pct']:6.1f} {r['wasted_cost']:7.3f}")
        return 0

    if args.scenario == "synthetic":
        policies = POLICY_SET if args.policy == "all" else [args.policy]
        until = args.until if args.until is not None else 3000.0
        rows = [run_synthetic(p, args.seed, until, args.selector,
                              args.alpha) for p in policies]
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            for r in rows:
                print(f"{r['policy']:20s} interruptions={r['interruptions']:5d} "
                      f"avg={r['avg_interruption_time']:7.2f}s "
                      f"max={r['max_interruption_time']:7.2f}s "
                      f"finished={r['spot_finished']:4d} "
                      f"terminated={r['spot_terminated']:4d} "
                      f"[{r['wall_s']}s]")
        return 0

    # trace scenario
    tcfg = TraceConfig(seed=args.seed, n_machines=args.machines,
                       sim_days=args.days, n_spot=args.spot)
    tr = generate_trace(tcfg)
    policy = make_policy(
        args.policy if args.policy != "all" else "hlem-vmp-adjusted")
    t0 = time.time()
    sim, metrics = simulate_trace(tr, policy=policy, cfg=tcfg)
    stats = metrics.spot_stats(sim.vms)
    stats.update(machines=args.machines, n_vms=len(sim.vms),
                 wall_s=round(time.time() - t0, 1))
    print(json.dumps(stats, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
