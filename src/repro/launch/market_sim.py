"""Market simulation launcher (the paper's §VII experiments from the CLI).

  python -m repro.launch.market_sim --scenario synthetic --policy all
  python -m repro.launch.market_sim --scenario trace --machines 200
  python -m repro.launch.market_sim --market                 # price regimes
  python -m repro.launch.market_sim --market --regimes volatile --pools 3

``--market`` runs the dynamic market engine: multi-pool price clearing over
the market scenario, HLEM vs First-Fit under calm / volatile /
correlated-pool price regimes, reporting interruption counts, max
interruption duration, and realized spot cost (billed at clearing price).

``--migration=POLICY`` (or ``all``) attaches the proactive cross-pool
migration planner and reports migrations / downtime / savings next to the
interruption metrics:

  python -m repro.launch.market_sim --market --migration all
  python -m repro.launch.market_sim --market --migration gradient-aware \\
      --regimes volatile,correlated --rebid

``--fleet STRATEGY`` attaches the spot-fleet manager (target capacity held
through a fallback ladder), ``--faults SCENARIO`` injects a seeded market
fault scenario, and ``--fleet compare --sweep N`` runs the fleet-vs-per-VM
resilience comparison:

  python -m repro.launch.market_sim --market --fleet diversified \\
      --faults storm
  python -m repro.launch.market_sim --market --regimes volatile \\
      --fleet compare --faults storm --sweep 10 \\
      --report results/sweep/fleet_resilience.json

``--serve CURVE`` runs the traffic-driven serving scenario: a demand curve
(``diurnal`` or ``bursty``) feeds a request queue served on the spot
fleet's live VMs, and the row reports SLO attainment, latency percentiles,
error-budget burn, and cost per served request.  ``--autoscale POLICY``
closes the loop (static, target-tracking, step, predictive-from-curve);
``--autoscale compare --sweep N`` sweeps target-tracking against the
static baseline:

  python -m repro.launch.market_sim --serve diurnal --fleet-target 24 \\
      --autoscale target-tracking
  python -m repro.launch.market_sim --serve diurnal --regimes volatile \\
      --faults storm --fleet-target 24 --autoscale compare --sweep 10 \\
      --report results/sweep/serve_slo_sweep.json

Every mode routes through the declarative scenario API
(:mod:`repro.api`): the CLI flags assemble a spec tree, ``api.build``
materializes fresh components per run.  Two spec-file modes make whole
experiments shareable artifacts:

  # seed sweep of the --market grid: mean ± 95% CI over N seeds per cell
  python -m repro.launch.market_sim --market --migration all --sweep 20 \\
      --report results/migration_sweep.json

  # run an ExperimentSpec JSON file directly (see examples/specs/)
  python -m repro.launch.market_sim --spec examples/specs/migration_sweep.json

Observability (single-run modes): ``--trace-out trace.json`` writes a
Chrome trace-event file, ``--profile`` / ``--profile-out`` aggregate the
per-subsystem self/total wall-time table, ``--counters-every 600`` prints a
live counter line per 600 s of sim time.  Tracing is observation-only —
the metrics rows are identical with and without it:

  python -m repro.launch.market_sim --market --regimes volatile \\
      --policy hlem-vmp-adjusted --trace-out results/profile/trace.json \\
      --profile --counters-every 600

The event flight recorder (``--events-out``) writes a structured log of
every lifecycle/market event (NDJSON or ``.npz`` by extension);
``--report-html`` renders a self-contained HTML run report, and
``--diff A B`` compares two recorded logs and reports the first
divergence (exit 1 when the runs diverge):

  python -m repro.launch.market_sim --market --regimes volatile \\
      --policy hlem-vmp-adjusted --events-out run.ndjson \\
      --report-html run.html
  python -m repro.launch.market_sim --diff run_a.ndjson run_b.ndjson

Live progress lines (counter snapshots, per-cell sweep progress) are
suppressed when stderr is not a terminal (e.g. under CI or redirection);
``--force-progress`` restores them.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from ..api import (
    AutoscaleSpec,
    BidSpec,
    ExperimentSpec,
    FaultSpec,
    FleetSpec,
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RebidSpec,
    RunSpec,
    ScenarioSpec,
    ServeSpec,
    collect_row,
    format_report,
    resolve_horizon,
    run_experiment,
    run_one,
)
from ..api import build as build_run
from ..market import MIGRATION_POLICIES, REGIMES
from ..obs import format_profile_table, run_manifest, write_chrome_trace
from ..obs import write_profile
from ..obs import first_divergence, format_divergence, write_html_report

POLICY_SET = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
              "hlem-vmp-adjusted"]
MARKET_POLICY_SET = ["first-fit", "hlem-vmp-adjusted"]


def _policy_spec(name: str, alpha: float = -0.5) -> PolicySpec:
    params = {"alpha": alpha} if name == "hlem-vmp-adjusted" else {}
    return PolicySpec(name, params)


def _market_scenario_spec(regime: str, n_pools: int = 4,
                          bid_strategy: str = "randomized",
                          tick_interval: float = 60.0,
                          from_advisor: bool = True,
                          horizon: float | None = None) -> ScenarioSpec:
    """The ``--market`` scenario as a spec: regional demand humps over
    long-lived pool-flexible spot VMs, per-pool advisor volatility, seeded
    bids.  Randomized bids are floored above the busy-fleet clearing base,
    so draws span the at-risk band instead of the permanently-below-base
    region."""
    bid_params = {"lo": 0.45} if bid_strategy == "randomized" else {}
    return ScenarioSpec(
        workload="market", regime=regime, n_pools=n_pools,
        tick_interval=tick_interval, from_advisor=from_advisor,
        bid=BidSpec(bid_strategy, bid_params), horizon=horizon)


def _progress_enabled(args) -> bool:
    """Live stderr progress (counter lines, per-cell sweep lines) is for
    humans watching a terminal: suppressed under ``--json`` and whenever
    stderr is not a TTY (CI logs, redirection), unless ``--force-progress``
    overrides."""
    if args.json:
        return False
    return bool(args.force_progress or sys.stderr.isatty())


def _live_counter_line(sim_t: float, snap: dict) -> None:
    """The counter tracer's live progress line (stderr — stdout stays a
    pure document for --json consumers)."""
    running = int(snap.get("gauge/running_spot", 0)
                  + snap.get("gauge/running_od", 0))
    intr = int(sum(v for k, v in snap.items()
                   if k.startswith("interruptions/")))
    print(f"# t={sim_t:9.0f}s  events={int(snap.get('events/total', 0)):8d}"
          f"  running={running:6d}"
          f"  waiting={int(snap.get('gauge/waiting', 0)):6d}"
          f"  hibernated={int(snap.get('gauge/hibernated', 0)):5d}"
          f"  queue={int(snap.get('gauge/queue_depth', 0)):6d}"
          f"  interruptions={intr:6d}",
          file=sys.stderr, flush=True)


def _emit_obs_artifacts(sim, spec: RunSpec, seed: int, args,
                        duration_s: float) -> dict:
    """Write/print the run's observability artifacts per the CLI flags;
    returns the extra blocks (counters) to merge into a JSON document."""
    tr = sim.obs
    evl = sim.events
    if not (tr.enabled or evl.enabled):
        return {}
    man = run_manifest(spec_dict=spec.to_dict(), seed=seed,
                       duration_s=duration_s)
    if args.trace_out:
        write_chrome_trace(tr, args.trace_out, manifest=man)
        print(f"# wrote {args.trace_out}", file=sys.stderr)
    if args.profile_out:
        write_profile(tr, args.profile_out, manifest=man)
        print(f"# wrote {args.profile_out}", file=sys.stderr)
    if args.profile and tr.enabled:
        print(format_profile_table(tr), file=sys.stderr)
    if args.events_out and evl.enabled:
        evl.save(args.events_out, manifest=man)
        print(f"# wrote {args.events_out}", file=sys.stderr)
    if args.report_html and evl.enabled:
        write_html_report(evl, args.report_html, manifest=man)
        print(f"# wrote {args.report_html}", file=sys.stderr)
    extra = {}
    if args.counters_every and tr.enabled:
        extra["counters"] = {
            "every": args.counters_every,
            "series": [{"t": round(t, 3), "values": snap}
                       for t, _wall, snap in tr.counters.series],
            "final": dict(tr.counters.values),
        }
    return extra


def _run_one_obs(spec: RunSpec, seed: int, until, args, sink: dict) -> dict:
    """Single-run unit with a live tracer: build, attach the live counter
    line, run, collect the standard row, then emit trace/profile/counters
    artifacts.  The metrics row is identical to :func:`repro.api.run_one`
    (tracing is observation-only; regression-tested in ``tests/obs``)."""
    sim = build_run(spec, seed)
    if args.counters_every and _progress_enabled(args):
        sim.obs.on_snapshot = _live_counter_line
    horizon = until if until is not None else resolve_horizon(spec.scenario)
    t0 = time.time()
    metrics = sim.run(until=horizon)
    wall = time.time() - t0
    row = collect_row(sim, metrics, spec, seed)
    row["wall_s"] = round(wall, 1)
    sink.update(_emit_obs_artifacts(sim, spec, seed, args, wall))
    return row


def run_synthetic(policy_name: str, seed: int, until: float,
                  selector: str = "list_order", alpha: float = -0.5,
                  obs: ObsSpec | None = None, cli_args=None,
                  obs_sink: dict | None = None) -> dict:
    """One §VII-E synthetic run through the scenario API."""
    spec = RunSpec(
        scenario=ScenarioSpec(
            workload="synthetic",
            sim_params={"interruption_selector": selector}),
        policy=_policy_spec(policy_name, alpha),
        obs=obs)
    if obs is not None and obs.enabled:
        return _run_one_obs(spec, seed, until, cli_args,
                            obs_sink if obs_sink is not None else {})
    t0 = time.time()
    stats = run_one(spec, seed, until=until)
    stats["wall_s"] = round(time.time() - t0, 1)
    return stats


def run_market(policy_name: str, regime: str, seed: int, until: float = 14400.0,
               n_pools: int = 4, bid_strategy: str = "randomized",
               tick_interval: float = 60.0, alpha: float = -0.5,
               migration: str = "none", rebid: bool = False,
               from_advisor: bool = True, fleet: FleetSpec | None = None,
               faults: FaultSpec | None = None,
               obs: ObsSpec | None = None, cli_args=None,
               obs_sink: dict | None = None) -> dict:
    """One engine-coupled run over the market scenario through the scenario
    API (fresh engine/planner per call; ``migration="none"`` is
    bit-identical to no planner; ``rebid`` switches on adaptive re-bidding
    on hibernation; ``fleet``/``faults`` attach the resilience layer;
    ``obs`` attaches the telemetry tracer — metrics rows are identical
    either way)."""
    spec = RunSpec(
        scenario=_market_scenario_spec(regime, n_pools, bid_strategy,
                                       tick_interval, from_advisor),
        policy=_policy_spec(policy_name, alpha),
        migration=MigrationSpec(migration),
        rebid=RebidSpec() if rebid else None,
        fleet=fleet, faults=faults, obs=obs)
    if obs is not None and obs.enabled:
        return _run_one_obs(spec, seed, until, cli_args,
                            obs_sink if obs_sink is not None else {})
    t0 = time.time()
    row = run_one(spec, seed, until=until)
    row["wall_s"] = round(time.time() - t0, 1)
    return row


def _serve_scenario_spec(args, regime: str, until: float) -> ScenarioSpec:
    wl_params = {}
    if args.serve_rate is not None:
        wl_params["base_rate"] = args.serve_rate
    return ScenarioSpec(
        workload=f"serve-{args.serve}", regime=regime, n_pools=args.pools,
        tick_interval=args.tick, from_advisor=not args.flat_volatility,
        horizon=until, workload_params=wl_params)


def _serve_run_spec(args, regime: str, policy: str,
                    autoscale: AutoscaleSpec | None, until: float,
                    obs: ObsSpec | None = None) -> RunSpec:
    return RunSpec(
        scenario=_serve_scenario_spec(args, regime, until),
        policy=_policy_spec(policy, args.alpha),
        fleet=FleetSpec(strategy=args.fleet or "diversified",
                        params={"target_capacity": args.fleet_target}),
        faults=FaultSpec(scenario=args.faults) if args.faults else None,
        serve=ServeSpec(), autoscale=autoscale, obs=obs)


def _print_serve_rows(rows, labels) -> None:
    print(f"{'regime':11s} {'autoscale':22s} {'arrived':>8s} {'done':>8s} "
          f"{'requeue':>7s} {'p95_s':>9s} {'slo':>6s} {'burn':>6s} "
          f"{'$/req':>9s} {'od_spill':>8s}")
    for lb, r in zip(labels, rows):
        print(f"{r['regime']:11s} {lb:22s} "
              f"{r['requests_arrived']:8d} {r['requests_done']:8d} "
              f"{r['requests_requeued']:7d} {r['p95_latency_s']:9.1f} "
              f"{r['slo_attainment']:6.3f} {r['error_budget_burn']:6.2f} "
              f"{r['cost_per_request']:9.5f} {r['od_spill_cost']:8.3f}")


def run_serve(args, obs_spec, ap, t_main: float) -> int:
    """The ``--serve`` mode: single runs per regime, or (with ``--sweep``)
    a seed-swept regime × autoscale-policy grid through
    :func:`repro.api.run_experiment`."""
    until = args.until if args.until is not None else 14400.0
    regimes = args.regimes.split(",")
    policy = args.policy if args.policy != "all" else "first-fit"
    if args.autoscale == "compare" and not args.sweep:
        ap.error("--autoscale compare requires --sweep N")

    if args.sweep:
        if args.autoscale == "compare":
            autoscales = (AutoscaleSpec("static"),
                          AutoscaleSpec("target-tracking"))
        elif args.autoscale:
            autoscales = (AutoscaleSpec(args.autoscale),)
        else:
            autoscales = None
        exp = ExperimentSpec(
            name=f"serve_sweep_{args.sweep}x",
            scenario=_serve_scenario_spec(args, regimes[0], until),
            policies=(_policy_spec(policy, args.alpha),),
            regimes=tuple(regimes),
            seeds=tuple(range(args.seed, args.seed + args.sweep)),
            fleets=(FleetSpec(strategy=args.fleet or "diversified",
                              params={"target_capacity": args.fleet_target}),),
            faults=FaultSpec(scenario=args.faults) if args.faults else None,
            serve=ServeSpec(), autoscales=autoscales)
        return _sweep_and_report(exp, args)

    if obs_spec is not None and len(regimes) > 1:
        ap.error("observability flags trace a single run — pick one "
                 "--regimes value")
    autoscale = AutoscaleSpec(args.autoscale) if args.autoscale else None
    label = args.autoscale or "none"
    rows, obs_sink = [], {}
    for regime in regimes:
        spec = _serve_run_spec(args, regime, policy, autoscale, until,
                               obs=obs_spec)
        if obs_spec is not None and obs_spec.enabled:
            row = _run_one_obs(spec, args.seed, until, args, obs_sink)
        else:
            t0 = time.time()
            row = run_one(spec, args.seed, until=until)
            row["wall_s"] = round(time.time() - t0, 1)
        rows.append(row)
    if args.json:
        doc = {"rows": rows, "manifest": _cli_manifest(args, t_main)}
        doc.update(obs_sink)
        print(json.dumps(doc, indent=1))
    else:
        _print_serve_rows(rows, [label] * len(rows))
    return 0


def run_sanitized(args) -> int:
    """One fixed-seed run inside :func:`repro.obs.sanitized` — wall-clock
    and global-RNG calls raise anywhere on the sim path, verifying at
    runtime what detlint's ``no-wallclock``/``no-global-rng`` rules claim
    statically.  Spec construction and ``build_run`` happen *outside* the
    scope (building draws from seeded Generators, which stay allowed);
    only the event loop itself runs sanitized."""
    from repro.obs.sanitize import sanitized
    if args.market:
        regime = args.regimes.split(",")[0]
        policy = args.policy if args.policy != "all" else "hlem-vmp-adjusted"
        migration = args.migration.split(",")[0]
        spec = RunSpec(
            scenario=_market_scenario_spec(regime, args.pools,
                                           args.bid_strategy, args.tick,
                                           not args.flat_volatility),
            policy=_policy_spec(policy, args.alpha),
            migration=MigrationSpec("none" if migration == "all"
                                    else migration),
            rebid=RebidSpec() if args.rebid else None,
            fleet=(FleetSpec(strategy=args.fleet,
                             params={"target_capacity": args.fleet_target})
                   if args.fleet and args.fleet != "compare" else None),
            faults=FaultSpec(scenario=args.faults) if args.faults else None)
        until = args.until if args.until is not None else 14400.0
    else:
        policy = args.policy if args.policy != "all" else "first-fit"
        spec = RunSpec(
            scenario=ScenarioSpec(
                workload="synthetic",
                sim_params={"interruption_selector": args.selector}),
            policy=_policy_spec(policy, args.alpha))
        until = args.until if args.until is not None else 3000.0
    sim = build_run(spec, args.seed)
    with sanitized():
        metrics = sim.run(until=until)
    row = collect_row(sim, metrics, spec, args.seed)
    row["sanitized"] = True
    if args.json:
        print(json.dumps({"rows": [row]}, indent=1))
    else:
        print(f"# sanitized run ok: seed={args.seed} until={until} "
              f"policy={row.get('policy')} — no wall-clock or global-RNG "
              "calls on the sim path")
    return 0


def _cli_manifest(args, t0: float) -> dict:
    """The provenance block for CLI-assembled (possibly multi-row) runs:
    the manifest's spec dict is the parsed CLI namespace, so the hash
    pins the exact flag combination that produced the document."""
    return run_manifest(spec_dict=dict(sorted(vars(args).items())),
                        seed=args.seed, duration_s=time.time() - t0)


def _print_market_rows(rows) -> None:
    fleet = any("time_below_target_s" in r for r in rows)
    print(f"{'regime':11s} {'policy':18s} {'migration':15s} "
          f"{'intr':>5s} {'waves':>5s} {'max_intr_s':>10s} "
          f"{'migr':>5s} {'down_s':>7s} {'spot_cost':>9s} "
          f"{'save%':>6s} {'waste':>7s}"
          + (f" {'below_tgt_s':>11s} {'recov_s':>8s} {'od_spill':>8s}"
             if fleet else ""))
    for r in rows:
        line = (f"{r['regime']:11s} {r['policy']:18s} "
                f"{r['migration']:15s} "
                f"{r['interruptions']:5d} {r['waves']:5d} "
                f"{r['max_interruption_time']:10.1f} "
                f"{r['migrations']:5d} "
                f"{r['migration_downtime_s']:7.1f} "
                f"{r['realized_spot_cost']:9.3f} "
                f"{r['savings_pct']:6.1f} {r['wasted_cost']:7.3f}")
        if "time_below_target_s" in r:
            line += (f" {r['time_below_target_s']:11.1f} "
                     f"{r['mean_recovery_s']:8.1f} "
                     f"{r['od_spill_launches']:8d}")
        print(line)


def _sweep_and_report(exp: ExperimentSpec, args) -> int:
    # report_path flushes the report after every completed cell (atomic
    # rename) and resumes from a matching partial report after a crash;
    # --fresh discards any checkpoint (e.g. after changing simulator code)
    report = run_experiment(exp, processes=args.workers,
                            progress=_progress_enabled(args),
                            report_path=args.report or None,
                            resume=not args.fresh, manifest=True)
    if args.report:
        # stderr keeps --json stdout a pure JSON document
        print(f"# wrote {args.report}", file=sys.stderr)
    if args.report_html:
        write_html_report(report, args.report_html)
        print(f"# wrote {args.report_html}", file=sys.stderr)
    if args.json:
        print(json.dumps(report, indent=1, sort_keys=True))
    else:
        print(format_report(report))
    return 0


def _diff_logs(path_a: str, path_b: str) -> int:
    """Standalone ``--diff A B`` mode: stream two recorded event logs,
    report the first divergence (with context) or confirm zero divergence.
    Exit status 1 when the runs diverge — scriptable as a bit-identity
    gate."""
    div = first_divergence(path_a, path_b)
    print(format_divergence(div, label_a=path_a, label_b=path_b))
    return 0 if div is None else 1


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=["synthetic", "trace"],
                    default="synthetic")
    ap.add_argument("--policy", default="all",
                    help="policy name or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--until", type=float, default=None,
                    help="horizon (s); default 3000, or 14400 in "
                         "--market mode (the four demand humps + drain)")
    ap.add_argument("--selector", default="list_order",
                    choices=["list_order", "best_fit_remaining",
                             "max_progress"])
    ap.add_argument("--alpha", type=float, default=-0.5)
    ap.add_argument("--machines", type=int, default=200)
    ap.add_argument("--spot", type=int, default=1000)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--json", action="store_true")
    # observability (single-run modes; see README "Observability")
    ap.add_argument("--trace-out", default="",
                    help="write a Chrome trace-event JSON of the run here "
                         "(open in chrome://tracing or Perfetto); single-run "
                         "modes only")
    ap.add_argument("--profile", action="store_true",
                    help="aggregate span wall-times and print the "
                         "per-subsystem self/total table to stderr")
    ap.add_argument("--profile-out", default="",
                    help="write the profile report JSON here "
                         "(implies --profile aggregation)")
    ap.add_argument("--counters-every", type=float, default=None,
                    metavar="SECS",
                    help="snapshot live counters every SECS of sim time; "
                         "prints a progress line per snapshot to stderr "
                         "(suppressed under --json; the series lands in the "
                         "JSON document instead)")
    ap.add_argument("--events-out", default="",
                    help="record the structured event flight log and write "
                         "it here (NDJSON, or compressed .npz by "
                         "extension); single-run modes only")
    ap.add_argument("--report-html", default="",
                    help="write a self-contained HTML report here: per-run "
                         "price/risk/occupancy charts (records the event "
                         "log), or the aggregate comparison in sweep modes")
    ap.add_argument("--diff", nargs=2, metavar=("A", "B"), default=None,
                    help="standalone mode: diff two recorded event logs "
                         "and report the first divergence (exit 1 when the "
                         "runs diverge)")
    ap.add_argument("--sanitize", action="store_true",
                    help="run one fixed-seed run with the runtime determinism "
                         "sanitizer armed: time.time/random.*/legacy "
                         "np.random.* raise inside the sim scope (the dynamic "
                         "twin of tools/detlint's no-wallclock/no-global-rng)")
    ap.add_argument("--force-progress", action="store_true",
                    help="emit live stderr progress lines even when stderr "
                         "is not a terminal (they are suppressed by default "
                         "under redirection/CI)")
    # market-engine mode
    ap.add_argument("--market", action="store_true",
                    help="run the dynamic market engine across price regimes")
    ap.add_argument("--regimes", default="calm,volatile,correlated",
                    help="comma-separated subset of " + ",".join(REGIMES))
    ap.add_argument("--pools", type=int, default=4)
    ap.add_argument("--bid-strategy", default="randomized",
                    choices=["on-demand-cap", "percentile", "randomized"])
    ap.add_argument("--tick", type=float, default=60.0,
                    help="price tick interval (s)")
    ap.add_argument("--migration", default="none",
                    help="proactive migration policy: a comma-separated "
                         "subset of " + ",".join(MIGRATION_POLICIES)
                         + ", or 'all' to compare every policy per regime")
    ap.add_argument("--rebid", action="store_true",
                    help="adaptive re-bidding on hibernation (Bhuyan-style)")
    ap.add_argument("--fleet", default="",
                    help="attach a spot-fleet manager: a fleet strategy "
                         "name (diversified, lowest-price, single-pool), or "
                         "'compare' to sweep the diversified fleet against "
                         "the per-VM baseline (sweep mode only)")
    ap.add_argument("--fleet-target", type=float, default=64.0,
                    help="fleet target capacity in CPU cores (with --fleet)")
    ap.add_argument("--faults", default="",
                    help="inject a registered fault scenario (storm, "
                         "random-storms, pool-outage, price-spike, "
                         "capacity-crunch, scripted)")
    # serving-scenario mode
    ap.add_argument("--serve", default="", choices=["", "diurnal", "bursty"],
                    help="run the traffic-driven serving scenario on the "
                         "named demand curve: requests queue against the "
                         "spot fleet's live capacity and the row reports "
                         "SLO/latency/cost-per-request metrics")
    ap.add_argument("--serve-rate", type=float, default=None,
                    metavar="REQ_S",
                    help="demand-curve base arrival rate in req/s "
                         "(default: the workload's registered default)")
    ap.add_argument("--autoscale", default="",
                    help="close the serving loop with an autoscale policy "
                         "(static, target-tracking, step, "
                         "predictive-from-curve), or 'compare' to sweep "
                         "target-tracking against the static baseline "
                         "(requires --sweep N)")
    ap.add_argument("--flat-volatility", action="store_true",
                    help="use the regime's hand-set volatility constant for "
                         "every pool instead of deriving per-pool sigmas "
                         "from the synthetic Spot-Advisor dataset")
    # declarative / sweep modes
    ap.add_argument("--sweep", type=int, default=0, metavar="N",
                    help="seed-swept evaluation: run the --market grid over "
                         "N seeds (seed..seed+N-1) and report mean ± 95%% CI "
                         "per regime × policy × migration cell")
    ap.add_argument("--spec", default="",
                    help="run an ExperimentSpec JSON file (overrides every "
                         "scenario flag; see examples/specs/)")
    ap.add_argument("--report", default="",
                    help="write the sweep's aggregate report JSON here "
                         "(flushed after every completed cell; a matching "
                         "partial report at this path is resumed)")
    ap.add_argument("--fresh", action="store_true",
                    help="ignore an existing report at --report instead of "
                         "resuming from it (use after code changes: resumed "
                         "cells reflect the run that produced them)")
    ap.add_argument("--workers", type=int, default=None,
                    help="sweep worker processes (default: cpu count; "
                         "0 = serial)")
    args = ap.parse_args(argv)

    if args.diff is not None:
        return _diff_logs(*args.diff)
    if args.sanitize:
        if args.sweep or args.spec:
            ap.error("--sanitize applies to a single fixed-seed run "
                     "(not --sweep/--spec)")
        return run_sanitized(args)
    if args.serve and args.market:
        ap.error("--serve and --market are separate modes — pick one")
    if args.autoscale and not args.serve:
        ap.error("--autoscale requires --serve CURVE")
    if args.sweep and not (args.market or args.serve or args.spec):
        ap.error("--sweep requires --market or --serve "
                 "(or use --spec FILE)")
    if (args.fleet or args.faults) and not (args.market or args.serve):
        ap.error("--fleet/--faults require --market or --serve")
    if args.report and not (args.sweep or args.spec):
        ap.error("--report only applies to sweep modes "
                 "(--sweep N or --spec FILE)")
    obs_spec = None
    sweep_mode = bool(args.sweep or args.spec)
    if (args.trace_out or args.profile or args.profile_out
            or args.counters_every is not None or args.events_out):
        if sweep_mode:
            ap.error("--trace-out/--profile/--profile-out/--counters-every/"
                     "--events-out apply to single runs only "
                     "(not --sweep/--spec)")
    # --report-html doubles as the sweep's aggregate report; in single-run
    # modes it records the event log like --events-out
    want_events = bool(args.events_out
                       or (args.report_html and not sweep_mode))
    if (args.trace_out or args.profile or args.profile_out
            or args.counters_every is not None or want_events):
        obs_spec = ObsSpec(trace=bool(args.trace_out),
                           profile=bool(args.profile or args.profile_out),
                           counters_every=args.counters_every,
                           events=want_events)
    t_main = time.time()

    if args.spec:
        return _sweep_and_report(ExperimentSpec.load(args.spec), args)

    if args.serve:
        if args.fleet == "compare":
            ap.error("--fleet compare is a --market sweep mode")
        return run_serve(args, obs_spec, ap, t_main)

    if args.market:
        # the migration comparison varies the migration policy against the
        # paper's allocator; the allocator comparison (PR 2) spans both
        policies = ((MARKET_POLICY_SET if args.migration == "none"
                     else ["hlem-vmp-adjusted"])
                    if args.policy == "all" else [args.policy])
        migrations = (list(MIGRATION_POLICIES) if args.migration == "all"
                      else args.migration.split(","))
        until = args.until if args.until is not None else 14400.0
        regimes = args.regimes.split(",")
        # the resilience layer: --fleet names a strategy ("compare" sweeps
        # fleet vs the per-VM baseline), --faults a fault scenario; both
        # fail fast at spec construction on unknown names
        faults = FaultSpec(scenario=args.faults) if args.faults else None
        fleet = None
        if args.fleet and args.fleet != "compare":
            fleet = FleetSpec(strategy=args.fleet,
                              params={"target_capacity": args.fleet_target})

        if args.sweep:
            fleets = None
            if args.fleet == "compare":
                fleets = (None, FleetSpec(
                    strategy="diversified",
                    params={"target_capacity": args.fleet_target}))
            elif fleet is not None:
                fleets = (fleet,)
            exp = ExperimentSpec(
                name=f"market_sweep_{args.sweep}x",
                scenario=_market_scenario_spec(
                    regimes[0], args.pools, args.bid_strategy, args.tick,
                    not args.flat_volatility, horizon=until),
                policies=tuple(_policy_spec(p, args.alpha)
                               for p in policies),
                migrations=tuple(MigrationSpec(m) for m in migrations),
                regimes=tuple(regimes),
                seeds=tuple(range(args.seed, args.seed + args.sweep)),
                rebid=RebidSpec() if args.rebid else None,
                fleets=fleets, faults=faults)
            return _sweep_and_report(exp, args)

        if args.fleet == "compare":
            ap.error("--fleet compare requires --sweep N")
        if obs_spec is not None and (len(regimes) > 1 or len(policies) > 1
                                     or len(migrations) > 1):
            ap.error("observability flags trace a single run — pick one "
                     "regime × policy × migration cell (e.g. --regimes "
                     "volatile --policy hlem-vmp-adjusted --migration none)")
        rows = []
        obs_sink: dict = {}
        for regime in regimes:
            for p in policies:
                for mig in migrations:
                    rows.append(run_market(
                        p, regime, args.seed, until,
                        n_pools=args.pools,
                        bid_strategy=args.bid_strategy,
                        tick_interval=args.tick, alpha=args.alpha,
                        migration=mig, rebid=args.rebid,
                        from_advisor=not args.flat_volatility,
                        fleet=fleet, faults=faults,
                        obs=obs_spec, cli_args=args, obs_sink=obs_sink))
        if args.json:
            doc = {"rows": rows, "manifest": _cli_manifest(args, t_main)}
            doc.update(obs_sink)
            print(json.dumps(doc, indent=1))
        else:
            _print_market_rows(rows)
        return 0

    if args.scenario == "synthetic":
        policies = POLICY_SET if args.policy == "all" else [args.policy]
        if obs_spec is not None and len(policies) > 1:
            ap.error("observability flags trace a single run — pick one "
                     "--policy")
        until = args.until if args.until is not None else 3000.0
        obs_sink: dict = {}
        rows = [run_synthetic(p, args.seed, until, args.selector,
                              args.alpha, obs=obs_spec, cli_args=args,
                              obs_sink=obs_sink) for p in policies]
        if args.json:
            doc = {"rows": rows, "manifest": _cli_manifest(args, t_main)}
            doc.update(obs_sink)
            print(json.dumps(doc, indent=1))
        else:
            for r in rows:
                print(f"{r['policy']:20s} interruptions={r['interruptions']:5d} "
                      f"avg={r['avg_interruption_time']:7.2f}s "
                      f"max={r['max_interruption_time']:7.2f}s "
                      f"finished={r['spot_finished']:4d} "
                      f"terminated={r['spot_terminated']:4d} "
                      f"[{r['wall_s']}s]")
        return 0

    # trace scenario — same SimConfig wiring as every other path: one
    # ScenarioSpec, materialized by api.build
    spec = RunSpec(
        scenario=ScenarioSpec(
            workload="trace",
            workload_params={"n_machines": args.machines,
                             "sim_days": args.days, "n_spot": args.spot}),
        policy=_policy_spec(
            args.policy if args.policy != "all" else "hlem-vmp-adjusted",
            args.alpha),
        obs=obs_spec)
    t0 = time.time()
    sim = build_run(spec, args.seed)
    if args.counters_every is not None and _progress_enabled(args):
        sim.obs.on_snapshot = _live_counter_line
    metrics = sim.run(until=args.until)
    wall = time.time() - t0
    stats = collect_row(sim, metrics, spec, args.seed)
    stats.update(machines=args.machines, n_vms=len(sim.vms),
                 wall_s=round(wall, 1))
    stats.update(_emit_obs_artifacts(sim, spec, args.seed, args, wall))
    stats["manifest"] = run_manifest(spec_dict=spec.to_dict(),
                                     seed=args.seed, duration_s=wall)
    print(json.dumps(stats, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
