"""Market simulation launcher (the paper's §VII experiments from the CLI).

  python -m repro.launch.market_sim --scenario synthetic --policy all
  python -m repro.launch.market_sim --scenario trace --machines 200
  python -m repro.launch.market_sim --market                 # price regimes
  python -m repro.launch.market_sim --market --regimes volatile --pools 3

``--market`` runs the dynamic market engine: multi-pool price clearing over
the §VII-E synthetic fleet, HLEM vs First-Fit under calm / volatile /
correlated-pool price regimes, reporting interruption counts, max
interruption duration, and realized spot cost (billed at clearing price).
"""
from __future__ import annotations

import argparse
import copy
import json
import time

from ..core import (
    MarketSimulator,
    ScenarioConfig,
    SimConfig,
    dynamic_vm_table,
    make_policy,
    spot_vm_table,
    synthetic_scenario,
    to_csv,
)
from ..market import (
    MarketEngine,
    REGIMES,
    TraceConfig,
    assign_bids,
    generate_trace,
    make_bid_strategy,
    make_market,
    realized_cost_stats,
    simulate_trace,
)

POLICY_SET = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
              "hlem-vmp-adjusted"]
MARKET_POLICY_SET = ["first-fit", "hlem-vmp-adjusted"]


def run_synthetic(policy_name: str, seed: int, until: float,
                  selector: str = "list_order", alpha: float = -0.5) -> dict:
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=seed))
    kwargs = {}
    if policy_name == "hlem-vmp-adjusted":
        kwargs["alpha"] = alpha
    policy = make_policy(policy_name, **kwargs)
    sim = MarketSimulator(policy=policy, config=SimConfig(
        record_timeline=False, interruption_selector=selector))
    for cap in hosts:
        sim.add_host(cap)
    for v in vms:
        sim.submit(copy.deepcopy(v))
    t0 = time.time()
    m = sim.run(until=until)
    stats = m.spot_stats(sim.vms)
    stats.update(policy=policy_name, wall_s=round(time.time() - t0, 1),
                 allocations=m.allocations, resubmissions=m.resubmissions)
    return stats


def run_market(policy_name: str, regime: str, seed: int, until: float,
               n_pools: int = 2, bid_strategy: str = "randomized",
               tick_interval: float = 60.0, alpha: float = -0.5) -> dict:
    """One engine-coupled run: §VII-E fleet split round-robin into
    ``n_pools`` capacity pools, seeded bids on every spot VM, price-driven
    interruption waves, realized-price cost accounting."""
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=seed))
    mc = make_market(regime, n_pools=n_pools, seed=seed,
                     tick_interval=tick_interval)
    engine = MarketEngine(mc)
    vms = [copy.deepcopy(v) for v in vms]
    strat = make_bid_strategy(bid_strategy, pool_cfg=mc.pools[0], seed=seed)
    assign_bids(vms, strat, seed=seed)
    kwargs = {"alpha": alpha} if policy_name == "hlem-vmp-adjusted" else {}
    sim = MarketSimulator(policy=make_policy(policy_name, **kwargs),
                          config=SimConfig(record_timeline=False),
                          engine=engine)
    for i, cap in enumerate(hosts):
        sim.add_host(cap, pool=i % n_pools)
    for v in vms:
        sim.submit(v)
    t0 = time.time()
    m = sim.run(until=until)
    wall = time.time() - t0
    s = m.spot_stats(sim.vms)
    ms = m.market_stats()
    cost = realized_cost_stats(sim.vms.values(), engine, sim.pool)
    return {
        "policy": policy_name,
        "regime": regime,
        "interruptions": s["interruptions"],
        "price_interruptions": ms["price_interruptions"],
        "waves": ms["waves"],
        "max_wave_size": ms["max_wave_size"],
        "avg_interruption_time": s["avg_interruption_time"],
        "max_interruption_time": s["max_interruption_time"],
        "spot_finished": s["spot_finished"],
        "spot_terminated": s["spot_terminated"],
        "realized_spot_cost": round(cost["spot_cost"], 4),
        "savings_pct": round(cost["savings_pct"], 1),
        "wasted_cost": round(cost["wasted_cost"], 4),
        "allocations": m.allocations,
        "wall_s": round(wall, 1),
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=["synthetic", "trace"],
                    default="synthetic")
    ap.add_argument("--policy", default="all",
                    help="policy name or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--until", type=float, default=3000.0)
    ap.add_argument("--selector", default="list_order",
                    choices=["list_order", "best_fit_remaining",
                             "max_progress"])
    ap.add_argument("--alpha", type=float, default=-0.5)
    ap.add_argument("--machines", type=int, default=200)
    ap.add_argument("--spot", type=int, default=1000)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--json", action="store_true")
    # market-engine mode
    ap.add_argument("--market", action="store_true",
                    help="run the dynamic market engine across price regimes")
    ap.add_argument("--regimes", default="calm,volatile,correlated",
                    help="comma-separated subset of " + ",".join(REGIMES))
    ap.add_argument("--pools", type=int, default=2)
    ap.add_argument("--bid-strategy", default="randomized",
                    choices=["on-demand-cap", "percentile", "randomized"])
    ap.add_argument("--tick", type=float, default=60.0,
                    help="price tick interval (s)")
    args = ap.parse_args(argv)

    if args.market:
        policies = (MARKET_POLICY_SET if args.policy == "all"
                    else [args.policy])
        rows = []
        for regime in args.regimes.split(","):
            for p in policies:
                rows.append(run_market(
                    p, regime, args.seed, args.until, n_pools=args.pools,
                    bid_strategy=args.bid_strategy,
                    tick_interval=args.tick, alpha=args.alpha))
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            print(f"{'regime':11s} {'policy':18s} {'intr':>5s} {'waves':>5s} "
                  f"{'max_intr_s':>10s} {'spot_cost':>9s} {'save%':>6s} "
                  f"{'waste':>7s}")
            for r in rows:
                print(f"{r['regime']:11s} {r['policy']:18s} "
                      f"{r['interruptions']:5d} {r['waves']:5d} "
                      f"{r['max_interruption_time']:10.1f} "
                      f"{r['realized_spot_cost']:9.3f} "
                      f"{r['savings_pct']:6.1f} {r['wasted_cost']:7.3f}")
        return 0

    if args.scenario == "synthetic":
        policies = POLICY_SET if args.policy == "all" else [args.policy]
        rows = [run_synthetic(p, args.seed, args.until, args.selector,
                              args.alpha) for p in policies]
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            for r in rows:
                print(f"{r['policy']:20s} interruptions={r['interruptions']:5d} "
                      f"avg={r['avg_interruption_time']:7.2f}s "
                      f"max={r['max_interruption_time']:7.2f}s "
                      f"finished={r['spot_finished']:4d} "
                      f"terminated={r['spot_terminated']:4d} "
                      f"[{r['wall_s']}s]")
        return 0

    # trace scenario
    tcfg = TraceConfig(seed=args.seed, n_machines=args.machines,
                       sim_days=args.days, n_spot=args.spot)
    tr = generate_trace(tcfg)
    policy = make_policy(
        args.policy if args.policy != "all" else "hlem-vmp-adjusted")
    t0 = time.time()
    sim, metrics = simulate_trace(tr, policy=policy, cfg=tcfg)
    stats = metrics.spot_stats(sim.vms)
    stats.update(machines=args.machines, n_vms=len(sim.vms),
                 wall_s=round(time.time() - t0, 1))
    print(json.dumps(stats, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
