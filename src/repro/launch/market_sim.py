"""Market simulation launcher (the paper's §VII experiments from the CLI).

  python -m repro.launch.market_sim --scenario synthetic --policy all
  python -m repro.launch.market_sim --scenario trace --machines 200
"""
from __future__ import annotations

import argparse
import copy
import json
import time

from ..core import (
    MarketSimulator,
    ScenarioConfig,
    SimConfig,
    dynamic_vm_table,
    make_policy,
    spot_vm_table,
    synthetic_scenario,
    to_csv,
)
from ..market import TraceConfig, generate_trace, simulate_trace

POLICY_SET = ["first-fit", "best-fit", "worst-fit", "hlem-vmp",
              "hlem-vmp-adjusted"]


def run_synthetic(policy_name: str, seed: int, until: float,
                  selector: str = "list_order", alpha: float = -0.5) -> dict:
    hosts, vms = synthetic_scenario(ScenarioConfig(seed=seed))
    kwargs = {}
    if policy_name == "hlem-vmp-adjusted":
        kwargs["alpha"] = alpha
    policy = make_policy(policy_name, **kwargs)
    sim = MarketSimulator(policy=policy, config=SimConfig(
        record_timeline=False, interruption_selector=selector))
    for cap in hosts:
        sim.add_host(cap)
    for v in vms:
        sim.submit(copy.deepcopy(v))
    t0 = time.time()
    m = sim.run(until=until)
    stats = m.spot_stats(sim.vms)
    stats.update(policy=policy_name, wall_s=round(time.time() - t0, 1),
                 allocations=m.allocations, resubmissions=m.resubmissions)
    return stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=["synthetic", "trace"],
                    default="synthetic")
    ap.add_argument("--policy", default="all",
                    help="policy name or 'all'")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--until", type=float, default=3000.0)
    ap.add_argument("--selector", default="list_order",
                    choices=["list_order", "best_fit_remaining",
                             "max_progress"])
    ap.add_argument("--alpha", type=float, default=-0.5)
    ap.add_argument("--machines", type=int, default=200)
    ap.add_argument("--spot", type=int, default=1000)
    ap.add_argument("--days", type=float, default=0.25)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    if args.scenario == "synthetic":
        policies = POLICY_SET if args.policy == "all" else [args.policy]
        rows = [run_synthetic(p, args.seed, args.until, args.selector,
                              args.alpha) for p in policies]
        if args.json:
            print(json.dumps(rows, indent=1))
        else:
            for r in rows:
                print(f"{r['policy']:20s} interruptions={r['interruptions']:5d} "
                      f"avg={r['avg_interruption_time']:7.2f}s "
                      f"max={r['max_interruption_time']:7.2f}s "
                      f"finished={r['spot_finished']:4d} "
                      f"terminated={r['spot_terminated']:4d} "
                      f"[{r['wall_s']}s]")
        return 0

    # trace scenario
    tcfg = TraceConfig(seed=args.seed, n_machines=args.machines,
                       sim_days=args.days, n_spot=args.spot)
    tr = generate_trace(tcfg)
    policy = make_policy(
        args.policy if args.policy != "all" else "hlem-vmp-adjusted")
    t0 = time.time()
    sim, metrics = simulate_trace(tr, policy=policy, cfg=tcfg)
    stats = metrics.spot_stats(sim.vms)
    stats.update(machines=args.machines, n_vms=len(sim.vms),
                 wall_s=round(time.time() - t0, 1))
    print(json.dumps(stats, indent=1))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
