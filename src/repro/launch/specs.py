"""Assigned input shapes and per-cell ShapeDtypeStruct builders.

Every (architecture × shape) cell defines which step function is lowered:
  train_4k    -> train_step (next-token CE + optimizer update)
  prefill_32k -> prefill_step (build the KV/SSM cache for the prompt)
  decode_32k  -> serve_step (1 new token, cache of seq_len)
  long_500k   -> serve_step (1 new token, 512k context) — sub-quadratic archs
                 only (SSM / sliding-window hybrid); skipped otherwise.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import decode_state_specs, init_decode_state
from ..models.sharding import attach
from ..train.train_step import init_train_state, train_state_specs


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip rules (recorded in EXPERIMENTS.md §Dry-run)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 512k dense KV decode is the "
                       "quadratic regime the shape excludes (DESIGN.md)")
    return True, ""


def _token_struct(cfg: ArchConfig, batch: int, seq: int):
    """ShapeDtypeStruct for model input: int tokens (text) or precomputed
    frontend embeddings (vlm/audio stub)."""
    if cfg.modality == "text":
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), jnp.bfloat16)


def batch_specs(cfg: ArchConfig, shape: ShapeSpec):
    """(shape_tree, logical_spec_tree) for the data batch of a train cell."""
    shapes = {
        "tokens": _token_struct(cfg, shape.global_batch, shape.seq_len),
        "labels": jax.ShapeDtypeStruct(
            (shape.global_batch, shape.seq_len), jnp.int32),
    }
    specs = {
        "tokens": (("batch", "seq") if cfg.modality == "text"
                   else ("batch", "seq", "embed")),
        "labels": ("batch", "seq"),
    }
    return shapes, specs


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[Any, ...]:
    """Sharded ShapeDtypeStruct stand-ins for every input of the lowered step
    (requires an active mesh via sharding.use_mesh)."""
    if shape.kind == "train":
        state_shapes = jax.eval_shape(
            lambda: init_train_state(cfg, jax.random.PRNGKey(0)))
        state = attach(state_shapes, train_state_specs(cfg))
        b_shapes, b_specs = batch_specs(cfg, shape)
        batch = attach(b_shapes, b_specs)
        return (state, batch)

    if shape.kind == "prefill":
        params_shapes = jax.eval_shape(
            lambda: __import__("repro.models.model", fromlist=["init_params"]
                               ).init_params(cfg, jax.random.PRNGKey(0)))
        from ..models.model import param_specs
        params = attach(params_shapes, param_specs(cfg))
        tokens = attach(
            _token_struct(cfg, shape.global_batch, shape.seq_len),
            (("batch", "seq") if cfg.modality == "text"
             else ("batch", "seq", "embed")))
        return (params, tokens)

    # decode
    from ..models.model import init_params, param_specs
    params_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    params = attach(params_shapes, param_specs(cfg))
    token = attach(
        _token_struct(cfg, shape.global_batch, 1),
        (("batch", "seq") if cfg.modality == "text"
         else ("batch", "seq", "embed")))
    st_shapes = jax.eval_shape(
        lambda: init_decode_state(cfg, shape.global_batch, shape.seq_len))
    state = attach(st_shapes, decode_state_specs(cfg))
    return (params, token, state)


def rules_for(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Per-cell sharding-rule overrides."""
    rules: Dict[str, Any] = {}
    if cfg.fsdp_over_pod:
        rules["fsdp"] = ("pod", "data")
    if cfg.seq_parallel and shape.kind in ("train", "prefill"):
        rules["res_seq"] = "model"
    if shape.kind == "decode" and cfg.has_attention:
        # flash-decoding-style cache layout: KV heads (often < model width)
        # replicate; the cache SEQ dim shards over "model" instead, so each
        # chip scans 1/16th of the context and GSPMD combines the softmax.
        rules["kv_seq"] = "model"
        rules["kv_heads"] = None
    return rules
