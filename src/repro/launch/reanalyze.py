"""Re-run the HLO analyzer over saved .hlo.gz artifacts and update the
dry-run result JSONs in place (no recompilation).  Used when the roofline
byte/flop model improves."""
from __future__ import annotations

import argparse
import glob
import gzip
import json
import os

from .hlo_analyzer import analyze
from .hlo_stats import roofline_terms


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="results/dryrun")
    args = ap.parse_args(argv)

    for path in sorted(glob.glob(os.path.join(args.results, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") != "ok":
            continue
        stem = os.path.splitext(os.path.basename(path))[0]
        hlo_path = os.path.join(args.results, "hlo", stem + ".hlo.gz")
        if not os.path.exists(hlo_path):
            print(f"[no-hlo] {stem}")
            continue
        with gzip.open(hlo_path, "rt") as f:
            ana = analyze(f.read())
        chips = rec["chips"]
        flops_g = ana.flops * chips
        rec["hlo_analysis"] = ana.asdict()
        rec["hlo_flops"] = flops_g
        mf = rec.get("model_flops", 0.0)
        rec["useful_flops_ratio"] = (mf / flops_g) if flops_g else None
        rec["roofline"] = roofline_terms(
            flops_g, ana.hbm_bytes * chips, ana.collective_bytes * chips,
            chips)
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline"]
        print(f"[ok] {stem}: dom={r['dominant']} "
              f"cmp={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
              f"col={r['collective_s']*1e3:.1f}ms")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
