"""Post-SPMD HLO analysis: collective traffic + roofline terms.

``collective_bytes`` builds a name->shape table from the optimized HLO text
and sums the operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction (the dry-run's substitute for a
real interconnect profile).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "c128": 16, "s4": 1, "u4": 1,
}

# `%name = f32[8,16]{1,0} op-name(...)`  (also matches tuple-free simple defs)
_DEF_RE = re.compile(
    r"%?([\w\.\-]+)\s*=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dtype: str, dims: str) -> int:
    nbytes = _DTYPE_BYTES.get(dtype)
    if nbytes is None:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * nbytes


@dataclass
class CollectiveStats:
    total_bytes: int = 0
    by_kind: Dict[str, int] = field(default_factory=dict)
    counts: Dict[str, int] = field(default_factory=dict)

    def asdict(self) -> dict:
        return {"total_bytes": self.total_bytes, "by_kind": dict(self.by_kind),
                "counts": dict(self.counts)}


def collective_bytes(hlo_text: str) -> CollectiveStats:
    """Sum operand sizes of collective ops in optimized HLO text.

    Loop bodies (while/scan) are counted once — multiply externally by trip
    count if desired; for roofline we report the static program traffic, and
    scan-over-layers collectives appear inside the loop body (noted in
    EXPERIMENTS.md).
    """
    # name -> bytes of each instruction's result
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        sizes[m.group(1)] = _shape_bytes(m.group(2), m.group(3))

    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = re.match(r"%?([\w\.\-]+)\s*=\s*.*?\s((?:all|reduce|collective)"
                     r"[a-z\-]*)\(", stripped)
        if not m:
            continue
        kind = m.group(2)
        if kind not in _COLLECTIVES and not any(
                kind.startswith(c) for c in _COLLECTIVES):
            continue
        # operands: %name tokens inside the call parens
        call = stripped[stripped.index("(") :]
        ops = re.findall(r"%([\w\.\-]+)", call)
        nbytes = sum(sizes.get(o, 0) for o in ops)
        if nbytes == 0:
            # fall back to the result size (covers fused/renamed operands)
            nbytes = sizes.get(m.group(1), 0)
        base = next(c for c in _COLLECTIVES if kind.startswith(c))
        stats.total_bytes += nbytes
        stats.by_kind[base] = stats.by_kind.get(base, 0) + nbytes
        stats.counts[base] = stats.counts.get(base, 0) + 1
    return stats


def while_trip_counts(hlo_text: str) -> list:
    """Best-effort extraction of while-loop trip counts (scan over layers /
    grad-accum microbatches) from known_trip_count annotations."""
    return [int(x) for x in re.findall(
        r'known_trip_count=\{?"?n"?[:=](\d+)', hlo_text)]


# ---------------------------------------------------------------------------
# Roofline terms (TPU v5e constants from the assignment)
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link


def roofline_terms(flops: float, hbm_bytes: float, coll_bytes: float,
                   chips: int) -> dict:
    compute_s = flops / (chips * PEAK_FLOPS) if flops else 0.0
    memory_s = hbm_bytes / (chips * HBM_BW) if hbm_bytes else 0.0
    collective_s = coll_bytes / (chips * ICI_BW) if coll_bytes else 0.0
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    total = max(bound, 1e-30)
    terms.update({
        "dominant": dominant,
        "bound_s": bound,
        "roofline_fraction_compute": compute_s / total,
    })
    return terms
