"""internlm2-20b — dense GQA model.
[arXiv:2403.17297; hf]  48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense", modality="text",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab=92544, rope_theta=1_000_000.0, mlp="gated_silu",
    grad_accum=2,
)

SMOKE_CONFIG = CONFIG.replace(
    grad_accum=1, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192, vocab=192,
    dtype="float32", attention_chunk=64)
