"""Assigned architecture registry (``--arch <id>``).

Each module defines ``CONFIG`` (the exact published configuration) and
``SMOKE_CONFIG`` (a reduced same-family configuration for CPU smoke tests).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from ..models.config import ArchConfig

ARCH_IDS: List[str] = [
    "phi_3_vision_4_2b",
    "kimi_k2_1t_a32b",
    "granite_moe_3b_a800m",
    "musicgen_large",
    "starcoder2_15b",
    "deepseek_7b",
    "internlm2_20b",
    "llama3_405b",
    "hymba_1_5b",
    "falcon_mamba_7b",
]

# public (dashed) ids as given in the assignment
PUBLIC_IDS = {
    "phi-3-vision-4.2b": "phi_3_vision_4_2b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "musicgen-large": "musicgen_large",
    "starcoder2-15b": "starcoder2_15b",
    "deepseek-7b": "deepseek_7b",
    "internlm2-20b": "internlm2_20b",
    "llama3-405b": "llama3_405b",
    "hymba-1.5b": "hymba_1_5b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def _module(arch: str):
    mod = PUBLIC_IDS.get(arch, arch).replace("-", "_").replace(".", "_")
    return importlib.import_module(f".{mod}", __name__)


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _module(arch).SMOKE_CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
