"""falcon-mamba-7b — attention-free mamba-1 architecture.
[arXiv:2410.05355; unverified]  64L d_model=4096 ssm_state=16 vocab=65024,
d_inner = 2 x d_model = 8192.  No attention, no KV cache: the long_500k cell
decodes against a constant-size recurrent state."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b", family="ssm", modality="text",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab=65024, ssm_state=16, d_inner=8192, conv_width=4,
    grad_accum=2,
)

SMOKE_CONFIG = CONFIG.replace(
    grad_accum=1, n_layers=2, d_model=64, ssm_state=8, d_inner=128, vocab=128,
    dtype="float32")
