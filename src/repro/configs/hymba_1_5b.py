"""hymba-1.5b — hybrid: parallel attention + mamba heads per block.
[arXiv:2411.13676; hf]  32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001
ssm_state=16.  Attention is sliding-window (W=1024) in the hybrid blocks, so
the arch is sub-quadratic and runs the long_500k cell (ring-buffer KV cache
of W slots + recurrent SSM state).  25 heads / 16-way model axis relies on
GSPMD padding."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="hymba-1.5b", family="hybrid", modality="text",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5,
    d_ff=5504, vocab=32001, ssm_state=16, d_inner=3200, conv_width=4,
    sliding_window=1024, rope_theta=10_000.0, mlp="gated_silu",
    head_dim=64, grad_accum=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=5, n_kv_heads=1, d_ff=128, vocab=128,
    ssm_state=8, d_inner=128, sliding_window=32, head_dim=16,
    dtype="float32", attention_chunk=64)
