"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8.
[arXiv:2501.kimi2; unverified]  61L d_model=7168 64H (GQA kv=8) d_ff=2048
(per expert) vocab=163840.  Optimizer: adafactor (fp32 Adam moments for 1T
params would not fit 512 x 16 GB; see DESIGN.md).  No shared expert is
modeled (deviation recorded in DESIGN.md)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b", family="moe", modality="text",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=2048, vocab=163840, n_experts=384, top_k=8,
    capacity_factor=1.25, moe_group_size=2048,
    rope_theta=50_000.0, mlp="gated_silu",
    optimizer="adafactor", grad_accum=8, fsdp_over_pod=True,
    accum_dtype="bfloat16",
)

SMOKE_CONFIG = CONFIG.replace(
    grad_accum=1, fsdp_over_pod=False,
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=64, vocab=256,
    n_experts=8, top_k=2, moe_group_size=64, dtype="float32",
    attention_chunk=64)
