"""granite-moe-3b-a800m — 40 experts top-8 MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]  32L d_model=1536 24H (kv=8)
d_ff=512 (per expert) vocab=49155.  24 heads and 49155 vocab are not
divisible by the 16-way model axis — GSPMD padding handles both (a main
reason the framework uses pjit semantics rather than shard_map)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe", modality="text",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
    d_ff=512, vocab=49155, n_experts=40, top_k=8,
    capacity_factor=1.25, moe_group_size=2048,
    rope_theta=10_000.0, mlp="gated_silu", grad_accum=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=48, n_heads=3, n_kv_heads=1, d_ff=32, vocab=129,
    n_experts=5, top_k=2, moe_group_size=64, dtype="float32",
    attention_chunk=64)
