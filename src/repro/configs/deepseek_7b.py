"""deepseek-7b — llama-architecture dense model.
[arXiv:2401.02954; hf]  30L d_model=4096 32H (kv=32) d_ff=11008
vocab=102400."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-7b", family="dense", modality="text",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=11008, vocab=102400, rope_theta=10_000.0, mlp="gated_silu",
    grad_accum=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160, vocab=256,
    dtype="float32", attention_chunk=64)
