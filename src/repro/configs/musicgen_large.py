"""musicgen-large — decoder-only transformer over EnCodec tokens.
[arXiv:2306.05284; hf]  48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048.
Modality: audio — input_specs() provides precomputed frame embeddings; the
EnCodec tokenizer/frontend is a stub per the assignment.  MusicGen uses
non-gated GELU FFNs."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="dense", modality="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, rope_theta=10_000.0, mlp="gelu", grad_accum=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=64,
    dtype="float32", attention_chunk=64)
