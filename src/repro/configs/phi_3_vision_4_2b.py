"""phi-3-vision-4.2b — phi3-mini backbone + CLIP frontend (stub).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]  32L d_model=3072 32H (kv=32)
d_ff=8192 vocab=32064.  Modality: vlm — input_specs() provides precomputed
patch embeddings; the CLIP tower is a stub per the assignment."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b", family="dense", modality="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, rope_theta=10_000.0, mlp="gated_silu",
    grad_accum=1,
)

SMOKE_CONFIG = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=128,
    dtype="float32", attention_chunk=64)
