"""starcoder2-15b — dense GQA + RoPE code model.
[arXiv:2402.19173; hf]  40L d_model=6144 48H (GQA kv=4) d_ff=24576
vocab=49152.  StarCoder2 uses non-gated GELU FFNs (d_ff = 4 x d_model)."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b", family="dense", modality="text",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4,
    d_ff=24576, vocab=49152, rope_theta=100_000.0, mlp="gelu", grad_accum=2,
)

SMOKE_CONFIG = CONFIG.replace(
    grad_accum=1, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256, vocab=128,
    dtype="float32", attention_chunk=64)
