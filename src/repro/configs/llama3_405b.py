"""llama3-405b — dense GQA, 128k vocab.
[arXiv:2407.21783; unverified]  126L d_model=16384 128H (GQA kv=8)
d_ff=53248 vocab=128256.  Training at 512 chips requires grad_accum=4
(microbatch 64) to fit activations besides the 405B param + AdamW state
footprint; see EXPERIMENTS.md memory analysis."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b", family="dense", modality="text",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8,
    d_ff=53248, vocab=128256, rope_theta=500_000.0, mlp="gated_silu",
    grad_accum=8, fsdp_over_pod=True, seq_parallel=True,
    moment_dtype="bfloat16", accum_dtype="bfloat16",
)

SMOKE_CONFIG = CONFIG.replace(
    grad_accum=1, fsdp_over_pod=False, seq_parallel=False,
    moment_dtype="float32", accum_dtype="float32",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192, vocab=256,
    dtype="float32", attention_chunk=64)
