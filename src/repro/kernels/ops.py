"""Public jit'd entry points for the kernel package.

Every op has an ``impl`` switch:
  * ``"xla"``     — the pure-jnp reference path (used by the multi-pod dry-run:
                    roofline terms are derived from XLA HLO, and TPU Pallas
                    kernels cannot lower on the CPU host platform),
  * ``"pallas"``  — the TPU kernel (compiled for real TPUs),
  * ``"interp"``  — the TPU kernel body interpreted on CPU (tests/validation).

This mirrors how production JAX frameworks gate custom kernels behind flags.
"""
from __future__ import annotations

from typing import Optional

import jax

from . import ref
from .flash_attention import flash_attention as _fa_pallas
from .hlem_score import hlem_score_pallas
from .ssm_scan import ssm_scan as _ssm_pallas

DEFAULT_IMPL = "xla"


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              impl: str = DEFAULT_IMPL, block_q: int = 128,
              block_k: int = 128) -> jax.Array:
    """Multi-head attention with GQA broadcast; q (B,H,Tq,dh), k/v (B,Hkv,Tk,dh)."""
    if impl == "xla":
        return ref.mha_ref(q, k, v, causal=causal, window=window)
    return _fa_pallas(q, k, v, causal=causal, window=window,
                      block_q=block_q, block_k=block_k,
                      interpret=(impl == "interp"))


def selective_scan(x, dt, a, b, c, d, h0=None, *, impl: str = DEFAULT_IMPL,
                   block_d: int = 256, block_t: int = 128):
    """Mamba-1 selective scan; returns (y, final_state)."""
    if impl == "xla":
        return ref.ssm_scan_ref(x, dt, a, b, c, d, h0)
    return _ssm_pallas(x, dt, a, b, c, d, h0, block_d=block_d,
                       block_t=block_t, interpret=(impl == "interp"))


def hlem_score(free, mask, spot_frac, alpha, *, impl: str = DEFAULT_IMPL,
               block: int = 512) -> jax.Array:
    """HLEM-VMP host scores (paper Eqs. 3-11)."""
    if impl == "xla":
        return ref.hlem_score_ref(free, mask, spot_frac, alpha)
    return hlem_score_pallas(free, mask, spot_frac, alpha, block=block,
                             interpret=(impl == "interp"))
