"""Selective state-space scan (Mamba-1) as a Pallas TPU kernel.

The recurrence h_t = exp(dt_t⊙a)·h_{t-1} + dt_t·b_t·x_t is sequential in t but
embarrassingly parallel over (batch, d_model).  TPU adaptation: tile d_model
into VMEM-resident blocks; grid = (batch, d_blocks, t_blocks) with the time
axis innermost (sequential on TPU), carrying the (block_d, N) state in VMEM
scratch across time blocks — the state never round-trips to HBM during the
sweep, unlike a naive jax.lax.scan whose carry is an HBM-resident residual.

Within a time block the recurrence runs as an unrolled fori_loop over rows;
each step is a (block_d, N) elementwise FMA + an N-reduction — VPU work that
pipelines with the next block's DMA.

Supports chunked/stateful execution (h0 in, hT out) for decode serving.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssm_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
                y_ref, hT_ref, h_ref, *, block_t: int, n_state: int):
    ti = pl.program_id(2)
    nt = pl.num_programs(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)      # (BT, BD)
    dt = dt_ref[0].astype(jnp.float32)    # (BT, BD)
    a = a_ref[...].astype(jnp.float32)    # (BD, N)
    b = b_ref[0].astype(jnp.float32)      # (BT, N)
    c = c_ref[0].astype(jnp.float32)      # (BT, N)
    dskip = d_ref[...].astype(jnp.float32)  # (1, BD)

    def step(i, carry):
        h, ys = carry
        dt_i = jax.lax.dynamic_slice_in_dim(dt, i, 1, 0)      # (1, BD)
        x_i = jax.lax.dynamic_slice_in_dim(x, i, 1, 0)        # (1, BD)
        b_i = jax.lax.dynamic_slice_in_dim(b, i, 1, 0)        # (1, N)
        c_i = jax.lax.dynamic_slice_in_dim(c, i, 1, 0)        # (1, N)
        da = jnp.exp(dt_i.T * a)                              # (BD, N)
        h = da * h + (dt_i * x_i).T * b_i                     # (BD, N)
        y_i = jnp.sum(h * c_i, axis=1)[None, :]               # (1, BD)
        ys = jax.lax.dynamic_update_slice_in_dim(ys, y_i, i, 0)
        return h, ys

    h0 = h_ref[...]
    ys0 = jnp.zeros_like(x)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h0, ys0))
    h_ref[...] = h
    y_ref[0] = (ys + x * dskip).astype(y_ref.dtype)

    @pl.when(ti == nt - 1)
    def _emit_state():
        hT_ref[0] = h.astype(hT_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_d", "block_t", "interpret"))
def ssm_scan(
    x: jax.Array,    # (B, T, Dm)
    dt: jax.Array,   # (B, T, Dm) positive
    a: jax.Array,    # (Dm, N)
    b: jax.Array,    # (B, T, N)
    c: jax.Array,    # (B, T, N)
    d: jax.Array,    # (Dm,)
    h0: jax.Array | None = None,   # (B, Dm, N)
    *,
    block_d: int = 256,
    block_t: int = 128,
    interpret: bool = False,
):
    """Returns (y (B,T,Dm), hT (B,Dm,N)). Matches ref.ssm_scan_ref."""
    bsz, t, dm = x.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, dm, n), dtype=jnp.float32)

    block_d = min(block_d, dm)
    block_t = min(block_t, t)
    dm_pad = pl.cdiv(dm, block_d) * block_d
    t_pad = pl.cdiv(t, block_t) * block_t

    pad3 = lambda z: jnp.pad(z, ((0, 0), (0, t_pad - t), (0, dm_pad - dm)))
    x_p, dt_p = pad3(x), pad3(dt)
    a_p = jnp.pad(a, ((0, dm_pad - dm), (0, 0)))
    b_p = jnp.pad(b, ((0, 0), (0, t_pad - t), (0, 0)))
    c_p = jnp.pad(c, ((0, 0), (0, t_pad - t), (0, 0)))
    d_p = jnp.pad(d, (0, dm_pad - dm))[None, :]
    h0_p = jnp.pad(h0, ((0, 0), (0, dm_pad - dm), (0, 0)))

    grid = (bsz, dm_pad // block_d, t_pad // block_t)
    kernel = functools.partial(_ssm_kernel, block_t=block_t, n_state=n)

    y, hT = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((block_d, n), lambda bi, di, ti: (di, 0)),
            pl.BlockSpec((1, block_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_t, n), lambda bi, di, ti: (bi, ti, 0)),
            pl.BlockSpec((1, block_d), lambda bi, di, ti: (0, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_d), lambda bi, di, ti: (bi, ti, di)),
            pl.BlockSpec((1, block_d, n), lambda bi, di, ti: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, t_pad, dm_pad), x.dtype),
            jax.ShapeDtypeStruct((bsz, dm_pad, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x_p, dt_p, a_p, b_p, c_p, d_p, h0_p)
    return y[:, :t, :dm], hT[:, :dm, :]
