"""Pallas TPU kernel for HLEM-VMP host scoring (paper Eqs. 3-11).

TPU adaptation of the hot loop: at Google-trace scale the simulator re-scores
~12.6 k hosts for every one of ~28.8 M allocations; the Java original walks
host objects one by one.  Here the host axis is laid out along TPU *lanes*
(128-wide) with the D=4 resource dims on sublanes, and the whole scoring —
four data-dependent reduction stages — runs as ONE ``pallas_call`` using the
TPU's sequential-grid guarantee to carry scratch accumulators across stages:

  stage 0: global per-dim min/max of free capacity     (Eq. 3 prerequisites)
  stage 1: column sums of standardized capacity        (Eq. 4 denominator)
  stage 2: Σ p·ln p entropy partials                   (Eq. 5)
  stage 3: weights w_d (Eqs. 6-8) + scores HS/AHS      (Eqs. 9-11), written out

Grid = (4 stages, n_host_blocks); scratch persists across the entire grid, so
no HBM round-trips between stages beyond the single streaming of host data per
stage (4 × n × D × 4 B total traffic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_EPS = 1e-12
_BIG = 3.4e38
SUB = 8          # sublane padding for the D=4 resource dims
DEFAULT_BLOCK = 512


def _kernel(alpha_ref, free_ref, spot_ref, mask_ref, out_ref,
            lo_ref, hi_ref, col_ref, plp_ref, m_ref, *, batched=False):
    # batched variant: grid (B, 4, nblk) — same 4-stage pipeline per batch
    # element; scratch accumulators are re-initialized at (stage 0, block 0)
    # of every element thanks to the TPU's sequential-grid guarantee.
    sdim = 1 if batched else 0
    stage = pl.program_id(sdim)
    jblk = pl.program_id(sdim + 1)
    nblk = pl.num_programs(sdim + 1)

    free = free_ref[...]          # (SUB, BN) — rows 0..3 are resource dims
    spot = spot_ref[...]          # (SUB, BN)
    mask = mask_ref[...]          # (1, BN) float32 {0,1}
    maskb = mask > 0.5

    @pl.when(jnp.logical_and(stage == 0, jblk == 0))
    def _init():
        lo_ref[...] = jnp.full_like(lo_ref, _BIG)
        hi_ref[...] = jnp.full_like(hi_ref, -_BIG)
        col_ref[...] = jnp.zeros_like(col_ref)
        plp_ref[...] = jnp.zeros_like(plp_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    @pl.when(stage == 0)
    def _minmax():
        fmin = jnp.where(maskb, free, _BIG).min(axis=1, keepdims=True)
        fmax = jnp.where(maskb, free, -_BIG).max(axis=1, keepdims=True)
        lo_ref[...] = jnp.minimum(lo_ref[...], fmin)
        hi_ref[...] = jnp.maximum(hi_ref[...], fmax)
        m_ref[...] = m_ref[...] + jnp.sum(mask, axis=1, keepdims=True)

    def _standardize():
        lo = lo_ref[...]
        hi = hi_ref[...]
        span = hi - lo
        degen = span <= _EPS
        c = jnp.where(degen, 1.0, (free - lo) / jnp.where(degen, 1.0, span))
        return c * mask  # broadcast (1,BN) over sublanes

    @pl.when(stage == 1)
    def _colsum():
        c = _standardize()
        col_ref[...] = col_ref[...] + jnp.sum(c, axis=1, keepdims=True)

    def _proportions():
        c = _standardize()
        col = col_ref[...]
        m = m_ref[0, 0]
        p = jnp.where(col > _EPS, c / jnp.where(col > _EPS, col, 1.0),
                      mask / jnp.maximum(m, 1.0))
        return p * mask

    @pl.when(stage == 2)
    def _entropy():
        p = _proportions()
        plogp = jnp.where(p > _EPS, p * jnp.log(jnp.maximum(p, _EPS)), 0.0)
        plp_ref[...] = plp_ref[...] + jnp.sum(plogp, axis=1, keepdims=True)

    @pl.when(stage == 3)
    def _score():
        m = m_ref[0, 0]
        k = jnp.where(m > 1.0, 1.0 / jnp.log(jnp.maximum(m, 2.0)), 0.0)
        e = -k * plp_ref[...]                     # (SUB, 1)
        d_real = 4.0
        # only rows 0..3 are real dims; padded rows carry col==0 & plp==0 ->
        # e==0, g==1 — mask them out of the weight normalization.
        row = jax.lax.broadcasted_iota(jnp.float32, e.shape, 0)
        real = row < d_real
        g = jnp.where(real, 1.0 - e, 0.0)
        gsum = jnp.sum(g)
        w = jnp.where(gsum > _EPS, g / jnp.where(gsum > _EPS, gsum, 1.0),
                      jnp.where(real, 1.0 / d_real, 0.0))  # (SUB, 1)
        c = _standardize()
        hs = jnp.sum(c * w, axis=0, keepdims=True)          # (1, BN)
        sl = jnp.sum(spot * w, axis=0, keepdims=True)
        hs = hs * (1.0 + alpha_ref[0, 0] * sl)
        out_ref[...] = jnp.where(maskb, hs, -_BIG)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def hlem_score_pallas(free: jax.Array, mask: jax.Array, spot_frac: jax.Array,
                      alpha: jax.Array, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = False) -> jax.Array:
    """Drop-in replacement for ``repro.core.hlem.hlem_scores_jax``.

    free (n, D) float, mask (n,) bool, spot_frac (n, D), alpha scalar.
    Returns (n,) float32 scores with -3.4e38 at masked hosts.
    """
    n, d = free.shape
    assert d <= SUB, f"at most {SUB} resource dims supported, got {d}"
    n_pad = max(pl.cdiv(n, block), 1) * block

    def to_tiles(x):  # (n, D) -> (SUB, n_pad), host axis on lanes
        x = jnp.asarray(x, jnp.float32)
        x = jnp.pad(x, ((0, n_pad - n), (0, SUB - d)))
        return x.T

    free_t = to_tiles(free)
    spot_t = to_tiles(spot_frac)
    mask_t = jnp.pad(mask.astype(jnp.float32), (0, n_pad - n))[None, :]
    alpha_arr = jnp.full((1, 1), alpha, jnp.float32)

    nblk = n_pad // block
    out = pl.pallas_call(
        _kernel,
        grid=(4, nblk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (0, 0)),
            pl.BlockSpec((SUB, block), lambda s, j: (0, j)),
            pl.BlockSpec((SUB, block), lambda s, j: (0, j)),
            pl.BlockSpec((1, block), lambda s, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda s, j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n_pad), jnp.float32),
        scratch_shapes=[
            # lo, hi, col, plogp accumulators (SUB,1) + candidate count (1,1)
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alpha_arr, free_t, spot_t, mask_t)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def hlem_score_pallas_batch(
    free: jax.Array, masks: jax.Array, spot_frac: jax.Array,
    alphas: jax.Array, *, block: int = DEFAULT_BLOCK,
    interpret: bool = False,
) -> jax.Array:
    """Batched scoring: B VM candidate sets × n hosts in ONE ``pallas_call``.

    Drop-in accelerator path for ``repro.core.hlem.hlem_scores_batch_np``:
    free (n, D) shared host state, masks (B, n) bool per-VM feasibility,
    spot_frac (n, D), alphas (B,) per-VM adjustment.  Returns (B, n) float32
    scores with -3.4e38 at masked hosts.

    Grid = (B, 4 stages, n_host_blocks): the batch axis is the new leading
    grid dimension over the existing 4-stage reduction pipeline; host data is
    streamed once per (element, stage) while each element's masks/outputs tile
    its own row of the (B, n_pad) layout.
    """
    n, d = free.shape
    b = masks.shape[0]
    assert d <= SUB, f"at most {SUB} resource dims supported, got {d}"
    n_pad = max(pl.cdiv(n, block), 1) * block

    def to_tiles(x):  # (n, D) -> (SUB, n_pad), host axis on lanes
        x = jnp.asarray(x, jnp.float32)
        x = jnp.pad(x, ((0, n_pad - n), (0, SUB - d)))
        return x.T

    free_t = to_tiles(free)
    spot_t = to_tiles(spot_frac)
    masks_t = jnp.pad(masks.astype(jnp.float32), ((0, 0), (0, n_pad - n)))
    alphas_arr = jnp.asarray(alphas, jnp.float32).reshape(b, 1)

    nblk = n_pad // block
    out = pl.pallas_call(
        functools.partial(_kernel, batched=True),
        grid=(b, 4, nblk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda bb, s, j: (bb, 0)),
            pl.BlockSpec((SUB, block), lambda bb, s, j: (0, j)),
            pl.BlockSpec((SUB, block), lambda bb, s, j: (0, j)),
            pl.BlockSpec((1, block), lambda bb, s, j: (bb, j)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda bb, s, j: (bb, j)),
        out_shape=jax.ShapeDtypeStruct((b, n_pad), jnp.float32),
        scratch_shapes=[
            # lo, hi, col, plogp accumulators (SUB,1) + candidate count (1,1)
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((SUB, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(alphas_arr, free_t, spot_t, masks_t)
    return out[:, :n]
