"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-12


# ---------------------------------------------------------------------------
# hlem_score — paper Eqs. 3-11 (masked formulation, matches core.hlem)
# ---------------------------------------------------------------------------
def hlem_score_ref(free: jax.Array, mask: jax.Array, spot_frac: jax.Array,
                   alpha: jax.Array) -> jax.Array:
    """(n,D) free capacity + (n,) candidate mask -> (n,) scores (-big if masked).

    Mirrors repro.core.hlem.hlem_scores_jax (float32 math).
    """
    free = free.astype(jnp.float32)
    maskf = mask.astype(jnp.float32)[:, None]
    m = jnp.sum(maskf)
    big = jnp.float32(3.4e38)

    lo = jnp.min(jnp.where(mask[:, None], free, jnp.inf), axis=0)
    hi = jnp.max(jnp.where(mask[:, None], free, -jnp.inf), axis=0)
    span = hi - lo
    degen = span <= _EPS
    c_std = jnp.where(degen[None, :], 1.0,
                      (free - lo[None, :]) / jnp.where(degen, 1.0, span)[None, :])
    c_std = c_std * maskf

    col = jnp.sum(c_std, axis=0)
    p = jnp.where(col[None, :] > _EPS,
                  c_std / jnp.where(col > _EPS, col, 1.0)[None, :],
                  maskf / jnp.maximum(m, 1.0))
    p = p * maskf
    k = jnp.where(m > 1.0, 1.0 / jnp.log(jnp.maximum(m, 2.0)), 0.0)
    plogp = jnp.where(p > _EPS, p * jnp.log(jnp.maximum(p, _EPS)), 0.0)
    e = -k * jnp.sum(plogp, axis=0)
    g = 1.0 - e
    gsum = jnp.sum(g)
    d = free.shape[1]
    w = jnp.where(gsum > _EPS, g / jnp.where(gsum > _EPS, gsum, 1.0), 1.0 / d)

    hs = c_std @ w
    sl = spot_frac.astype(jnp.float32) @ w
    hs = hs * (1.0 + alpha * sl)
    return jnp.where(mask, hs, -big)


# ---------------------------------------------------------------------------
# flash_attention — causal multi-head attention oracle
# ---------------------------------------------------------------------------
def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True,
            window: int | None = None, scale: float | None = None) -> jax.Array:
    """q (B,H,Tq,dh), k/v (B,Hkv,Tk,dh) with GQA head-group broadcast.

    ``window``: optional sliding-window size (attend to the last W positions).
    Positions are aligned at the end: query i attends to keys j with
    j <= i + (Tk - Tq) (supports decode where Tq < Tk).
    """
    b, h, tq, dh = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = dh ** -0.5
    group = h // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    tk = k.shape[2]
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    qpos = jnp.arange(tq)[:, None] + (tk - tq)
    kpos = jnp.arange(tk)[None, :]
    ok = jnp.ones((tq, tk), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    logits = jnp.where(ok[None, None], logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)
    return out.astype(q.dtype)


def mha_chunked_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None,
                    chunk: int = 1024) -> jax.Array:
    """Flash-style online-softmax attention in pure jnp (lax.scan over kv
    chunks).  Numerically equals ``mha_ref`` but with O(Tq·chunk) live memory
    instead of O(Tq·Tk) — this is the model's default "xla" attention path
    (CPU-lowerable for the dry-run, memory-safe at 32k prefill).
    """
    b, h, tq, dh = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = dh ** -0.5
    group = h // hkv
    tk = k.shape[2]
    if tk <= chunk:
        return mha_ref(q, k, v, causal=causal, window=window, scale=scale)
    n_chunks = -(-tk // chunk)
    tk_pad = n_chunks * chunk
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tk_pad - tk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tk_pad - tk), (0, 0)))
    kp = kp.reshape(b, hkv, n_chunks, chunk, dh)
    vp = vp.reshape(b, hkv, n_chunks, chunk, dh)

    qf = q.astype(jnp.float32)
    qpos = jnp.arange(tq)[:, None] + (tk - tq)          # (tq, 1)

    def body(carry, inp):
        m_prev, l_prev, acc = carry
        kc, vc, ci = inp                                # (b,hkv,chunk,dh) x2
        kc = jnp.repeat(kc, group, axis=1).astype(jnp.float32)
        vc = jnp.repeat(vc, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kc) * scale
        kpos = ci * chunk + jnp.arange(chunk)[None, :]  # (1, chunk)
        ok = kpos < tk
        if causal:
            ok = ok & (kpos <= qpos)
        if window is not None:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.where(ok[None, None], jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isfinite(m_prev), jnp.exp(m_prev - m_safe), 0.0)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, tq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, tq), jnp.float32)
    a0 = jnp.zeros((b, h, tq, dh), jnp.float32)
    kcs = jnp.moveaxis(kp, 2, 0)
    vcs = jnp.moveaxis(vp, 2, 0)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (kcs, vcs, jnp.arange(n_chunks)))
    out = acc / jnp.where(l > 0, l, 1.0)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# ssm_scan — Mamba-1 selective scan oracle
# ---------------------------------------------------------------------------
def ssm_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, d: jax.Array,
                 h0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Selective state-space scan (Mamba-1 discretization).

    x  (B,T,Dm)   input sequence
    dt (B,T,Dm)   positive step sizes (already softplus'd)
    a  (Dm,N)     state matrix (negative real), log-space NOT applied here
    b  (B,T,N)    input projection
    c  (B,T,N)    output projection
    d  (Dm,)      skip connection
    h0 (B,Dm,N)   optional initial state

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * b_t * x_t   (ZOH-ish, as in mamba)
    y_t = (h_t @ c_t) + d * x_t
    Returns (y (B,T,Dm), h_T (B,Dm,N)).
    """
    bsz, t, dm = x.shape
    n = a.shape[1]
    if h0 is None:
        h0 = jnp.zeros((bsz, dm, n), dtype=jnp.float32)

    # decay (B,T,Dm,N) and drive terms
    da = jnp.exp(dt[..., None] * a[None, None])                   # (B,T,Dm,N)
    db = dt[..., None] * b[:, :, None, :] * x[..., None]          # (B,T,Dm,N)

    def step(h, inp):
        da_t, db_t, c_t = inp
        h = da_t * h + db_t
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    da_s = jnp.moveaxis(da, 1, 0)
    db_s = jnp.moveaxis(db, 1, 0)
    c_s = jnp.moveaxis(c, 1, 0).astype(jnp.float32)
    hT, ys = jax.lax.scan(step, h0.astype(jnp.float32),
                          (da_s.astype(jnp.float32), db_s.astype(jnp.float32), c_s))
    y = jnp.moveaxis(ys, 0, 1) + x * d[None, None, :]
    return y.astype(x.dtype), hT
