"""Blocked (flash) causal attention for TPU, with GQA and sliding windows.

MXU-oriented tiling: (block_q × head_dim) @ (head_dim × block_k) matmuls with
online softmax (running max / normalizer) carried in VMEM scratch across the
sequential kv-block grid axis.  Causal and sliding-window blocks that are
fully masked are skipped via ``pl.when`` (no MXU issue, no VMEM fill).

Grid = (batch*heads, n_q_blocks, n_kv_blocks), kv innermost (sequential on
TPU), so scratch (acc, m, l) lives across the kv sweep for one q block.

Query/key positions are aligned at sequence end (supports Tq < Tk decode
windows): query i attends keys j with  j <= i + (Tk - Tq)  and, with window W,
j > i + (Tk - Tq) - W.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
               *, scale: float, causal: bool, window: int | None,
               block_q: int, block_k: int, t_q: int, t_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    offs = t_k - t_q
    q_lo = qi * block_q

    def _needed() -> jax.Array:
        if not causal and window is None:
            return jnp.bool_(True)
        k_lo = kj * block_k
        need = jnp.bool_(True)
        if causal:  # any key in tile <= any query pos in tile (+offs)
            need = jnp.logical_and(need, k_lo <= q_lo + block_q - 1 + offs)
        if window is not None:
            need = jnp.logical_and(
                need, k_lo + block_k - 1 > q_lo + offs - window)
        return need

    @pl.when(_needed())
    def _compute():
        q = q_ref[0].astype(jnp.float32)                # (BQ, dh)
        k = k_ref[0].astype(jnp.float32)                # (BK, dh)
        v = v_ref[0].astype(jnp.float32)                # (BK, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + offs
        kpos = kj * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < t_k  # padding
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, _NEG)

        m_prev = m_ref[...]                              # (BQ, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(ok, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l > 0, l, 1.0)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,                    # (B, H, Tq, dh)
    k: jax.Array,                    # (B, Hkv, Tk, dh)
    v: jax.Array,                    # (B, Hkv, Tk, dh)
    *,
    causal: bool = True,
    window: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, h, t_q, dh = q.shape
    _, hkv, t_k, _ = k.shape
    assert h % hkv == 0, "GQA requires H % Hkv == 0"
    group = h // hkv
    scale = dh ** -0.5

    block_q = min(block_q, max(t_q, 1))
    block_k = min(block_k, max(t_k, 1))
    tq_pad = pl.cdiv(t_q, block_q) * block_q
    tk_pad = pl.cdiv(t_k, block_k) * block_k
    if tq_pad != t_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, tq_pad - t_q), (0, 0)))
    if tk_pad != t_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, tk_pad - t_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, tk_pad - t_k), (0, 0)))

    qr = q.reshape(b * h, tq_pad, dh)
    kr = k.reshape(b * hkv, tk_pad, dh)
    vr = v.reshape(b * hkv, tk_pad, dh)

    grid = (b * h, tq_pad // block_q, tk_pad // block_k)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, t_q=t_q, t_k=t_k)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda bh, qi, kj: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
            pl.BlockSpec((1, block_k, dh),
                         lambda bh, qi, kj, g=group: (bh // g, kj, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda bh, qi, kj: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, tq_pad, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, dh), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, tq_pad, dh)[:, :, :t_q, :]
