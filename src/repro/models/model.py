"""Model assembly: init / forward / prefill / decode for all four families.

Layer stacks run under ``jax.lax.scan`` over stacked per-layer parameters
(compact HLO, fast AOT compiles at 126 layers) with optional remat.  Decode
threads per-layer caches through the same scan.

Modality handling: ``text`` models embed integer tokens; ``vlm``/``audio``
backbones accept precomputed (B, S, d_model) embeddings from the (stubbed)
frontend, per the assignment spec.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ArchConfig
from .layers import (
    attention_decode,
    attention_fwd,
    hymba_decode,
    hymba_fwd,
    init_attention,
    init_hymba_mixer,
    init_mamba,
    init_mlp,
    init_moe,
    mamba_decode,
    mamba_fwd,
    mlp_fwd,
    moe_fwd,
    rmsnorm,
)
from .sharding import constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_layer(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    params: Params = {"ln1": jnp.ones((d,), jnp.float32)}
    specs: Params = {"ln1": ("embed",)}
    if cfg.family == "dense" or cfg.family == "moe":
        p, s = init_attention(cfg, ks[0])
        params["attn"], specs["attn"] = p, s
        params["ln2"] = jnp.ones((d,), jnp.float32)
        specs["ln2"] = ("embed",)
        if cfg.is_moe:
            p, s = init_moe(cfg, ks[1])
            params["moe"], specs["moe"] = p, s
        else:
            p, s = init_mlp(cfg, ks[1])
            params["mlp"], specs["mlp"] = p, s
    elif cfg.family == "ssm":
        p, s = init_mamba(cfg, ks[0])
        params["mamba"], specs["mamba"] = p, s
    elif cfg.family == "hybrid":
        p, s = init_hymba_mixer(cfg, ks[0])
        params["mixer"], specs["mixer"] = p, s
        params["ln2"] = jnp.ones((d,), jnp.float32)
        specs["ln2"] = ("embed",)
        p, s = init_mlp(cfg, ks[1])
        params["mlp"], specs["mlp"] = p, s
    else:
        raise ValueError(cfg.family)
    return params, specs


def init_params(cfg: ArchConfig, key) -> Params:
    kemb, khead, *kl = jax.random.split(key, 2 + cfg.n_layers)
    d, v = cfg.d_model, cfg.vocab
    dt = jnp.dtype(cfg.dtype)
    params: Params = {}
    if cfg.modality == "text":
        params["embed"] = (jax.random.normal(kemb, (v, d), jnp.float32)
                           ).astype(dt)
    params["final_ln"] = jnp.ones((d,), jnp.float32)
    params["lm_head"] = (d ** -0.5 * jax.random.normal(
        khead, (d, v), jnp.float32)).astype(dt)

    layers = [_init_layer(cfg, k)[0] for k in kl]
    if cfg.scan_layers:
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    else:
        params["layers"] = layers
    return params


def param_specs(cfg: ArchConfig) -> Params:
    """Logical-axis tree matching init_params structure (no materialization)."""
    specs: Params = {}
    if cfg.modality == "text":
        specs["embed"] = ("vocab", "fsdp")
    specs["final_ln"] = ("embed",)
    specs["lm_head"] = ("fsdp", "vocab")
    from .sharding import is_spec_leaf
    layer_specs = _init_layer_specs(cfg)
    if cfg.scan_layers:
        specs["layers"] = jax.tree.map(
            lambda names: (None,) + tuple(names), layer_specs,
            is_leaf=is_spec_leaf)
    else:
        specs["layers"] = [layer_specs] * cfg.n_layers
    return specs


def _init_layer_specs(cfg: ArchConfig):
    # Build the specs tree without touching RNG/materialization.
    d = cfg.d_model
    specs: Params = {"ln1": ("embed",)}
    if cfg.family in ("dense", "moe"):
        specs["attn"] = {
            "wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
            "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp")}
        specs["ln2"] = ("embed",)
        if cfg.is_moe:
            specs["moe"] = {
                "router": ("embed", "experts"),
                "w_gate": ("experts", "fsdp", "expert_ff"),
                "w_up": ("experts", "fsdp", "expert_ff"),
                "w_down": ("experts", "expert_ff", "fsdp")}
        else:
            specs["mlp"] = (
                {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
                 "w_down": ("ff", "fsdp")} if cfg.mlp == "gated_silu" else
                {"w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp")})
    elif cfg.family == "ssm":
        specs["mamba"] = _MAMBA_SPECS
    elif cfg.family == "hybrid":
        specs["mixer"] = {
            "attn": {"wq": ("fsdp", "heads"), "wk": ("fsdp", "kv_heads"),
                     "wv": ("fsdp", "kv_heads"), "wo": ("heads", "fsdp")},
            "mamba": _MAMBA_SPECS,
            "norm_a": ("embed",), "norm_s": ("embed",)}
        specs["ln2"] = ("embed",)
        specs["mlp"] = (
            {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
             "w_down": ("ff", "fsdp")} if cfg.mlp == "gated_silu" else
            {"w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp")})
    return specs


_MAMBA_SPECS = {
    "in_proj": ("fsdp", "ff"), "conv_w": ("ff", "conv"), "conv_b": ("ff",),
    "x_proj": ("ff", None), "dt_proj": (None, "ff"), "dt_bias": ("ff",),
    "a_log": ("ff", "state"), "d_skip": ("ff",), "out_proj": ("ff", "fsdp"),
}


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------
def _layer_fwd(cfg: ArchConfig, lp: Params, x: jax.Array, pos0: int,
               impl: str):
    """One transformer block. Returns (x, cache_contrib)."""
    h = rmsnorm(x, lp["ln1"])
    if cfg.family in ("dense", "moe"):
        ao, kv = attention_fwd(cfg, lp["attn"], h, pos0=pos0, impl=impl)
        x = x + ao
        h2 = rmsnorm(x, lp["ln2"])
        ff = moe_fwd(cfg, lp["moe"], h2) if cfg.is_moe else \
            mlp_fwd(cfg, lp["mlp"], h2)
        x = x + ff
        return x, (kv, None)
    if cfg.family == "ssm":
        mo, state = mamba_fwd(cfg, lp["mamba"], h, impl=impl)
        return x + mo, (None, state)
    if cfg.family == "hybrid":
        mo, kv, state = hymba_fwd(cfg, lp["mixer"], h, pos0=pos0, impl=impl)
        x = x + mo
        h2 = rmsnorm(x, lp["ln2"])
        x = x + mlp_fwd(cfg, lp["mlp"], h2)
        return x, (kv, state)
    raise ValueError(cfg.family)


def embed_tokens(cfg: ArchConfig, params: Params, tokens: jax.Array):
    if cfg.modality == "text":
        x = jnp.take(params["embed"], tokens, axis=0)
    else:
        x = tokens  # precomputed frontend embeddings (B, S, d)
    return constrain(x.astype(jnp.dtype(cfg.dtype)), "batch", "res_seq", "embed")


def forward(cfg: ArchConfig, params: Params, tokens: jax.Array,
            pos0: int = 0, impl: str = "xla", return_caches: bool = False):
    """tokens: int (B,S) for text, float (B,S,d) otherwise. -> logits (B,S,V).

    ``return_caches`` also returns per-layer (kv, ssm_state) stacks for
    prefill→decode handoff.
    """
    x = embed_tokens(cfg, params, tokens)

    def body(x, lp):
        x, cache = _layer_fwd(cfg, lp, x, pos0, impl)
        return x, (cache if return_caches else None)

    if cfg.remat:
        body = jax.checkpoint(body)

    if cfg.scan_layers:
        x, caches = jax.lax.scan(body, x, params["layers"])
    else:
        caches = []
        for lp in params["layers"]:
            x, c = body(x, lp)
            caches.append(c)

    x = rmsnorm(x, params["final_ln"])
    logits = constrain(x @ params["lm_head"], "batch", "seq", "vocab")
    if return_caches:
        return logits, caches
    return logits


# ---------------------------------------------------------------------------
# decode (serving): one new token against populated caches
# ---------------------------------------------------------------------------
class DecodeState(NamedTuple):
    """Per-layer caches stacked on a leading layer axis (scan-compatible)."""
    kv_k: Optional[jax.Array]       # (L, B, Hkv, T_cache, hd)
    kv_v: Optional[jax.Array]
    ssm_h: Optional[jax.Array]      # (L, B, d_inner, N)
    ssm_conv: Optional[jax.Array]   # (L, B, W-1, d_inner)
    pos: jax.Array                  # scalar int32 — next write position


def init_decode_state(cfg: ArchConfig, batch: int, cache_len: int,
                      dtype=None) -> DecodeState:
    dt = dtype or jnp.dtype(cfg.dtype)
    L = cfg.n_layers
    kv_k = kv_v = ssm_h = ssm_conv = None
    if cfg.has_attention:
        t = cache_len if cfg.sliding_window is None else min(
            cache_len, cfg.sliding_window)
        shape = (L, batch, cfg.n_kv_heads, t, cfg.hd)
        kv_k = jnp.zeros(shape, dt)
        kv_v = jnp.zeros(shape, dt)
    if cfg.has_ssm:
        ssm_h = jnp.zeros((L, batch, cfg.dinner, cfg.ssm_state), jnp.float32)
        ssm_conv = jnp.zeros((L, batch, cfg.conv_width - 1, cfg.dinner), dt)
    return DecodeState(kv_k, kv_v, ssm_h, ssm_conv,
                       jnp.zeros((), jnp.int32))


def decode_state_specs(cfg: ArchConfig):
    """Logical-axis tuples for DecodeState (for dry-run in_shardings)."""
    return DecodeState(
        kv_k=(None, "batch", "kv_heads", "kv_seq", None)
        if cfg.has_attention else None,
        kv_v=(None, "batch", "kv_heads", "kv_seq", None)
        if cfg.has_attention else None,
        ssm_h=(None, "batch", "ff", "state") if cfg.has_ssm else None,
        ssm_conv=(None, "batch", None, "ff") if cfg.has_ssm else None,
        pos=(),
    )


def decode_step(cfg: ArchConfig, params: Params, token: jax.Array,
                state: DecodeState) -> Tuple[jax.Array, DecodeState]:
    """token: int (B,1) text / float (B,1,d) otherwise.
    Returns (logits (B,1,V), new state)."""
    x = embed_tokens(cfg, params, token)
    pos = state.pos

    def body(x, per_layer):
        lp, kv_k, kv_v, ssm_h, ssm_conv = per_layer
        h = rmsnorm(x, lp["ln1"])
        if cfg.family in ("dense", "moe"):
            ao, (kv_k, kv_v) = attention_decode(
                cfg, lp["attn"], h, (kv_k, kv_v), pos)
            x = x + ao
            h2 = rmsnorm(x, lp["ln2"])
            ff = moe_fwd(cfg, lp["moe"], h2) if cfg.is_moe else \
                mlp_fwd(cfg, lp["mlp"], h2)
            x = x + ff
        elif cfg.family == "ssm":
            mo, (ssm_h, ssm_conv_t) = mamba_decode(
                cfg, lp["mamba"], h, (ssm_h, ssm_conv))
            ssm_conv = ssm_conv_t
            x = x + mo
        else:  # hybrid
            mo, (kv_k, kv_v), (ssm_h, ssm_conv) = hymba_decode(
                cfg, lp["mixer"], h, (kv_k, kv_v), (ssm_h, ssm_conv), pos)
            x = x + mo
            h2 = rmsnorm(x, lp["ln2"])
            x = x + mlp_fwd(cfg, lp["mlp"], h2)
        return x, (kv_k, kv_v, ssm_h, ssm_conv)

    L = cfg.n_layers
    dummy = jnp.zeros((L, 1))
    xs = (params["layers"],
          state.kv_k if state.kv_k is not None else dummy,
          state.kv_v if state.kv_v is not None else dummy,
          state.ssm_h if state.ssm_h is not None else dummy,
          state.ssm_conv if state.ssm_conv is not None else dummy)

    def scan_body(x, per_layer):
        lp, kk, vv, hh, cc = per_layer
        x, (kk2, vv2, hh2, cc2) = body(
            x, (lp,
                kk if state.kv_k is not None else None,
                vv if state.kv_v is not None else None,
                hh if state.ssm_h is not None else None,
                cc if state.ssm_conv is not None else None))
        return x, (kk2 if kk2 is not None else kk,
                   vv2 if vv2 is not None else vv,
                   hh2 if hh2 is not None else hh,
                   cc2 if cc2 is not None else cc)

    x, (kk, vv, hh, cc) = jax.lax.scan(scan_body, x, xs)
    x = rmsnorm(x, params["final_ln"])
    logits = constrain(x @ params["lm_head"], "batch", "seq", "vocab")
    new_state = DecodeState(
        kk if state.kv_k is not None else None,
        vv if state.kv_v is not None else None,
        hh if state.ssm_h is not None else None,
        cc if state.ssm_conv is not None else None,
        pos + 1)
    return logits, new_state
