"""Model building blocks: RMSNorm, RoPE, GQA attention (train + decode),
gated MLP, capacity-based MoE (gather/scatter dispatch), Mamba-1 block, and
the Hymba parallel attn+SSM block.

Every init_* returns (params, specs): ``specs`` is a matching pytree of
logical-axis tuples consumed by sharding.tree_shardings — this lets the
dry-run construct in_shardings without materializing any parameter.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ArchConfig
from .sharding import constrain

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def _init(key, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(
        key, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * w).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x (..., S, hd), positions (S,) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # (S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA) — training/prefill + single-token decode
# ---------------------------------------------------------------------------
def init_attention(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    d, hq, hkv = cfg.d_model, cfg.n_heads * cfg.hd, cfg.n_kv_heads * cfg.hd
    ks = jax.random.split(key, 4)
    dt = _dtype(cfg)
    s = d ** -0.5
    params = {
        "wq": _init(ks[0], (d, hq), s, dt),
        "wk": _init(ks[1], (d, hkv), s, dt),
        "wv": _init(ks[2], (d, hkv), s, dt),
        "wo": _init(ks[3], (hq, d), (hq) ** -0.5, dt),
    }
    specs = {
        "wq": ("fsdp", "heads"),
        "wk": ("fsdp", "kv_heads"),
        "wv": ("fsdp", "kv_heads"),
        "wo": ("heads", "fsdp"),
    }
    return params, specs


def attention_fwd(cfg: ArchConfig, p: Params, x: jax.Array, pos0: int = 0,
                  impl: str = "xla") -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention. Returns (out (B,S,d), (k, v) for caching)."""
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = constrain(x @ p["wq"], "batch", "seq", "heads")
    k = constrain(x @ p["wk"], "batch", "seq", "kv_heads")
    v = constrain(x @ p["wv"], "batch", "seq", "kv_heads")
    q = q.reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    positions = pos0 + jnp.arange(s)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if impl == "xla":
        from ..kernels.ref import mha_chunked_ref
        o = mha_chunked_ref(q, k, v, causal=True, window=cfg.sliding_window,
                            chunk=cfg.attention_chunk)
    else:
        o = ops.attention(q, k, v, causal=True, window=cfg.sliding_window,
                          impl=impl)
    o = constrain(o, "batch", "heads", "seq", "head_dim")
    o = o.transpose(0, 2, 1, 3).reshape(b, s, h * hd)
    out = constrain(o @ p["wo"], "batch", "res_seq", "embed")
    return out, (k, v)


def attention_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                     cache: Tuple[jax.Array, jax.Array], pos: jax.Array,
                     ) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """One-token decode. x (B,1,d); cache k/v (B,Hkv,T,hd); pos scalar —
    the index at which the new token is written.

    For sliding-window configs the cache is a ring buffer of length W; slot
    = pos % W and masking uses true positions reconstructed from the ring.
    """
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kc, vc = cache
    t_cache = kc.shape[2]
    ring = cfg.sliding_window is not None and t_cache == cfg.sliding_window

    q = (x @ p["wq"]).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(b, 1, hkv, hd).transpose(0, 2, 1, 3)
    posv = pos[None] if pos.ndim == 0 else pos
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)

    slot = jnp.where(ring, pos % t_cache, pos)
    kc = jax.lax.dynamic_update_slice(kc, k, (0, 0, slot, 0))
    vc = jax.lax.dynamic_update_slice(vc, v, (0, 0, slot, 0))
    kc = constrain(kc, "batch", "kv_heads", "kv_seq", None)
    vc = constrain(vc, "batch", "kv_heads", "kv_seq", None)

    # grouped-query attention WITHOUT materializing a head-replicated cache:
    # fold the query-head group G into the query tensor and einsum against
    # the (B, Hkv, T, hd) cache directly (logits accumulate in f32 on the
    # MXU via preferred_element_type; the cache stays bf16 in HBM).
    group = h // hkv
    qg = q.reshape(b, hkv, group, hd)                       # (B,Hkv,G,hd)
    s = jnp.einsum("bkgd,bktd->bkgt", qg, kc,
                   preferred_element_type=jnp.float32) * (hd ** -0.5)
    idx = jnp.arange(t_cache)
    if ring:
        # slot i holds position: pos - ((slot - i) mod W)
        kpos = pos - ((slot - idx) % t_cache)
    else:
        kpos = idx
    ok = (kpos <= pos) & (kpos >= 0)
    if cfg.sliding_window is not None:
        ok = ok & (kpos > pos - cfg.sliding_window)
    s = jnp.where(ok[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(vc.dtype)        # (B,Hkv,G,T)
    o = jnp.einsum("bkgt,bktd->bkgd", pr, vc,
                   preferred_element_type=jnp.float32)
    o = o.reshape(b, h, 1, hd).astype(x.dtype)
    o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * hd)
    return constrain(o @ p["wo"], "batch", "seq", "embed"), (kc, vc)


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------
def init_mlp(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    d, f = cfg.d_model, cfg.d_ff
    dt = _dtype(cfg)
    if cfg.mlp == "gated_silu":
        k1, k2, k3 = jax.random.split(key, 3)
        params = {
            "w_gate": _init(k1, (d, f), d ** -0.5, dt),
            "w_up": _init(k2, (d, f), d ** -0.5, dt),
            "w_down": _init(k3, (f, d), f ** -0.5, dt),
        }
        specs = {"w_gate": ("fsdp", "ff"), "w_up": ("fsdp", "ff"),
                 "w_down": ("ff", "fsdp")}
    else:  # gelu
        k1, k2 = jax.random.split(key, 2)
        params = {
            "w_in": _init(k1, (d, f), d ** -0.5, dt),
            "w_out": _init(k2, (f, d), f ** -0.5, dt),
        }
        specs = {"w_in": ("fsdp", "ff"), "w_out": ("ff", "fsdp")}
    return params, specs


def mlp_fwd(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp == "gated_silu":
        g = constrain(x @ p["w_gate"], "batch", "seq", "ff")
        u = constrain(x @ p["w_up"], "batch", "seq", "ff")
        return constrain((jax.nn.silu(g) * u) @ p["w_down"],
                         "batch", "res_seq", "embed")
    h = constrain(x @ p["w_in"], "batch", "seq", "ff")
    return constrain(jax.nn.gelu(h) @ p["w_out"], "batch", "res_seq", "embed")


# ---------------------------------------------------------------------------
# MoE — top-k routing with per-expert capacity, gather/scatter dispatch.
# ---------------------------------------------------------------------------
# No (tokens × experts × capacity) one-hot and no dispatch einsum: token slots
# are materialized with argsort-derived positions and moved with gather /
# scatter ops (O(1) FLOPs), so HLO compute stays ≈ the useful expert FFN
# FLOPs — this is what makes the 384-expert kimi-k2 config tractable.
def init_moe(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    dt = _dtype(cfg)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "router": _init(k1, (d, e), d ** -0.5, jnp.float32),
        "w_gate": _init(k2, (e, d, f), d ** -0.5, dt),
        "w_up": _init(k3, (e, d, f), d ** -0.5, dt),
        "w_down": _init(k4, (e, f, d), f ** -0.5, dt),
    }
    specs = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "fsdp", "expert_ff"),
        "w_up": ("experts", "fsdp", "expert_ff"),
        "w_down": ("experts", "expert_ff", "fsdp"),
    }
    return params, specs


def _moe_route(cfg: ArchConfig, gate: jax.Array, eidx: jax.Array, cap: int,
               g: int):
    """Per-group slot assignment (vmapped over groups; small int ops only).
    Returns (table (E,cap) slot->token id w/ sentinel g, gate_slot (E,cap))."""
    e, k = cfg.n_experts, cfg.top_k
    flat_e = eidx.reshape(-1)                                  # (g*K,)
    tok_id = jnp.repeat(jnp.arange(g), k)                      # (g*K,)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    order = jnp.argsort(flat_e, stable=True)                   # (g*K,)
    rank = jnp.zeros((g * k,), jnp.int32).at[order].set(
        jnp.arange(g * k, dtype=jnp.int32))
    pos = rank - starts[flat_e]                                # position in expert
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e * cap)        # overflow bucket
    table = jnp.full((e * cap + 1,), g, jnp.int32).at[slot].set(
        jnp.where(keep, tok_id, g))[: e * cap].reshape(e, cap)
    gate_slot = jnp.zeros((e * cap + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, gate.reshape(-1), 0.0))[: e * cap].reshape(e, cap)
    return table, gate_slot


def moe_fwd(cfg: ArchConfig, p: Params, x: jax.Array) -> jax.Array:
    """x (B, S, d) -> (B, S, d).

    Sharding-aware layout: every large tensor keeps an EXPLICIT group axis
    (sharded over "data") and expert axis (sharded over "model"), and all
    sharding constraints are applied to the STACKED tensors — constraining
    inside a vmapped function would leave the group axis unspecified and
    GSPMD then all-gathers the (G, E, cap, d) activations across the data
    axis in the backward pass (observed as a 2.7x collective-bound blowup on
    granite-moe; see EXPERIMENTS.md §Perf).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    g = min(cfg.moe_group_size, t)
    n_groups = -(-t // g)
    t_pad = n_groups * g
    xt = x.reshape(t, d)
    if t_pad != t:
        xt = jnp.pad(xt, ((0, t_pad - t), (0, 0)))
    xt = constrain(xt.reshape(n_groups, g, d), "groups", None, "embed")
    cap = max(1, int(g * cfg.top_k / cfg.n_experts * cfg.capacity_factor))

    # routing (f32) + per-group slot assignment
    logits = jnp.einsum("Ggd,de->Gge", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                       # (G,g,K)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
    table, gate_slot = jax.vmap(
        lambda gt, ei: _moe_route(cfg, gt, ei, cap, g))(gate, eidx)
    table = constrain(table, "groups", "experts", None)
    gate_slot = constrain(gate_slot, "groups", "experts", None)

    # dispatch: gather tokens into (G, E, cap, d); tokens are replicated
    # across "model", so each model shard gathers its own experts locally
    x_pad = jnp.concatenate(
        [xt, jnp.zeros((n_groups, 1, d), xt.dtype)], axis=1)   # (G,g+1,d)
    xe = jax.vmap(lambda xp, tb: jnp.take(xp, tb, axis=0))(x_pad, table)
    xe = constrain(xe, "groups", "experts", "capacity", "embed")

    # expert FFNs (E sharded over "model", G over "data")
    hg = jnp.einsum("Gecd,edf->Gecf", xe, p["w_gate"])
    hu = jnp.einsum("Gecd,edf->Gecf", xe, p["w_up"])
    ye = jnp.einsum("Gecf,efd->Gecd", jax.nn.silu(hg) * hu, p["w_down"])
    ye = constrain(ye, "groups", "experts", "capacity", "embed")

    # combine: scatter-add weighted expert outputs back to token space; each
    # model shard contributes its local experts and GSPMD reduces the (g, d)
    # partials (tokens x d traffic, not (E, cap, d) resharding)
    contrib = (ye * gate_slot[..., None].astype(ye.dtype)).reshape(
        n_groups, e * cap, d)
    flat_tb = table.reshape(n_groups, e * cap)

    def _scatter(tb, ct):
        return jnp.zeros((g + 1, d), ct.dtype).at[tb].add(ct)[:g]

    out = jax.vmap(_scatter)(flat_tb, contrib)
    out = constrain(out, "groups", None, "embed")
    out = out.reshape(t_pad, d)[:t].reshape(b, s, d)
    return constrain(out, "batch", "res_seq", "embed")


# ---------------------------------------------------------------------------
# Mamba-1 block
# ---------------------------------------------------------------------------
def init_mamba(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    d, di, ns, dr, w = (cfg.d_model, cfg.dinner, cfg.ssm_state, cfg.dtrank,
                        cfg.conv_width)
    dt = _dtype(cfg)
    ks = jax.random.split(key, 6)
    params = {
        "in_proj": _init(ks[0], (d, 2 * di), d ** -0.5, dt),
        "conv_w": _init(ks[1], (di, w), w ** -0.5, dt),
        "conv_b": jnp.zeros((di,), dt),
        "x_proj": _init(ks[2], (di, dr + 2 * ns), di ** -0.5, dt),
        "dt_proj": _init(ks[3], (dr, di), dr ** -0.5, dt),
        "dt_bias": jnp.full((di,), -4.0, jnp.float32),  # softplus ≈ 0.018
        "a_log": jnp.log(jnp.tile(
            jnp.arange(1, ns + 1, dtype=jnp.float32)[None, :], (di, 1))),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": _init(ks[5], (di, d), di ** -0.5, dt),
    }
    specs = {
        "in_proj": ("fsdp", "ff"),
        "conv_w": ("ff", "conv"),
        "conv_b": ("ff",),
        "x_proj": ("ff", None),
        "dt_proj": (None, "ff"),
        "dt_bias": ("ff",),
        "a_log": ("ff", "state"),
        "d_skip": ("ff",),
        "out_proj": ("ff", "fsdp"),
    }
    return params, specs


def _causal_conv(xz: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv along seq via shifted adds (width ≤ ~8).
    xz (B,S,di); w (di,W); state (B, W-1, di) prefix for chunked decode."""
    bsz, s, di = xz.shape
    width = w.shape[1]
    if state is None:
        state = jnp.zeros((bsz, width - 1, di), xz.dtype)
    ext = jnp.concatenate([state, xz], axis=1)  # (B, S+W-1, di)
    out = jnp.zeros_like(xz, dtype=jnp.float32)
    for i in range(width):
        out = out + ext[:, i:i + s, :].astype(jnp.float32) * w[:, i]
    return (out + b).astype(xz.dtype)


def mamba_fwd(cfg: ArchConfig, p: Params, x: jax.Array,
              state: Optional[Tuple[jax.Array, jax.Array]] = None,
              impl: str = "xla"):
    """x (B,S,d) -> (y (B,S,d), (ssm_state (B,di,N), conv_state (B,W-1,di)))."""
    b, s, d = x.shape
    di, ns = cfg.dinner, cfg.ssm_state
    h0, conv0 = state if state is not None else (None, None)

    xz = constrain(x @ p["in_proj"], "batch", "seq", "ff")
    xin, z = jnp.split(xz, 2, axis=-1)
    xc = _causal_conv(xin, p["conv_w"], p["conv_b"], conv0)
    xc = jax.nn.silu(xc)
    # roll the conv state forward: last (W-1) raw inputs
    prefix = conv0 if conv0 is not None else jnp.zeros(
        (b, cfg.conv_width - 1, di), x.dtype)
    new_conv = jax.lax.dynamic_slice_in_dim(
        jnp.concatenate([prefix, xin], axis=1), s, cfg.conv_width - 1, axis=1)

    proj = xc @ p["x_proj"]                                   # (B,S,dr+2N)
    dt_raw = proj[..., : cfg.dtrank]
    b_in = proj[..., cfg.dtrank: cfg.dtrank + ns]
    c_in = proj[..., cfg.dtrank + ns:]
    dt = jax.nn.softplus(dt_raw @ p["dt_proj"] + p["dt_bias"]).astype(xc.dtype)
    a = -jnp.exp(p["a_log"])                                   # (di, N)

    y, h_t = ops.selective_scan(xc, dt, a, b_in, c_in, p["d_skip"], h0,
                                impl=impl)
    y = y * jax.nn.silu(z)
    out = constrain(y @ p["out_proj"], "batch", "res_seq", "embed")
    return out, (h_t, new_conv)


def mamba_decode(cfg: ArchConfig, p: Params, x: jax.Array,
                 state: Tuple[jax.Array, jax.Array]):
    """Single-token decode: x (B,1,d); state (h (B,di,N), conv (B,W-1,di))."""
    return mamba_fwd(cfg, p, x, state)


# ---------------------------------------------------------------------------
# Hymba: parallel attention + SSM heads in one block
# ---------------------------------------------------------------------------
def init_hymba_mixer(cfg: ArchConfig, key) -> Tuple[Params, Params]:
    k1, k2 = jax.random.split(key)
    attn_p, attn_s = init_attention(cfg, k1)
    mamba_p, mamba_s = init_mamba(cfg, k2)
    d = cfg.d_model
    params = {"attn": attn_p, "mamba": mamba_p,
              "norm_a": jnp.ones((d,), jnp.float32),
              "norm_s": jnp.ones((d,), jnp.float32)}
    specs = {"attn": attn_s, "mamba": mamba_s,
             "norm_a": ("embed",), "norm_s": ("embed",)}
    return params, specs


def hymba_fwd(cfg: ArchConfig, p: Params, x: jax.Array,
              state=None, pos0: int = 0, impl: str = "xla"):
    ao, kv = attention_fwd(cfg, p["attn"], x, pos0=pos0, impl=impl)
    so, new_state = mamba_fwd(cfg, p["mamba"], x, state, impl=impl)
    out = 0.5 * (rmsnorm(ao, p["norm_a"]) + rmsnorm(so, p["norm_s"]))
    return out.astype(x.dtype), kv, new_state


def hymba_decode(cfg: ArchConfig, p: Params, x: jax.Array, kv_cache,
                 ssm_state, pos):
    ao, kv = attention_decode(cfg, p["attn"], x, kv_cache, pos)
    so, new_state = mamba_decode(cfg, p["mamba"], x, ssm_state)
    out = 0.5 * (rmsnorm(ao, p["norm_a"]) + rmsnorm(so, p["norm_s"]))
    return out.astype(x.dtype), kv, new_state
