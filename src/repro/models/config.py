"""Architecture configuration."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid
    modality: str = "text"         # text | vlm | audio
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4               # 0 for attention-free archs
    n_kv_heads: int = 4
    d_ff: int = 1024               # per-expert width for MoE
    vocab: int = 1024
    head_dim: int = 0              # default d_model // n_heads
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 2048     # tokens per dispatch group
    # SSM (mamba-1)
    ssm_state: int = 0
    d_inner: int = 0               # default 2 * d_model
    conv_width: int = 4
    dt_rank: int = 0               # default ceil(d_model / 16)
    # attention
    sliding_window: Optional[int] = None
    rope_theta: float = 10_000.0
    mlp: str = "gated_silu"        # | gelu
    # numerics / execution
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attention_chunk: int = 1024    # kv-chunk for the memory-safe xla attention
    # optimizer selection (framework-level, used by train/)
    optimizer: str = "adamw"       # | adafactor
    grad_accum: int = 1            # microbatch count for train_4k at prod scale
    moment_dtype: str = "float32"  # AdamW m/v dtype (bf16 for 100B+ models)
    accum_dtype: str = "float32"   # grad-accumulator dtype
    # distribution knobs (see DESIGN.md §5 and the per-arch memory napkin math
    # in EXPERIMENTS.md): ZeRO-3 across pods, sequence-parallel residual stream
    fsdp_over_pod: bool = False
    seq_parallel: bool = False

    # -- derived -------------------------------------------------------------
    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def dinner(self) -> int:
        return self.d_inner or 2 * self.d_model

    @property
    def dtrank(self) -> int:
        return self.dt_rank or max(1, -(-self.d_model // 16))

    @property
    def has_attention(self) -> bool:
        return self.n_heads > 0 and self.family in ("dense", "moe", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.family == "moe" and self.n_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k decode."""
        return self.family in ("ssm",) or (
            self.family == "hybrid" and self.sliding_window is not None)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def n_params(self) -> int:
        """Total parameter count (embedding + layers + head)."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        n = 0
        if self.modality == "text":
            n += V * d                       # token embedding
        n += d * V                           # lm head
        n += d                               # final norm
        per_layer = 0
        if self.has_attention:
            hq = self.n_heads * self.hd
            hkv = self.n_kv_heads * self.hd
            per_layer += d * hq + 2 * d * hkv + hq * d + d  # qkvo + ln
        if self.has_ssm:
            di, ns, dr = self.dinner, self.ssm_state, self.dtrank
            per_layer += d * 2 * di + di * self.conv_width + di
            per_layer += di * (dr + 2 * ns) + dr * di + di  # x_proj, dt_proj, bias
            per_layer += di * ns + di                       # A_log, D
            per_layer += di * d + d                         # out_proj + ln
        if self.is_moe:
            per_layer += d * self.n_experts                 # router
            per_layer += self.n_experts * 3 * d * self.d_ff  # expert FFNs
            per_layer += d                                  # ln
        elif self.family != "ssm":
            if self.mlp == "gated_silu":
                per_layer += 3 * d * self.d_ff + d
            else:
                per_layer += 2 * d * self.d_ff + d
        return n + L * per_layer

    def n_active_params(self) -> int:
        """Active parameters per token (MoE counts top_k experts only)."""
        if not self.is_moe:
            return self.n_params()
        d, L = self.d_model, self.n_layers
        inactive = L * (self.n_experts - self.top_k) * 3 * d * self.d_ff
        return self.n_params() - inactive
