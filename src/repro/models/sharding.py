"""Logical-axis sharding rules (GSPMD/pjit style).

We deliberately use jit + NamedSharding + with_sharding_constraint rather than
shard_map: GSPMD tolerates non-divisible dimension/axis pairs by padding,
which several assigned architectures require (granite's 24 heads and 49 155
vocab on a 16-way model axis, hymba's 25 heads).

Logical axes:
  batch    -> ("pod", "data")   activations' batch dim
  seq      -> None (or "model" under sequence-parallel contexts)
  embed    -> None              residual stream
  heads/kv_heads/ff/vocab/experts -> "model"   tensor parallel
  fsdp     -> "data"            ZeRO-3 parameter sharding dim
  groups   -> ("pod", "data")   MoE token groups
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...], None]

DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "groups": ("pod", "data"),
    "seq": None,
    "res_seq": None,            # residual-stream seq dim; "model" under seq-parallel
    "kv_seq": None,             # decode KV-cache seq dim; "model" for serve cells
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_ff": None,
    "capacity": None,
    "state": None,
    "conv": None,
    "layers": None,
    "fsdp": "data",
    "replicated": None,
}

_tls = threading.local()


def _state():
    if not hasattr(_tls, "mesh"):
        _tls.mesh = None
        _tls.rules = dict(DEFAULT_RULES)
    return _tls


def set_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    st = _state()
    st.mesh = mesh
    st.rules = dict(DEFAULT_RULES)
    if rules:
        st.rules.update(rules)


def current_mesh() -> Optional[Mesh]:
    return _state().mesh


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    st = _state()
    prev = (st.mesh, st.rules)
    set_mesh(mesh, rules)
    try:
        yield
    finally:
        st.mesh, st.rules = prev


def _resolve(name: Optional[str], mesh: Mesh) -> Axis:
    if name is None:
        return None
    st = _state()
    ax = st.rules.get(name, None)
    if ax is None:
        return None
    if isinstance(ax, str):
        return ax if ax in mesh.axis_names else None
    present = tuple(a for a in ax if a in mesh.axis_names)
    return present if present else None


def spec(*names: Optional[str]) -> P:
    """Logical axis names -> PartitionSpec under the current mesh/rules."""
    mesh = current_mesh()
    if mesh is None:
        return P()
    return P(*(_resolve(n, mesh) for n in names))


def named_sharding(*names: Optional[str]) -> Optional[NamedSharding]:
    mesh = current_mesh()
    if mesh is None:
        return None
    return NamedSharding(mesh, spec(*names))


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active mesh (no-op without one)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec(*names)))


def is_spec_leaf(t) -> bool:
    """Spec leaves are PLAIN tuples of logical names (or empty = replicated).
    NamedTuples (TrainState etc.) are containers, not leaves."""
    return type(t) is tuple and all(n is None or isinstance(n, str) for n in t)


def tree_shardings(spec_tree):
    """Map a pytree of logical-name tuples to NamedShardings."""
    mesh = current_mesh()
    if mesh is None:
        return None
    return jax.tree.map(
        lambda names: NamedSharding(mesh, spec(*names)),
        spec_tree, is_leaf=is_spec_leaf)


def _axis_size(mesh: Mesh, ax: Axis) -> int:
    if ax is None:
        return 1
    if isinstance(ax, str):
        return mesh.shape[ax]
    n = 1
    for a in ax:
        n *= mesh.shape[a]
    return n


def divisible_spec(shape, names, mesh: Mesh) -> P:
    """Resolve logical names to a PartitionSpec, replicating any dim whose
    size is not divisible by its mesh-axis product.

    jit *argument* shardings must tile arrays exactly (unlike internal
    with_sharding_constraint, where GSPMD pads) — granite's 49 155 vocab or
    24 heads on a 16-way model axis degrade to replication at the argument
    boundary while staying model-sharded inside the program.
    """
    base = spec(*names)
    fixed = []
    for i, ax in enumerate(base):
        if i >= len(shape):
            break
        fixed.append(ax if shape[i] % _axis_size(mesh, ax) == 0 else None)
    return P(*fixed)


def constrain_tree(tree, spec_tree):
    """with_sharding_constraint over a pytree with a logical-spec tree
    (e.g. pin a gradient accumulator to the parameter shardings so GSPMD
    reduce-scatters microbatch gradients instead of all-reducing them)."""
    mesh = current_mesh()
    if mesh is None:
        return tree
    flat, treedef = jax.tree.flatten(tree)
    flat_specs = jax.tree.flatten(spec_tree, is_leaf=is_spec_leaf)[0]
    assert len(flat) == len(flat_specs), (len(flat), len(flat_specs))
    out = [
        jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, spec(*names)))
        for x, names in zip(flat, flat_specs)
    ]
    return jax.tree.unflatten(treedef, out)


def attach(shape_tree, spec_tree):
    """Zip a ShapeDtypeStruct tree with a logical-spec tree -> structs with
    shardings attached (the dry-run's argument maker)."""
    mesh = current_mesh()
    flat_shapes, treedef = jax.tree.flatten(shape_tree)
    flat_specs = jax.tree.flatten(spec_tree, is_leaf=is_spec_leaf)[0]
    assert len(flat_shapes) == len(flat_specs), (
        f"shape/spec tree mismatch: {len(flat_shapes)} vs {len(flat_specs)}")
    out = [
        jax.ShapeDtypeStruct(
            s.shape, s.dtype,
            sharding=NamedSharding(
                mesh, divisible_spec(s.shape, names, mesh)) if mesh else None)
        for s, names in zip(flat_shapes, flat_specs)
    ]
    return jax.tree.unflatten(treedef, out)
