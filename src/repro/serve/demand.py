"""Deterministic request-rate curves for serving scenarios.

A demand curve is a plain callable ``rate(t) -> requests/second``.  Two
families back the serve workloads:

* :func:`make_diurnal` — a sinusoidal day/night cycle (the classic
  capacity-planning shape: base load plus a smooth daily swing);
* :func:`make_bursty` — base load plus pre-drawn spike episodes whose
  magnitudes follow a heavy-tailed Pareto draw (a cheap stand-in for
  self-similar traffic: a few spikes dominate the aggregate).

Both are *pure* after construction: the bursty curve draws its whole spike
schedule from a seeded generator up front, so evaluating ``rate(t)`` during
the run never touches an RNG — identical (spec, seed) pairs replay the same
demand bit for bit, which the chaos-determinism tests rely on.
"""
from __future__ import annotations

import math
from typing import Callable

import numpy as np

DemandCurve = Callable[[float], float]


def make_diurnal(base_rate: float = 0.2, amplitude: float = 0.15,
                 period: float = 86400.0, phase: float = 0.0) -> DemandCurve:
    """Sinusoidal diurnal demand: ``base + amplitude·sin(2π(t−phase)/period)``,
    clamped at zero.  ``amplitude > base_rate`` yields dead-of-night troughs
    where demand is exactly zero."""
    if base_rate < 0:
        raise ValueError(f"base_rate must be >= 0 (got {base_rate!r})")
    if amplitude < 0:
        raise ValueError(f"amplitude must be >= 0 (got {amplitude!r})")
    if not period > 0:
        raise ValueError(f"period must be > 0 (got {period!r})")
    two_pi = 2.0 * math.pi

    def rate(t: float) -> float:
        return max(0.0, base_rate
                   + amplitude * math.sin(two_pi * (t - phase) / period))

    return rate


def make_bursty(base_rate: float = 0.15, spike_every: float = 1800.0,
                spike_mag: float = 0.5, spike_alpha: float = 1.6,
                spike_duration: float = 300.0, horizon: float = 86400.0,
                seed: int = 0) -> DemandCurve:
    """Base load plus Pareto-magnitude spike episodes.

    ``horizon/spike_every`` spike starts are drawn uniformly over
    ``[0, horizon)``; each runs for an exponential duration (mean
    ``spike_duration``) and adds ``spike_mag·(1 + Pareto(spike_alpha))``
    requests/s while active.  ``spike_alpha`` near 1 gives rare giant
    spikes (heavier tail); larger values tame them.
    """
    if base_rate < 0:
        raise ValueError(f"base_rate must be >= 0 (got {base_rate!r})")
    if not spike_every > 0:
        raise ValueError(f"spike_every must be > 0 (got {spike_every!r})")
    if not spike_alpha > 0:
        raise ValueError(f"spike_alpha must be > 0 (got {spike_alpha!r})")
    if not spike_duration > 0:
        raise ValueError(
            f"spike_duration must be > 0 (got {spike_duration!r})")
    if not horizon > 0:
        raise ValueError(f"horizon must be > 0 (got {horizon!r})")
    rng = np.random.default_rng(seed)
    n = max(1, int(horizon / spike_every))
    starts = np.sort(rng.uniform(0.0, horizon, size=n))
    ends = starts + rng.exponential(spike_duration, size=n)
    mags = spike_mag * (1.0 + rng.pareto(spike_alpha, size=n))

    def rate(t: float) -> float:
        active = (starts <= t) & (t < ends)
        return base_rate + float(np.sum(mags[active]))

    return rate
