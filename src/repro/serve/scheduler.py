"""Interruption-aware request scheduling (ties serving to the spot market).

Pure-Python request lifecycle — kept jax-free so the market simulator's
serving loop (``repro.serve.service``) can import it without pulling the
model stack in ``repro.serve.engine``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List


@dataclass
class Request:
    id: int
    prompt_len: int
    target_tokens: int
    generated: float = 0
    state: str = "queued"     # queued | running | hibernated | done | dropped
    interruptions: int = 0


@dataclass
class SpotServingScheduler:
    """Schedules decode batches over capacity that can be reclaimed.

    When the market simulator interrupts the serving instance, in-flight
    requests are either *hibernated* (their decode state checkpointed and
    resumed later — like the paper's HIBERNATE behavior) or requeued from
    scratch (TERMINATE).  Mirrors the VM lifecycle at request granularity.
    """
    batch_size: int
    hibernate: bool = True
    queue: List[Request] = field(default_factory=list)
    running: List[Request] = field(default_factory=list)
    hibernated: List[Request] = field(default_factory=list)
    done: List[Request] = field(default_factory=list)

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def fill_batch(self) -> List[Request]:
        # resume hibernated requests first (paper's resubmission order)
        while self.hibernated and len(self.running) < self.batch_size:
            r = self.hibernated.pop(0)
            r.state = "running"
            self.running.append(r)
        while self.queue and len(self.running) < self.batch_size:
            r = self.queue.pop(0)
            r.state = "running"
            self.running.append(r)
        return self.running

    def step(self, n: float = 1) -> None:
        finished = []
        for r in self.running:
            r.generated += n
            if r.generated >= r.target_tokens:
                r.state = "done"
                finished.append(r)
        for r in finished:
            self.running.remove(r)
            self.done.append(r)

    def interrupt(self) -> None:
        """Capacity reclaimed: hibernate or requeue all running requests."""
        for r in self.running:
            r.interruptions += 1
            if self.hibernate:
                r.state = "hibernated"
                self.hibernated.append(r)
            else:
                r.state = "queued"
                r.generated = 0
                self.queue.append(r)
        self.running = []

    def stats(self) -> Dict[str, int]:
        return {
            "done": len(self.done),
            "queued": len(self.queue),
            "hibernated": len(self.hibernated),
            "running": len(self.running),
            "interruptions": sum(
                r.interruptions for r in
                self.done + self.queue + self.hibernated + self.running),
        }
