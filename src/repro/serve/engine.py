"""Serving engine: prefill → batched decode with KV/SSM caches, plus an
interruption-aware request scheduler (requests on spot capacity are requeued
or hibernated exactly like the paper's VMs).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import (
    DecodeState,
    decode_step,
    forward,
    init_decode_state,
)

Params = Any


def make_prefill_step(cfg: ArchConfig, cache_len: int, impl: str = "xla"):
    """Returns prefill(params, tokens) -> (last_logits (B,V), DecodeState).

    Builds caches sized ``cache_len`` with the prompt written at the front
    (or, for ring-buffer sliding-window caches, the last W positions).
    """

    def prefill(params, tokens):
        b = tokens.shape[0]
        s = tokens.shape[1]
        logits, caches = forward(cfg, params, tokens, impl=impl,
                                 return_caches=True)
        state = init_decode_state(cfg, b, cache_len)
        kv_k, kv_v, ssm_h, ssm_conv = (state.kv_k, state.kv_v,
                                       state.ssm_h, state.ssm_conv)
        kv, ssm = caches
        if cfg.has_attention:
            k_new, v_new = kv  # (L, B, Hkv, S, hd)
            t_cache = kv_k.shape[3]
            if t_cache >= s:
                kv_k = jax.lax.dynamic_update_slice(
                    kv_k, k_new.astype(kv_k.dtype), (0, 0, 0, 0, 0))
                kv_v = jax.lax.dynamic_update_slice(
                    kv_v, v_new.astype(kv_v.dtype), (0, 0, 0, 0, 0))
            else:  # ring buffer: keep the last t_cache positions
                kv_k = k_new[:, :, :, s - t_cache:, :].astype(kv_k.dtype)
                kv_v = v_new[:, :, :, s - t_cache:, :].astype(kv_v.dtype)
        if cfg.has_ssm:
            h_t, conv_t = ssm
            ssm_h = h_t.astype(ssm_h.dtype)
            ssm_conv = conv_t.astype(ssm_conv.dtype)
        st = DecodeState(kv_k, kv_v, ssm_h, ssm_conv,
                         jnp.asarray(s, jnp.int32))
        return logits[:, -1, :], st

    return prefill


def make_serve_step(cfg: ArchConfig):
    """One decode step: (params, token, state) -> (logits (B,1,V), state).

    This is the unit the multi-pod dry-run lowers for decode_* / long_* cells.
    """

    def serve_step(params, token, state: DecodeState):
        return decode_step(cfg, params, token, state)

    return serve_step


def greedy_generate(cfg: ArchConfig, params, prompt, n_tokens: int,
                    cache_len: Optional[int] = None, impl: str = "xla"):
    """Greedy decode helper for tests/examples (text modality)."""
    b, s = prompt.shape[0], prompt.shape[1]
    cache_len = cache_len or (s + n_tokens)
    prefill = make_prefill_step(cfg, cache_len, impl=impl)
    step = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, prompt)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    for _ in range(n_tokens - 1):
        lg, state = step(params, tok, state)
        tok = jnp.argmax(lg[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Interruption-aware request scheduling (ties serving to the spot market) —
# lives in the jax-free ``scheduler`` module; re-exported here for
# backward compatibility
# ---------------------------------------------------------------------------
from .scheduler import Request, SpotServingScheduler  # noqa: E402,F401
