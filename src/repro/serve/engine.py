"""Serving engine: prefill → batched decode with KV/SSM caches, plus an
interruption-aware request scheduler (requests on spot capacity are requeued
or hibernated exactly like the paper's VMs).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import (
    DecodeState,
    decode_step,
    forward,
    init_decode_state,
)

Params = Any


def make_prefill_step(cfg: ArchConfig, cache_len: int, impl: str = "xla"):
    """Returns prefill(params, tokens) -> (last_logits (B,V), DecodeState).

    Builds caches sized ``cache_len`` with the prompt written at the front
    (or, for ring-buffer sliding-window caches, the last W positions).
    """

    def prefill(params, tokens):
        b = tokens.shape[0]
        s = tokens.shape[1]
        logits, caches = forward(cfg, params, tokens, impl=impl,
                                 return_caches=True)
        state = init_decode_state(cfg, b, cache_len)
        kv_k, kv_v, ssm_h, ssm_conv = (state.kv_k, state.kv_v,
                                       state.ssm_h, state.ssm_conv)
        kv, ssm = caches
        if cfg.has_attention:
            k_new, v_new = kv  # (L, B, Hkv, S, hd)
            t_cache = kv_k.shape[3]
            if t_cache >= s:
                kv_k = jax.lax.dynamic_update_slice(
                    kv_k, k_new.astype(kv_k.dtype), (0, 0, 0, 0, 0))
                kv_v = jax.lax.dynamic_update_slice(
                    kv_v, v_new.astype(kv_v.dtype), (0, 0, 0, 0, 0))
            else:  # ring buffer: keep the last t_cache positions
                kv_k = k_new[:, :, :, s - t_cache:, :].astype(kv_k.dtype)
                kv_v = v_new[:, :, :, s - t_cache:, :].astype(kv_v.dtype)
        if cfg.has_ssm:
            h_t, conv_t = ssm
            ssm_h = h_t.astype(ssm_h.dtype)
            ssm_conv = conv_t.astype(ssm_conv.dtype)
        st = DecodeState(kv_k, kv_v, ssm_h, ssm_conv,
                         jnp.asarray(s, jnp.int32))
        return logits[:, -1, :], st

    return prefill


def make_serve_step(cfg: ArchConfig):
    """One decode step: (params, token, state) -> (logits (B,1,V), state).

    This is the unit the multi-pod dry-run lowers for decode_* / long_* cells.
    """

    def serve_step(params, token, state: DecodeState):
        return decode_step(cfg, params, token, state)

    return serve_step


def greedy_generate(cfg: ArchConfig, params, prompt, n_tokens: int,
                    cache_len: Optional[int] = None, impl: str = "xla"):
    """Greedy decode helper for tests/examples (text modality)."""
    b, s = prompt.shape[0], prompt.shape[1]
    cache_len = cache_len or (s + n_tokens)
    prefill = make_prefill_step(cfg, cache_len, impl=impl)
    step = jax.jit(make_serve_step(cfg))
    logits, state = prefill(params, prompt)
    tok = jnp.argmax(logits, axis=-1)[:, None]
    out = [tok]
    for _ in range(n_tokens - 1):
        lg, state = step(params, tok, state)
        tok = jnp.argmax(lg[:, -1, :], axis=-1)[:, None]
        out.append(tok)
    return jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Interruption-aware request scheduling (ties serving to the spot market)
# ---------------------------------------------------------------------------
@dataclass
class Request:
    id: int
    prompt_len: int
    target_tokens: int
    generated: int = 0
    state: str = "queued"     # queued | running | hibernated | done | dropped
    interruptions: int = 0


@dataclass
class SpotServingScheduler:
    """Schedules decode batches over capacity that can be reclaimed.

    When the market simulator interrupts the serving instance, in-flight
    requests are either *hibernated* (their decode state checkpointed and
    resumed later — like the paper's HIBERNATE behavior) or requeued from
    scratch (TERMINATE).  Mirrors the VM lifecycle at request granularity.
    """
    batch_size: int
    hibernate: bool = True
    queue: List[Request] = field(default_factory=list)
    running: List[Request] = field(default_factory=list)
    hibernated: List[Request] = field(default_factory=list)
    done: List[Request] = field(default_factory=list)

    def add(self, req: Request) -> None:
        self.queue.append(req)

    def fill_batch(self) -> List[Request]:
        # resume hibernated requests first (paper's resubmission order)
        while self.hibernated and len(self.running) < self.batch_size:
            r = self.hibernated.pop(0)
            r.state = "running"
            self.running.append(r)
        while self.queue and len(self.running) < self.batch_size:
            r = self.queue.pop(0)
            r.state = "running"
            self.running.append(r)
        return self.running

    def step(self, n: int = 1) -> None:
        finished = []
        for r in self.running:
            r.generated += n
            if r.generated >= r.target_tokens:
                r.state = "done"
                finished.append(r)
        for r in finished:
            self.running.remove(r)
            self.done.append(r)

    def interrupt(self) -> None:
        """Capacity reclaimed: hibernate or requeue all running requests."""
        for r in self.running:
            r.interruptions += 1
            if self.hibernate:
                r.state = "hibernated"
                self.hibernated.append(r)
            else:
                r.state = "queued"
                r.generated = 0
                self.queue.append(r)
        self.running = []

    def stats(self) -> Dict[str, int]:
        return {
            "done": len(self.done),
            "queued": len(self.queue),
            "hibernated": len(self.hibernated),
            "running": len(self.running),
            "interruptions": sum(
                r.interruptions for r in
                self.done + self.queue + self.hibernated + self.running),
        }
