from .engine import (
    Request,
    SpotServingScheduler,
    greedy_generate,
    make_prefill_step,
    make_serve_step,
)

__all__ = [k for k in dir() if not k.startswith("_")]
