"""Serving layer: request scheduling on spot capacity.

The market-simulation side (``scheduler``, ``demand``, ``autoscale``,
``slo``, ``service``) is pure Python + numpy and imports eagerly; the
model-serving side (``engine``: prefill/decode over the jax model stack)
loads lazily on first attribute access, so building a serve scenario
never pays the jax import.
"""
from .autoscale import (
    AUTOSCALE_REGISTRY,
    Autoscaler,
    AutoscaleConfig,
    DemandSignals,
    make_autoscaler,
    register_autoscale_policy,
    validate_autoscale_config,
)
from .demand import make_bursty, make_diurnal
from .scheduler import Request, SpotServingScheduler
from .service import (
    ServeConfig,
    ServeManager,
    make_serve_manager,
    validate_serve_config,
)
from .slo import (
    cost_forecast,
    cost_per_request,
    error_budget_burn,
    latency_percentiles,
    serve_stats,
    slo_attainment,
)

#: jax-backed exports, resolved on demand (PEP 562)
_ENGINE_EXPORTS = ("greedy_generate", "make_prefill_step", "make_serve_step")

__all__ = [k for k in dir() if not k.startswith("_")] + list(_ENGINE_EXPORTS)


def __getattr__(name):
    if name in _ENGINE_EXPORTS:
        from . import engine
        return getattr(engine, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
