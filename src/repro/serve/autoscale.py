"""Autoscaler: demand signals → fleet target-capacity decisions.

Closes the serving loop around the PR 6 fleet manager: on a control
cadence the :class:`Autoscaler` reads :class:`DemandSignals` (arrival-rate
EWMA, queue depth, windowed latency percentile) assembled by the serve
manager, asks a registered policy for a desired unit count, and — after
hysteresis/cooldown damping — retargets the fleet through
``FleetManager.set_target_units``.  The damping is what lets the
autoscaler *compose* with the fleet's fallback ladder instead of fighting
it: the ladder replaces individual dead slots on backoff timescales, the
autoscaler moves the whole target on slower, rate-limited timescales.

Policies register by name in :data:`AUTOSCALE_REGISTRY`
(``@register_autoscale_policy("name")``), so ``AutoscaleSpec`` can sweep
policies PR 4 registry style.  A policy is a pure function
``(signals, cfg) -> desired_units`` — all pacing state (cooldown stamps)
lives in the Autoscaler, so policies stay trivially testable.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from ..core.registry import Registry

#: string-keyed registry of autoscale policies — pure functions
#: ``(signals: DemandSignals, cfg: AutoscaleConfig) -> int`` desired units
AUTOSCALE_REGISTRY = Registry("autoscale policy")
register_autoscale_policy = AUTOSCALE_REGISTRY.register


@dataclass(frozen=True)
class AutoscaleConfig:
    """Configuration of one autoscaler (the ``AutoscaleSpec`` payload).

    ``cadence`` paces decisions; ``hysteresis`` (minimum fractional change)
    and ``cooldown`` (minimum seconds between applied changes) damp them.
    ``headroom`` is the capacity safety factor over measured demand,
    ``queue_drain`` the target horizon (seconds) for working off queued
    backlog, ``lead`` the look-ahead of the predictive policy, and
    ``step_units``/``queue_hi``/``queue_lo`` parameterize the step policy
    (thresholds are queued requests *per live unit*)."""
    cadence: float = 300.0
    min_units: int = 1
    max_units: int = 512
    hysteresis: float = 0.1
    cooldown: float = 600.0
    headroom: float = 1.2
    ewma_alpha: float = 0.3
    latency_window: float = 1800.0
    queue_drain: float = 600.0
    lead: float = 900.0
    step_units: int = 2
    queue_hi: float = 4.0
    queue_lo: float = 0.5


def validate_autoscale_config(cfg: AutoscaleConfig) -> None:
    """Fail-fast validation (construction-time, PR 4 error style)."""
    if not cfg.cadence > 0:
        raise ValueError(
            f"autoscale cadence must be > 0 (got {cfg.cadence!r})")
    if int(cfg.min_units) < 0:
        raise ValueError(
            f"autoscale min_units must be >= 0 (got {cfg.min_units!r})")
    if int(cfg.max_units) < int(cfg.min_units):
        raise ValueError(
            f"autoscale max_units must be >= min_units "
            f"(got {cfg.max_units!r} < {cfg.min_units!r})")
    if not 0.0 <= cfg.hysteresis < 1.0:
        raise ValueError(
            f"autoscale hysteresis must be in [0, 1) (got {cfg.hysteresis!r})")
    if cfg.cooldown < 0:
        raise ValueError(
            f"autoscale cooldown must be >= 0 (got {cfg.cooldown!r})")
    if not cfg.headroom > 0:
        raise ValueError(
            f"autoscale headroom must be > 0 (got {cfg.headroom!r})")
    if not 0.0 < cfg.ewma_alpha <= 1.0:
        raise ValueError(
            f"autoscale ewma_alpha must be in (0, 1] (got {cfg.ewma_alpha!r})")
    if not cfg.latency_window > 0:
        raise ValueError(
            f"autoscale latency_window must be > 0 "
            f"(got {cfg.latency_window!r})")
    if not cfg.queue_drain > 0:
        raise ValueError(
            f"autoscale queue_drain must be > 0 (got {cfg.queue_drain!r})")
    if cfg.lead < 0:
        raise ValueError(f"autoscale lead must be >= 0 (got {cfg.lead!r})")
    if int(cfg.step_units) < 1:
        raise ValueError(
            f"autoscale step_units must be >= 1 (got {cfg.step_units!r})")
    if not cfg.queue_hi > cfg.queue_lo >= 0:
        raise ValueError(
            f"autoscale thresholds need queue_hi > queue_lo >= 0 "
            f"(got hi={cfg.queue_hi!r}, lo={cfg.queue_lo!r})")


@dataclass(frozen=True)
class DemandSignals:
    """One decision's input snapshot, assembled by the serve manager.

    ``unit_throughput`` is the requests/s one live unit sustains at the
    configured decode speed and batch width; ``rate_ahead`` is the demand
    curve evaluated ``lead`` seconds ahead (the predictive policy's input —
    the curve is *known* to the operator who deployed the workload)."""
    t: float
    rate_ewma: float          # smoothed observed arrivals (requests/s)
    queue_depth: int          # requests waiting (queued + hibernated)
    p95_latency: float        # windowed p95 latency (s); nan if no samples
    live_units: int           # serving-capable fleet VMs right now
    target_units: int         # the fleet's current unit target
    unit_throughput: float    # requests/s per unit
    rate_ahead: float         # curve(t + lead), requests/s


def _units_for_rate(rate: float, signals: DemandSignals,
                    cfg: AutoscaleConfig) -> int:
    """Units needed to sustain ``rate`` with headroom, plus enough surplus
    to drain the current backlog within ``queue_drain`` seconds."""
    per_unit = max(signals.unit_throughput, 1e-12)
    steady = (rate * cfg.headroom) / per_unit
    drain = signals.queue_depth / (per_unit * cfg.queue_drain)
    return int(math.ceil(steady + drain))


@register_autoscale_policy("static")
def _static(signals: DemandSignals, cfg: AutoscaleConfig) -> int:
    """Hold whatever the fleet was provisioned with — the fixed-capacity
    baseline the sweep compares against."""
    return signals.target_units


@register_autoscale_policy("target-tracking")
def _target_tracking(signals: DemandSignals, cfg: AutoscaleConfig) -> int:
    """Track measured demand: capacity for the arrival-rate EWMA with
    headroom, plus backlog-drain surplus."""
    return _units_for_rate(signals.rate_ewma, signals, cfg)


@register_autoscale_policy("step")
def _step(signals: DemandSignals, cfg: AutoscaleConfig) -> int:
    """Threshold stepping: queue pressure above ``queue_hi`` per unit adds
    ``step_units``; a drained queue (below ``queue_lo`` per unit) removes
    them.  No demand model — the classic ops-alarm autoscaler."""
    units = max(signals.live_units, 1)
    per_unit = signals.queue_depth / units
    if per_unit > cfg.queue_hi:
        return signals.target_units + int(cfg.step_units)
    if per_unit < cfg.queue_lo:
        return signals.target_units - int(cfg.step_units)
    return signals.target_units


@register_autoscale_policy("predictive-from-curve")
def _predictive(signals: DemandSignals, cfg: AutoscaleConfig) -> int:
    """Provision for the *known* demand curve ``lead`` seconds ahead (plus
    backlog drain) — capacity is in place before the ramp arrives, at the
    price of trusting the forecast."""
    rate = max(signals.rate_ahead, signals.rate_ewma)
    return _units_for_rate(rate, signals, cfg)


class Autoscaler:
    """Policy + damping state.  :meth:`decide` returns the new unit target
    when a change should be applied, else ``None``."""

    def __init__(self, policy: str, config: Optional[AutoscaleConfig] = None):
        self.policy_name = str(policy)
        self.policy = AUTOSCALE_REGISTRY.get(self.policy_name)  # fail fast
        self.config = config if config is not None else AutoscaleConfig()
        validate_autoscale_config(self.config)
        self._last_change = -float("inf")

    def decide(self, signals: DemandSignals) -> Optional[int]:
        cfg = self.config
        desired = int(self.policy(signals, cfg))
        desired = min(max(desired, int(cfg.min_units)), int(cfg.max_units))
        cur = int(signals.target_units)
        if desired == cur:
            return None
        if abs(desired - cur) / max(cur, 1) < cfg.hysteresis:
            return None
        if signals.t - self._last_change < cfg.cooldown:
            return None
        self._last_change = signals.t
        return desired


def make_autoscaler(policy: str,
                    config: Optional[AutoscaleConfig] = None,
                    **kwargs) -> Autoscaler:
    """Build an autoscaler from a config (or config kwargs); unknown policy
    names fail fast with the known list, PR 4 registry style."""
    cfg = config if config is not None else AutoscaleConfig(**kwargs)
    return Autoscaler(policy, cfg)
