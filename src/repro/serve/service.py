"""ServeManager: the demand → queue → spot-capacity closed loop.

Driven by two self-scheduling simulator events:

* ``SERVE_TICK`` (cadence ``ServeConfig.tick``): integrate the demand
  curve into whole request arrivals (fractional-accumulator, no RNG in
  the hot path), map serving capacity onto the live fleet VMs — one
  :class:`~repro.serve.scheduler.SpotServingScheduler` per VM, sized
  ``slots_per_vm`` — dispatch queued requests, advance every batch by
  ``tokens_per_s · dt`` decode tokens, and record per-request latencies.
* ``AUTOSCALE`` (cadence ``AutoscaleConfig.cadence``): assemble
  :class:`~repro.serve.autoscale.DemandSignals` and apply the policy's
  damped decision through ``FleetManager.set_target_units``.

Interrupted (or finished / decommissioned) serving VMs requeue their
in-flight requests through the simulator's ordinary lifecycle listeners:
the per-VM scheduler's ``interrupt()`` applies the configured
hibernate-vs-requeue behavior, then everything it still holds drains back
into the global queue to be re-dispatched onto surviving capacity.

Determinism: request ids, arrival counts and token-length draws depend
only on (config, seed, event order); VM iteration is in sorted-id order;
the token-length generator is seeded per run.  Identical specs replay
bit for bit, serve-absent runs are untouched (the manager only exists
when ``ServeSpec`` is present).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Deque, Dict, List, Optional, Tuple

import collections

import numpy as np

from ..core.types import VmState
from ..obs.eventlog import NULL_RECORDER
from ..obs.tracer import NULL_TRACER
from .autoscale import Autoscaler, DemandSignals
from .demand import DemandCurve
from .scheduler import Request, SpotServingScheduler

#: VM states that hold serving capacity (MIGRATING VMs are in flight and
#: decode nothing — their requests wait out the stop-and-copy window)
_SERVING_STATES = (VmState.RUNNING, VmState.INTERRUPTING)


@dataclass(frozen=True)
class ServeConfig:
    """Configuration of one serving scenario (the ``ServeSpec`` payload).

    ``tick`` paces the serving loop; each live fleet VM contributes
    ``slots_per_vm`` concurrent decode slots at ``tokens_per_s`` tokens/s
    each.  Request token lengths draw from an exponential with mean
    ``mean_tokens`` (seeded per run).  ``slo_latency_s`` / ``slo_objective``
    / ``window_s`` define the SLO: attainment is the fraction of requests
    served within the latency bound, the error budget ``1 − objective``
    burns per ``window_s`` window.  ``hibernate_requests`` selects the
    paper's HIBERNATE analogue at request granularity (keep decode
    progress across a VM loss) vs TERMINATE (restart from scratch)."""
    tick: float = 60.0
    slots_per_vm: int = 4
    tokens_per_s: float = 2.0
    prompt_len: int = 128
    mean_tokens: float = 240.0
    slo_latency_s: float = 300.0
    slo_objective: float = 0.95
    window_s: float = 1800.0
    hibernate_requests: bool = True

    @property
    def unit_throughput(self) -> float:
        """Requests/s one live VM sustains at steady state."""
        return self.slots_per_vm * self.tokens_per_s / self.mean_tokens


def validate_serve_config(cfg: ServeConfig) -> None:
    """Fail-fast validation (construction-time, PR 4 error style)."""
    if not cfg.tick > 0:
        raise ValueError(f"serve tick must be > 0 (got {cfg.tick!r})")
    if int(cfg.slots_per_vm) < 1:
        raise ValueError(
            f"serve slots_per_vm must be >= 1 (got {cfg.slots_per_vm!r})")
    if not cfg.tokens_per_s > 0:
        raise ValueError(
            f"serve tokens_per_s must be > 0 (got {cfg.tokens_per_s!r})")
    if int(cfg.prompt_len) < 0:
        raise ValueError(
            f"serve prompt_len must be >= 0 (got {cfg.prompt_len!r})")
    if not cfg.mean_tokens > 0:
        raise ValueError(
            f"serve mean_tokens must be > 0 (got {cfg.mean_tokens!r})")
    if not cfg.slo_latency_s > 0:
        raise ValueError(
            f"serve slo_latency_s must be > 0 (got {cfg.slo_latency_s!r})")
    if not 0.0 < cfg.slo_objective < 1.0:
        raise ValueError(
            f"serve slo_objective must be in (0, 1) "
            f"(got {cfg.slo_objective!r})")
    if not cfg.window_s > 0:
        raise ValueError(
            f"serve window_s must be > 0 (got {cfg.window_s!r})")


class ServeManager:
    """Holds the global request queue and the per-VM scheduler map.

    Stateful across one run; use a fresh manager per simulation, like the
    engine and the fleet manager."""

    #: telemetry hook (``repro.obs``); the build layer swaps in the live
    #: tracer — arrival/served/requeue counters feed the counter registry
    tracer = NULL_TRACER
    #: event recorder — request/serve/autoscale records for the flight log
    events = NULL_RECORDER

    def __init__(self, config: ServeConfig,
                 autoscaler: Optional[Autoscaler] = None, seed: int = 0):
        validate_serve_config(config)
        self.config = config
        self.autoscaler = autoscaler
        self.curve: Optional[DemandCurve] = None
        self.seed = int(seed)
        # token-length draws only — arrivals come from the deterministic
        # fractional accumulator, so the sequence of generator calls is a
        # pure function of (config, seed, event order)
        self._rng = np.random.default_rng(0x5E12 + 7919 * self.seed)
        self._queue: Deque[Request] = collections.deque()
        self._scheds: Dict[int, SpotServingScheduler] = {}
        self._arrive_t: Dict[int, float] = {}
        self._next_id = 0
        self._accum = 0.0
        self._last_t = 0.0
        self._ewma: Optional[float] = None
        self._lat_window: Deque[Tuple[float, float]] = collections.deque()
        if autoscaler is not None:
            self._alpha = autoscaler.config.ewma_alpha
            self._window = autoscaler.config.latency_window
        else:
            self._alpha = 0.3
            self._window = 1800.0

    # ------------------------------------------------------------- queries
    def set_demand(self, curve: DemandCurve) -> None:
        """Attach the demand curve (called by the serve workload's
        ``populate`` — the curve's seed/horizon live in workload params)."""
        self.curve = curve

    def queue_depth(self) -> int:
        """Requests waiting anywhere: the global queue plus every per-VM
        scheduler's local queued + hibernated backlog."""
        depth = len(self._queue)
        for sched in self._scheds.values():
            depth += len(sched.queue) + len(sched.hibernated)
        return depth

    def pending(self) -> bool:
        """Outstanding requests (keeps an unbounded run's event chains
        alive until the backlog drains).  ``_arrive_t`` holds exactly the
        arrived-but-not-served ids — entries pop when the request is
        served."""
        return bool(self._arrive_t)

    def target_units(self, sim) -> int:
        if sim.fleet is not None:
            return int(sim.fleet.target_units)
        return len(self._scheds)

    # ---------------------------------------------------------------- tick
    def on_tick(self, sim, now: float) -> None:
        cfg = self.config
        m = sim.metrics
        dt = now - self._last_t
        self._last_t = now
        # -- arrivals: integrate the demand curve ---------------------------
        rate = float(self.curve(now)) if self.curve is not None else 0.0
        self._accum += rate * dt
        n_new = int(self._accum)
        self._accum -= n_new
        for _ in range(n_new):
            tokens = max(1, int(round(
                float(self._rng.exponential(cfg.mean_tokens)))))
            req = Request(id=self._next_id, prompt_len=int(cfg.prompt_len),
                          target_tokens=tokens)
            self._next_id += 1
            self._queue.append(req)
            self._arrive_t[req.id] = now
        m.requests_arrived += n_new
        obs_rate = n_new / dt if dt > 0 else 0.0
        self._ewma = (obs_rate if self._ewma is None
                      else self._alpha * obs_rate
                      + (1.0 - self._alpha) * self._ewma)
        if self.tracer.enabled and n_new:
            self.tracer.counters.inc("serve/arrivals", n_new)
        if self.events.enabled:
            self.events.emit(now, "request-arrive", a=float(n_new),
                             b=float(rate))
        # -- capacity sync: one scheduler per live serving VM ---------------
        live = self._live_vids(sim)
        live_set = set(live)
        for vid in sorted(self._scheds):
            if vid not in live_set:
                # left the serving set without an interrupt/finish event
                # (e.g. departed into a migration flight): requeue
                self._requeue_vm(sim, now, vid)
        for vid in live:
            if vid not in self._scheds:
                self._scheds[vid] = SpotServingScheduler(
                    batch_size=int(cfg.slots_per_vm),
                    hibernate=cfg.hibernate_requests)
        # -- dispatch + decode ----------------------------------------------
        tokens_dt = cfg.tokens_per_s * dt
        n_done = 0
        for vid in sorted(self._scheds):
            sched = self._scheds[vid]
            free = (cfg.slots_per_vm - len(sched.running)
                    - len(sched.hibernated) - len(sched.queue))
            while free > 0 and self._queue:
                sched.add(self._queue.popleft())
                free -= 1
            sched.fill_batch()
            if sched.running and tokens_dt > 0:
                sched.step(tokens_dt)
            while sched.done:
                r = sched.done.pop(0)
                lat = now - self._arrive_t.pop(r.id)
                m.request_latencies.append(lat)
                m.request_done_times.append(now)
                n_done += 1
                self._lat_window.append((now, lat))
                if self.events.enabled:
                    self.events.emit(now, "request-done", a=float(lat),
                                     b=float(r.target_tokens))
        m.requests_done += n_done
        if self.tracer.enabled and n_done:
            self.tracer.counters.inc("serve/done", n_done)
        while self._lat_window and self._lat_window[0][0] < now - self._window:
            self._lat_window.popleft()
        # -- sample ---------------------------------------------------------
        depth = self.queue_depth()
        tgt = self.target_units(sim)
        m.serve_samples.append((now, float(n_new), float(rate),
                                float(depth), float(len(self._scheds)),
                                float(tgt)))
        if self.events.enabled:
            self.events.emit(now, "serve-sample", a=float(depth),
                             b=float(len(self._scheds)))

    # ----------------------------------------------------------- autoscale
    def on_autoscale(self, sim, now: float) -> None:
        if self.autoscaler is None or sim.fleet is None:
            return
        cfg = self.config
        m = sim.metrics
        old = int(sim.fleet.target_units)
        p95 = float("nan")
        if self._lat_window:
            lats = np.asarray([x[1] for x in self._lat_window],
                              dtype=np.float64)
            p95 = float(np.percentile(lats, 95.0))
        lead = self.autoscaler.config.lead
        ahead = (float(self.curve(now + lead))
                 if self.curve is not None else 0.0)
        signals = DemandSignals(
            t=now, rate_ewma=self._ewma if self._ewma is not None else 0.0,
            queue_depth=self.queue_depth(), p95_latency=p95,
            live_units=len(self._scheds), target_units=old,
            unit_throughput=cfg.unit_throughput, rate_ahead=ahead)
        decided = self.autoscaler.decide(signals)
        new = old if decided is None else int(decided)
        m.autoscale_decisions.append((now, old, new))
        if self.events.enabled:
            self.events.emit(now, "autoscale", a=float(new), b=float(old),
                             aux=self.autoscaler.policy_name)
        if decided is not None:
            if self.tracer.enabled:
                self.tracer.counters.inc("autoscale/actions")
                self.tracer.instant("serve", "autoscale", now,
                                    {"from": old, "to": new})
            sim.fleet.set_target_units(sim, new, now)

    # ------------------------------------------------- lifecycle listeners
    def on_vm_interrupted(self, sim, time: float, vm, **kw) -> None:
        """Simulator ``vm_interrupted`` listener: a serving VM lost its
        capacity — bounce its in-flight requests through the configured
        hibernate/requeue behavior back into the global queue."""
        if vm.id in self._scheds:
            self._requeue_vm(sim, time, vm.id)

    def on_vm_finished(self, sim, time: float, vm, **kw) -> None:
        """Simulator ``vm_finished`` listener: an on-demand lease expired or
        the autoscaler decommissioned the VM — same requeue path."""
        if vm.id in self._scheds:
            self._requeue_vm(sim, time, vm.id)

    def _requeue_vm(self, sim, now: float, vid: int) -> None:
        sched = self._scheds.pop(vid)
        n_inflight = len(sched.running)
        sched.interrupt()
        moved = 0
        # hibernated first (the paper's resubmission order: checkpointed
        # requests resume before fresh queued work)
        for r in sched.hibernated:
            self._queue.append(r)
            moved += 1
        for r in sched.queue:
            self._queue.append(r)
            moved += 1
        m = sim.metrics
        m.requests_requeued += n_inflight
        if self.tracer.enabled and n_inflight:
            self.tracer.counters.inc("serve/requeued", n_inflight)
        if self.events.enabled:
            vm = sim.vms[vid]
            self.events.emit(now, "request-requeue", vm=vid,
                             pool=int(vm.pool), a=float(n_inflight),
                             b=float(moved))

    # ------------------------------------------------------------ internal
    def _live_vids(self, sim) -> List[int]:
        """Serving-capable VM ids, sorted (determinism): the fleet's live
        unretired/unshed slots, or — with no fleet attached — every running
        market spot VM."""
        fleet = sim.fleet
        if fleet is not None:
            vids = []
            for s in range(fleet.n_slots):
                if fleet.slot_retired[s] or fleet.slot_shed[s]:
                    continue
                vid = int(fleet.slot_vid[s])
                if vid < 0:
                    continue
                if sim.vms[vid].state in _SERVING_STATES:
                    vids.append(vid)
            vids.sort()
            return vids
        return sorted(v.id for v in sim.vms.values()
                      if v.pool >= 0 and v.state in _SERVING_STATES)


def make_serve_manager(config: Optional[ServeConfig] = None,
                       autoscaler: Optional[Autoscaler] = None,
                       seed: int = 0, **kwargs) -> ServeManager:
    """Build a manager from a config (or config kwargs), PR 4 style."""
    cfg = config if config is not None else ServeConfig(**kwargs)
    return ServeManager(cfg, autoscaler=autoscaler, seed=seed)
