"""SLO and cost metrics for the serving scenario.

Per-request latency distribution (p50/p95/p99), SLO attainment with
windowed error-budget burn (SRE-style: burn rate 1.0 = exactly spending
the budget the objective allows), and cost-effectiveness on the PR 5
batched realized-billing path — cost per served request and a linear
end-of-horizon cost forecast.  Everything here is pure aggregation over
the run's :class:`~repro.core.metrics.Metrics`; the realized fleet cost
itself comes from ``Metrics.resilience_stats`` (one batched
``price_integrals`` call) and is passed in, never recomputed.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np


def latency_percentiles(latencies: Sequence[float],
                        qs: Sequence[float] = (50.0, 95.0, 99.0)
                        ) -> Dict[str, float]:
    """``{"p50": ..., "p95": ...}`` over the sample (0.0 when empty —
    aggregate rows must stay numeric for the sweep's mean ± CI pass)."""
    if not len(latencies):
        return {f"p{g:g}": 0.0 for g in qs}
    arr = np.asarray(latencies, dtype=np.float64)
    vals = np.percentile(arr, qs)
    return {f"p{g:g}": float(v) for g, v in zip(qs, vals)}


def slo_attainment(latencies: Sequence[float], threshold: float) -> float:
    """Fraction of served requests at or under ``threshold`` seconds
    (1.0 when nothing was served — an empty run violates nothing)."""
    if not len(latencies):
        return 1.0
    arr = np.asarray(latencies, dtype=np.float64)
    return float(np.count_nonzero(arr <= threshold)) / arr.size


def error_budget_burn(done_times: Sequence[float],
                      latencies: Sequence[float], threshold: float,
                      objective: float, window: float,
                      horizon: float) -> Dict[str, float]:
    """Windowed error-budget burn over the run.

    The objective grants a violation budget of ``1 - objective`` per
    window; the burn rate of a window is its observed violation fraction
    over that budget (1.0 = spending the budget exactly, >1 = on track to
    exhaust it).  Returns the overall burn plus the worst window."""
    budget = max(1.0 - objective, 1e-12)
    out = {"burn_rate": 0.0, "max_window_burn": 0.0}
    if not len(done_times):
        return out
    t = np.asarray(done_times, dtype=np.float64)
    bad = (np.asarray(latencies, dtype=np.float64) > threshold)
    out["burn_rate"] = float(np.count_nonzero(bad)) / t.size / budget
    edges = np.arange(0.0, horizon + window, window, dtype=np.float64)
    idx = np.clip(np.searchsorted(edges, t, side="right") - 1, 0,
                  len(edges) - 2)
    n_win = len(edges) - 1
    total = np.bincount(idx, minlength=n_win).astype(np.float64)
    viol = np.bincount(idx, weights=bad.astype(np.float64),
                       minlength=n_win)
    with np.errstate(invalid="ignore"):
        burns = np.where(total > 0, viol / np.maximum(total, 1.0) / budget,
                         0.0)
    out["max_window_burn"] = float(np.max(burns)) if burns.size else 0.0
    return out


def cost_per_request(cost: float, n_done: int) -> float:
    """Realized price·hours per served request (0.0 when nothing served)."""
    return cost / n_done if n_done > 0 else 0.0


def cost_forecast(cost: float, elapsed: float, horizon: float) -> float:
    """Linear end-of-horizon projection of the realized cost so far."""
    if elapsed <= 0:
        return 0.0
    return cost * (horizon / elapsed)


def serve_stats(metrics, slo_latency: float, slo_objective: float,
                window: float, horizon: float,
                cost: Optional[float] = None) -> dict:
    """Aggregate serving row for :func:`repro.api.build.collect_row`.

    ``cost`` is the run's realized fleet cost (spot + on-demand spill,
    price·hours) from ``resilience_stats``; ``None`` (no fleet billing
    available) zeroes the cost-effectiveness keys."""
    lat = metrics.request_latencies
    pct = latency_percentiles(lat)
    burn = error_budget_burn(metrics.request_done_times, lat, slo_latency,
                             slo_objective, window, horizon)
    samples = metrics.serve_samples
    depth: List[float] = [s[3] for s in samples]
    live: List[float] = [s[4] for s in samples]
    out = {
        "requests_arrived": metrics.requests_arrived,
        "requests_done": metrics.requests_done,
        "requests_requeued": metrics.requests_requeued,
        "requests_outstanding": (metrics.requests_arrived
                                 - metrics.requests_done),
        "p50_latency_s": pct["p50"],
        "p95_latency_s": pct["p95"],
        "p99_latency_s": pct["p99"],
        "slo_attainment": slo_attainment(lat, slo_latency),
        "error_budget_burn": burn["burn_rate"],
        "max_window_burn": burn["max_window_burn"],
        "throughput_rps": (metrics.requests_done / horizon
                           if horizon > 0 else 0.0),
        "mean_queue_depth": float(np.mean(depth)) if depth else 0.0,
        "max_queue_depth": float(np.max(depth)) if depth else 0.0,
        "mean_live_units": float(np.mean(live)) if live else 0.0,
        "autoscale_actions": sum(
            1 for (_, old, new) in metrics.autoscale_decisions
            if old != new),
        "cost_per_request": 0.0,
        "cost_forecast": 0.0,
    }
    if cost is not None:
        elapsed = samples[-1][0] if samples else horizon
        out["cost_per_request"] = cost_per_request(cost,
                                                   metrics.requests_done)
        out["cost_forecast"] = cost_forecast(cost, elapsed, horizon)
    return out
