"""Core datatypes for the dynamic cloud marketspace simulator.

Mirrors the entity model of the paper's CloudSim Plus extension (§V-E):
``DynamicVm`` (abstract) -> ``OnDemandInstance`` / ``SpotInstance``, hosts with
4 resource dimensions (CPU, RAM, BW, Storage), and the extended VM lifecycle
states of Fig. 4.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

# Resource dimension order, fixed everywhere (D = 4), as in the paper
# (CPU cores, memory MB, bandwidth Mbps, storage MB).
RESOURCE_DIMS: Tuple[str, ...] = ("cpu", "ram", "bw", "storage")
N_DIMS = len(RESOURCE_DIMS)


def resources(cpu: float, ram: float, bw: float, storage: float) -> np.ndarray:
    """Build a resource vector in canonical dimension order."""
    return np.array([cpu, ram, bw, storage], dtype=np.float64)


class VmType(enum.Enum):
    ON_DEMAND = "on-demand"
    SPOT = "spot"


class InterruptionBehavior(enum.Enum):
    """What happens to a spot VM when the provider reclaims capacity (§V-C)."""

    TERMINATE = "terminate"
    HIBERNATE = "hibernate"


class VmState(enum.Enum):
    """Extended VM lifecycle states (paper Fig. 4; MIGRATING is the
    beyond-paper proactive cross-pool migration extension)."""

    CREATED = "created"          # defined, not yet submitted
    WAITING = "waiting"          # persistent request, waiting for capacity
    RUNNING = "running"          # allocated to a host, executing
    INTERRUPTING = "interrupting"  # received interruption warning, still running
    HIBERNATED = "hibernated"    # interrupted w/ HIBERNATE, awaiting resubmission
    MIGRATING = "migrating"      # in flight between hosts (stop-and-copy window)
    FINISHED = "finished"        # workload completed
    TERMINATED = "terminated"    # interrupted w/ TERMINATE or hibernation expired
    FAILED = "failed"            # request never fulfilled (waiting timed out)


@dataclass
class ExecutionInterval:
    """One contiguous period of execution on a host (§V-E ExecutionHistory).

    ``via`` records what started the interval: ``"start"`` (fresh allocation
    or resubmission after an interruption) or ``"migrate"`` (arrival of a
    proactive migration) — interruption-gap statistics must not count the
    voluntary migration downtime as interruption time."""

    host: int
    start: float
    stop: Optional[float] = None
    via: str = "start"


@dataclass
class Vm:
    """A dynamic VM request (on-demand or spot).

    ``duration`` is the total required execution time of the attached cloudlet;
    progress only accrues while RUNNING/INTERRUPTING, so hibernation pauses the
    workload exactly as in the paper's extension.
    """

    id: int
    demand: np.ndarray                      # (4,) resource request
    vm_type: VmType
    duration: float
    submit_time: float = 0.0
    # Spot-specific configuration (ignored for on-demand):
    behavior: InterruptionBehavior = InterruptionBehavior.TERMINATE
    min_running_time: float = 0.0           # cannot be interrupted before this
    hibernation_timeout: float = float("inf")
    # Persistent-request configuration (both types may be persistent, §V-D):
    persistent: bool = True
    waiting_timeout: float = float("inf")
    # Market configuration (price-driven engine; ignored when no engine runs):
    #   bid  — max clearing price this spot VM pays; the engine interrupts it
    #          whenever its pool's price exceeds the bid, and admission masks
    #          only open hosts whose pool currently clears at <= bid.  The
    #          inf default means "pay whatever" (never price-interrupted).
    #   pool — capacity-pool constraint: >= 0 pins the VM to that pool
    #          (region-bound); -1 lets it run in any pool whose price clears.
    bid: float = float("inf")
    pool: int = -1
    # --- runtime state ---
    state: VmState = VmState.CREATED
    host: int = -1
    remaining: float = field(default=-1.0)  # initialized to duration on submit
    run_start: float = -1.0                 # start of the current running interval
    waiting_since: float = -1.0
    hibernated_at: float = -1.0
    interruptions: int = 0
    migrations: int = 0                     # completed proactive migrations
    #: migration hysteresis: the planner may not select this VM again before
    #: this simulation time (stamped on arrival of a completed migration)
    migrate_cooldown_until: float = 0.0
    history: List[ExecutionInterval] = field(default_factory=list)
    generation: int = 0                     # invalidates stale scheduled events
    finish_time: float = -1.0

    def __post_init__(self) -> None:
        self.demand = np.asarray(self.demand, dtype=np.float64)
        if self.remaining < 0:
            self.remaining = float(self.duration)

    # -- convenience -------------------------------------------------------
    @property
    def is_spot(self) -> bool:
        return self.vm_type is VmType.SPOT

    def runtime_so_far(self, now: float) -> float:
        """Time accrued in the current running interval."""
        if self.state in (VmState.RUNNING, VmState.INTERRUPTING) and self.run_start >= 0:
            return now - self.run_start
        return 0.0

    def interruptible(self, now: float) -> bool:
        """Spot VM may be reclaimed only after its minimum running time (§IV-B)."""
        return (
            self.is_spot
            and self.state is VmState.RUNNING
            and self.runtime_so_far(now) >= self.min_running_time
        )

    def interruption_gaps(self) -> List[float]:
        """Durations between consecutive execution intervals (resumed gaps).

        Gaps closed by a proactive migration arrival (``via == "migrate"``)
        are voluntary downtime, accounted separately in the migration metrics
        — they are not interruption time."""
        gaps = []
        for prev, nxt in zip(self.history, self.history[1:]):
            if prev.stop is not None and nxt.via != "migrate":
                gaps.append(nxt.start - prev.stop)
        return gaps

    def average_interruption_time(self) -> float:
        gaps = self.interruption_gaps()
        return float(np.mean(gaps)) if gaps else 0.0


def make_spot(
    vm_id: int,
    demand: np.ndarray,
    duration: float,
    *,
    behavior: InterruptionBehavior = InterruptionBehavior.HIBERNATE,
    min_running_time: float = 0.0,
    hibernation_timeout: float = float("inf"),
    persistent: bool = True,
    waiting_timeout: float = float("inf"),
    submit_time: float = 0.0,
    bid: float = float("inf"),
    pool: int = -1,
) -> Vm:
    return Vm(
        id=vm_id, demand=demand, vm_type=VmType.SPOT, duration=duration,
        behavior=behavior, min_running_time=min_running_time,
        hibernation_timeout=hibernation_timeout, persistent=persistent,
        waiting_timeout=waiting_timeout, submit_time=submit_time,
        bid=bid, pool=pool,
    )


def make_on_demand(
    vm_id: int,
    demand: np.ndarray,
    duration: float,
    *,
    persistent: bool = True,
    waiting_timeout: float = float("inf"),
    submit_time: float = 0.0,
    pool: int = -1,
) -> Vm:
    return Vm(
        id=vm_id, demand=demand, vm_type=VmType.ON_DEMAND, duration=duration,
        persistent=persistent, waiting_timeout=waiting_timeout,
        submit_time=submit_time, pool=pool,
    )
