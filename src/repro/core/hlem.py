"""HLEM-VMP host scoring (paper §VI, Eqs. 1–11).

Three implementations of the same math:

* ``hlem_scores_np``  — pure-numpy oracle (readable, used as test reference),
* ``hlem_scores_jax`` — vectorized/jitted JAX (production path on accelerators),
* ``repro.kernels.hlem_score`` — Pallas TPU kernel (tiled over hosts), validated
  against the numpy oracle in interpret mode.

All take a *masked* formulation: every host is scored, infeasible hosts carry
``mask=False`` and receive ``-inf`` so downstream argmax ignores them.  This is
the jit-friendly equivalent of the paper's explicit candidate-list construction.

Phases (paper §VI-A):
  1. host filtering   — feasibility + RsDiff threshold (Eqs. 1–2), done by the
                        policy layer (see allocation.py), expressed as ``mask``;
  2. load evaluation  — min-max standardize free capacity per dimension (Eq. 3),
                        proportions (Eq. 4), entropy e_d (Eqs. 5–6), variation
                        g_d = 1 - e_d (Eq. 7), weights w_d (Eq. 8);
  3. selection        — host score HS_i = sum_d w_d * C~_i^d (Eq. 9), argmax.

Adjusted variant (§VI-C): spot load SL_i = sum_d w_d * spot_used/total (Eq. 10)
scales the score AHS_i = HS_i * (1 + alpha * SL_i) (Eq. 11).  A *negative*
``alpha`` penalizes spot-heavy hosts, which is the behavior the paper's text
describes ("distribute spot instances more evenly"); the magnitude is tunable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------
def hlem_weights_np(free: np.ndarray, mask: np.ndarray):
    """Entropy-derived resource weights over the masked candidate set.

    Returns (standardized capacity C~ (n,D), weights w (D,)).
    """
    free = np.asarray(free, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    n_cand = int(mask.sum())
    d = free.shape[1]
    if n_cand == 0:
        return np.zeros_like(free), np.full(d, 1.0 / d)

    sel = free[mask]  # (m, D)
    lo, hi = sel.min(axis=0), sel.max(axis=0)
    span = hi - lo
    # Eq. 3 — min-max standardization; degenerate dimension -> all equal (1.0)
    c_std = np.where(span > _EPS, (sel - lo) / np.where(span > _EPS, span, 1.0), 1.0)
    # Eq. 4 — proportions over candidates
    col = c_std.sum(axis=0)
    p = np.where(col > _EPS, c_std / np.where(col > _EPS, col, 1.0), 1.0 / n_cand)
    # Eqs. 5–6 — entropy with k = 1/ln(n); n == 1 degenerates to zero entropy
    if n_cand > 1:
        k = 1.0 / np.log(n_cand)
        plogp = np.where(p > _EPS, p * np.log(np.maximum(p, _EPS)), 0.0)
        e = -k * plogp.sum(axis=0)
    else:
        e = np.zeros(d)
    # Eqs. 7–8 — variation factors and weights
    g = 1.0 - e
    gsum = g.sum()
    w = g / gsum if gsum > _EPS else np.full(d, 1.0 / d)

    c_full = np.zeros_like(free)
    c_full[mask] = c_std
    return c_full, w


def hlem_scores_np(
    free: np.ndarray,
    mask: np.ndarray,
    spot_frac: np.ndarray | None = None,
    alpha: float = 0.0,
) -> np.ndarray:
    """Full HLEM-VMP host scores; -inf where mask is False.

    ``spot_frac`` is spot_used/total per (host, dim); with ``alpha != 0`` this
    computes the adjusted score AHS (Eq. 11).
    """
    mask = np.asarray(mask, dtype=bool)
    c_std, w = hlem_weights_np(free, mask)
    hs = c_std @ w  # Eq. 9
    if spot_frac is not None and alpha != 0.0:
        sl = np.asarray(spot_frac, dtype=np.float64) @ w  # Eq. 10
        hs = hs * (1.0 + alpha * sl)  # Eq. 11
    return np.where(mask, hs, -np.inf)


def hlem_select_np(free, mask, spot_frac=None, alpha=0.0) -> int:
    """argmax host id, or -1 if no candidate."""
    if not np.any(mask):
        return -1
    return int(np.argmax(hlem_scores_np(free, mask, spot_frac, alpha)))


# ---------------------------------------------------------------------------
# JAX (jitted, mask-based — fixed shapes, no data-dependent control flow)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=())
def hlem_scores_jax(
    free: jax.Array,           # (n, D) float32/float64
    mask: jax.Array,           # (n,) bool
    spot_frac: jax.Array,      # (n, D)
    alpha: jax.Array,          # scalar
) -> jax.Array:
    """Identical math to ``hlem_scores_np``, jit-compiled."""
    free = free.astype(jnp.float32)
    maskf = mask.astype(jnp.float32)[:, None]          # (n,1)
    m = jnp.sum(maskf)                                 # candidate count
    big = jnp.float32(3.4e38)

    masked = jnp.where(mask[:, None], free, jnp.inf)
    lo = jnp.min(masked, axis=0)
    masked_hi = jnp.where(mask[:, None], free, -jnp.inf)
    hi = jnp.max(masked_hi, axis=0)
    span = hi - lo
    degen = span <= _EPS
    c_std = jnp.where(degen[None, :], 1.0, (free - lo[None, :]) / jnp.where(degen, 1.0, span)[None, :])
    c_std = c_std * maskf

    col = jnp.sum(c_std, axis=0)
    p = jnp.where(col[None, :] > _EPS, c_std / jnp.where(col > _EPS, col, 1.0)[None, :],
                  maskf / jnp.maximum(m, 1.0))
    p = p * maskf
    k = jnp.where(m > 1.0, 1.0 / jnp.log(jnp.maximum(m, 2.0)), 0.0)
    plogp = jnp.where(p > _EPS, p * jnp.log(jnp.maximum(p, _EPS)), 0.0)
    e = -k * jnp.sum(plogp, axis=0)
    g = 1.0 - e
    gsum = jnp.sum(g)
    d = free.shape[1]
    w = jnp.where(gsum > _EPS, g / jnp.where(gsum > _EPS, gsum, 1.0), 1.0 / d)

    hs = c_std @ w
    sl = spot_frac.astype(jnp.float32) @ w
    hs = hs * (1.0 + alpha * sl)
    return jnp.where(mask, hs, -big)


@jax.jit
def hlem_select_jax(free, mask, spot_frac, alpha) -> jax.Array:
    scores = hlem_scores_jax(free, mask, spot_frac, alpha)
    idx = jnp.argmax(scores)
    return jnp.where(jnp.any(mask), idx, -1)


# Batched variant: score B pending VM demands against the same host state in one
# call (used when flushing the resubmission queue) — a beyond-CloudSim
# vectorization enabled by the masked formulation.
@jax.jit
def hlem_select_batch_jax(
    free: jax.Array,        # (n, D)
    masks: jax.Array,       # (B, n) per-VM feasibility masks
    spot_frac: jax.Array,   # (n, D)
    alpha: jax.Array,
) -> jax.Array:             # (B,) selected host per VM (ignoring cross-VM capacity)
    fn = jax.vmap(lambda m: hlem_select_jax(free, m, spot_frac, alpha))
    return fn(masks)


# ---------------------------------------------------------------------------
# Filtering math shared by the policy layer
# ---------------------------------------------------------------------------
def rsdiff_np(
    demand_cpu: float,
    used_cpu: np.ndarray,
    total_cpu: np.ndarray,
    rc: float = 0.95,
) -> np.ndarray:
    """Eq. 1 — RsDiff = R_j(t) - U_i(t) * Rc, in CPU-fraction units.

    R_j is the VM's CPU request relative to the host's CPU capacity; U_i is the
    host's current CPU utilization. Hosts already loaded with similar workloads
    (high utilization relative to the request) are filtered out (Eq. 2).
    """
    tot = np.maximum(total_cpu, _EPS)
    r_j = demand_cpu / tot
    u_i = used_cpu / tot
    return r_j - u_i * rc
