"""HLEM-VMP host scoring (paper §VI, Eqs. 1–11).

Three implementations of the same math:

* ``hlem_scores_np``  — pure-numpy oracle (readable, used as test reference),
* ``hlem_scores_jax`` — vectorized/jitted JAX (production path on accelerators),
* ``repro.kernels.hlem_score`` — Pallas TPU kernel (tiled over hosts), validated
  against the numpy oracle in interpret mode.

All take a *masked* formulation: every host is scored, infeasible hosts carry
``mask=False`` and receive ``-inf`` so downstream argmax ignores them.  This is
the jit-friendly equivalent of the paper's explicit candidate-list construction.

Phases (paper §VI-A):
  1. host filtering   — feasibility + RsDiff threshold (Eqs. 1–2), done by the
                        policy layer (see allocation.py), expressed as ``mask``;
  2. load evaluation  — min-max standardize free capacity per dimension (Eq. 3),
                        proportions (Eq. 4), entropy e_d (Eqs. 5–6), variation
                        g_d = 1 - e_d (Eq. 7), weights w_d (Eq. 8);
  3. selection        — host score HS_i = sum_d w_d * C~_i^d (Eq. 9), argmax.

Adjusted variant (§VI-C): spot load SL_i = sum_d w_d * spot_used/total (Eq. 10)
scales the score AHS_i = HS_i * (1 + alpha * SL_i) (Eq. 11).  A *negative*
``alpha`` penalizes spot-heavy hosts, which is the behavior the paper's text
describes ("distribute spot instances more evenly"); the magnitude is tunable.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

_EPS = 1e-12


# ---------------------------------------------------------------------------
# numpy oracle
# ---------------------------------------------------------------------------
def hlem_weights_np(free: np.ndarray, mask: np.ndarray):
    """Entropy-derived resource weights over the masked candidate set.

    Returns (standardized capacity C~ (n,D), weights w (D,)).
    """
    free = np.asarray(free, dtype=np.float64)
    mask = np.asarray(mask, dtype=bool)
    n_cand = int(mask.sum())
    d = free.shape[1]
    if n_cand == 0:
        return np.zeros_like(free), np.full(d, 1.0 / d)

    sel = free[mask]  # (m, D)
    lo, hi = sel.min(axis=0), sel.max(axis=0)
    span = hi - lo
    # Eq. 3 — min-max standardization; degenerate dimension -> all equal (1.0)
    c_std = np.where(span > _EPS, (sel - lo) / np.where(span > _EPS, span, 1.0), 1.0)
    # Eq. 4 — proportions over candidates
    col = c_std.sum(axis=0)
    p = np.where(col > _EPS, c_std / np.where(col > _EPS, col, 1.0), 1.0 / n_cand)
    # Eqs. 5–6 — entropy with k = 1/ln(n); n == 1 degenerates to zero entropy
    if n_cand > 1:
        k = 1.0 / np.log(n_cand)
        plogp = np.where(p > _EPS, p * np.log(np.maximum(p, _EPS)), 0.0)
        e = -k * plogp.sum(axis=0)
    else:
        e = np.zeros(d)
    # Eqs. 7–8 — variation factors and weights
    g = 1.0 - e
    gsum = g.sum()
    w = g / gsum if gsum > _EPS else np.full(d, 1.0 / d)

    c_full = np.zeros_like(free)
    c_full[mask] = c_std
    return c_full, w


def hlem_scores_np(
    free: np.ndarray,
    mask: np.ndarray,
    spot_frac: np.ndarray | None = None,
    alpha: float = 0.0,
) -> np.ndarray:
    """Full HLEM-VMP host scores; -inf where mask is False.

    ``spot_frac`` is spot_used/total per (host, dim); with ``alpha != 0`` this
    computes the adjusted score AHS (Eq. 11).
    """
    mask = np.asarray(mask, dtype=bool)
    c_std, w = hlem_weights_np(free, mask)
    hs = c_std @ w  # Eq. 9
    if spot_frac is not None and alpha != 0.0:
        sl = np.asarray(spot_frac, dtype=np.float64) @ w  # Eq. 10
        hs = hs * (1.0 + alpha * sl)  # Eq. 11
    return np.where(mask, hs, -np.inf)


def hlem_select_np(free, mask, spot_frac=None, alpha=0.0) -> int:
    """argmax host id, or -1 if no candidate."""
    if not np.any(mask):
        return -1
    return int(np.argmax(hlem_scores_np(free, mask, spot_frac, alpha)))


def hlem_pick_np(
    free: np.ndarray,
    mask: np.ndarray,
    spot_frac: np.ndarray,
    alpha: float = 0.0,
) -> int:
    """Fused single-VM selection: ``argmax(hlem_scores_np(...))`` without
    materializing full-fleet score arrays.

    Decision-identical to scoring + argmax: the standardization/entropy math
    (Eqs. 3-9) runs on the *compressed* candidate rows — exactly the arrays
    ``hlem_scores_np`` reduces over — and the compressed argmax maps back
    through ``flatnonzero`` (order-preserving, so ties break to the same
    host).  This is the allocation hot path's scorer; ``hlem_scores_np``
    remains the readable oracle."""
    idx = np.flatnonzero(mask)
    return hlem_pick_candidates_np(free, idx, spot_frac, alpha)


class _PickWorkspace:
    """Preallocated scratch for the fused pick — the hot path allocates
    nothing per call (arrays grow monotonically with the fleet)."""

    def __init__(self):
        self.cap = 0

    def ensure(self, m: int, d: int) -> None:
        if m <= self.cap:
            return
        cap = max(m, max(self.cap * 2, 64))
        self.sel = np.empty((cap, d))
        self.tmp = np.empty((cap, d))
        self.tmp2 = np.empty((cap, d))
        self.boolbuf = np.empty((cap, d), dtype=bool)
        self.hs = np.empty(cap)
        self.cap = cap


_WS = _PickWorkspace()


def hlem_pick_candidates_np(
    free: np.ndarray,
    idx: np.ndarray,
    spot_frac: np.ndarray,
    alpha: float = 0.0,
) -> int:
    """:func:`hlem_pick_np` over an explicit candidate-id array (the policy
    layer already holds ``flatnonzero`` of its masks).

    Runs the oracle's exact operation sequence on compressed candidate rows
    with preallocated workspace buffers — values (and therefore the argmax
    decision, ties included) match scoring + argmax bit for bit."""
    m = idx.size
    if m == 0:
        return -1
    if m == 1:
        return int(idx[0])  # degenerate candidate set: any weighting agrees
    free = np.asarray(free, dtype=np.float64)
    d = free.shape[1]
    _WS.ensure(m, d)
    sel = np.take(free, idx, axis=0, out=_WS.sel[:m])
    lo, hi = sel.min(axis=0), sel.max(axis=0)
    span = hi - lo
    nondegen = span > _EPS
    c_std = _WS.tmp[:m]
    np.subtract(sel, lo, out=c_std)
    if nondegen.all():
        np.divide(c_std, span, out=c_std)
    else:
        if alpha == 0.0 and not nondegen.any():
            # all dims degenerate: HS identical for every candidate and the
            # adjustment is off, so the argmax tie-breaks to the first
            return int(idx[0])
        np.divide(c_std, np.where(nondegen, span, 1.0), out=c_std)
        np.copyto(c_std, 1.0, where=~nondegen)
    # each column sums to >= 1 (its max candidate standardizes to 1.0, or the
    # degenerate all-ones case sums to m), so the col > eps guard of the
    # oracle never fires and plain division is value-identical
    col = c_std.sum(axis=0)
    # p reuses the gather buffer (sel is not read past this point); the
    # entropy chain below computes where(p > eps, p*log(max(p, eps)), 0)
    # elementwise-identically with zero allocation
    p = np.divide(c_std, col, out=_WS.sel[:m])
    small = np.less_equal(p, _EPS, out=_WS.boolbuf[:m])
    plogp = np.maximum(p, _EPS, out=_WS.tmp2[:m])
    np.log(plogp, out=plogp)
    np.multiply(p, plogp, out=plogp)
    np.copyto(plogp, 0.0, where=small)
    k = 1.0 / math.log(m)
    e = -k * plogp.sum(axis=0)
    g = 1.0 - e
    gsum = g.sum()
    w = g / gsum if gsum > _EPS else np.full(d, 1.0 / d)
    hs = np.dot(c_std, w, out=_WS.hs[:m])
    if alpha != 0.0:
        sl = np.take(np.asarray(spot_frac, dtype=np.float64), idx, axis=0) @ w
        hs = hs * (1.0 + alpha * sl)
    return int(idx[np.argmax(hs)])


#: fleet-size crossover for the batched numpy scorer: above this many hosts
#: the (B, n, D) broadcast core loses to a compressed per-row pass (its
#: masked intermediates thrash cache, while the per-row path reduces over the
#: compressed candidate set) — measured ~1.4-1.9x per-row advantage at
#: n >= 1000 for B in 4..32, batch advantage up to 2.2x at n <= 300.
BATCH_NP_N_CUTOVER = 512


def hlem_scores_batch_np(
    free: np.ndarray,          # (n, D) shared host state
    masks: np.ndarray,         # (B, n) per-VM candidate masks
    spot_frac: np.ndarray,     # (n, D)
    alphas: np.ndarray | float = 0.0,   # (B,) or scalar per-VM adjustment
    n_cutover: int | None = None,       # override BATCH_NP_N_CUTOVER (tests)
) -> np.ndarray:               # (B, n) scores, -inf outside each row's mask
    """Score B pending VMs against the same host state in one pass.

    Row b equals ``hlem_scores_np(free, masks[b], spot_frac, alphas[b])`` up
    to summation order (each row's entropy weights are derived from its own
    candidate set, Eqs. 3-9; Eq. 11 applied with the row's alpha).  This is
    the oracle for the batched Pallas kernel and the engine of the batched
    resubmission path.

    Large fleets (``n > BATCH_NP_N_CUTOVER``) route through the compressed
    per-row oracle instead of the broadcast core (same masked semantics, ulp-
    level summation-order differences — exactly the tolerance the broadcast
    core already carries vs the oracle).
    """
    free = np.asarray(free, dtype=np.float64)
    masks = np.asarray(masks, dtype=bool)
    spot_frac = np.asarray(spot_frac, dtype=np.float64)
    b, n = masks.shape
    d = free.shape[1]
    alphas = np.broadcast_to(np.asarray(alphas, dtype=np.float64), (b,))
    cut = BATCH_NP_N_CUTOVER if n_cutover is None else n_cutover
    if n > cut:
        out = np.empty((b, n))
        for i in range(b):
            out[i] = hlem_scores_np(free, masks[i], spot_frac,
                                    float(alphas[i]))
        return out
    maskf = masks[..., None].astype(np.float64)        # (B, n, 1)
    m = masks.sum(axis=1).astype(np.float64)           # (B,) candidate counts

    # Eq. 3 — per-row min-max standardization over each candidate set
    lo = np.where(masks[..., None], free[None], np.inf).min(axis=1)   # (B, D)
    hi = np.where(masks[..., None], free[None], -np.inf).max(axis=1)
    span = hi - lo
    degen = span <= _EPS
    c = np.where(degen[:, None, :], 1.0,
                 (free[None] - lo[:, None]) / np.where(degen, 1.0, span)[:, None])
    c = c * maskf
    # Eq. 4 — proportions over each row's candidates
    col = c.sum(axis=1)                                # (B, D)
    p = np.where(col[:, None] > _EPS,
                 c / np.where(col > _EPS, col, 1.0)[:, None],
                 maskf / np.maximum(m, 1.0)[:, None, None])
    p = p * maskf
    # Eqs. 5-6 — entropy with k = 1/ln(m); m <= 1 degenerates to zero entropy
    k = np.where(m > 1.0, 1.0 / np.log(np.maximum(m, 2.0)), 0.0)
    plogp = np.where(p > _EPS, p * np.log(np.maximum(p, _EPS)), 0.0)
    e = -k[:, None] * plogp.sum(axis=1)                # (B, D)
    # Eqs. 7-8 — variation factors and weights
    g = 1.0 - e
    gsum = g.sum(axis=1)
    w = np.where(gsum[:, None] > _EPS,
                 g / np.where(gsum > _EPS, gsum, 1.0)[:, None], 1.0 / d)
    # Eqs. 9-11
    hs = np.einsum("bnd,bd->bn", c, w)
    sl = np.einsum("nd,bd->bn", spot_frac, w)
    hs = hs * (1.0 + alphas[:, None] * sl)
    return np.where(masks, hs, -np.inf)


# ---------------------------------------------------------------------------
# JAX (jitted, mask-based — fixed shapes, no data-dependent control flow)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=())
def hlem_scores_jax(
    free: jax.Array,           # (n, D) float32/float64
    mask: jax.Array,           # (n,) bool
    spot_frac: jax.Array,      # (n, D)
    alpha: jax.Array,          # scalar
) -> jax.Array:
    """Identical math to ``hlem_scores_np``, jit-compiled."""
    free = free.astype(jnp.float32)
    maskf = mask.astype(jnp.float32)[:, None]          # (n,1)
    m = jnp.sum(maskf)                                 # candidate count
    big = jnp.float32(3.4e38)

    masked = jnp.where(mask[:, None], free, jnp.inf)
    lo = jnp.min(masked, axis=0)
    masked_hi = jnp.where(mask[:, None], free, -jnp.inf)
    hi = jnp.max(masked_hi, axis=0)
    span = hi - lo
    degen = span <= _EPS
    c_std = jnp.where(degen[None, :], 1.0, (free - lo[None, :]) / jnp.where(degen, 1.0, span)[None, :])
    c_std = c_std * maskf

    col = jnp.sum(c_std, axis=0)
    p = jnp.where(col[None, :] > _EPS, c_std / jnp.where(col > _EPS, col, 1.0)[None, :],
                  maskf / jnp.maximum(m, 1.0))
    p = p * maskf
    k = jnp.where(m > 1.0, 1.0 / jnp.log(jnp.maximum(m, 2.0)), 0.0)
    plogp = jnp.where(p > _EPS, p * jnp.log(jnp.maximum(p, _EPS)), 0.0)
    e = -k * jnp.sum(plogp, axis=0)
    g = 1.0 - e
    gsum = jnp.sum(g)
    d = free.shape[1]
    w = jnp.where(gsum > _EPS, g / jnp.where(gsum > _EPS, gsum, 1.0), 1.0 / d)

    hs = c_std @ w
    sl = spot_frac.astype(jnp.float32) @ w
    hs = hs * (1.0 + alpha * sl)
    return jnp.where(mask, hs, -big)


@jax.jit
def hlem_select_jax(free, mask, spot_frac, alpha) -> jax.Array:
    scores = hlem_scores_jax(free, mask, spot_frac, alpha)
    idx = jnp.argmax(scores)
    return jnp.where(jnp.any(mask), idx, -1)


# Batched variants: score B pending VM demands against the same host state in
# one call (used when flushing the resubmission queue) — a beyond-CloudSim
# vectorization enabled by the masked formulation.
@jax.jit
def hlem_scores_batch_jax(
    free: jax.Array,        # (n, D) shared host state
    masks: jax.Array,       # (B, n) per-VM feasibility masks
    spot_frac: jax.Array,   # (n, D)
    alphas: jax.Array,      # (B,) per-VM adjustment
) -> jax.Array:             # (B, n) scores, -big outside each row's mask
    fn = jax.vmap(lambda m, a: hlem_scores_jax(free, m, spot_frac, a))
    return fn(masks, alphas)


@jax.jit
def hlem_select_batch_jax(
    free: jax.Array,        # (n, D)
    masks: jax.Array,       # (B, n) per-VM feasibility masks
    spot_frac: jax.Array,   # (n, D)
    alpha: jax.Array,
) -> jax.Array:             # (B,) selected host per VM (ignoring cross-VM capacity)
    fn = jax.vmap(lambda m: hlem_select_jax(free, m, spot_frac, alpha))
    return fn(masks)


# ---------------------------------------------------------------------------
# Filtering math shared by the policy layer
# ---------------------------------------------------------------------------
def rsdiff_np(
    demand_cpu: float,
    used_cpu: np.ndarray,
    total_cpu: np.ndarray,
    rc: float = 0.95,
) -> np.ndarray:
    """Eq. 1 — RsDiff = R_j(t) - U_i(t) * Rc, in CPU-fraction units.

    R_j is the VM's CPU request relative to the host's CPU capacity; U_i is the
    host's current CPU utilization. Hosts already loaded with similar workloads
    (high utilization relative to the request) are filtered out (Eq. 2).
    """
    tot = np.maximum(total_cpu, _EPS)
    r_j = demand_cpu / tot
    u_i = used_cpu / tot
    return r_j - u_i * rc
