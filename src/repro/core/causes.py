"""Canonical interruption-cause names (one constants module, no drift).

Every :class:`repro.core.metrics.InterruptionEvent` carries a ``cause``
string.  Before this module they were scattered literals ("capacity",
"price-wave", "migration-failed"); the fault-injection layer adds more, so
the names now live in one place.  The values are **serialized identifiers**
(they appear in metrics JSON, sweep reports, and tests) — they must never
change, only grow.
"""
from __future__ import annotations


class InterruptionCause:
    """String constants for ``InterruptionEvent.cause``.

    Plain ``str`` constants rather than an Enum: causes are serialized
    verbatim into metrics rows and committed sweep reports, and historical
    artifacts compare by raw string — a constants class keeps equality,
    hashing, and ``json.dumps`` behavior byte-for-byte identical to the
    pre-unification literals.
    """

    #: reclaimed by an on-demand request's preemption (the default)
    CAPACITY = "capacity"
    #: pool clearing price crossed the VM's bid (market engine wave)
    PRICE_WAVE = "price-wave"
    #: a proactive migration flight whose destination stopped clearing
    MIGRATION_FAILED = "migration-failed"
    #: the VM's host was removed (trace machine event / host churn)
    HOST_REMOVED = "host-removed"
    #: injected correlated interruption storm (``market/faults``)
    FAULT_STORM = "fault-storm"
    #: injected transient pool outage (``market/faults``)
    FAULT_OUTAGE = "fault-outage"

    ALL = (CAPACITY, PRICE_WAVE, MIGRATION_FAILED, HOST_REMOVED,
           FAULT_STORM, FAULT_OUTAGE)
    #: causes emitted by the fault-injection layer
    FAULT_CAUSES = (FAULT_STORM, FAULT_OUTAGE)

    @classmethod
    def validate(cls, cause: str) -> str:
        if cause not in cls.ALL:
            raise ValueError(
                f"unknown interruption cause {cause!r} "
                f"(known: {', '.join(cls.ALL)})")
        return cause
