"""Vectorized host pool.

Host state lives in dense numpy arrays (capacity / used / spot-used per
resource dimension) so allocation policies can score *all* hosts in one
vectorized pass — this is the JAX/TPU-native replacement for CloudSim Plus's
per-host Java object iteration (the paper reports 1.5 real days per simulated
day, bottlenecked on per-entity updates; §VII-D1).
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Optional, Set

import numpy as np

from .types import N_DIMS, Vm


class HostPool:
    """Dense, growable pool of hosts supporting dynamic add/remove (trace
    machine events) and spot/on-demand accounting."""

    def __init__(self, capacity_hint: int = 64):
        n = max(capacity_hint, 1)
        self.total = np.zeros((n, N_DIMS), dtype=np.float64)
        self.used = np.zeros((n, N_DIMS), dtype=np.float64)
        self.spot_used = np.zeros((n, N_DIMS), dtype=np.float64)
        self.active = np.zeros(n, dtype=bool)
        self.n_hosts = 0
        # host -> set of resident VM ids, in insertion order (dict preserves it)
        self.residents: List[Dict[int, Vm]] = [dict() for _ in range(n)]

    # -- structural ---------------------------------------------------------
    def _grow(self, need: int) -> None:
        cap = self.total.shape[0]
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        pad = new_cap - cap
        self.total = np.vstack([self.total, np.zeros((pad, N_DIMS))])
        self.used = np.vstack([self.used, np.zeros((pad, N_DIMS))])
        self.spot_used = np.vstack([self.spot_used, np.zeros((pad, N_DIMS))])
        self.active = np.concatenate([self.active, np.zeros(pad, dtype=bool)])
        self.residents.extend(dict() for _ in range(pad))

    def add_host(self, capacity: np.ndarray) -> int:
        """Register a new host; returns its id."""
        hid = self.n_hosts
        self._grow(hid + 1)
        self.total[hid] = np.asarray(capacity, dtype=np.float64)
        self.used[hid] = 0.0
        self.spot_used[hid] = 0.0
        self.active[hid] = True
        self.residents[hid] = dict()
        self.n_hosts += 1
        return hid

    def update_host(self, hid: int, capacity: np.ndarray) -> None:
        """Trace 'UPDATE' machine event — change host capacity in place."""
        self.total[hid] = np.asarray(capacity, dtype=np.float64)

    def remove_host(self, hid: int) -> List[Vm]:
        """Deactivate a host; returns resident VMs (caller decides their fate)."""
        victims = list(self.residents[hid].values())
        self.active[hid] = False
        return victims

    def reactivate_host(self, hid: int) -> None:
        self.active[hid] = True

    # -- views --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.n_hosts

    def free(self) -> np.ndarray:
        """(n_hosts, 4) free capacity (inactive hosts report 0 free)."""
        f = self.total[: self.n] - self.used[: self.n]
        return np.where(self.active[: self.n, None], f, 0.0)

    def totals(self) -> np.ndarray:
        return self.total[: self.n]

    def used_view(self) -> np.ndarray:
        return self.used[: self.n]

    def spot_used_view(self) -> np.ndarray:
        return self.spot_used[: self.n]

    def active_view(self) -> np.ndarray:
        return self.active[: self.n]

    def cpu_utilization(self) -> np.ndarray:
        tot = self.total[: self.n, 0]
        return np.divide(self.used[: self.n, 0], tot, out=np.zeros(self.n), where=tot > 0)

    # -- allocation ---------------------------------------------------------
    def fits(self, hid: int, demand: np.ndarray) -> bool:
        return bool(
            self.active[hid]
            and np.all(self.total[hid] - self.used[hid] >= demand - 1e-9)
        )

    def place(self, vm: Vm, hid: int) -> None:
        assert self.fits(hid, vm.demand), f"host {hid} cannot fit vm {vm.id}"
        self.used[hid] += vm.demand
        if vm.is_spot:
            self.spot_used[hid] += vm.demand
        self.residents[hid][vm.id] = vm
        vm.host = hid

    def release(self, vm: Vm) -> None:
        hid = vm.host
        assert hid >= 0 and vm.id in self.residents[hid], (
            f"vm {vm.id} not resident on host {hid}"
        )
        self.used[hid] -= vm.demand
        if vm.is_spot:
            self.spot_used[hid] -= vm.demand
        # numerical hygiene: clamp tiny negatives from float accumulation
        np.clip(self.used[hid], 0.0, None, out=self.used[hid])
        np.clip(self.spot_used[hid], 0.0, None, out=self.spot_used[hid])
        del self.residents[hid][vm.id]
        vm.host = -1

    def spot_vms_on(self, hid: int) -> List[Vm]:
        """Resident spot VMs in insertion order (CloudSim host-VM-list order)."""
        return [v for v in self.residents[hid].values() if v.is_spot]

    # -- invariant checks (used by property tests) ---------------------------
    def check_invariants(self) -> None:
        for hid in range(self.n):
            res = sum(
                (v.demand for v in self.residents[hid].values()),
                np.zeros(N_DIMS),
            )
            assert np.allclose(res, self.used[hid], atol=1e-6), (
                f"host {hid}: used {self.used[hid]} != resident sum {res}"
            )
            spot = sum(
                (v.demand for v in self.residents[hid].values() if v.is_spot),
                np.zeros(N_DIMS),
            )
            assert np.allclose(spot, self.spot_used[hid], atol=1e-6)
            assert np.all(self.used[hid] <= self.total[hid] + 1e-6), (
                f"host {hid} over capacity: {self.used[hid]} > {self.total[hid]}"
            )
