"""Vectorized host pool with incremental accounting.

Host state lives in dense numpy arrays (capacity / used / spot-used per
resource dimension) so allocation policies can score *all* hosts in one
vectorized pass — this is the JAX/TPU-native replacement for CloudSim Plus's
per-host Java object iteration (the paper reports 1.5 real days per simulated
day, bottlenecked on per-entity updates; §VII-D1).

Incremental accounting (the trace-scale hot path):

* ``free`` / ``spot_frac`` / cpu-utilization caches are updated **in place**
  on every ``place``/``release``/host add/remove/update, so feasibility masks
  and HLEM scoring read cached rows instead of recomputing ``total - used``
  for the whole fleet per call.
* Reclaimable spot capacity (what ``clearing_mask`` needs) is maintained as a
  per-host running sum over *interruptible* resident spot VMs.  Minimum
  running time (§IV-B) is handled by a time-threshold index: a VM placed with
  ``min_running_time > 0`` sits in a ready-time heap and is folded into the
  reclaimable sum by :meth:`refresh_reclaim` once its threshold passes — no
  per-call Python walk over residents.
* A monotone *gain log* records every host whose free capacity increased
  (release / add / reactivate / capacity update).  The simulator's
  resubmission queue uses it to skip VMs whose placement can't possibly have
  become feasible since their last failed attempt.

Market mode (price-driven engine; see ``repro.market.engine``):

* Every host belongs to a *capacity pool* (``pool_of``; region / instance
  class).  When a market engine is attached (:meth:`enable_market`), each
  pool's clearing price is pushed down per tick via :meth:`set_pool_prices`
  into a per-host price row, and all feasibility masks additionally require
  ``host_price <= vm.bid`` (spot admission) and — when a VM is pool-pinned —
  ``pool_of == vm.pool``.  A price *drop* is treated like a capacity gain:
  the affected hosts are appended to the gain log so the resubmission memo
  rechecks queued spot VMs whose bid now clears (price rises only shrink
  masks, so memos stay valid without flooding).
* Running spot VMs are mirrored in a dense *market registry* (bid / pool /
  min-running-time-ready arrays with swap-remove).  Interruption-wave victim
  selection is one masked comparison over these arrays
  (:meth:`market_victims`) — no Python walk over residents.

Contract: a spot VM's ``min_running_time`` must be set **before** it is
placed; the reclaim index snapshots it at placement time.

Every mutation bumps ``epoch``; ``check_invariants`` cross-checks all cached
arrays against from-scratch recomputation.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from .types import N_DIMS, Vm, VmState, VmType

_EPS = 1e-9          # feasibility slack (matches the allocation layer)
_EPS_RS = 1e-12      # RsDiff clamp (matches repro.core.hlem._EPS)


class HostPool:
    """Dense, growable pool of hosts supporting dynamic add/remove (trace
    machine events), spot/on-demand accounting, and O(1)-amortized cached
    views for the allocation hot path."""

    def __init__(self, capacity_hint: int = 64):
        n = max(capacity_hint, 1)
        self.total = np.zeros((n, N_DIMS), dtype=np.float64)
        self.used = np.zeros((n, N_DIMS), dtype=np.float64)
        self.spot_used = np.zeros((n, N_DIMS), dtype=np.float64)
        self.active = np.zeros(n, dtype=bool)
        self.n_hosts = 0
        # host -> set of resident VM ids, in insertion order (dict preserves it)
        self.residents: List[Dict[int, Vm]] = [dict() for _ in range(n)]
        # -- incremental caches (epoch-stamped) ------------------------------
        self.epoch = 0
        #: total - used where active, 0 elsewhere; updated row-wise in place
        self._free = np.zeros((n, N_DIMS), dtype=np.float64)
        #: spot_used / max(total, 1e-9) per (host, dim)
        self._spot_frac = np.zeros((n, N_DIMS), dtype=np.float64)
        #: max(total, 1e-9) — the spot_frac denominator, refreshed only when
        #: capacity changes (place/release divide by the cached row)
        self._tot_clamped = np.full((n, N_DIMS), _EPS, dtype=np.float64)
        #: max(total_cpu, 1e-12) and used_cpu / that — RsDiff inputs (Eq. 1)
        self._rs_tot_cpu = np.full(n, _EPS_RS, dtype=np.float64)
        self._rs_util_cpu = np.zeros(n, dtype=np.float64)
        #: per-host sum of demands of interruptible-now resident spot VMs
        self._reclaim_ready = np.zeros((n, N_DIMS), dtype=np.float64)
        # min-running-time index: vm_id -> (ready_time, hid) awaiting expiry,
        # vm_id -> hid once folded into _reclaim_ready; heap entries are
        # lazily invalidated against _reclaim_pending.
        self._reclaim_pending: Dict[int, Tuple[float, int]] = {}
        self._reclaim_counted: Dict[int, int] = {}
        self._reclaim_heap: List[Tuple[float, int]] = []
        #: log of hosts whose free capacity increased; consumers remember a
        #: position (``gain_pos``) and later scan the suffix.  Positions are
        #: absolute: ``_gain_base`` counts entries dropped by
        #: :meth:`compact_gain_log`, which bounds memory over long runs.
        self.gain_log: List[int] = []
        self._gain_base = 0
        # scratch buffers for zero-allocation mask computation
        self._scratch_ge = np.zeros((n, N_DIMS), dtype=bool)
        self._scratch_row = np.zeros(n, dtype=bool)
        self._scratch_row2 = np.zeros(n, dtype=bool)
        self._scratch_sum = np.zeros((n, N_DIMS), dtype=np.float64)
        self._scratch_dm = np.zeros(N_DIMS, dtype=np.float64)
        # -- market state (inert until enable_market) ------------------------
        #: capacity pool each host belongs to (region / instance class)
        self.pool_of = np.zeros(n, dtype=np.int64)
        self.n_pools = 1
        self._market_on = False
        #: current clearing price of each host's pool (0.0 = everything
        #: admissible until the engine's first tick)
        self._host_price = np.zeros(n, dtype=np.float64)
        self._scratch_adm = np.zeros(n, dtype=bool)
        # dense registry of RUNNING spot VMs for vectorized wave selection and
        # migration-planner scoring: (bid, pool, min-running-time expiry,
        # vm id, host, cpu demand, remaining work at placement, placement
        # time, pool pin, migration-cooldown expiry) with swap-remove
        self._mk_cap = 0
        self._mk_n = 0
        self._mk_bid = np.zeros(0, dtype=np.float64)
        self._mk_ready = np.zeros(0, dtype=np.float64)
        self._mk_pool = np.zeros(0, dtype=np.int64)
        self._mk_vid = np.zeros(0, dtype=np.int64)
        self._mk_hid = np.zeros(0, dtype=np.int64)
        self._mk_cpu = np.zeros(0, dtype=np.float64)
        self._mk_rem0 = np.zeros(0, dtype=np.float64)
        self._mk_t0 = np.zeros(0, dtype=np.float64)
        self._mk_pin = np.zeros(0, dtype=np.int64)
        self._mk_cd = np.zeros(0, dtype=np.float64)
        self._mk_slot: Dict[int, int] = {}
        #: last prices pushed by the engine (hosts added mid-run inherit them)
        self._pool_prices = np.zeros(1, dtype=np.float64)
        #: migration reservations: vm_id -> (dest host, demand) held in
        #: ``used`` (capacity blocked) but NOT in residents/spot_used/the
        #: registry — a reserved slot is neither wave-interruptible nor
        #: reclaimable, and the in-flight VM is resident nowhere (no
        #: double-counting across source and destination)
        self._reserved: Dict[int, Tuple[int, np.ndarray]] = {}

    # -- structural ---------------------------------------------------------
    def _grow(self, need: int) -> None:
        cap = self.total.shape[0]
        if need <= cap:
            return
        new_cap = max(need, cap * 2)
        pad = new_cap - cap

        def vpad(a, fill=0.0):
            return np.vstack([a, np.full((pad, N_DIMS), fill, dtype=np.float64)])

        self.total = vpad(self.total)
        self.used = vpad(self.used)
        self.spot_used = vpad(self.spot_used)
        self.active = np.concatenate([self.active, np.zeros(pad, dtype=bool)])
        self.residents.extend(dict() for _ in range(pad))
        self._free = vpad(self._free)
        self._spot_frac = vpad(self._spot_frac)
        self._tot_clamped = vpad(self._tot_clamped, _EPS)
        self._rs_tot_cpu = np.concatenate(
            [self._rs_tot_cpu, np.full(pad, _EPS_RS, dtype=np.float64)])
        self._rs_util_cpu = np.concatenate(
            [self._rs_util_cpu, np.zeros(pad, dtype=np.float64)])
        self._reclaim_ready = vpad(self._reclaim_ready)
        self._scratch_ge = np.zeros((new_cap, N_DIMS), dtype=bool)
        self._scratch_row = np.zeros(new_cap, dtype=bool)
        self._scratch_row2 = np.zeros(new_cap, dtype=bool)
        self._scratch_sum = np.zeros((new_cap, N_DIMS), dtype=np.float64)
        self.pool_of = np.concatenate(
            [self.pool_of, np.zeros(pad, dtype=np.int64)])
        self._host_price = np.concatenate(
            [self._host_price, np.zeros(pad, dtype=np.float64)])
        self._scratch_adm = np.zeros(new_cap, dtype=bool)

    def _refresh_static_row(self, hid: int) -> None:
        """Recompute capacity-derived caches (host add / capacity update)."""
        np.maximum(self.total[hid], _EPS, out=self._tot_clamped[hid])
        self._rs_tot_cpu[hid] = max(float(self.total[hid, 0]), _EPS_RS)

    def _refresh_row(self, hid: int, spot_changed: bool = True) -> None:
        """Recompute load-derived caches for one host (place/release path)."""
        if self.active[hid]:
            np.subtract(self.total[hid], self.used[hid], out=self._free[hid])
        else:
            self._free[hid] = 0.0
        if spot_changed:
            np.divide(self.spot_used[hid], self._tot_clamped[hid],
                      out=self._spot_frac[hid])
        self._rs_util_cpu[hid] = self.used[hid, 0] / self._rs_tot_cpu[hid]

    def _log_gain(self, hid: int) -> None:
        if self.active[hid]:
            self.gain_log.append(hid)

    def add_host(self, capacity: np.ndarray, pool: int = 0) -> int:
        """Register a new host (optionally into capacity pool ``pool``);
        returns its id."""
        hid = self.n_hosts
        self._grow(hid + 1)
        self.total[hid] = np.asarray(capacity, dtype=np.float64)
        self.used[hid] = 0.0
        self.spot_used[hid] = 0.0
        self.active[hid] = True
        self.residents[hid] = dict()
        self.n_hosts += 1
        self._reclaim_ready[hid] = 0.0
        assert pool >= 0, f"pool id must be >= 0, got {pool}"
        if self._market_on:
            # fail fast here instead of at an unrelated later tick: the
            # engine's price vector is sized to its pool count
            assert pool < self._pool_prices.size, (
                f"host pool {pool} out of range for the attached market "
                f"engine ({self._pool_prices.size} pools)")
        self.pool_of[hid] = pool
        self.n_pools = max(self.n_pools, pool + 1)
        self._host_price[hid] = (self._pool_prices[pool]
                                 if pool < self._pool_prices.size else 0.0)
        self._refresh_static_row(hid)
        self._refresh_row(hid)
        self._log_gain(hid)
        self.epoch += 1
        return hid

    def update_host(self, hid: int, capacity: np.ndarray) -> None:
        """Trace 'UPDATE' machine event — change host capacity in place."""
        self.total[hid] = np.asarray(capacity, dtype=np.float64)
        self._refresh_static_row(hid)
        self._refresh_row(hid)
        self._log_gain(hid)  # capacity may have grown; rechecks are cheap
        self.epoch += 1

    def remove_host(self, hid: int) -> List[Vm]:
        """Deactivate a host; returns resident VMs (caller decides their fate)."""
        victims = list(self.residents[hid].values())
        self.active[hid] = False
        self._refresh_row(hid)
        self.epoch += 1
        return victims

    def reactivate_host(self, hid: int) -> None:
        self.active[hid] = True
        self._refresh_row(hid)
        self._log_gain(hid)
        self.epoch += 1

    # -- views --------------------------------------------------------------
    @property
    def n(self) -> int:
        return self.n_hosts

    def free(self) -> np.ndarray:
        """(n_hosts, 4) free capacity (inactive hosts report 0 free).

        Returns a cached read-only-by-convention view; do not mutate."""
        return self._free[: self.n]

    def spot_frac_view(self) -> np.ndarray:
        """(n_hosts, 4) spot_used / total (cached)."""
        return self._spot_frac[: self.n]

    def totals(self) -> np.ndarray:
        return self.total[: self.n]

    def used_view(self) -> np.ndarray:
        return self.used[: self.n]

    def spot_used_view(self) -> np.ndarray:
        return self.spot_used[: self.n]

    def active_view(self) -> np.ndarray:
        return self.active[: self.n]

    def reclaim_ready_view(self) -> np.ndarray:
        """(n_hosts, 4) reclaimable (interruptible-now) spot capacity.

        Call :meth:`refresh_reclaim` first so min-running-time expiries up to
        ``now`` are folded in."""
        return self._reclaim_ready[: self.n]

    def cpu_utilization(self) -> np.ndarray:
        tot = self.total[: self.n, 0]
        return np.divide(self.used[: self.n, 0], tot, out=np.zeros(self.n, dtype=np.float64), where=tot > 0)

    def rsdiff_inputs(self) -> Tuple[np.ndarray, np.ndarray]:
        """Cached (clamped cpu totals, cpu utilization) for Eq. 1."""
        return self._rs_tot_cpu[: self.n], self._rs_util_cpu[: self.n]

    # -- feasibility masks (scratch-backed, zero per-call allocation) --------
    def direct_mask_into(self, demand: np.ndarray, bid: float = np.inf,
                         pid: int = -1) -> np.ndarray:
        """Hosts that fit ``demand`` right now (and, in market mode, whose
        pool clears at <= ``bid`` / matches a ``pid`` pin).  Returns a view
        into a scratch buffer — consume (or copy) before the next
        ``*_mask_into`` call."""
        n = self.n
        np.subtract(demand, _EPS, out=self._scratch_dm)
        np.greater_equal(self._free[:n], self._scratch_dm,
                         out=self._scratch_ge[:n])
        np.logical_and.reduce(self._scratch_ge[:n], axis=1,
                              out=self._scratch_row[:n])
        np.logical_and(self._scratch_row[:n], self.active[:n],
                       out=self._scratch_row[:n])
        if (self._market_on and bid != np.inf) or pid >= 0:
            self.market_admit(self._scratch_row[:n], bid, pid)
        return self._scratch_row[:n]

    def clearing_mask_into(self, demand: np.ndarray, bid: float = np.inf,
                           pid: int = -1) -> np.ndarray:
        """Hosts that fit ``demand`` after deallocating interruptible spot VMs
        (§VI-A).  Uses the cached reclaimable sums; callers must
        :meth:`refresh_reclaim` first.  Scratch-backed like
        :meth:`direct_mask_into` (separate buffer, so one direct + one
        clearing mask may be alive simultaneously)."""
        n = self.n
        np.add(self._free[:n], self._reclaim_ready[:n],
               out=self._scratch_sum[:n])
        np.greater_equal(self._scratch_sum[:n], demand - _EPS,
                         out=self._scratch_ge[:n])
        np.logical_and.reduce(self._scratch_ge[:n], axis=1,
                              out=self._scratch_row2[:n])
        np.logical_and(self._scratch_row2[:n], self.active[:n],
                       out=self._scratch_row2[:n])
        if (self._market_on and bid != np.inf) or pid >= 0:
            self.market_admit(self._scratch_row2[:n], bid, pid)
        return self._scratch_row2[:n]

    def direct_idx_into(self, demand: np.ndarray, bid: float = np.inf,
                        pid: int = -1) -> np.ndarray:
        """Candidate host ids fitting ``demand`` (fresh index array; one
        C-level nonzero pass over the scratch mask)."""
        return self.direct_mask_into(demand, bid, pid).nonzero()[0]

    def direct_mask_batch(self, demands: np.ndarray,
                          bids: Optional[np.ndarray] = None,
                          pids: Optional[np.ndarray] = None) -> np.ndarray:
        """(B, n) feasibility matrix for a batch of demands — one vectorized
        comparison for the whole resubmission queue.  ``bids`` / ``pids``
        (per-row bid and pool pin) apply the market admission of
        :meth:`market_admit` row-wise."""
        demands = np.asarray(demands, dtype=np.float64)
        n = self.n
        ok = np.all(self._free[None, :n] >= demands[:, None] - _EPS, axis=2)
        ok &= self.active[:n][None]
        if self._market_on and bids is not None:
            finite = np.isfinite(bids)
            if finite.any():
                ok &= ((self._host_price[None, :n] <= bids[:, None] + _EPS)
                       | ~finite[:, None])
        if pids is not None:
            pinned = pids >= 0
            if pinned.any():
                ok &= ((self.pool_of[None, :n] == pids[:, None])
                       | ~pinned[:, None])
        return ok

    # -- allocation ---------------------------------------------------------
    def fits(self, hid: int, demand: np.ndarray) -> bool:
        return bool(
            self.active[hid]
            and np.all(self.total[hid] - self.used[hid] >= demand - _EPS)
        )

    def fits_fast(self, hid: int, demand: np.ndarray) -> bool:
        """Same predicate as :meth:`fits` via the cached free row and scalar
        compares — the gain-log memo filter calls this per (VM, gained host),
        so it must not pay vectorized-numpy call overhead."""
        if not self.active[hid]:
            return False
        f = self._free[hid]
        for k in range(N_DIMS):
            if f[k] < demand[k] - _EPS:
                return False
        return True

    def place(self, vm: Vm, hid: int, now: float = 0.0) -> None:
        assert self.fits_fast(hid, vm.demand), \
            f"host {hid} cannot fit vm {vm.id}"
        spot = vm.vm_type is VmType.SPOT
        self.used[hid] += vm.demand
        if spot:
            self.spot_used[hid] += vm.demand
            self._register_reclaim(vm, hid, now)
            if self._market_on:
                self._mk_add(vm, hid, now)
        self.residents[hid][vm.id] = vm
        vm.host = hid
        self._refresh_row(hid, spot_changed=spot)
        self.epoch += 1

    def release(self, vm: Vm) -> None:
        hid = vm.host
        assert hid >= 0 and vm.id in self.residents[hid], (
            f"vm {vm.id} not resident on host {hid}"
        )
        spot = vm.vm_type is VmType.SPOT
        self.used[hid] -= vm.demand
        # numerical hygiene: clamp tiny negatives from float accumulation
        np.maximum(self.used[hid], 0.0, out=self.used[hid])
        if spot:
            self.spot_used[hid] -= vm.demand
            self._drop_reclaim(vm, hid)
            if self._market_on:
                self._mk_drop(vm.id)
            np.maximum(self.spot_used[hid], 0.0, out=self.spot_used[hid])
        del self.residents[hid][vm.id]
        vm.host = -1
        self._refresh_row(hid, spot_changed=spot)
        self._log_gain(hid)
        self.epoch += 1

    def spot_vms_on(self, hid: int) -> List[Vm]:
        """Resident spot VMs in insertion order (CloudSim host-VM-list order)."""
        return [v for v in self.residents[hid].values() if v.is_spot]

    # -- reclaimable-capacity index ------------------------------------------
    def _register_reclaim(self, vm: Vm, hid: int, now: float) -> None:
        if vm.min_running_time <= 0.0:
            self._reclaim_ready[hid] += vm.demand
            self._reclaim_counted[vm.id] = hid
        else:
            ready = now + vm.min_running_time
            self._reclaim_pending[vm.id] = (ready, hid)
            heapq.heappush(self._reclaim_heap, (ready, vm.id))

    def _drop_reclaim(self, vm: Vm, hid: int) -> None:
        counted = self._reclaim_counted.pop(vm.id, None)
        if counted is not None:
            self._reclaim_ready[hid] -= vm.demand
            np.clip(self._reclaim_ready[hid], 0.0, None,
                    out=self._reclaim_ready[hid])
        else:
            self._reclaim_pending.pop(vm.id, None)

    def mark_uninterruptible(self, vm: Vm) -> None:
        """Remove a still-resident spot VM from the reclaimable pool (it has
        left RUNNING, e.g. received an interruption warning)."""
        if vm.host >= 0:
            self._drop_reclaim(vm, vm.host)
            if self._market_on:
                self._mk_drop(vm.id)
            self.epoch += 1

    def refresh_reclaim(self, now: float) -> None:
        """Fold min-running-time expiries up to ``now`` into the reclaimable
        sums.  O(expired log n); O(1) when nothing expired."""
        heap = self._reclaim_heap
        while heap and heap[0][0] <= now:
            ready, vid = heapq.heappop(heap)
            ent = self._reclaim_pending.get(vid)
            if ent is None or ent[0] != ready:
                continue  # stale heap entry (VM released / re-placed)
            del self._reclaim_pending[vid]
            hid = ent[1]
            vm = self.residents[hid].get(vid)
            if vm is None or not vm.is_spot or vm.state is not VmState.RUNNING:
                continue
            self._reclaim_ready[hid] += vm.demand
            self._reclaim_counted[vid] = hid
            self.epoch += 1

    # -- market mode ---------------------------------------------------------
    def enable_market(self, n_pools: int) -> None:
        """Switch on price admission + the wave-selection registry.  Must be
        called before any spot VM is placed (the registry mirrors placements
        from this point on)."""
        assert self._mk_n == 0 and not any(
            v.is_spot for r in self.residents[: self.n] for v in r.values()
        ), "enable_market must precede spot placements"
        assert int(self.pool_of[: self.n].max(initial=-1)) < n_pools, (
            "existing hosts reference pools beyond the engine's pool count")
        self._market_on = True
        self.n_pools = max(self.n_pools, n_pools)
        if self._pool_prices.size < self.n_pools:
            self._pool_prices = np.zeros(self.n_pools, dtype=np.float64)

    @property
    def market_on(self) -> bool:
        return self._market_on

    def set_pool_prices(self, prices: np.ndarray) -> None:
        """Push per-pool clearing prices down to the per-host price row.

        A price *drop* re-opens hosts to queued spot VMs whose bid now
        clears; those hosts are appended to the gain log so the resubmission
        memo rechecks exactly the VMs that might benefit (``fits_fast`` is
        capacity-only, which is conservative but correct: the full mask still
        applies price admission).  Price rises only shrink masks, so existing
        memos stay valid.
        """
        prices = np.asarray(prices, dtype=np.float64)
        n = self.n
        self._pool_prices = prices.copy()
        new = prices[self.pool_of[:n]]
        np.less(new, self._host_price[:n] - 1e-15, out=self._scratch_adm[:n])
        np.logical_and(self._scratch_adm[:n], self.active[:n],
                       out=self._scratch_adm[:n])
        if self._scratch_adm[:n].any():
            self.gain_log.extend(np.flatnonzero(self._scratch_adm[:n]).tolist())
        self._host_price[:n] = new
        self.epoch += 1

    def market_admit(self, row_mask: np.ndarray, bid: float,
                     pid: int) -> np.ndarray:
        """AND market admission into ``row_mask`` in place: hosts whose pool
        clears at <= ``bid`` (skipped for infinite bids / market off) and —
        when ``pid >= 0`` — hosts belonging to pool ``pid``."""
        n = self.n
        if self._market_on and bid != np.inf:
            np.less_equal(self._host_price[:n], bid + _EPS,
                          out=self._scratch_adm[:n])
            np.logical_and(row_mask, self._scratch_adm[:n], out=row_mask)
        if pid >= 0:
            np.equal(self.pool_of[:n], pid, out=self._scratch_adm[:n])
            np.logical_and(row_mask, self._scratch_adm[:n], out=row_mask)
        return row_mask

    def pool_cpu_utilization(self) -> np.ndarray:
        """(n_pools,) CPU utilization per capacity pool over active hosts —
        the demand signal driving each pool's price process."""
        n = self.n
        act = self.active[:n]
        pools = self.pool_of[:n][act]
        used = np.bincount(pools, weights=self.used[:n, 0][act],
                           minlength=self.n_pools)
        tot = np.bincount(pools, weights=self.total[:n, 0][act],
                          minlength=self.n_pools)
        return np.divide(used, tot, out=np.zeros(self.n_pools, dtype=np.float64),
                         where=tot > 0)

    # -- market registry (vectorized wave selection) -------------------------
    def _mk_grow(self, need: int) -> None:
        if need <= self._mk_cap:
            return
        cap = max(need, max(self._mk_cap * 2, 64))

        def pad(a, dtype):
            out = np.zeros(cap, dtype=dtype)
            out[: a.size] = a
            return out

        self._mk_bid = pad(self._mk_bid, np.float64)
        self._mk_ready = pad(self._mk_ready, np.float64)
        self._mk_pool = pad(self._mk_pool, np.int64)
        self._mk_vid = pad(self._mk_vid, np.int64)
        self._mk_hid = pad(self._mk_hid, np.int64)
        self._mk_cpu = pad(self._mk_cpu, np.float64)
        self._mk_rem0 = pad(self._mk_rem0, np.float64)
        self._mk_t0 = pad(self._mk_t0, np.float64)
        self._mk_pin = pad(self._mk_pin, np.int64)
        self._mk_cd = pad(self._mk_cd, np.float64)
        self._mk_cap = cap

    def _mk_add(self, vm: Vm, hid: int, now: float) -> None:
        i = self._mk_n
        self._mk_grow(i + 1)
        self._mk_bid[i] = vm.bid
        self._mk_ready[i] = now + vm.min_running_time
        self._mk_pool[i] = self.pool_of[hid]
        self._mk_vid[i] = vm.id
        self._mk_hid[i] = hid
        self._mk_cpu[i] = vm.demand[0]
        self._mk_rem0[i] = vm.remaining
        self._mk_t0[i] = now
        self._mk_pin[i] = vm.pool
        self._mk_cd[i] = vm.migrate_cooldown_until
        self._mk_slot[vm.id] = i
        self._mk_n = i + 1

    def _mk_drop(self, vid: int) -> None:
        i = self._mk_slot.pop(vid, None)
        if i is None:
            return
        last = self._mk_n - 1
        if i != last:  # swap-remove keeps the arrays dense
            self._mk_bid[i] = self._mk_bid[last]
            self._mk_ready[i] = self._mk_ready[last]
            self._mk_pool[i] = self._mk_pool[last]
            self._mk_hid[i] = self._mk_hid[last]
            self._mk_cpu[i] = self._mk_cpu[last]
            self._mk_rem0[i] = self._mk_rem0[last]
            self._mk_t0[i] = self._mk_t0[last]
            self._mk_pin[i] = self._mk_pin[last]
            self._mk_cd[i] = self._mk_cd[last]
            moved = int(self._mk_vid[last])
            self._mk_vid[i] = moved
            self._mk_slot[moved] = i
        self._mk_n = last

    def market_registry(self) -> Dict[str, np.ndarray]:
        """Read-only views of the dense RUNNING-spot registry, length
        ``_mk_n`` — the migration planner's scoring input.  Valid until the
        next pool mutation; do not hold across events."""
        m = self._mk_n
        return {
            "vid": self._mk_vid[:m], "bid": self._mk_bid[:m],
            "pool": self._mk_pool[:m], "hid": self._mk_hid[:m],
            "cpu": self._mk_cpu[:m], "rem0": self._mk_rem0[:m],
            "t0": self._mk_t0[:m], "ready": self._mk_ready[:m],
            "pin": self._mk_pin[:m], "cooldown": self._mk_cd[:m],
        }

    def market_victims(self, prices: np.ndarray,
                       now: float) -> Tuple[np.ndarray, np.ndarray]:
        """(victim vm ids, their pools): running spot VMs past their minimum
        running time whose bid is strictly below their pool's clearing price.
        One masked comparison over the dense registry — no per-VM walk."""
        m = self._mk_n
        if m == 0:
            empty = np.zeros(0, dtype=np.int64)
            return empty, empty
        pools = self._mk_pool[:m]
        mask = self._mk_bid[:m] < np.asarray(prices, float)[pools] - _EPS
        mask &= self._mk_ready[:m] <= now + _EPS
        return self._mk_vid[:m][mask].copy(), pools[mask].copy()

    # -- migration reservations ----------------------------------------------
    def reserve(self, vm: Vm, hid: int) -> None:
        """Hold ``vm.demand`` on ``hid`` for an in-flight migration.  The
        capacity is blocked in ``used`` (feasibility masks and the pool
        utilization signal see it) but the VM is resident nowhere: not in
        ``residents``/``spot_used``, not reclaimable, not wave-interruptible.
        """
        assert vm.id not in self._reserved, f"vm {vm.id} already reserved"
        assert self.fits_fast(hid, vm.demand), (
            f"host {hid} cannot hold reservation for vm {vm.id}")
        self.used[hid] += vm.demand
        self._reserved[vm.id] = (hid, vm.demand.copy())
        self._refresh_row(hid, spot_changed=False)
        self.epoch += 1

    def release_reservation(self, vm_id: int) -> int:
        """Drop a migration reservation (arrival commit or failed flight);
        returns the host it was held on."""
        hid, demand = self._reserved.pop(vm_id)
        self.used[hid] -= demand
        np.maximum(self.used[hid], 0.0, out=self.used[hid])
        self._refresh_row(hid, spot_changed=False)
        self._log_gain(hid)
        self.epoch += 1
        return hid

    def stamp_migration_cooldown(self, vm: Vm, until: float) -> None:
        """Black the VM out of migration planning until ``until``, updating
        the live registry row in place (the column is otherwise only read
        from the VM at placement time).  Used when a planned move finds no
        destination host — without the stamp, a pool-level-feasible but
        host-level-infeasible VM would re-top the plan ranking every tick."""
        vm.migrate_cooldown_until = until
        i = self._mk_slot.get(vm.id)
        if i is not None:
            self._mk_cd[i] = until

    def price_clears(self, hid: int, bid: float) -> bool:
        """Does ``hid``'s pool currently clear at <= ``bid``?  (Always true
        with the market off or an infinite bid.)"""
        if not self._market_on or bid == np.inf:
            return True
        return bool(self._host_price[hid] <= bid + _EPS)

    def pool_free_cpu(self) -> np.ndarray:
        """(n_pools,) free CPU per capacity pool over active hosts — the
        migration planner's destination-headroom signal (reservations are
        already inside ``used``, hence excluded from ``free``)."""
        n = self.n
        act = self.active[:n]
        return np.bincount(self.pool_of[:n][act],
                           weights=self._free[:n, 0][act],
                           minlength=self.n_pools)

    def pool_total_cpu(self) -> np.ndarray:
        """(n_pools,) total CPU per capacity pool over active hosts — the
        denominator of the planner's price-impact estimate."""
        n = self.n
        act = self.active[:n]
        return np.bincount(self.pool_of[:n][act],
                           weights=self.total[:n, 0][act],
                           minlength=self.n_pools)

    # -- gain log ------------------------------------------------------------
    def gain_pos(self) -> int:
        """Current (absolute) position in the gain log; pass to
        :meth:`gained_since`."""
        return self._gain_base + len(self.gain_log)

    def gained_since(self, pos: int) -> List[int]:
        """Host ids whose free capacity increased since ``pos``."""
        start = pos - self._gain_base
        if start <= 0:
            return self.gain_log[:]
        return self.gain_log[start:]

    def compact_gain_log(self, min_live_pos: int) -> None:
        """Drop log entries before ``min_live_pos`` (the smallest position any
        consumer still holds).  Keeps memory bounded over trace-length runs;
        absolute positions remain valid."""
        drop = min(min_live_pos - self._gain_base, len(self.gain_log))
        if drop > 0:
            del self.gain_log[:drop]
            self._gain_base += drop

    # -- invariant checks (used by property tests) ---------------------------
    def check_invariants(self, now: Optional[float] = None) -> None:
        n = self.n
        reserved_sum = np.zeros((n, N_DIMS), dtype=np.float64)
        for _vid, (rhid, dem) in self._reserved.items():
            reserved_sum[rhid] += dem
        for hid in range(n):
            res = sum(
                (v.demand for v in self.residents[hid].values()),
                np.zeros(N_DIMS, dtype=np.float64),
            ) + reserved_sum[hid]
            assert np.allclose(res, self.used[hid], atol=1e-6), (
                f"host {hid}: used {self.used[hid]} != resident+reserved sum "
                f"{res}"
            )
            spot = sum(
                (v.demand for v in self.residents[hid].values() if v.is_spot),
                np.zeros(N_DIMS, dtype=np.float64),
            )
            assert np.allclose(spot, self.spot_used[hid], atol=1e-6)
            assert np.all(self.used[hid] <= self.total[hid] + 1e-6), (
                f"host {hid} over capacity: {self.used[hid]} > {self.total[hid]}"
            )
        # cached arrays vs from-scratch recomputation
        f = np.where(self.active[:n, None], self.total[:n] - self.used[:n], 0.0)
        assert np.allclose(f, self._free[:n], atol=1e-9), "stale free cache"
        sf = self.spot_used[:n] / np.maximum(self.total[:n], _EPS)
        assert np.allclose(sf, self._spot_frac[:n], atol=1e-12), (
            "stale spot_frac cache")
        tc = np.maximum(self.total[:n, 0], _EPS_RS)
        assert np.allclose(tc, self._rs_tot_cpu[:n])
        assert np.allclose(self.used[:n, 0] / tc, self._rs_util_cpu[:n])
        # reclaim index: every counted VM is a resident spot VM; per-host sums
        # match; every RUNNING resident spot VM is tracked exactly once
        ready_sum = np.zeros((n, N_DIMS), dtype=np.float64)
        for vid, hid in self._reclaim_counted.items():
            vm = self.residents[hid].get(vid)
            assert vm is not None and vm.is_spot, (
                f"reclaim-counted vm {vid} not a resident spot VM of {hid}")
            ready_sum[hid] += vm.demand
        assert np.allclose(ready_sum, self._reclaim_ready[:n], atol=1e-6), (
            "stale reclaim_ready cache")
        for hid in range(n):
            for vm in self.residents[hid].values():
                if vm.is_spot and vm.state is VmState.RUNNING:
                    assert (vm.id in self._reclaim_counted
                            or vm.id in self._reclaim_pending), (
                        f"running spot vm {vm.id} missing from reclaim index")
        if now is not None:
            self.refresh_reclaim(now)
            for hid in range(n):
                expect = sum(
                    (v.demand for v in self.residents[hid].values()
                     if v.interruptible(now)),
                    np.zeros(N_DIMS, dtype=np.float64),
                )
                assert np.allclose(expect, self._reclaim_ready[hid],
                                   atol=1e-6), (
                    f"host {hid}: reclaimable {self._reclaim_ready[hid]} != "
                    f"interruptible sum {expect} at t={now}")
        if self._market_on:
            # market registry mirrors RUNNING resident spot VMs exactly
            assert len(self._mk_slot) == self._mk_n
            for vid, i in self._mk_slot.items():
                assert int(self._mk_vid[i]) == vid
            running = {v.id for hid in range(n)
                       for v in self.residents[hid].values()
                       if v.is_spot and v.state is VmState.RUNNING}
            assert set(self._mk_slot) == running, (
                f"market registry {set(self._mk_slot)} != running spot "
                f"{running}")
            for hid in range(n):
                for v in self.residents[hid].values():
                    if v.id in self._mk_slot:
                        i = self._mk_slot[v.id]
                        assert self._mk_bid[i] == v.bid
                        assert int(self._mk_pool[i]) == int(self.pool_of[hid])
                        assert int(self._mk_hid[i]) == hid
                        assert self._mk_cpu[i] == v.demand[0]
                        assert int(self._mk_pin[i]) == v.pool
                        assert self._mk_cd[i] == v.migrate_cooldown_until
