"""Discrete-event machinery (paper §II-C, Fig. 1; CloudSim's future event queue).

Events carry a (time, priority, seq) ordering key: ties at the same timestamp
are broken first by priority (deallocation before allocation, so capacity freed
at time t is visible to requests arriving at t) and then FIFO by sequence
number — deterministic replay is a hard requirement for the paper's
"same randomized values reused across all simulation runs" methodology (§VII-E2).
"""
from __future__ import annotations

import enum
import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Optional


class EventKind(enum.Enum):
    VM_SUBMIT = "vm-submit"
    VM_FINISH = "vm-finish"
    WAIT_EXPIRE = "wait-expire"
    HIBERNATION_EXPIRE = "hibernation-expire"
    INTERRUPT_COMMIT = "interrupt-commit"   # end of the warning period
    PRICE_TICK = "price-tick"               # market engine reprice + wave scan
    MIGRATE_START = "migrate-start"         # planner-chosen VM leaves its host
    MIGRATE_COMPLETE = "migrate-complete"   # end of the stop-and-copy window
    HOST_ADD = "host-add"
    HOST_REMOVE = "host-remove"
    HOST_UPDATE = "host-update"
    SERVE_TICK = "serve-tick"               # serving loop: arrivals + decode
    AUTOSCALE = "autoscale"                 # autoscaler control cadence


# lower = processed earlier at equal timestamps
PRIORITY = {
    EventKind.HOST_ADD: 0,
    EventKind.HOST_UPDATE: 0,
    EventKind.VM_FINISH: 1,
    EventKind.INTERRUPT_COMMIT: 2,
    # a migration arrival is an allocation: process after same-time finishes
    # and wave commits so it sees settled capacity
    EventKind.MIGRATE_COMPLETE: 2,
    EventKind.HOST_REMOVE: 3,
    EventKind.HIBERNATION_EXPIRE: 4,
    EventKind.WAIT_EXPIRE: 5,
    # reprice after deallocations/expiries at t, before new submissions at t
    # see the fresh price (ties with WAIT_EXPIRE break FIFO by seq)
    EventKind.PRICE_TICK: 5,
    EventKind.VM_SUBMIT: 6,
    # migrations are opportunistic: same-time fresh submissions claim
    # capacity first, the start handler re-validates its reservation target
    EventKind.MIGRATE_START: 7,
    # the serving loop observes fully settled same-time state (post-wave,
    # post-flush, post-fleet); the autoscaler reads the serve tick's fresh
    # signals, so it sorts after SERVE_TICK at coincident timestamps
    EventKind.SERVE_TICK: 8,
    EventKind.AUTOSCALE: 9,
}


@dataclass(order=True)
class Event:
    time: float
    priority: int
    seq: int
    kind: EventKind = field(compare=False)
    payload: Any = field(compare=False, default=None)
    #: generation stamp — stale events (VM re-allocated since scheduling) are
    #: dropped at dispatch; mirrors CloudSim's event cancellation.
    generation: int = field(compare=False, default=-1)


class EventQueue:
    """Future event queue ordered by (time, priority, seq).

    The heap holds plain key tuples (C-speed comparisons; the unique ``seq``
    guarantees the Event itself is never compared) — at trace scale heap
    sifting is a measurable slice of the event loop."""

    def __init__(self) -> None:
        self._heap: list[tuple] = []
        self._seq = itertools.count()

    def push(self, time: float, kind: EventKind, payload: Any = None,
             generation: int = -1) -> Event:
        ev = Event(time, PRIORITY[kind], next(self._seq), kind, payload, generation)
        heapq.heappush(self._heap, (ev.time, ev.priority, ev.seq, ev))
        return ev

    def pop(self) -> Optional[Event]:
        return heapq.heappop(self._heap)[3] if self._heap else None

    def peek_time(self) -> Optional[float]:
        return self._heap[0][0] if self._heap else None

    def __len__(self) -> int:
        return len(self._heap)
