"""repro.core — the paper's contribution: dynamic spot-market simulation.

Public API:
  MarketSimulator, SimConfig — discrete-event spot-market engine (§V)
  allocation policies        — FirstFit/BestFit/WorstFit/HLEM-VMP/adjusted (§VI)
  hlem scoring               — numpy oracle + jitted JAX (Eqs. 1-11)
  workload generators        — §VII-E synthetic scenario, random fleets
  metrics & table builders   — §V-E reporting
"""
from .allocation import (
    AllocationPolicy,
    BestFit,
    FirstFit,
    HlemVmp,
    HlemVmpAdjusted,
    POLICIES,
    POLICY_REGISTRY,
    WorstFit,
    clearing_mask,
    direct_mask,
    make_policy,
    register_policy,
)
from .registry import Registry
from .hlem import (
    hlem_scores_batch_jax,
    hlem_scores_batch_np,
    hlem_scores_jax,
    hlem_scores_np,
    hlem_select_batch_jax,
    hlem_select_jax,
    hlem_select_np,
    hlem_weights_np,
    rsdiff_np,
)
from .hosts import HostPool
from .metrics import (
    InterruptionEvent,
    Metrics,
    MigrationEvent,
    WaveEvent,
    dynamic_vm_table,
    execution_table,
    spot_vm_table,
    to_csv,
    to_json,
)
from .simulator import MarketSimulator, SimConfig
from .types import (
    InterruptionBehavior,
    N_DIMS,
    RESOURCE_DIMS,
    Vm,
    VmState,
    VmType,
    make_on_demand,
    make_spot,
    resources,
)
from .workload import (
    HOST_COUNTS,
    HOST_TYPES,
    VM_PROFILES,
    MarketScenarioConfig,
    ScenarioConfig,
    build_hosts,
    market_scenario,
    random_fleet,
    random_vms,
    synthetic_scenario,
)

__all__ = [k for k in dir() if not k.startswith("_")]
