"""The dynamic marketspace simulator (paper §V).

Implements the full spot-instance lifecycle of Fig. 4 on top of a discrete
event queue: persistent requests, capacity-driven interruption with a warning
period, TERMINATE/HIBERNATE behaviors, minimum running time, hibernation
timeout, waiting timeout, resubmission on deallocation, and dynamic host
add/remove (trace machine events).

Design notes vs. the Java original:
* Victim selection during preemption is configurable (``interruption_selector``)
  instead of the original's non-deterministic host-VM-list order — ``list_order``
  reproduces the paper's behavior; ``best_fit_remaining`` / ``max_progress`` are
  deterministic beyond-paper strategies (the paper's own §IX future-work item).
* Resubmission is triggered on every deallocation (the paper's
  onHostDeallocationListener variant) in the order: waiting on-demand →
  waiting spot → hibernated spot (configurable).

Trace-scale performance (§VII-D1): the resubmission pass is *batched* —
one feasibility matrix and one batched scoring call decide the whole queue,
and a gain-log memo skips VMs whose placement cannot have become feasible
since their last failed attempt (only hosts whose free capacity has since
*increased* need rechecking).  ``SimConfig.flush_mode = "per_vm"`` selects the
original one-VM-at-a-time loop, kept as the decision-identical reference the
batched path is regression-tested against.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from .allocation import AllocationPolicy, FirstFit
from .causes import InterruptionCause
from .events import Event, EventKind, EventQueue
from .hosts import HostPool
from .metrics import (FaultRecord, InterruptionEvent, Metrics,
                      MigrationEvent, WaveEvent)
from ..obs.eventlog import NULL_RECORDER
from ..obs.tracer import NULL_TRACER
from .types import (
    ExecutionInterval,
    Vm,
    VmState,
    VmType,
)

_EPS = 1e-9


@dataclass
class SimConfig:
    warning_time: float = 0.0              # grace period before interruption
    interruption_selector: str = "list_order"  # | best_fit_remaining | max_progress
    resubmit_order: tuple = ("waiting_od", "waiting_spot", "hibernated")
    max_time: float = float("inf")
    record_timeline: bool = True
    strict_invariants: bool = False        # re-check host accounting each event
    flush_mode: str = "batched"            # | "per_vm" (legacy reference path)


class MarketSimulator:
    """Discrete-event spot-market simulator."""

    def __init__(self, policy: Optional[AllocationPolicy] = None,
                 config: Optional[SimConfig] = None,
                 engine=None, migration=None, rebid=None,
                 fleet=None, faults=None, serve=None, obs=None,
                 events=None):
        """``engine`` — optional :class:`repro.market.engine.MarketEngine`.
        When attached, the simulator runs periodic PRICE_TICK events: each
        tick re-clears every capacity pool's price from live utilization,
        interrupts resident spot VMs whose bid the price crossed (a
        vectorized *interruption wave*), and re-flushes the queue so victims
        can reallocate into cheaper pools.  Engines are stateful (price
        processes, cost integrals): use a fresh engine per run.  With
        ``engine=None`` every code path is bit-identical to the engine-less
        simulator.

        ``migration`` — optional
        :class:`repro.market.migration.MigrationPlanner`.  Runs after each
        tick's wave + flush and emits batched MIGRATE_START →
        MIGRATE_COMPLETE moves toward cheaper pools.  A planner with policy
        ``"none"`` (or ``migration=None``) leaves every run bit-identical to
        a planner-less simulator.

        ``rebid`` — optional :class:`repro.market.bids.RebidOnResume`:
        adaptive re-bidding applied when a spot VM enters hibernation, so it
        resubmits with a (seeded, randomized) higher bid.  Off by default.

        ``fleet`` — optional :class:`repro.market.fleet.FleetManager`.  Runs
        at the end of each PRICE_TICK (post-wave, post-flush, post-planner):
        it samples the fleet's live capacity, and launches replacements for
        dead slots through its fallback ladder.  ``fleet=None`` is
        bit-identical to a fleet-less simulator.

        ``faults`` — optional :class:`repro.market.faults.FaultInjector`.
        Each PRICE_TICK first advances the fault schedule: pool outages
        deactivate/reactivate their hosts, crunch/spike windows bias the
        engine's tick inputs, and interruption storms reclaim resident spot
        VMs right after the normal price wave.  ``faults=None`` is
        bit-identical to a fault-less simulator.

        ``serve`` — optional :class:`repro.serve.service.ServeManager`.
        Adds two self-scheduling event chains: SERVE_TICK (demand arrivals,
        request dispatch onto live fleet capacity, decode progress) and —
        when the manager carries an autoscaler — AUTOSCALE (damped
        target-capacity decisions applied to the fleet).  Interrupted or
        finished serving VMs requeue their in-flight requests through the
        ordinary lifecycle listeners.  ``serve=None`` is bit-identical to a
        serve-less simulator.

        ``obs`` — optional :class:`repro.obs.tracer.Tracer`.  When enabled,
        the event loop runs a traced variant that records a span per
        dispatch, per-kind/per-cause counters, and cadence counter
        snapshots; subsystem tick phases add nested spans.  The tracer is
        observation-only (no randomness, no state mutation), so metrics
        are identical with or without it; ``obs=None`` selects the plain
        untraced loop with zero added per-event work.

        ``events`` — optional :class:`repro.obs.eventlog.EventLog`: the
        structured flight recorder.  Every lifecycle and market transition
        emits one record (guarded by ``events.enabled`` — a single
        attribute load when off); like the tracer it is observation-only,
        so logged and unlogged runs produce byte-identical metrics."""
        self.policy = policy or FirstFit()
        self.obs = obs if obs is not None else NULL_TRACER
        self.events = events if events is not None else NULL_RECORDER
        self.config = config or SimConfig()
        assert self.config.flush_mode in ("batched", "per_vm")
        self.pool = HostPool()
        self.engine = engine
        self.migration = migration
        if migration is not None and migration.config.policy != "none":
            assert engine is not None, (
                "a migration planner (policy != 'none') requires a market "
                "engine — prices drive the scoring")
        self._rebid = rebid
        self.fleet = fleet
        self.faults = faults
        if fleet is not None:
            assert engine is not None, (
                "a fleet manager requires a market engine — pool prices "
                "drive admission and the fallback ladder")
        if faults is not None:
            assert engine is not None, (
                "a fault injector requires a market engine — faults flow "
                "through the PRICE_TICK machinery")
            assert faults.n_pools == engine.n_pools, (
                f"fault injector covers {faults.n_pools} pools, engine has "
                f"{engine.n_pools}")
        self.serve = serve
        if serve is not None:
            assert engine is not None, (
                "a serve manager requires a market engine — serving "
                "capacity is live spot VMs priced by the market")
        # transient pool outages: fault-event index -> deactivated host ids
        self._outage_hosts: Dict[int, List[int]] = {}
        # storms that fired at the current tick, applied after the wave
        self._storms_due: List = []
        # in-flight migrations: vm_id -> its MigrationEvent, plus a per-pool
        # arrival counter feeding the risk-budgeted planner
        self._migrating: Dict[int, MigrationEvent] = {}
        self._mig_inflight = np.zeros(
            engine.n_pools if engine is not None else 1, dtype=np.int64)
        self.queue = EventQueue()
        self.vms: Dict[int, Vm] = {}
        self.metrics = Metrics()
        self.now = 0.0
        self._waiting_od: Dict[int, Vm] = {}
        self._waiting_spot: Dict[int, Vm] = {}
        self._hibernated: Dict[int, Vm] = {}
        # hosts with a pending interruption commit: host -> reserved VM ids
        self._pending_victims: Dict[int, List[int]] = {}
        # gain-log position at a queued VM's last failed full placement test;
        # absent = never tested against current membership (full check needed)
        self._retry_pos: Dict[int, int] = {}
        self.listeners: Dict[str, List[Callable]] = {}
        self._next_vm_id = 0
        self._run_limit = self.config.max_time
        self._tick_armed = False
        if engine is not None:
            self.pool.enable_market(engine.n_pools)
            self._arm_tick(0.0)
        if serve is not None:
            # start the serving chain one serve tick in (arrivals integrate
            # the demand curve over (0, tick]); the autoscale chain one
            # control period in.  VM-loss requeue rides the ordinary
            # lifecycle listeners — serve-less runs keep `listeners` empty.
            self.queue.push(serve.config.tick, EventKind.SERVE_TICK)
            if serve.autoscaler is not None:
                self.queue.push(serve.autoscaler.config.cadence,
                                EventKind.AUTOSCALE)
            self.on("vm_interrupted", serve.on_vm_interrupted)
            self.on("vm_finished", serve.on_vm_finished)

    def _arm_tick(self, t: float) -> None:
        """(Re)start the PRICE_TICK chain.  The chain stops itself when the
        simulator goes fully idle, so every entry point that can introduce
        new activity (submit, scheduled host events) must re-arm it —
        otherwise later-submitted VMs would be admitted against frozen
        prices."""
        if self.engine is not None and not self._tick_armed:
            self._tick_armed = True
            self.queue.push(max(t, self.now), EventKind.PRICE_TICK)

    # ------------------------------------------------------------------ setup
    def add_host(self, capacity: np.ndarray, pool: int = 0) -> int:
        return self.pool.add_host(capacity, pool)

    def on(self, event_name: str, fn: Callable) -> None:
        """Register an event listener (CloudSim Plus EventListener analogue).

        Names: vm_allocated, vm_deallocated, vm_interrupted, vm_finished,
        vm_failed, clock_tick."""
        self.listeners.setdefault(event_name, []).append(fn)

    def _emit(self, name: str, **kw) -> None:
        if not self.listeners:
            return
        for fn in self.listeners.get(name, ()):
            fn(sim=self, time=self.now, **kw)

    def submit(self, vm: Vm) -> None:
        """Submit a VM at ``vm.submit_time`` (broker submitVm)."""
        assert vm.id not in self.vms, f"duplicate vm id {vm.id}"
        self.vms[vm.id] = vm
        self.queue.push(vm.submit_time, EventKind.VM_SUBMIT, vm.id)
        self._arm_tick(vm.submit_time)

    def new_vm_id(self) -> int:
        while self._next_vm_id in self.vms:
            self._next_vm_id += 1
        vid = self._next_vm_id
        self._next_vm_id += 1
        return vid

    def schedule_host_add(self, time: float, capacity: np.ndarray,
                          pool: int = 0) -> None:
        self.queue.push(time, EventKind.HOST_ADD,
                        (np.asarray(capacity, float), pool))
        self._arm_tick(time)

    def schedule_host_remove(self, time: float, hid: int) -> None:
        self.queue.push(time, EventKind.HOST_REMOVE, hid)
        self._arm_tick(time)

    def schedule_host_update(self, time: float, hid: int, capacity) -> None:
        self.queue.push(time, EventKind.HOST_UPDATE,
                        (hid, np.asarray(capacity, float)))
        self._arm_tick(time)

    # ----------------------------------------------------------- transitions
    def _set_state(self, vm: Vm, new: VmState) -> None:
        """Single funnel for VM state changes — keeps the metrics' incremental
        state counters exact (replaces the per-event full-VM scan)."""
        old = vm.state
        if old is new:
            return
        self.metrics.on_transition(vm, old, new)
        vm.state = new

    # ------------------------------------------------------------------- run
    def run(self, until: Optional[float] = None) -> Metrics:
        limit = until if until is not None else self.config.max_time
        self._run_limit = limit
        heap = self.queue._heap  # hot loop: skip peek/pop wrapper calls
        if (self.engine is not None and not self._tick_armed
                and (heap or sum(self.metrics.state_counts[1:]) > 0)):
            # the chain stopped in a previous run (idle, or queued-only
            # state under an unbounded horizon); resume it for this run
            self._arm_tick(self.now)
        if self.obs.enabled:
            return self._run_traced(limit)
        heappop = heapq.heappop
        strict = self.config.strict_invariants
        while heap and heap[0][0] <= limit:
            ev = heappop(heap)[3]
            self.now = ev.time
            self._dispatch(ev)
            if strict:
                self.pool.check_invariants(self.now)
        self.now = min(limit, self.now) if limit != float("inf") else self.now
        return self.metrics

    def _run_traced(self, limit: float) -> Metrics:
        """Traced twin of the ``run`` hot loop: a ``dispatch/<kind>`` span
        and per-kind counter per event, plus cadence counter snapshots.
        Kept separate so the untraced loop carries zero added per-event
        work — selecting the loop body happens once per ``run`` call."""
        heap = self.queue._heap
        heappop = heapq.heappop
        strict = self.config.strict_invariants
        tr = self.obs
        counters = tr.counters
        inc = counters.inc
        while heap and heap[0][0] <= limit:
            ev = heappop(heap)[3]
            t = ev.time
            self.now = t
            kind_name = ev.kind.value
            inc("events/total")
            inc("events/" + kind_name)
            tr.begin("event-loop", "dispatch/" + kind_name)
            try:
                self._dispatch(ev)
            except BaseException:
                # a handler (or a listener it called) raised mid-span:
                # close every open span so the stack stays well-nested and
                # the truncated trace still exports as valid Chrome JSON
                tr.unwind(t)
                raise
            tr.end(t, None)
            if tr.counters_due(t):
                tr.snapshot(t, self._obs_gauges())
            if strict:
                self.pool.check_invariants(self.now)
        self.now = min(limit, self.now) if limit != float("inf") else self.now
        # closing snapshot so the counter timeseries always covers run end
        tr.snapshot(self.now, self._obs_gauges())
        return self.metrics

    def _obs_gauges(self) -> Dict[str, float]:
        """Point-in-time gauges merged into each counter snapshot."""
        c = self.metrics.state_counts
        pool = self.pool
        return {
            "gauge/queue_depth": len(self.queue._heap),
            "gauge/registry_size": getattr(pool, "_mk_n", 0) or 0,
            "gauge/running_spot": c[1],
            "gauge/running_od": c[2],
            "gauge/waiting": c[3],
            "gauge/hibernated": c[4],
            "gauge/hosts_active": int(np.count_nonzero(pool.active[:pool.n])),
        }

    def _dispatch(self, ev: Event) -> None:
        kind = ev.kind
        if kind is EventKind.VM_SUBMIT:
            self._on_submit(self.vms[ev.payload])
        elif kind is EventKind.VM_FINISH:
            vm = self.vms[ev.payload]
            if ev.generation == vm.generation:
                self._on_finish(vm)
        elif kind is EventKind.WAIT_EXPIRE:
            vm = self.vms[ev.payload]
            if ev.generation == vm.generation and vm.state is VmState.WAITING:
                self._on_wait_expire(vm)
        elif kind is EventKind.HIBERNATION_EXPIRE:
            vm = self.vms[ev.payload]
            if ev.generation == vm.generation and vm.state is VmState.HIBERNATED:
                self._on_hibernation_expire(vm)
        elif kind is EventKind.INTERRUPT_COMMIT:
            self._on_interrupt_commit(ev.payload)
        elif kind is EventKind.PRICE_TICK:
            self._on_price_tick()
        elif kind is EventKind.MIGRATE_START:
            self._on_migrate_start(ev.payload, ev.generation)
        elif kind is EventKind.MIGRATE_COMPLETE:
            self._on_migrate_complete(ev.payload, ev.generation)
        elif kind is EventKind.HOST_ADD:
            hid = self.pool.add_host(*ev.payload)
            if self.events.enabled:
                self.events.emit(self.now, "host-add", host=hid,
                                 pool=int(ev.payload[1]))
            self._flush_pending()
        elif kind is EventKind.HOST_REMOVE:
            self._on_host_remove(ev.payload)
        elif kind is EventKind.HOST_UPDATE:
            hid, cap = ev.payload
            self.pool.update_host(hid, cap)
        elif kind is EventKind.SERVE_TICK:
            self._on_serve_tick()
        elif kind is EventKind.AUTOSCALE:
            self._on_autoscale()
        if self.listeners:
            self._emit("clock_tick")

    # ------------------------------------------------------------ allocation
    def _on_submit(self, vm: Vm) -> None:
        self._set_state(vm, VmState.WAITING)
        vm.waiting_since = self.now
        if self.events.enabled:
            self.events.emit(self.now, "submit", vm=vm.id,
                             a=float(vm.bid) if np.isfinite(vm.bid) else 0.0,
                             aux=vm.vm_type.value)
        self._try_allocate(vm, fresh=True)
        self._record()

    def _try_allocate(self, vm: Vm, fresh: bool) -> bool:
        if self.obs.enabled:
            self.obs.counters.inc("alloc/find_host")
        hid, needs_clearing = self.policy.find_host(
            vm, self.pool, self.now, allow_spot_clearing=True
        )
        if hid < 0:
            self._enqueue_pending(vm, fresh, tested=True)
            return False
        if needs_clearing:
            self.metrics.preemption_scans += 1
            started = self._preempt_for(vm, hid)
            if not started:
                self._enqueue_pending(vm, fresh, tested=True)
            return False  # allocation happens at INTERRUPT_COMMIT
        self._start_vm(vm, hid)
        return True

    def _enqueue_pending(self, vm: Vm, fresh: bool, tested: bool = False) -> None:
        if not vm.persistent:
            self._set_state(vm, VmState.FAILED)
            if self.events.enabled:
                self.events.emit(self.now, "fail", vm=vm.id,
                                 aux="unplaceable")
            self._emit("vm_failed", vm=vm)
            return
        if tested:
            # direct placement just failed against the current pool state:
            # only hosts gaining capacity after this point need rechecking
            self._retry_pos[vm.id] = self.pool.gain_pos()
        else:
            self._retry_pos.pop(vm.id, None)
        self._set_state(vm, VmState.HIBERNATED if vm.hibernated_at >= 0
                        else VmState.WAITING)
        if vm.hibernated_at >= 0:
            self._hibernated[vm.id] = vm
        elif vm.vm_type is VmType.ON_DEMAND:
            self._waiting_od[vm.id] = vm
        else:
            self._waiting_spot[vm.id] = vm
        if fresh and np.isfinite(vm.waiting_timeout) and vm.hibernated_at < 0:
            self.queue.push(vm.waiting_since + vm.waiting_timeout,
                            EventKind.WAIT_EXPIRE, vm.id, vm.generation)

    def _start_vm(self, vm: Vm, hid: int) -> None:
        self._waiting_od.pop(vm.id, None)
        self._waiting_spot.pop(vm.id, None)
        self._retry_pos.pop(vm.id, None)
        resumed = self._hibernated.pop(vm.id, None) is not None
        self.pool.place(vm, hid, now=self.now)
        self._set_state(vm, VmState.RUNNING)
        vm.run_start = self.now
        vm.hibernated_at = -1.0
        vm.generation += 1
        vm.history.append(ExecutionInterval(host=hid, start=self.now))
        self.queue.push(self.now + vm.remaining, EventKind.VM_FINISH,
                        vm.id, vm.generation)
        self.metrics.allocations += 1
        if resumed:
            self.metrics.resubmissions += 1
        if self.events.enabled:
            self.events.emit(
                self.now, "resume" if resumed else "start", vm=vm.id,
                pool=int(self.pool.pool_of[hid]), host=hid,
                a=float(vm.bid) if np.isfinite(vm.bid) else 0.0)
        self._emit("vm_allocated", vm=vm, host=hid, resumed=resumed)

    # ----------------------------------------------------------- preemption
    def _select_victims(self, vm: Vm, hid: int) -> List[Vm]:
        """Choose interruptible spot VMs on ``hid`` to cover the deficit."""
        free = self.pool.free()[hid]
        deficit = np.maximum(vm.demand - free, 0.0)
        candidates = [v for v in self.pool.spot_vms_on(hid)
                      if v.interruptible(self.now)]
        sel = self.config.interruption_selector
        if sel == "best_fit_remaining":
            # fewest wasted resources: smallest remaining work first among those
            # that cover the deficit; deterministic beyond-paper strategy.
            candidates.sort(key=lambda v: (v.remaining, v.id))
        elif sel == "max_progress":
            # protect VMs closest to completion: interrupt least-progressed first
            candidates.sort(key=lambda v: (-(v.duration - v.remaining), v.id))
        # "list_order": keep host residence order (paper's behavior)
        victims, covered = [], np.zeros_like(deficit)
        for v in candidates:
            if np.all(covered >= deficit - _EPS):
                break
            victims.append(v)
            covered += v.demand
        if not np.all(covered >= deficit - _EPS):
            return []  # cannot actually free enough (mid-warning state changed)
        return victims

    def _preempt_for(self, vm: Vm, hid: int) -> bool:
        victims = self._select_victims(vm, hid)
        if not victims:
            return False
        w = self.config.warning_time
        for v in victims:
            # keep the victim's VM_FINISH event valid: a spot VM that
            # completes during the warning window finishes normally (its
            # capacity is then free at commit time anyway).
            self._set_state(v, VmState.INTERRUPTING)
            self.pool.mark_uninterruptible(v)
        self._pending_victims[hid] = [v.id for v in victims]
        self.queue.push(self.now + w, EventKind.INTERRUPT_COMMIT,
                        (hid, vm.id, [v.id for v in victims]))
        return True

    def _on_interrupt_commit(self, payload) -> None:
        if payload[0] == "wave":
            # end of a price-wave warning window: apply each victim's behavior
            for vid in payload[1]:
                v = self.vms[vid]
                if v.state is not VmState.INTERRUPTING:
                    continue  # finished during the warning
                self._interrupt(v, kind=v.behavior.value,
                                cause=InterruptionCause.PRICE_WAVE)
            self._flush_pending()
            self._record()
            return
        hid, od_id, victim_ids = payload
        od = self.vms[od_id]
        self._pending_victims.pop(hid, None)
        for vid in victim_ids:
            v = self.vms[vid]
            if v.state is not VmState.INTERRUPTING:
                continue  # finished or otherwise transitioned during warning
            self._interrupt(v, kind=v.behavior.value)
        if od.state in (VmState.WAITING,) and self.pool.fits(hid, od.demand):
            self._start_vm(od, hid)
        elif od.state is VmState.WAITING:
            # capacity changed during the warning window; retry globally
            self._try_allocate(od, fresh=False)
        self._flush_pending()
        self._record()

    def _interrupt(self, vm: Vm, kind: str,
                   cause: str = InterruptionCause.CAPACITY) -> None:
        """Stop a running/interrupting spot VM and apply its behavior."""
        self._account_progress(vm)
        self.pool.release(vm)
        vm.interruptions += 1
        self.metrics.interruption_events.append(
            InterruptionEvent(vm.id, self.now, vm.history[-1].host, kind,
                              cause))
        if self.obs.enabled:
            self.obs.counters.inc("interruptions/" + cause)
        if self.events.enabled:
            hid = vm.history[-1].host
            self.events.emit(self.now, "interrupt", vm=vm.id,
                             pool=int(self.pool.pool_of[hid]), host=hid,
                             a=float(vm.bid) if np.isfinite(vm.bid) else 0.0,
                             aux=cause)
        self._emit("vm_interrupted", vm=vm, kind=kind)
        self._apply_interruption_behavior(vm, kind)

    def _apply_interruption_behavior(self, vm: Vm, kind: str) -> None:
        """Shared post-interruption triage (capacity/wave interruption, host
        removal, failed migration): a VM whose work is done finishes;
        otherwise it hibernates or terminates per ``kind``."""
        if vm.remaining <= _EPS:
            self._finish_now(vm)
        elif kind == "hibernate":
            self._enter_hibernation(vm)
        else:
            self._set_state(vm, VmState.TERMINATED)
            vm.generation += 1
            if self.events.enabled:
                self.events.emit(self.now, "terminate", vm=vm.id)
            self._emit("vm_terminated", vm=vm)

    def _enter_hibernation(self, vm: Vm) -> None:
        """Shared hibernation entry (wave/capacity interruption, host
        removal, failed migration).  The VM is already released from its
        host.  The optional re-bid hook fires here: the VM resubmits with
        its adapted bid governing readmission."""
        if self._rebid is not None:
            vm.bid = self._rebid.rebid(vm)
        self._set_state(vm, VmState.HIBERNATED)
        vm.hibernated_at = self.now
        vm.generation += 1
        self._hibernated[vm.id] = vm
        self._retry_pos.pop(vm.id, None)  # untested in hibernated form
        if self.events.enabled:
            # a carries the (possibly re-bid) price governing readmission
            self.events.emit(self.now, "hibernate", vm=vm.id,
                             a=float(vm.bid) if np.isfinite(vm.bid) else 0.0)
        if np.isfinite(vm.hibernation_timeout):
            self.queue.push(self.now + vm.hibernation_timeout,
                            EventKind.HIBERNATION_EXPIRE, vm.id,
                            vm.generation)

    # ------------------------------------------------------------ market tick
    def _on_price_tick(self) -> None:
        """Re-clear every pool's price from live utilization, then emit the
        interruption wave: one masked comparison over the market registry
        selects every resident spot VM whose bid the new price crossed."""
        eng = self.engine
        t = self.now
        fi = self.faults
        tr = self.obs
        traced = tr.enabled
        if fi is not None:
            # outage transitions first (the utilization signal must see the
            # downed hosts), then crunch/spike biases into the normal tick
            if traced:
                tr.begin("market-tick", "tick/faults")
            self._fault_begin_tick(t)
            if traced:
                tr.end(t, None)
                tr.begin("market-tick", "tick/engine")
            prices = eng.tick(self.pool, t, util_bias=fi.util_bias(t),
                              shock_bias=fi.shock_bias(t))
        else:
            if traced:
                tr.begin("market-tick", "tick/engine")
            prices = eng.tick(self.pool, t)
        if traced:
            tr.end(t, None)
            tr.counters.inc("ticks")
            tr.begin("market-tick", "tick/wave")
        self.pool.set_pool_prices(prices)
        m = self.metrics
        m.price_series.extend(
            (t, pid, float(p)) for pid, p in enumerate(prices))
        victims, vpools = self.pool.market_victims(prices, t)
        if victims.size:
            counts = np.bincount(vpools, minlength=eng.n_pools)
            evl = self.events
            for pid in np.flatnonzero(counts):
                m.wave_events.append(
                    WaveEvent(t, int(pid), float(prices[pid]),
                              int(counts[pid])))
                if evl.enabled:
                    evl.emit(t, "wave", pool=int(pid),
                             a=float(prices[pid]), b=float(counts[pid]))
            if traced:
                tr.counters.inc("waves")
                tr.counters.inc("wave_victims", int(victims.size))
                tr.instant("market-tick", "wave", t,
                           {"victims": int(victims.size)})
            w = self.config.warning_time
            if w > 0:
                vids = [int(v) for v in victims]
                for vid in vids:
                    v = self.vms[vid]
                    self._set_state(v, VmState.INTERRUPTING)
                    self.pool.mark_uninterruptible(v)
                self.queue.push(t + w, EventKind.INTERRUPT_COMMIT,
                                ("wave", vids))
            else:
                for vid in victims:
                    v = self.vms[int(vid)]
                    self._interrupt(v, kind=v.behavior.value,
                                    cause=InterruptionCause.PRICE_WAVE)
        if traced:
            tr.end(t, {"victims": int(victims.size)})
        # injected interruption storms land after the ordinary wave — the
        # wave already reclaimed below-bid VMs, the storm takes its share of
        # whoever is left running
        if fi is not None and self._storms_due:
            if traced:
                tr.begin("market-tick", "tick/storms")
                self._fault_apply_storms()
                tr.end(t, None)
            else:
                self._fault_apply_storms()
        # capacity freed by the wave (and any price drops, via the gain log)
        # feeds straight back into the queue — victims can land in a cheaper
        # pool within the same tick
        self._flush_pending()
        # proactive migration: the planner scores the settled post-wave,
        # post-flush state and emits MIGRATE_START events at this timestamp
        # (processed after same-time submissions; each start re-validates)
        if self.migration is not None:
            if traced:
                tr.begin("market-tick", "tick/migration")
                self._plan_migrations()
                tr.end(t, None)
            else:
                self._plan_migrations()
        # the fleet manager observes the settled post-wave, post-flush,
        # post-planner state: sample capacity, replace dead slots (its
        # submissions are VM_SUBMIT events at this timestamp, processed
        # after the tick by event priority)
        if self.fleet is not None:
            if traced:
                tr.begin("market-tick", "tick/fleet")
                self.fleet.on_tick(self, t)
                tr.end(t, None)
            else:
                self.fleet.on_tick(self, t)
        self._record()
        # keep ticking while any event or live VM remains (the chain is the
        # only self-scheduling event kind, so it must not outlive the run).
        # With an *unbounded* horizon, queued-only state (WAITING/HIBERNATED
        # with infinite timeouts, gated purely on a price that may never
        # clear) must not keep the chain alive — the pre-engine simulator
        # terminated there, and run(until=inf) would otherwise never return.
        # A fleet with live (unretired) slots, or a fault schedule with
        # events still to fire, also keeps a *bounded* run ticking — backoff
        # retries and future faults need the clock even when nothing runs.
        c = m.state_counts
        bounded = self._run_limit != float("inf")
        if (self.queue._heap or c[1] + c[2] > 0
                or (bounded and c[3] + c[4] > 0)
                or (bounded and self.fleet is not None
                    and self.fleet.wants_tick())
                or (bounded and fi is not None and fi.pending())):
            self.queue.push(t + eng.tick_interval, EventKind.PRICE_TICK)
        else:
            self._tick_armed = False  # idle: submit()/schedule_* re-arm

    # -------------------------------------------------------- serving layer
    def _serve_rearm(self) -> bool:
        """Keep a serve chain alive?  A bounded run carries its chains to
        the horizon (events past the limit stay in the heap, like
        PRICE_TICK's re-arm); an unbounded run stops once the request
        backlog drained and nothing runs, so ``run(until=inf)`` returns."""
        c = self.metrics.state_counts
        return (self._run_limit != float("inf") or self.serve.pending()
                or c[1] + c[2] > 0)

    def _on_serve_tick(self) -> None:
        sv = self.serve
        if sv is None:
            return
        t = self.now
        tr = self.obs
        if tr.enabled:
            tr.begin("serve", "tick/serve")
            sv.on_tick(self, t)
            tr.end(t, None)
        else:
            sv.on_tick(self, t)
        if self._serve_rearm():
            self.queue.push(t + sv.config.tick, EventKind.SERVE_TICK)

    def _on_autoscale(self) -> None:
        sv = self.serve
        if sv is None or sv.autoscaler is None:
            return
        t = self.now
        tr = self.obs
        if tr.enabled:
            tr.begin("serve", "tick/autoscale")
            sv.on_autoscale(self, t)
            tr.end(t, None)
        else:
            sv.on_autoscale(self, t)
        if self._serve_rearm():
            self.queue.push(t + sv.autoscaler.config.cadence,
                            EventKind.AUTOSCALE)

    def decommission(self, vm: Vm) -> None:
        """Voluntarily end a RUNNING/INTERRUPTING VM now (autoscaler
        scale-in): rides the ordinary VM_FINISH path, so progress
        accounting, host release, metrics, and lifecycle listeners behave
        exactly like a natural completion."""
        self.queue.push(self.now, EventKind.VM_FINISH, vm.id, vm.generation)

    # ---------------------------------------------------- proactive migration
    def _plan_migrations(self) -> None:
        plans = self.migration.plan(self.pool, self.engine, self.now,
                                    self._mig_inflight)
        if not plans:
            return
        self.metrics.migrations_planned += len(plans)
        for p in plans:
            vm = self.vms[p.vm_id]
            self.queue.push(self.now, EventKind.MIGRATE_START,
                            (p.vm_id, p.dst_pool, p.predicted_saving),
                            vm.generation)

    def _on_migrate_start(self, payload, gen: int) -> None:
        """Leave the source host and reserve the destination: the VM makes no
        progress (and pays nothing) until MIGRATE_COMPLETE."""
        vid, dst_pool, predicted = payload
        vm = self.vms[vid]
        if gen != vm.generation or vm.state is not VmState.RUNNING:
            return  # finished / interrupted / preempt-warned since planning
        mask = self.pool.direct_mask_into(vm.demand, vm.bid, dst_pool)
        hid = self.policy._pick_direct(mask, vm, self.pool) if mask.any() else -1
        if hid < 0:
            # no single host fits (pool-aggregate headroom was fragmented,
            # or same-time submissions took it): stay put, and black the VM
            # out of planning for one cooldown so it cannot re-top the
            # ranking and monopolize the per-tick plan budget every tick
            self.pool.stamp_migration_cooldown(
                vm, self.now + self.migration.config.cooldown)
            return
        src = vm.host
        self._account_progress(vm)
        self.pool.release(vm)
        self._set_state(vm, VmState.MIGRATING)
        vm.generation += 1
        vm.run_start = -1.0
        self.pool.reserve(vm, hid)
        self._mig_inflight[dst_pool] += 1
        mev = MigrationEvent(vid, self.now, src, hid,
                             int(self.pool.pool_of[src]), int(dst_pool),
                             predicted, bid=vm.bid)
        self._migrating[vid] = mev
        self.metrics.migration_events.append(mev)
        self.metrics.migrations_started += 1
        if self.obs.enabled:
            self.obs.counters.inc("migrations/started")
        if self.events.enabled:
            # pool/host name the *source* (the departure side — occupancy
            # analytics key on it); the destination pool rides in b and the
            # arrival is its own migrate-complete event
            self.events.emit(self.now, "migrate-start", vm=vid,
                             pool=int(self.pool.pool_of[src]), host=src,
                             a=float(predicted), b=float(dst_pool))
        self.queue.push(self.now + self.migration.config.downtime,
                        EventKind.MIGRATE_COMPLETE, (vid, hid),
                        vm.generation)
        self._emit("vm_migration_start", vm=vm, src=src, dst=hid)
        # the vacated source capacity is a gain: queued VMs may take it now
        self._flush_pending()
        self._record()

    def _on_migrate_complete(self, payload, gen: int) -> None:
        """End of the stop-and-copy window: commit the reservation into a
        placement — or, if the destination stopped clearing during the
        flight (price spiked above the bid / host removed), fail the
        migration and apply the VM's interruption behavior."""
        vid, hid = payload
        vm = self.vms[vid]
        if gen != vm.generation or vm.state is not VmState.MIGRATING:
            return
        mev = self._migrating.pop(vid)
        self.pool.release_reservation(vid)
        self._mig_inflight[mev.dst_pool] -= 1
        mev.t_complete = self.now
        pool = self.pool
        if (pool.active[hid] and pool.price_clears(hid, vm.bid)
                and pool.fits_fast(hid, vm.demand)):
            # arrival: like _start_vm, but the interval is via="migrate" and
            # the cooldown stamp lands in the registry before place()
            vm.migrate_cooldown_until = self.now + self.migration.config.cooldown
            pool.place(vm, hid, now=self.now)
            self._set_state(vm, VmState.RUNNING)
            vm.run_start = self.now
            vm.generation += 1
            vm.migrations += 1
            vm.history.append(
                ExecutionInterval(host=hid, start=self.now, via="migrate"))
            self.queue.push(self.now + vm.remaining, EventKind.VM_FINISH,
                            vm.id, vm.generation)
            self.metrics.migrations_completed += 1
            self.metrics.migration_downtime += self.now - mev.t_start
            if self.obs.enabled:
                self.obs.counters.inc("migrations/completed")
            if self.events.enabled:
                self.events.emit(self.now, "migrate-complete", vm=vm.id,
                                 pool=int(mev.dst_pool), host=hid,
                                 a=float(mev.predicted_saving), aux="ok")
            self._emit("vm_migrated", vm=vm, host=hid)
        else:
            mev.failed = True
            self.metrics.migrations_failed += 1
            vm.interruptions += 1
            kind = vm.behavior.value
            # the flight's downtime becomes part of the interruption gap
            # (the interval closed at MIGRATE_START), so it is NOT also
            # added to migration_downtime — each second has one home.
            # Attribute the event to the host the VM last ran on (like
            # every other interruption path); the destination it never
            # reached is in the MigrationEvent.
            self.metrics.interruption_events.append(
                InterruptionEvent(vid, self.now, vm.history[-1].host, kind,
                                  cause=InterruptionCause.MIGRATION_FAILED))
            if self.obs.enabled:
                self.obs.counters.inc(
                    "interruptions/" + InterruptionCause.MIGRATION_FAILED)
                self.obs.counters.inc("migrations/failed")
            if self.events.enabled:
                self.events.emit(self.now, "migrate-complete", vm=vm.id,
                                 pool=int(mev.dst_pool), host=hid,
                                 aux="failed")
                last = vm.history[-1].host
                self.events.emit(
                    self.now, "interrupt", vm=vm.id,
                    pool=int(self.pool.pool_of[last]), host=last,
                    a=float(vm.bid) if np.isfinite(vm.bid) else 0.0,
                    aux=InterruptionCause.MIGRATION_FAILED)
            self._emit("vm_interrupted", vm=vm, kind=kind)
            self._apply_interruption_behavior(vm, kind)
        self._flush_pending()
        self._record()

    def _account_progress(self, vm: Vm) -> None:
        """Close the current execution interval and decrement remaining work."""
        ran = self.now - vm.run_start
        vm.remaining = max(0.0, vm.remaining - ran)
        vm.history[-1].stop = self.now
        self._emit("vm_deallocated", vm=vm, host=vm.host)

    # ------------------------------------------------------------ lifecycle
    def _on_finish(self, vm: Vm) -> None:
        if vm.state not in (VmState.RUNNING, VmState.INTERRUPTING):
            return
        hid = vm.history[-1].host
        self._account_progress(vm)
        self.pool.release(vm)
        self._finish_now(vm, host=hid)
        self._flush_pending()
        self._record()

    def _finish_now(self, vm: Vm, host: int = -1) -> None:
        self._set_state(vm, VmState.FINISHED)
        vm.finish_time = self.now
        vm.generation += 1
        self._hibernated.pop(vm.id, None)
        self._retry_pos.pop(vm.id, None)
        if self.events.enabled:
            # host/pool only for the ran-to-completion path — departure
            # accounting in obs.analyze keys on pool >= 0 (finishes after
            # an interruption already decremented via the interrupt event)
            self.events.emit(
                self.now, "finish", vm=vm.id, host=host,
                pool=int(self.pool.pool_of[host]) if host >= 0 else -1)
        self._emit("vm_finished", vm=vm)

    def _on_wait_expire(self, vm: Vm) -> None:
        self._waiting_od.pop(vm.id, None)
        self._waiting_spot.pop(vm.id, None)
        self._retry_pos.pop(vm.id, None)
        self._set_state(vm, VmState.FAILED)
        vm.generation += 1
        if self.events.enabled:
            self.events.emit(self.now, "fail", vm=vm.id, aux="wait-expire")
        self._emit("vm_failed", vm=vm)
        self._record()

    def _on_hibernation_expire(self, vm: Vm) -> None:
        self._hibernated.pop(vm.id, None)
        self._retry_pos.pop(vm.id, None)
        self._set_state(vm, VmState.TERMINATED)
        vm.generation += 1
        if self.events.enabled:
            self.events.emit(self.now, "terminate", vm=vm.id,
                             aux="hibernation-expire")
        self._emit("vm_terminated", vm=vm)
        self._record()

    def _on_host_remove(self, hid: int) -> None:
        self._evict_host(hid, InterruptionCause.CAPACITY)
        self._flush_pending()
        self._record()

    def _evict_host(self, hid: int,
                    cause: str = InterruptionCause.CAPACITY) -> None:
        """Deactivate ``hid`` and evict its residents through the ordinary
        interruption lifecycle (spot VMs take their behavior, on-demand VMs
        requeue).  Shared by trace machine-removal events (``cause``
        "capacity", the historical value) and transient pool outages from
        the fault injector ("fault-outage").  The caller flushes/records."""
        if self.events.enabled:
            self.events.emit(self.now, "host-remove", host=hid,
                             pool=int(self.pool.pool_of[hid]), aux=cause)
        victims = self.pool.remove_host(hid)
        for v in victims:
            if v.vm_type is VmType.SPOT:
                self._account_progress(v)
                self.pool.release(v)
                v.interruptions += 1
                self.metrics.interruption_events.append(
                    InterruptionEvent(v.id, self.now, hid,
                                      InterruptionCause.HOST_REMOVED, cause))
                if self.obs.enabled:
                    self.obs.counters.inc("interruptions/" + cause)
                if self.events.enabled:
                    self.events.emit(
                        self.now, "interrupt", vm=v.id,
                        pool=int(self.pool.pool_of[hid]), host=hid,
                        a=float(v.bid) if np.isfinite(v.bid) else 0.0,
                        aux=cause)
                self._apply_interruption_behavior(v, v.behavior.value)
            else:
                # on-demand VMs are resubmitted as persistent requests
                self._account_progress(v)
                self.pool.release(v)
                v.generation += 1
                if v.remaining <= _EPS:
                    self._finish_now(v)
                else:
                    self._set_state(v, VmState.WAITING)
                    v.waiting_since = self.now
                    self._waiting_od[v.id] = v
                    self._retry_pos.pop(v.id, None)  # untested after removal

    # -------------------------------------------------------- fault injection
    def _fault_begin_tick(self, t: float) -> None:
        """Advance the fault schedule to ``t``: record fired faults, start /
        end pool outages, and stash storms for application after the wave."""
        fi = self.faults
        started, ended = fi.begin_tick(t)
        for i, ev in started:
            self.metrics.fault_records.append(
                FaultRecord(ev.kind, ev.t0, ev.t1,
                            tuple(fi._pool_ids(ev)), ev.magnitude))
            if ev.kind == "pool-outage":
                pool = self.pool
                n = pool.n
                hids = [int(h) for p in fi._pool_ids(ev)
                        for h in np.flatnonzero(
                            pool.active[:n] & (pool.pool_of[:n] == p))]
                for hid in hids:
                    self._evict_host(hid, InterruptionCause.FAULT_OUTAGE)
                self._outage_hosts[i] = hids
            elif ev.kind == "storm":
                self._storms_due.append(ev)
        for i in ended:
            for hid in self._outage_hosts.pop(i, ()):
                self.pool.reactivate_host(hid)

    def _fault_apply_storms(self) -> None:
        """Reclaim each due storm's victims — a fraction of the resident
        running spot VMs per affected pool, lowest bids first — through the
        normal interruption path (cause "fault-storm", no warning: storms
        model abrupt provider reclamation)."""
        fi = self.faults
        for ev in self._storms_due:
            vids = fi.victims(self.pool.market_registry(), ev)
            for vid in vids:
                v = self.vms[int(vid)]
                self._interrupt(v, kind=v.behavior.value,
                                cause=InterruptionCause.FAULT_STORM)
        self._storms_due.clear()

    # --------------------------------------------------------- resubmission
    def _flush_pending(self) -> None:
        """Resubmission pass: try to place queued requests (§V-D)."""
        tr = self.obs
        evl = self.events
        if not (tr.enabled or evl.enabled):
            if self.config.flush_mode == "per_vm":
                self._flush_pending_per_vm()
            else:
                self._flush_pending_batched()
            return
        mode = self.config.flush_mode
        before = self.metrics.allocations
        if tr.enabled:
            tr.begin("allocation", "flush/" + mode)
        if mode == "per_vm":
            self._flush_pending_per_vm()
        else:
            self._flush_pending_batched()
        placed = self.metrics.allocations - before
        if tr.enabled:
            tr.end(self.now, {"placed": placed})
        if evl.enabled:
            evl.emit(self.now, "alloc-flush", a=float(placed))

    def _queues(self) -> Dict[str, Dict[int, Vm]]:
        return {
            "waiting_od": self._waiting_od,
            "waiting_spot": self._waiting_spot,
            "hibernated": self._hibernated,
        }

    def _flush_pending_per_vm(self) -> None:
        """Legacy reference path: one full ``find_host`` per queued VM per
        pass.  Kept verbatim as the oracle the batched path is tested against."""
        queues = self._queues()
        progress = True
        while progress:
            progress = False
            for name in self.config.resubmit_order:
                q = queues[name]
                for vid in list(q.keys()):
                    vm = q[vid]
                    if vm.state not in (VmState.WAITING, VmState.HIBERNATED):
                        q.pop(vid, None)
                        continue
                    allow_clear = vm.vm_type is VmType.ON_DEMAND
                    hid, needs_clearing = self.policy.find_host(
                        vm, self.pool, self.now, allow_spot_clearing=allow_clear)
                    if hid >= 0 and not needs_clearing:
                        q.pop(vid, None)
                        self._start_vm(vm, hid)
                        progress = True
                    # note: queued on-demand VMs do not trigger *new* preemption
                    # cascades here — preemption happens on the submit path;
                    # this avoids livelock between queued od and running spot.
        self._maybe_compact_gains()

    def _flush_pending_batched(self) -> None:
        """Batched resubmission: decision-identical to the per-VM loop.

        Per pass, one feasibility matrix decides which queued VM places next
        (a VM places iff its row is non-empty) and scoring runs only for that
        row; after each placement the not-yet-visited suffix is re-decided
        (state changed).  A gain-log memo skips VMs for which no host's free
        capacity has increased since their last failed test — placements
        can't create feasibility, so the answer is unchanged by construction.
        Queued VMs never trigger new preemption cascades (see the per-VM
        loop's note), so only direct placements are considered."""
        if not (self._waiting_od or self._waiting_spot or self._hibernated):
            # still bound the gain log: market price *drops* flood it every
            # tick (hosts re-opened to queued bids), and with no queued VMs
            # nobody would otherwise ever consume or compact those entries
            self._maybe_compact_gains()
            return
        queues = self._queues()
        while True:
            pending: List[Tuple[Dict[int, Vm], Vm]] = []
            for name in self.config.resubmit_order:
                q = queues[name]
                stale = False
                for vm in q.values():
                    if vm.state in (VmState.WAITING, VmState.HIBERNATED):
                        pending.append((q, vm))
                    else:
                        stale = True
                if stale:  # rare: purge invalid entries with a snapshot pass
                    for vid in list(q.keys()):
                        if q[vid].state not in (VmState.WAITING,
                                                VmState.HIBERNATED):
                            q.pop(vid, None)
                            self._retry_pos.pop(vid, None)
            if not pending or not self._flush_batch_pass(pending):
                self._maybe_compact_gains()
                return

    def _maybe_compact_gains(self) -> None:
        """Bound the pool's gain log: drop entries no queued VM still
        references (positions only move forward, so this is safe)."""
        pool = self.pool
        if len(pool.gain_log) > max(1024, 4 * pool.n):
            pool.compact_gain_log(
                min(self._retry_pos.values(), default=pool.gain_pos()))

    def _flush_batch_pass(self, pending) -> int:
        """One pass over the queue snapshot; returns the number placed."""
        pool, placed, i = self.pool, 0, 0
        retry, log = self._retry_pos, pool.gain_log
        fits = pool.fits_fast
        n_pending = len(pending)
        while i < n_pending:
            # memo filter: keep only VMs that might fit under current state —
            # a VM that failed its last full test can only have become
            # feasible on a host whose free capacity increased since then.
            # Positions are absolute (base counts compacted-away entries).
            base = pool._gain_base
            glen = base + len(log)
            check: List[int] = []
            for j in range(i, n_pending):
                vm = pending[j][1]
                pos = retry.get(vm.id)
                if pos is not None:
                    if pos >= glen:
                        continue  # nothing gained since the last failure
                    hit = False
                    for h in log[max(pos - base, 0):]:
                        if fits(h, vm.demand):
                            hit = True
                            break
                    if not hit:
                        retry[vm.id] = glen
                        continue
                check.append(j)
            if not check:
                break
            # one feasibility matrix decides which VM places (a VM places iff
            # its row is non-empty); scoring runs for that single row only
            if len(check) == 1:
                hid = self.policy.find_direct(pending[check[0]][1], pool)
                b = 0 if hid >= 0 else 1
            else:
                b, hid = self.policy.find_first_direct(
                    [pending[j][1] for j in check], pool)
            pos_now = base + len(log)
            for j in check[:b]:
                retry[pending[j][1].id] = pos_now
            if hid < 0:
                break
            q, vm = pending[check[b]]
            q.pop(vm.id, None)
            self._start_vm(vm, hid)
            placed += 1
            # pool state changed: re-decide the remaining suffix
            i = check[b] + 1
        return placed

    def _record(self) -> None:
        if self.config.record_timeline:
            self.metrics.record_sample(self.now)

    # ------------------------------------------------------------- reporting
    def finished_vms(self) -> List[Vm]:
        return [v for v in self.vms.values() if v.state is VmState.FINISHED]

    def all_vms(self) -> List[Vm]:
        return list(self.vms.values())
