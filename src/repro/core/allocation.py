"""VM allocation policies (paper §II-D, §VI).

Each policy implements ``find_host(vm, pool, now, allow_spot_clearing)`` and
returns ``(host_id, needs_clearing)``; ``host_id == -1`` means no placement.
``needs_clearing`` signals that the chosen host only becomes feasible after
interrupting (some of) its spot VMs — the simulator performs the actual victim
selection and interruption (DynamicAllocation.spotAllocation in the paper).

Spot-clearing feasibility counts only *interruptible* spot VMs: those past
their minimum running time (§IV-B "minimum runtime must be enforced") — the
pool maintains that sum incrementally (see ``hosts.HostPool``), so both masks
are single vectorized comparisons against cached arrays.

Batched paths (clearing is never considered: queued VMs do not trigger new
preemption cascades, see simulator._flush_pending):

* ``find_first_direct(vms, pool)`` is the engine of the simulator's batched
  flush — one feasibility matrix decides which VM places, then a single-row
  scoring pass (bit-identical to the per-VM path) picks its host;
* ``find_hosts_batch(vms, pool, now)`` decides ALL rows in one shot (one
  feasibility matrix + one batched HLEM scoring pass) for offline/accelerator
  use; rows match per-VM ``find_host`` up to float summation order (a
  near-tie argmax can differ at the ulp level).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from .hlem import (
    hlem_pick_candidates_np,
    hlem_pick_np,
    hlem_scores_batch_np,
    hlem_select_jax,
)
from .hosts import HostPool
from ..obs.tracer import NULL_TRACER
from .registry import Registry
from .types import Vm

_EPS = 1e-9

#: string-keyed plugin registry for allocation policies — the scenario API's
#: extension point.  Register custom policies with
#: ``@register_policy("my-policy")``; ``make_policy`` and ``PolicySpec``
#: resolve against it.
POLICY_REGISTRY = Registry("allocation policy")
register_policy = POLICY_REGISTRY.register


def direct_mask(vm: Vm, pool: HostPool) -> np.ndarray:
    """Hosts that fit the demand right now (fresh array; hot paths use
    ``pool.direct_mask_into`` which is scratch-backed)."""
    return pool.direct_mask_into(vm.demand, vm.bid, vm.pool).copy()


def clearing_mask(vm: Vm, pool: HostPool, now: float) -> np.ndarray:
    """Hosts that would fit the demand after deallocating their interruptible
    spot VMs (§VI-A: "checks the potential capacity of hosts if active spot
    instances were to be deallocated").

    One vectorized comparison against the pool's incrementally maintained
    reclaimable-capacity cache; min-running-time expiries up to ``now`` are
    folded in first.
    """
    pool.refresh_reclaim(now)
    return pool.clearing_mask_into(vm.demand, vm.bid, vm.pool).copy()


def feasibility_masks(vm: Vm, pool: HostPool, now: float):
    """(direct_mask, clearing_mask) — kept for tests; prefer the lazy pair."""
    return direct_mask(vm, pool), clearing_mask(vm, pool, now)


class AllocationPolicy:
    name = "abstract"

    #: telemetry hook (``repro.obs``); the build layer swaps in the live
    #: tracer — batched-flush scoring volume feeds the counter registry
    tracer = NULL_TRACER

    def _pick(self, mask: np.ndarray, vm: Vm, pool: HostPool) -> int:
        raise NotImplementedError

    def find_host(
        self, vm: Vm, pool: HostPool, now: float, allow_spot_clearing: bool
    ) -> Tuple[int, bool]:
        hid = self._pick(pool.direct_mask_into(vm.demand, vm.bid, vm.pool),
                         vm, pool)
        if hid >= 0:
            return hid, False
        if allow_spot_clearing and not vm.is_spot:
            pool.refresh_reclaim(now)
            hid = self._pick(
                pool.clearing_mask_into(vm.demand, vm.bid, vm.pool), vm, pool)
            if hid >= 0:
                return hid, True
        return -1, False

    def _pick_direct(self, mask: np.ndarray, vm: Vm, pool: HostPool) -> int:
        """Select from a direct-feasibility mask; >= 0 whenever mask is
        non-empty.  Shared by ``find_host`` and the batched flush."""
        return self._pick(mask, vm, pool)

    def find_direct(self, vm: Vm, pool: HostPool) -> int:
        """Direct placement only (no spot clearing): chosen host or -1."""
        mask = pool.direct_mask_into(vm.demand, vm.bid, vm.pool)
        if not mask.any():
            return -1
        return self._pick_direct(mask, vm, pool)

    # -- batched path --------------------------------------------------------
    def find_hosts_batch(
        self, vms: Sequence[Vm], pool: HostPool, now: float
    ) -> np.ndarray:
        """(B,) chosen host per VM (-1 = none), direct placements only.

        Row b matches ``find_host(vms[b], ...)`` against the same pool state
        with spot clearing ignored (for HLEM, up to float summation order in
        the batched scorer).  The result is only valid until the pool mutates
        (committing one row invalidates the rest)."""
        demands = np.stack([vm.demand for vm in vms])
        bids = np.array([vm.bid for vm in vms])
        pids = np.array([vm.pool for vm in vms], dtype=np.int64)
        feas = pool.direct_mask_batch(demands, bids, pids)
        return self._pick_batch(feas, vms, pool)

    def find_first_direct(
        self, vms: Sequence[Vm], pool: HostPool
    ) -> Tuple[int, int]:
        """(index, host) of the first VM in ``vms`` that fits somewhere right
        now, or (B, -1) if none does.

        One vectorized feasibility matrix decides *which* VM places (a VM
        places iff its feasibility row is non-empty); scoring then runs for
        that single row only.  This is the engine of the batched flush: the
        greedy commit loop re-decides only the suffix after each placement,
        so scoring work is one pass per placement instead of per queued VM."""
        nvm = len(vms)
        if self.tracer.enabled:
            self.tracer.counters.inc("alloc/batch_calls")
            self.tracer.counters.inc("alloc/batch_rows", nvm)
        demands = np.empty((nvm, vms[0].demand.shape[0]))
        bids = np.empty(nvm)
        pids = np.empty(nvm, dtype=np.int64)
        for b, vm in enumerate(vms):
            demands[b] = vm.demand
            bids[b] = vm.bid
            pids[b] = vm.pool
        feas = pool.direct_mask_batch(demands, bids, pids)
        any_row = feas.any(axis=1)
        for b in np.flatnonzero(any_row):
            return int(b), self._pick_direct(feas[b], vms[b], pool)
        return nvm, -1

    def _pick_batch(self, feas: np.ndarray, vms: Sequence[Vm],
                    pool: HostPool) -> np.ndarray:
        # generic fallback: per-row _pick on the shared feasibility matrix
        return np.array([self._pick(feas[b], vms[b], pool)
                         for b in range(feas.shape[0])], dtype=np.int64)


@register_policy("first-fit")
class FirstFit(AllocationPolicy):
    """CloudSim Plus baseline: first host (insertion order) that fits."""

    name = "first-fit"

    def _pick(self, mask, vm, pool):
        idx = np.flatnonzero(mask)
        return int(idx[0]) if idx.size else -1

    def _pick_batch(self, feas, vms, pool):
        any_row = feas.any(axis=1)
        return np.where(any_row, feas.argmax(axis=1), -1)


@register_policy("best-fit")
class BestFit(AllocationPolicy):
    """Host with the least free CPU that still fits (tightest packing)."""

    name = "best-fit"

    def _pick(self, mask, vm, pool):
        if not mask.any():
            return -1
        free_cpu = np.where(mask, pool.free()[:, 0], np.inf)
        return int(np.argmin(free_cpu))

    def _pick_batch(self, feas, vms, pool):
        any_row = feas.any(axis=1)
        free_cpu = np.where(feas, pool.free()[None, :, 0], np.inf)
        return np.where(any_row, free_cpu.argmin(axis=1), -1)


@register_policy("worst-fit")
class WorstFit(AllocationPolicy):
    """Host with the most free CPU (max headroom)."""

    name = "worst-fit"

    def _pick(self, mask, vm, pool):
        if not mask.any():
            return -1
        free_cpu = np.where(mask, pool.free()[:, 0], -np.inf)
        return int(np.argmax(free_cpu))

    def _pick_batch(self, feas, vms, pool):
        any_row = feas.any(axis=1)
        free_cpu = np.where(feas, pool.free()[None, :, 0], -np.inf)
        return np.where(any_row, free_cpu.argmax(axis=1), -1)


@register_policy("hlem-vmp")
class HlemVmp(AllocationPolicy):
    """HLEM-VMP (paper §VI-A/B).

    Phase 1 filters feasible hosts and applies the RsDiff threshold (Eqs. 1–2);
    if that leaves no candidate, the threshold filter is relaxed (and, for
    on-demand VMs, the spot-clearing candidate list is used — Algorithm 1).
    Phases 2–3 score candidates with entropy weights and pick the max.
    """

    name = "hlem-vmp"
    #: adjusted-variant knobs (unused in the base class)
    alpha = 0.0
    adjust_spot_only = True

    def __init__(self, rc: float = 0.95, threshold: float = 0.0,
                 backend: str = "numpy"):
        self.rc = rc
        self.threshold = threshold
        assert backend in ("numpy", "jax")
        self.backend = backend

    # -- phase 1 ------------------------------------------------------------
    def _rsdiff_ok(self, vm: Vm, pool: HostPool) -> np.ndarray:
        tot, util = pool.rsdiff_inputs()
        rs = vm.demand[0] / tot - util * self.rc
        return rs > self.threshold

    # -- phases 2-3 ---------------------------------------------------------
    def _alpha_for(self, vm: Vm) -> float:
        if self.alpha != 0.0 and (vm.is_spot or not self.adjust_spot_only):
            return self.alpha
        return 0.0

    def _score_pick(self, mask: np.ndarray, vm: Vm, pool: HostPool) -> int:
        if not mask.any():
            return -1
        free = pool.free()
        spot_frac = pool.spot_frac_view()
        alpha = self._alpha_for(vm)
        if self.backend == "jax":
            hid = int(hlem_select_jax(free, mask, spot_frac, np.float32(alpha)))
            return hid
        return hlem_pick_np(free, mask, spot_frac, alpha)

    def _pick_direct(self, mask, vm, pool):
        # primary candidate list: feasible AND RsDiff above threshold;
        # relaxed to plain feasibility if that leaves no candidate
        if self.backend == "jax":
            rs_ok = self._rsdiff_ok(vm, pool)
            hid = self._score_pick(mask & rs_ok, vm, pool)
            if hid >= 0:
                return hid
            return self._score_pick(mask, vm, pool)
        # numpy hot path: compress once, apply Eqs. 1-2 on the candidates only
        return self._pick_direct_idx(np.flatnonzero(mask), vm, pool)

    def _pick_direct_idx(self, idx: np.ndarray, vm, pool) -> int:
        if idx.size == 0:
            return -1
        if idx.size == 1:
            return int(idx[0])  # RsDiff filtering cannot change a 1-set pick
        tot, util = pool.rsdiff_inputs()
        rs_ok = (vm.demand[0] / tot[idx] - util[idx] * self.rc
                 ) > self.threshold
        cand = idx[rs_ok] if rs_ok.any() else idx
        return hlem_pick_candidates_np(
            pool.free(), cand, pool.spot_frac_view(), self._alpha_for(vm))

    def find_host(self, vm, pool, now, allow_spot_clearing):
        if self.backend == "jax":
            direct = pool.direct_mask_into(vm.demand, vm.bid, vm.pool)
            if direct.any():
                return self._pick_direct(direct, vm, pool), False
        else:
            idx = pool.direct_idx_into(vm.demand, vm.bid, vm.pool)
            if idx.size:
                return self._pick_direct_idx(idx, vm, pool), False
        # spot-clearing list (Algorithm 1, lines 8-10) — on-demand only
        if allow_spot_clearing and not vm.is_spot:
            pool.refresh_reclaim(now)
            clearing = pool.clearing_mask_into(vm.demand, vm.bid, vm.pool)
            if clearing.any():
                return self._pick_direct(clearing, vm, pool), True
        return -1, False

    def find_direct(self, vm, pool):
        if self.backend == "jax":
            return super().find_direct(vm, pool)
        return self._pick_direct_idx(
            pool.direct_idx_into(vm.demand, vm.bid, vm.pool), vm, pool)

    def _pick_batch(self, feas, vms, pool):
        B = feas.shape[0]
        out = np.full(B, -1, dtype=np.int64)
        rows = np.flatnonzero(feas.any(axis=1))
        if rows.size == 0:
            return out
        # Eqs. 1-2 vectorized over the batch: rs[b, i] for every (VM, host)
        tot, util = pool.rsdiff_inputs()
        demands_cpu = np.array([vms[b].demand[0] for b in rows])
        rs_ok = (demands_cpu[:, None] / tot[None] - util[None] * self.rc
                 ) > self.threshold
        primary = feas[rows] & rs_ok
        use_primary = primary.any(axis=1)
        masks = np.where(use_primary[:, None], primary, feas[rows])
        alphas = np.array([self._alpha_for(vms[b]) for b in rows])
        scores = hlem_scores_batch_np(
            pool.free(), masks, pool.spot_frac_view(), alphas)
        out[rows] = np.argmax(scores, axis=1)
        return out


@register_policy("hlem-vmp-adjusted")
class HlemVmpAdjusted(HlemVmp):
    """Adjusted HLEM-VMP (§VI-C): spot-load-aware score AHS = HS*(1+α·SL).

    With α < 0 (default -0.5) spot-heavy hosts are penalized when placing spot
    VMs, spreading spot load across hosts to reduce interruption counts.
    ``adjust_spot_only=False`` applies the adjustment to on-demand placement
    too (then on-demand avoids spot-heavy hosts as well — fewer preemptions,
    beyond-paper variant benchmarked in EXPERIMENTS.md).
    """

    name = "hlem-vmp-adjusted"

    def __init__(self, rc: float = 0.95, threshold: float = 0.0,
                 alpha: float = -0.5, adjust_spot_only: bool = True,
                 backend: str = "numpy"):
        super().__init__(rc=rc, threshold=threshold, backend=backend)
        self.alpha = alpha
        self.adjust_spot_only = adjust_spot_only


#: live name → class view of the registry (kept for backward compatibility;
#: register new policies via ``register_policy``, not by mutating this)
POLICIES = POLICY_REGISTRY.entries


def make_policy(name: str, **kwargs) -> AllocationPolicy:
    return POLICY_REGISTRY.build(name, **kwargs)
