"""VM allocation policies (paper §II-D, §VI).

Each policy implements ``find_host(vm, pool, now, allow_spot_clearing)`` and
returns ``(host_id, needs_clearing)``; ``host_id == -1`` means no placement.
``needs_clearing`` signals that the chosen host only becomes feasible after
interrupting (some of) its spot VMs — the simulator performs the actual victim
selection and interruption (DynamicAllocation.spotAllocation in the paper).

Spot-clearing feasibility counts only *interruptible* spot VMs: those past
their minimum running time (§IV-B "minimum runtime must be enforced").
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .hlem import hlem_scores_np, hlem_select_jax, rsdiff_np
from .hosts import HostPool
from .types import Vm

_EPS = 1e-9


def direct_mask(vm: Vm, pool: HostPool) -> np.ndarray:
    """Hosts that fit the demand right now."""
    free = pool.free()
    return pool.active_view() & np.all(free >= vm.demand - _EPS, axis=1)


def clearing_mask(vm: Vm, pool: HostPool, now: float) -> np.ndarray:
    """Hosts that would fit the demand after deallocating their interruptible
    spot VMs (§VI-A: "checks the potential capacity of hosts if active spot
    instances were to be deallocated").

    Vectorized pre-filter: ``free + spot_used`` upper-bounds the reclaimable
    capacity, so only hosts passing that cheap test get the exact per-VM
    minimum-running-time check.
    """
    free = pool.free()
    active = pool.active_view()
    upper = active & np.all(free + pool.spot_used_view() >= vm.demand - _EPS, axis=1)
    out = np.zeros_like(upper)
    for hid in np.flatnonzero(upper):
        reclaim = free[hid].copy()
        for v in pool.residents[hid].values():
            if v.interruptible(now):
                reclaim += v.demand
        out[hid] = np.all(reclaim >= vm.demand - _EPS)
    return out


def feasibility_masks(vm: Vm, pool: HostPool, now: float):
    """(direct_mask, clearing_mask) — kept for tests; prefer the lazy pair."""
    return direct_mask(vm, pool), clearing_mask(vm, pool, now)


class AllocationPolicy:
    name = "abstract"

    def find_host(
        self, vm: Vm, pool: HostPool, now: float, allow_spot_clearing: bool
    ) -> Tuple[int, bool]:
        raise NotImplementedError

    def _pick(self, mask: np.ndarray, vm: Vm, pool: HostPool) -> int:
        raise NotImplementedError

    def find_host(self, vm, pool, now, allow_spot_clearing):
        hid = self._pick(direct_mask(vm, pool), vm, pool)
        if hid >= 0:
            return hid, False
        if allow_spot_clearing and not vm.is_spot:
            hid = self._pick(clearing_mask(vm, pool, now), vm, pool)
            if hid >= 0:
                return hid, True
        return -1, False


class FirstFit(AllocationPolicy):
    """CloudSim Plus baseline: first host (insertion order) that fits."""

    name = "first-fit"

    def _pick(self, mask, vm, pool):
        idx = np.flatnonzero(mask)
        return int(idx[0]) if idx.size else -1


class BestFit(AllocationPolicy):
    """Host with the least free CPU that still fits (tightest packing)."""

    name = "best-fit"

    def _pick(self, mask, vm, pool):
        if not mask.any():
            return -1
        free_cpu = np.where(mask, pool.free()[:, 0], np.inf)
        return int(np.argmin(free_cpu))

class WorstFit(AllocationPolicy):
    """Host with the most free CPU (max headroom)."""

    name = "worst-fit"

    def _pick(self, mask, vm, pool):
        if not mask.any():
            return -1
        free_cpu = np.where(mask, pool.free()[:, 0], -np.inf)
        return int(np.argmax(free_cpu))


class HlemVmp(AllocationPolicy):
    """HLEM-VMP (paper §VI-A/B).

    Phase 1 filters feasible hosts and applies the RsDiff threshold (Eqs. 1–2);
    if that leaves no candidate, the threshold filter is relaxed (and, for
    on-demand VMs, the spot-clearing candidate list is used — Algorithm 1).
    Phases 2–3 score candidates with entropy weights and pick the max.
    """

    name = "hlem-vmp"
    #: adjusted-variant knobs (unused in the base class)
    alpha = 0.0
    adjust_spot_only = True

    def __init__(self, rc: float = 0.95, threshold: float = 0.0,
                 backend: str = "numpy"):
        self.rc = rc
        self.threshold = threshold
        assert backend in ("numpy", "jax")
        self.backend = backend

    # -- phase 1 ------------------------------------------------------------
    def _rsdiff_ok(self, vm: Vm, pool: HostPool) -> np.ndarray:
        rs = rsdiff_np(vm.demand[0], pool.used_view()[:, 0],
                       pool.totals()[:, 0], self.rc)
        return rs > self.threshold

    # -- phases 2-3 ---------------------------------------------------------
    def _alpha_for(self, vm: Vm) -> float:
        if self.alpha != 0.0 and (vm.is_spot or not self.adjust_spot_only):
            return self.alpha
        return 0.0

    def _score_pick(self, mask: np.ndarray, vm: Vm, pool: HostPool) -> int:
        if not mask.any():
            return -1
        free = pool.free()
        tot = np.maximum(pool.totals(), _EPS)
        spot_frac = pool.spot_used_view() / tot
        alpha = self._alpha_for(vm)
        if self.backend == "jax":
            hid = int(hlem_select_jax(free, mask, spot_frac, np.float32(alpha)))
            return hid
        scores = hlem_scores_np(free, mask, spot_frac, alpha)
        return int(np.argmax(scores))

    def find_host(self, vm, pool, now, allow_spot_clearing):
        direct = direct_mask(vm, pool)
        rs_ok = self._rsdiff_ok(vm, pool)
        # primary candidate list: feasible AND RsDiff above threshold
        hid = self._score_pick(direct & rs_ok, vm, pool)
        if hid >= 0:
            return hid, False
        # relaxed: feasible regardless of RsDiff
        hid = self._score_pick(direct, vm, pool)
        if hid >= 0:
            return hid, False
        # spot-clearing list (Algorithm 1, lines 8-10) — on-demand only
        if allow_spot_clearing and not vm.is_spot:
            clearing = clearing_mask(vm, pool, now)
            hid = self._score_pick(clearing & rs_ok, vm, pool)
            if hid >= 0:
                return hid, True
            hid = self._score_pick(clearing, vm, pool)
            if hid >= 0:
                return hid, True
        return -1, False


class HlemVmpAdjusted(HlemVmp):
    """Adjusted HLEM-VMP (§VI-C): spot-load-aware score AHS = HS*(1+α·SL).

    With α < 0 (default -0.5) spot-heavy hosts are penalized when placing spot
    VMs, spreading spot load across hosts to reduce interruption counts.
    ``adjust_spot_only=False`` applies the adjustment to on-demand placement
    too (then on-demand avoids spot-heavy hosts as well — fewer preemptions,
    beyond-paper variant benchmarked in EXPERIMENTS.md).
    """

    name = "hlem-vmp-adjusted"

    def __init__(self, rc: float = 0.95, threshold: float = 0.0,
                 alpha: float = -0.5, adjust_spot_only: bool = True,
                 backend: str = "numpy"):
        super().__init__(rc=rc, threshold=threshold, backend=backend)
        self.alpha = alpha
        self.adjust_spot_only = adjust_spot_only


POLICIES = {
    "first-fit": FirstFit,
    "best-fit": BestFit,
    "worst-fit": WorstFit,
    "hlem-vmp": HlemVmp,
    "hlem-vmp-adjusted": HlemVmpAdjusted,
}


def make_policy(name: str, **kwargs) -> AllocationPolicy:
    return POLICIES[name](**kwargs)
