"""Simulation output & monitoring (paper §IV-B: execution history, interruption
counts, average interruption times) + table builders (§V-E-f) with CSV/JSON
export (§V-F TableBuilder extension)."""
from __future__ import annotations

import csv
import io
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from .causes import InterruptionCause
from .types import Vm, VmState, VmType


@dataclass
class InterruptionEvent:
    vm_id: int
    time: float
    host: int
    kind: str  # "terminate" | "hibernate" | "host-removed"
    #: why — one of :class:`repro.core.causes.InterruptionCause` (serialized
    #: verbatim; "capacity" is the classic on-demand-preemption default)
    cause: str = InterruptionCause.CAPACITY


@dataclass
class WaveEvent:
    """One price-driven interruption wave in one capacity pool: at ``time``
    the pool's clearing price crossed ``size`` resident spot bids."""
    time: float
    pool: int
    price: float
    size: int


@dataclass
class FaultRecord:
    """One injected market fault that fired during the run (``market/faults``).

    ``t1`` equals ``t0`` for instantaneous faults (storms); windowed faults
    (crunch / spike / outage) carry their scheduled end."""
    kind: str
    t0: float
    t1: float
    pools: tuple
    magnitude: float


@dataclass
class MigrationEvent:
    """One proactive cross-pool migration (MIGRATE_START → MIGRATE_COMPLETE).

    ``predicted_saving`` is the planner's net score at plan time in
    price·seconds (expected price-gap over the remaining work minus the
    downtime penalty).  ``t_complete`` stays -1 while in flight; ``failed``
    marks a flight whose destination stopped clearing (price spike above the
    bid, host removal) — the VM then takes its interruption behavior."""
    vm_id: int
    t_start: float
    src_host: int
    dst_host: int
    src_pool: int
    dst_pool: int
    predicted_saving: float
    t_complete: float = -1.0
    failed: bool = False
    #: the VM's bid when the flight left (realized-saving integrals cap at
    #: this, not the final bid — adaptive re-bidding may change it later)
    bid: float = float("inf")


def _timeline_bucket(state: VmState, vm_type: VmType) -> int:
    """Timeline column (1-4) a (state, type) pair contributes to, or 0."""
    if state in (VmState.RUNNING, VmState.INTERRUPTING):
        return 1 if vm_type is VmType.SPOT else 2
    if state is VmState.WAITING:
        return 3
    if state is VmState.HIBERNATED:
        return 4
    return 0


#: precomputed state -> bucket tables (one per VM type); on_transition runs
#: per VM state change, so it pays one enum-key dict lookup, not tuple hashing
_BUCKET_SPOT = {s: _timeline_bucket(s, VmType.SPOT) for s in VmState}
_BUCKET_OD = {s: _timeline_bucket(s, VmType.ON_DEMAND) for s in VmState}


@dataclass
class Metrics:
    """Collected over one simulation run.

    The timeline columns (active spot / active on-demand / waiting /
    hibernated) are maintained as O(1) incremental counters updated at each
    VM state transition (:meth:`on_transition`), replacing the original
    full-VM scan per event — at trace scale that scan made recording O(V²)
    over the run (the paper's §VII-D1 per-entity-update bottleneck)."""

    interruption_events: List[InterruptionEvent] = field(default_factory=list)
    # time series sampled at every state change: (t, active_spot, active_od,
    # waiting, hibernated)
    timeline: List[tuple] = field(default_factory=list)
    allocations: int = 0
    resubmissions: int = 0
    preemption_scans: int = 0
    # incremental state counters, indexed by _timeline_bucket (slot 0 unused)
    state_counts: List[int] = field(default_factory=lambda: [0, 0, 0, 0, 0])
    # -- market engine series (empty when no engine is attached) -------------
    # (t, pool, clearing price) per pool per PRICE_TICK
    price_series: List[tuple] = field(default_factory=list)
    wave_events: List[WaveEvent] = field(default_factory=list)
    # -- proactive migration subsystem (empty when no planner is attached) ---
    migration_events: List[MigrationEvent] = field(default_factory=list)
    migrations_planned: int = 0     # plans emitted by the planner
    migrations_started: int = 0     # flights that left their source host
    migrations_completed: int = 0   # arrivals placed on the destination
    migrations_failed: int = 0      # flights whose destination stopped clearing
    #: stop-and-copy seconds of *completed* migrations; a failed flight's
    #: downtime lands in the VM's interruption gap instead (one home each)
    migration_downtime: float = 0.0
    # -- fleet resilience layer (empty when no FleetManager is attached) -----
    #: (t, up_cpu, target_cpu) sampled by the fleet manager each PRICE_TICK
    fleet_samples: List[tuple] = field(default_factory=list)
    #: fallback-ladder rung usage: rung name -> replacement attempts routed
    #: through it (including the implicit initial "launch" rung)
    fallback_counts: Dict[str, int] = field(default_factory=dict)
    fleet_launches: int = 0         # spot launch attempts submitted
    od_spill_launches: int = 0      # on-demand fallback launches submitted
    fleet_slots_retired: int = 0    # slots that exhausted the ladder
    #: vm ids the fleet manager launched (spot / on-demand spill), for the
    #: batched realized-billing pass in :meth:`resilience_stats`
    fleet_spot_ids: List[int] = field(default_factory=list)
    fleet_od_ids: List[int] = field(default_factory=list)
    # -- fault injection (empty when no FaultInjector is attached) -----------
    fault_records: List[FaultRecord] = field(default_factory=list)
    # -- serving layer (empty when no ServeManager is attached) --------------
    #: (t, arrivals, rate, queue_depth, live_units, target_units) per
    #: SERVE_TICK, sampled after dispatch — the closed loop's flight data
    serve_samples: List[tuple] = field(default_factory=list)
    request_latencies: List[float] = field(default_factory=list)
    request_done_times: List[float] = field(default_factory=list)
    requests_arrived: int = 0
    requests_done: int = 0
    requests_requeued: int = 0      # in-flight requests bounced by VM loss
    #: (t, old_units, new_units) per AUTOSCALE evaluation (old == new when
    #: the policy or its hysteresis/cooldown damping held the target)
    autoscale_decisions: List[tuple] = field(default_factory=list)

    def on_transition(self, vm: Vm, old: VmState, new: VmState) -> None:
        """Update the incremental counters for one VM state change."""
        table = _BUCKET_SPOT if vm.vm_type is VmType.SPOT else _BUCKET_OD
        a = table[old]
        b = table[new]
        if a != b:
            if a:
                self.state_counts[a] -= 1
            if b:
                self.state_counts[b] += 1

    def record_sample(self, t: float) -> None:
        """Append a timeline sample from the incremental counters — O(1)."""
        c = self.state_counts
        self.timeline.append((t, c[1], c[2], c[3], c[4]))

    def record_state(self, t: float, vms: Dict[int, Vm]) -> None:
        """Legacy full-scan recording (O(V) per call); kept as the oracle the
        incremental counters are validated against in tests."""
        spot = od = waiting = hib = 0
        for v in vms.values():
            if v.state in (VmState.RUNNING, VmState.INTERRUPTING):
                if v.vm_type is VmType.SPOT:
                    spot += 1
                else:
                    od += 1
            elif v.state is VmState.WAITING:
                waiting += 1
            elif v.state is VmState.HIBERNATED:
                hib += 1
        self.timeline.append((t, spot, od, waiting, hib))

    # -- aggregate statistics -------------------------------------------------
    def interruption_count(self) -> int:
        return len(self.interruption_events)

    def spot_stats(self, vms: Dict[int, Vm]) -> dict:
        """Aggregates matching the paper's Figs. 14–15 and §VII-D2."""
        gaps: List[float] = []
        per_vm_interruptions: List[int] = []
        finished = finished_after_interruption = terminated = 0
        uninterrupted_finished = 0
        for v in vms.values():
            if v.vm_type is not VmType.SPOT:
                continue
            g = v.interruption_gaps()
            gaps.extend(g)
            per_vm_interruptions.append(v.interruptions)
            if v.state is VmState.FINISHED:
                finished += 1
                if v.interruptions > 0:
                    finished_after_interruption += 1
                else:
                    uninterrupted_finished += 1
            elif v.state is VmState.TERMINATED:
                terminated += 1
        return {
            "interruptions": self.interruption_count(),
            "avg_interruption_time": float(np.mean(gaps)) if gaps else 0.0,
            "max_interruption_time": float(np.max(gaps)) if gaps else 0.0,
            "min_interruption_time": float(np.min(gaps)) if gaps else 0.0,
            "max_interruptions_per_vm": int(max(per_vm_interruptions, default=0)),
            "resumed_gaps": len(gaps),
            "spot_finished": finished,
            "spot_finished_after_interruption": finished_after_interruption,
            "spot_finished_uninterrupted": uninterrupted_finished,
            "spot_terminated": terminated,
        }

    def market_stats(self) -> dict:
        """Price/wave aggregates of a market-engine run (paper-style market
        risk summary).  All-zero when no engine was attached."""
        waves = self.wave_events
        sizes = [w.size for w in waves]
        price_interruptions = sum(
            1 for e in self.interruption_events
            if e.cause == InterruptionCause.PRICE_WAVE)
        by_pool: Dict[int, List[float]] = {}
        for (_, pid, price) in self.price_series:
            by_pool.setdefault(pid, []).append(price)
        pool_rows = {
            pid: {
                "mean_price": float(np.mean(ps)),
                "max_price": float(np.max(ps)),
                "price_cv": float(np.std(ps) / max(np.mean(ps), 1e-12)),
            }
            for pid, ps in sorted(by_pool.items())
        }
        return {
            "waves": len(waves),
            "wave_victims": int(sum(sizes)),
            "max_wave_size": int(max(sizes, default=0)),
            "price_interruptions": price_interruptions,
            "pools": pool_rows,
        }

    def migration_stats(self, vms: Optional[Dict[int, Vm]] = None,
                        engine=None) -> dict:
        """Aggregates of the proactive migration subsystem.  With ``vms`` and
        the run's :class:`repro.market.engine.MarketEngine`, also reports the
        *realized* saving of each completed migration — the price-gap
        integral ∫ (price_src − price_dst) dt (both capped at the VM's bid,
        matching billing) over the interval the VM actually ran on its
        destination — next to the planner's prediction."""
        out = {
            "planned": self.migrations_planned,
            "started": self.migrations_started,
            "completed": self.migrations_completed,
            "failed": self.migrations_failed,
            "downtime_s": round(self.migration_downtime, 3),
            "predicted_saving": float(sum(
                e.predicted_saving for e in self.migration_events
                if e.t_complete >= 0 and not e.failed)),
        }
        if vms is None or engine is None:
            return out
        # an interval still open at end-of-run realizes savings up to the
        # engine's last reprice (otherwise in-flight migrations would count
        # their prediction but contribute zero realization)
        ts = engine.tick_times()
        end = float(ts[-1]) if ts.size else 0.0
        # gather every realized span, then bill src and dst in one batched
        # price_integrals call each (the scalar capped integral scans the
        # whole price history per call — per-event billing would be
        # O(events × ticks))
        src_p: List[int] = []
        dst_p: List[int] = []
        t0s: List[float] = []
        t1s: List[float] = []
        caps: List[float] = []
        for e in self.migration_events:
            if e.t_complete < 0 or e.failed:
                continue
            vm = vms[e.vm_id]
            for itv in vm.history:
                if itv.start == e.t_complete and itv.host == e.dst_host:
                    stop = (itv.stop if itv.stop is not None
                            else max(end, e.t_complete))
                    src_p.append(e.src_pool)
                    dst_p.append(e.dst_pool)
                    t0s.append(itv.start)
                    t1s.append(stop)
                    caps.append(e.bid)
                    break
        t0a, t1a, capa = (np.asarray(t0s), np.asarray(t1s),
                          np.asarray(caps))
        src_int = engine.price_integrals(np.asarray(src_p, dtype=np.int64),
                                         t0a, t1a, capa)
        dst_int = engine.price_integrals(np.asarray(dst_p, dtype=np.int64),
                                         t0a, t1a, capa)
        # sequential left-to-right accumulation, matching the historical
        # per-event loop bit for bit (a .sum()-of-sums reorders the floats)
        out["realized_saving"] = float(sum((src_int - dst_int).tolist(),
                                           0.0))
        return out

    def resilience_stats(self, vms: Optional[Dict[int, Vm]] = None,
                         engine=None, host_pool=None) -> dict:
        """Fleet resilience aggregates (all-zero when no fleet manager ran).

        Core statistics integrate the per-tick ``fleet_samples`` series
        piecewise-constant: *time below target capacity* (seconds the fleet's
        running CPU sat under its effective target), *shortfall area*
        (∫ max(target − up, 0) dt, CPU·seconds — how deep × how long), and a
        per-fault *recovery time* (from the fault start to the first sample
        back at target after the dip; censored at the last sample when the
        fleet never recovered).  With ``vms`` + the run's engine + host pool,
        also bills the fleet's realized cost: spot launches through one
        batched :meth:`~repro.market.engine.MarketEngine.price_integrals`
        call (clearing price capped at bid, the billing contract), on-demand
        spill at the pools' flat on-demand rates — both in price·hours, the
        same unit as :func:`~repro.market.pricing.realized_cost_stats`."""
        samples = self.fleet_samples
        out = {
            "time_below_target": 0.0,
            "shortfall_area": 0.0,
            "time_below_frac": 0.0,
            "fleet_launches": self.fleet_launches,
            "od_spill_launches": self.od_spill_launches,
            "slots_retired": self.fleet_slots_retired,
            "fallback_counts": dict(sorted(self.fallback_counts.items())),
            "faults_fired": len(self.fault_records),
            "mean_recovery_s": 0.0,
            "max_recovery_s": 0.0,
        }
        if len(samples) >= 2:
            arr = np.asarray(samples, dtype=np.float64)
            t, up, tgt = arr[:, 0], arr[:, 1], arr[:, 2]
            dt = np.diff(t)
            short = np.maximum(tgt[:-1] - up[:-1], 0.0)
            below = short > 1e-12
            out["time_below_target"] = float(np.sum(dt[below]))
            out["shortfall_area"] = float(np.sum(short * dt))
            span = float(t[-1] - t[0])
            if span > 0:
                out["time_below_frac"] = out["time_below_target"] / span
            # per-fault recovery: from the fault start, find the dip below
            # the effective target, then the first sample back at it
            recoveries = []
            fault_rows = []
            for rec in self.fault_records:
                after = np.flatnonzero(t >= rec.t0 - 1e-9)
                r = 0.0
                censored = False
                if after.size:
                    dips = after[up[after] < tgt[after] - 1e-12]
                    if dips.size:
                        d0 = dips[0]
                        back = np.flatnonzero(up[d0:] >= tgt[d0:] - 1e-12)
                        if back.size:
                            r = float(t[d0 + back[0]] - rec.t0)
                        else:
                            r = float(t[-1] - rec.t0)
                            censored = True
                recoveries.append(r)
                fault_rows.append({
                    "kind": rec.kind, "t0": rec.t0,
                    "recovery_s": round(r, 3), "censored": censored,
                })
            if recoveries:
                out["mean_recovery_s"] = float(np.mean(recoveries))
                out["max_recovery_s"] = float(np.max(recoveries))
            out["faults"] = fault_rows
        if vms is None or engine is None or host_pool is None:
            return out
        # realized fleet billing: one batched integral call for every closed
        # spot interval, flat od rate × duration for the spill
        pool_of = host_pool.pool_of
        pids: List[int] = []
        t0s: List[float] = []
        t1s: List[float] = []
        caps: List[float] = []
        for vid in self.fleet_spot_ids:
            vm = vms[vid]
            for itv in vm.history:
                if itv.stop is None:
                    continue
                pids.append(int(pool_of[itv.host]))
                t0s.append(itv.start)
                t1s.append(itv.stop)
                caps.append(vm.bid)
        integrals = engine.price_integrals(
            np.asarray(pids, dtype=np.int64), np.asarray(t0s),
            np.asarray(t1s), np.asarray(caps))
        out["fleet_spot_cost"] = float(sum(integrals.tolist(), 0.0)) / 3600.0
        od_rates = engine.od_rates
        spill = 0.0
        for vid in self.fleet_od_ids:
            vm = vms[vid]
            for itv in vm.history:
                if itv.stop is None:
                    continue
                spill += float(od_rates[int(pool_of[itv.host])]) * (
                    itv.stop - itv.start) / 3600.0
        out["od_spill_cost"] = spill
        return out


# ---------------------------------------------------------------------------
# Table builders (DynamicVmTableBuilder / SpotVmTableBuilder /
# ExecutionTableBuilder equivalents)
# ---------------------------------------------------------------------------
def dynamic_vm_table(vms: List[Vm]) -> List[dict]:
    rows = []
    for v in vms:
        start = v.history[0].start if v.history else -1.0
        stop = v.history[-1].stop if v.history and v.history[-1].stop is not None else -1.0
        rows.append({
            "vm_id": v.id,
            "host": v.history[-1].host if v.history else -1,
            "cpu": float(v.demand[0]),
            "ram": float(v.demand[1]),
            "start_time": start,
            "stop_time": stop,
            "submission_delay": v.submit_time,
            "type": v.vm_type.value,
            "state": v.state.value,
        })
    return rows


def spot_vm_table(vms: List[Vm]) -> List[dict]:
    rows = []
    for v in vms:
        if v.vm_type is not VmType.SPOT:
            continue
        rows.append({
            "vm_id": v.id,
            "cpu": float(v.demand[0]),
            "state": v.state.value,
            "interruptions": v.interruptions,
            "avg_interruption_time": v.average_interruption_time(),
        })
    return rows


def execution_table(vms: List[Vm]) -> List[dict]:
    rows = []
    for v in vms:
        for i, itv in enumerate(v.history):
            rows.append({
                "vm_id": v.id,
                "interval": i,
                "host": itv.host,
                "start": itv.start,
                "stop": itv.stop if itv.stop is not None else -1.0,
            })
    return rows


def to_csv(rows: List[dict], path: Optional[str] = None) -> str:
    buf = io.StringIO()
    if rows:
        writer = csv.DictWriter(buf, fieldnames=list(rows[0].keys()))
        writer.writeheader()
        writer.writerows(rows)
    out = buf.getvalue()
    if path:
        with open(path, "w") as f:
            f.write(out)
    return out


def to_json(rows: List[dict], path: Optional[str] = None) -> str:
    out = json.dumps(rows, indent=1)
    if path:
        with open(path, "w") as f:
            f.write(out)
    return out
