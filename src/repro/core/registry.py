"""String-keyed plugin registries (the scenario API's extension points).

Every pluggable family in the simulator — allocation policies, bid
strategies, migration policies, price processes, workload generators — is a
:class:`Registry`: a name → factory mapping with a uniform registration
decorator and a fail-fast error message that lists the known names.  The
legacy factory helpers (``make_policy``, ``make_bid_strategy``,
``make_migration_planner``, …) delegate here, so examples and tests can add
custom strategies without touching core:

    from repro.core.registry import Registry
    from repro.core.allocation import POLICY_REGISTRY

    @POLICY_REGISTRY.register("my-policy")
    class MyPolicy(AllocationPolicy):
        ...

    make_policy("my-policy")          # now resolves
    ScenarioSpec / PolicySpec("my-policy")  # and validates in the spec tree
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, Optional


class Registry:
    """Ordered name → factory mapping with decorator registration.

    ``kind`` names the family in error messages ("allocation policy", …).
    Factories are arbitrary callables (classes or functions); ``build``
    invokes them with the caller's kwargs.
    """

    def __init__(self, kind: str):
        self.kind = kind
        self.entries: Dict[str, Any] = {}

    # -- registration --------------------------------------------------------
    def register(self, name: str, obj: Any = None,
                 overwrite: bool = False) -> Callable:
        """Register ``obj`` under ``name``; usable as a decorator:

            @REG.register("name")
            class Thing: ...
        """
        def _add(target: Any) -> Any:
            if not overwrite and name in self.entries:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered "
                    f"(pass overwrite=True to replace it)")
            self.entries[name] = target
            return target

        return _add if obj is None else _add(obj)

    # -- lookup --------------------------------------------------------------
    def get(self, name: str) -> Any:
        try:
            return self.entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} {name!r} "
                f"(known: {', '.join(self.names()) or '<none>'})") from None

    def build(self, name: str, **kwargs: Any) -> Any:
        return self.get(name)(**kwargs)

    def names(self) -> tuple:
        return tuple(self.entries)

    def __contains__(self, name: object) -> bool:
        return name in self.entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.entries)

    def __len__(self) -> int:
        return len(self.entries)

    def __repr__(self) -> str:
        return f"Registry({self.kind!r}, {list(self.entries)})"
