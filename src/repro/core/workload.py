"""Workload and infrastructure generators.

``synthetic_scenario`` reproduces the paper's §VII-E evaluation setup exactly:
Table II host fleet (20/30/30/20 small..x-large), Table III VM profiles with
the per-profile spot / on-demand counts, 400 spot + 600 on-demand submitted at
t=0 and the remaining 1 000 with randomized delays.  All randomized draws come
from a seeded generator so different allocation policies see *identical*
workloads ("the same randomized values were reused across all simulation
runs", §VII-E2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple

import numpy as np

from .types import (
    InterruptionBehavior,
    Vm,
    make_on_demand,
    make_spot,
    resources,
)

# --- paper Table II ---------------------------------------------------------
HOST_TYPES = {
    "small": resources(8, 16_384, 5_000, 200_000),
    "medium": resources(16, 32_768, 10_000, 400_000),
    "large": resources(32, 65_536, 20_000, 800_000),
    "x-large": resources(64, 131_072, 40_000, 1_600_000),
}
HOST_COUNTS = {"small": 20, "medium": 30, "large": 30, "x-large": 20}

# --- paper Table III --------------------------------------------------------
# (cpu, ram, bw, storage, #spot, #on-demand)
VM_PROFILES: List[Tuple[float, float, float, float, int, int]] = [
    (1, 1_024, 100, 10_000, 31, 160),
    (2, 1_024, 100, 10_000, 42, 175),
    (1, 2_048, 200, 20_000, 36, 168),
    (2, 2_048, 200, 20_000, 44, 146),
    (4, 2_048, 200, 20_000, 40, 158),
    (4, 4_096, 500, 50_000, 40, 145),
    (6, 4_096, 500, 50_000, 36, 170),
    (6, 8_192, 1_000, 80_000, 51, 155),
    (8, 8_192, 1_000, 80_000, 33, 162),
    (10, 8_192, 1_000, 80_000, 47, 168),
]


@dataclass
class ScenarioConfig:
    seed: int = 0
    # workload timing (paper leaves the ranges unspecified; these are
    # calibrated so interruption counts land in the paper's range — a few
    # hundred total, ~2 max per VM — then held fixed across policies)
    duration_range: Tuple[float, float] = (50.0, 200.0)
    delay_range: Tuple[float, float] = (0.0, 900.0)
    immediate_on_demand: int = 600
    # spot lifecycle parameters (§V-C time-based parameters)
    spot_behavior: InterruptionBehavior = InterruptionBehavior.HIBERNATE
    min_running_time: float = 5.0
    hibernation_timeout: float = 600.0
    waiting_timeout: float = 600.0
    warning_time: float = 0.0


def build_hosts() -> List[np.ndarray]:
    hosts = []
    for name, count in HOST_COUNTS.items():
        hosts.extend([HOST_TYPES[name].copy() for _ in range(count)])
    return hosts


def synthetic_scenario(cfg: ScenarioConfig | None = None):
    """Returns (host_capacities, vms) for the §VII-E comparison."""
    cfg = cfg or ScenarioConfig()
    rng = np.random.default_rng(cfg.seed)
    hosts = build_hosts()

    vms: List[Vm] = []
    vm_id = 0
    spot_vms: List[Vm] = []
    od_vms: List[Vm] = []
    for cpu, ram, bw, st, n_spot, n_od in VM_PROFILES:
        demand = resources(cpu, ram, bw, st)
        for _ in range(n_spot):
            dur = rng.uniform(*cfg.duration_range)
            spot_vms.append(make_spot(
                vm_id, demand.copy(), dur,
                behavior=cfg.spot_behavior,
                min_running_time=cfg.min_running_time,
                hibernation_timeout=cfg.hibernation_timeout,
                waiting_timeout=cfg.waiting_timeout,
            ))
            vm_id += 1
        for _ in range(n_od):
            dur = rng.uniform(*cfg.duration_range)
            od_vms.append(make_on_demand(
                vm_id, demand.copy(), dur,
                waiting_timeout=cfg.waiting_timeout,
            ))
            vm_id += 1

    # 400 spot + 600 on-demand immediately; remaining on-demand delayed
    rng.shuffle(od_vms)
    for v in od_vms[cfg.immediate_on_demand:]:
        v.submit_time = float(rng.uniform(*cfg.delay_range))
    vms = spot_vms + od_vms
    vms.sort(key=lambda v: (v.submit_time, v.id))
    return hosts, vms


@dataclass
class MarketScenarioConfig:
    """Workload for the dynamic-market / migration experiments (beyond-paper).

    The §VII-E scenario's 50–200 s VMs are too short-lived relative to a
    60 s price tick for market dynamics to matter.  This scenario keeps the
    Table III profile mix but models a *regional spot market day*: long-
    running spot VMs (pool-flexible, submitted up front) ride out staggered
    regional on-demand demand humps (pool-pinned, diurnal-style arrival
    waves per §VII trace Fig. 9) that push each capacity pool's utilization
    — and hence its clearing price — up and back down in sequence.  Rolling,
    *predictable* per-pool price ramps are exactly the regime where
    proactive cross-pool migration is supposed to earn its keep."""

    seed: int = 0
    n_pools: int = 4
    #: host fleet = Table II fleet tiled and cut to 100 × fleet_scale hosts
    fleet_scale: float = 1.7
    spot_duration_range: Tuple[float, float] = (7_200.0, 10_800.0)
    spot_submit_window: float = 600.0
    min_running_time: float = 300.0
    hibernation_timeout: float = 3_600.0
    od_duration_range: Tuple[float, float] = (1_200.0, 4_800.0)
    #: pool p's on-demand wave arrives in
    #: [hump_start + p·hump_spacing, … + hump_width]
    od_hump_start: float = 600.0
    od_hump_spacing: float = 2_400.0
    od_hump_width: float = 2_400.0
    spot_behavior: InterruptionBehavior = InterruptionBehavior.HIBERNATE


def market_scenario(cfg: MarketScenarioConfig | None = None):
    """Returns (host_capacities, host_pool_ids, vms) for the market-regime
    comparison (``market_sim --market``).  All draws are seeded: every
    (allocation policy × migration policy) combination sees the identical
    workload."""
    cfg = cfg or MarketScenarioConfig()
    rng = np.random.default_rng(cfg.seed)
    base = build_hosts()
    n_hosts = int(round(len(base) * cfg.fleet_scale))
    tiles = -(-n_hosts // len(base))  # ceil
    hosts = (base * tiles)[:n_hosts]
    pool_ids = [i % cfg.n_pools for i in range(n_hosts)]

    vms: List[Vm] = []
    vid = 0
    for cpu, ram, bw, st, n_spot, n_od in VM_PROFILES:
        demand = resources(cpu, ram, bw, st)
        for _ in range(n_spot):
            vms.append(make_spot(
                vid, demand.copy(),
                float(rng.uniform(*cfg.spot_duration_range)),
                behavior=cfg.spot_behavior,
                min_running_time=cfg.min_running_time,
                hibernation_timeout=cfg.hibernation_timeout,
                submit_time=float(rng.uniform(0.0, cfg.spot_submit_window)),
            ))
            vid += 1
        for _ in range(n_od):
            p = vid % cfg.n_pools
            t0 = (cfg.od_hump_start + p * cfg.od_hump_spacing
                  + float(rng.uniform(0.0, cfg.od_hump_width)))
            vms.append(make_on_demand(
                vid, demand.copy(),
                float(rng.uniform(*cfg.od_duration_range)),
                submit_time=t0, pool=p,
            ))
            vid += 1
    vms.sort(key=lambda v: (v.submit_time, v.id))
    return hosts, pool_ids, vms


def random_fleet(n_hosts: int, seed: int = 0) -> List[np.ndarray]:
    """Uniform random fleet drawn from the Table II types (for property tests
    and throughput benchmarks)."""
    rng = np.random.default_rng(seed)
    types = list(HOST_TYPES.values())
    return [types[rng.integers(len(types))].copy() for _ in range(n_hosts)]


def random_vms(n_vms: int, seed: int = 0, spot_fraction: float = 0.4,
               t_max: float = 300.0,
               behavior: InterruptionBehavior = InterruptionBehavior.HIBERNATE,
               ) -> List[Vm]:
    rng = np.random.default_rng(seed)
    out: List[Vm] = []
    for i in range(n_vms):
        cpu, ram, bw, st, _, _ = VM_PROFILES[rng.integers(len(VM_PROFILES))]
        demand = resources(cpu, ram, bw, st)
        dur = float(rng.uniform(20.0, 300.0))
        t0 = float(rng.uniform(0.0, t_max))
        if rng.random() < spot_fraction:
            out.append(make_spot(i, demand, dur, behavior=behavior,
                                 min_running_time=2.0,
                                 hibernation_timeout=300.0,
                                 waiting_timeout=300.0, submit_time=t0))
        else:
            out.append(make_on_demand(i, demand, dur, waiting_timeout=300.0,
                                      submit_time=t0))
    return out
