"""Per-subsystem self/total wall-time profile from tracer span aggregates.

The profiling mode (``Tracer(profile=True)``) folds every span into a
``(cat, name) -> [count, total_s, self_s]`` dict online; this module turns
that into the sorted table committed as ``results/profile/PROFILE_pr7.json``
— the ROADMAP direction-1 evidence for where per-event Python time goes.

*self* time is a span's duration minus its traced children, so rows sum to
(approximately) total traced wall time without double-counting nesting:
``dispatch/price-tick`` contains the tick phases, the tick phases contain
planner scoring, and each level reports only its own residue.
"""
from __future__ import annotations

import json
import os
from typing import List, Optional


def profile_table(tracer) -> List[dict]:
    """Sorted (self-time descending) per-span-site rows."""
    prof = tracer.profile()
    total_self = sum(v[2] for v in prof.values()) or 1.0
    rows = []
    for (cat, name), (count, total, self_t) in prof.items():
        rows.append({
            "cat": cat,
            "name": name,
            "count": count,
            "total_ms": round(total * 1e3, 6),
            "self_ms": round(self_t * 1e3, 6),
            "self_pct": round(100.0 * self_t / total_self, 3),
            "self_us_per_call": round(self_t * 1e6 / max(count, 1), 3),
        })
    rows.sort(key=lambda r: (-r["self_ms"], r["cat"], r["name"]))
    return rows


def profile_report(tracer, manifest: Optional[dict] = None) -> dict:
    rows = profile_table(tracer)
    doc = {
        "total_self_ms": round(sum(r["self_ms"] for r in rows), 6),
        "wall_elapsed_ms": round(tracer.wall_elapsed() * 1e3, 6),
        "rows": rows,
    }
    if rows:
        doc["dominant"] = {"cat": rows[0]["cat"], "name": rows[0]["name"],
                           "self_pct": rows[0]["self_pct"]}
    if manifest is not None:
        doc["manifest"] = manifest
    return doc


def write_profile(tracer, path: str,
                  manifest: Optional[dict] = None) -> dict:
    doc = profile_report(tracer, manifest)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    return doc


def format_profile_table(tracer, top: int = 20) -> str:
    """Human-readable table for terminal output (``--profile``)."""
    rows = profile_table(tracer)
    lines = [f"{'subsystem':<42} {'count':>9} {'total ms':>11} "
             f"{'self ms':>11} {'self %':>7} {'self us/call':>13}"]
    for r in rows[:top]:
        site = f"{r['cat']}:{r['name']}"
        lines.append(f"{site:<42} {r['count']:>9} {r['total_ms']:>11.3f} "
                     f"{r['self_ms']:>11.3f} {r['self_pct']:>7.2f} "
                     f"{r['self_us_per_call']:>13.3f}")
    if len(rows) > top:
        lines.append(f"... ({len(rows) - top} more rows)")
    return "\n".join(lines)
