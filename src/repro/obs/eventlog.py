"""Structured event flight recorder (ISSUE 8).

An :class:`EventLog` is an append-only log of every lifecycle and market
event a run produces — submit / start / resume / interrupt / hibernate /
terminate / finish, migrate plan / start / complete, price ticks, waves,
faults, fleet fallback rungs, allocation flushes, host add/remove.  It is
the per-run substrate the paper's "market risk" analytics need (storm
timing, per-VM timelines, pool-level exposure) and the input to the
first-divergence diff that debugs bit-identity failures
(:mod:`repro.obs.diff`).

Storage is *columnar*: eight parallel columns (sim time, interned kind id,
vm / pool / host ids, two float payload slots, interned aux-string id), so
a multi-hundred-thousand-event run costs a few flat Python lists while
recording and exports to dense numpy arrays for the vectorized queries in
:mod:`repro.obs.analyze`.  Two interchangeable on-disk formats:

* **NDJSON** — a header record (schema, version, string tables, manifest)
  followed by one JSON object per event.  ``json`` float repr round-trips
  exactly, so NDJSON logs preserve bit-identity and two runs can be diffed
  line-by-line or streamed through :func:`repro.obs.diff.first_divergence`.
* **npz** — ``numpy.savez_compressed`` of the columns + string tables, the
  compact archival format for committed artifacts.

Overhead contract (the PR 7 pattern): :data:`NULL_RECORDER` is the default
``events`` attribute everywhere, every emit site guards on
``events.enabled`` (one attribute load + branch), and a log-off run takes
the untouched plain event loop.  Nothing here draws randomness or mutates
engine state — recording is observation-only, so logged and unlogged runs
of the same spec + seed produce byte-identical metrics (regression-tested
in ``tests/obs/test_eventlog.py``; perf half CI-gated via
``obs/eventlog_overhead``).
"""
from __future__ import annotations

import enum
import json
import os
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

SCHEMA = "repro.eventlog"
SCHEMA_VERSION = 1


class LogEventKind(str, enum.Enum):
    """The full event vocabulary — the single source of truth.

    Validation (:func:`validate_event_log`), the detlint ``event-coverage``
    pass, and the analytics layer all derive their known-kind sets from
    this enum, so adding a kind here without wiring its emit site (or vice
    versa) fails closed instead of silently passing.
    """

    SUBMIT = "submit"
    START = "start"
    RESUME = "resume"
    FINISH = "finish"
    FAIL = "fail"
    INTERRUPT = "interrupt"
    HIBERNATE = "hibernate"
    TERMINATE = "terminate"
    MIGRATE_PLAN = "migrate-plan"
    MIGRATE_START = "migrate-start"
    MIGRATE_COMPLETE = "migrate-complete"
    PRICE_TICK = "price-tick"
    WAVE = "wave"
    FAULT = "fault"
    FLEET_RUNG = "fleet-rung"
    FLEET_LAUNCH = "fleet-launch"
    FLEET_RETIRE = "fleet-retire"
    ALLOC_FLUSH = "alloc-flush"
    HOST_ADD = "host-add"
    HOST_REMOVE = "host-remove"
    # -- serving layer (PR 10): request flow + autoscaler decisions ---------
    REQUEST_ARRIVE = "request-arrive"     # per serve tick: a=count, b=rate
    REQUEST_DONE = "request-done"         # per request: a=latency_s, b=tokens
    REQUEST_REQUEUE = "request-requeue"   # VM loss: a=in-flight, b=moved
    SERVE_SAMPLE = "serve-sample"         # per serve tick: a=depth, b=live
    AUTOSCALE = "autoscale"               # per decision: a=new, b=old units


#: kept as a tuple for existing callers; derived from the enum above
EVENT_KINDS = tuple(k.value for k in LogEventKind)

#: one normalized record: (t, kind, vm, pool, host, a, b, aux)
Record = Tuple[float, str, int, int, int, float, float, Optional[str]]

_FIELDS = ("t", "k", "vm", "pool", "host", "a", "b", "x")


class NullRecorder:
    """Inert event recorder: ``enabled`` is False and ``emit`` is a no-op.

    Every ``events`` attribute defaults to the :data:`NULL_RECORDER`
    singleton, so emit sites cost one attribute load + branch and never
    need a ``None`` check — the same contract as
    :class:`repro.obs.tracer.NullTracer`."""

    enabled = False

    def emit(self, t: float, kind: str, vm: int = -1, pool: int = -1,
             host: int = -1, a: float = 0.0, b: float = 0.0,
             aux: Optional[str] = None) -> None:
        pass

    def __len__(self) -> int:
        return 0

    def records(self) -> Iterator[Record]:
        return iter(())


#: the default recorder everywhere an ``events`` attribute exists
NULL_RECORDER = NullRecorder()


class EventLog:
    """Columnar append-only event log with interned string tables.

    ``emit`` appends one row; kinds and aux strings are interned into
    per-log tables so the hot path stores only small ints.  An optional
    ``[t_min, t_max)`` window drops events outside it at emit time — the
    windowed-rerun mode :func:`repro.obs.diff.bisect_divergence` uses to
    keep divergence hunting at trace scale out of memory trouble."""

    enabled = True

    def __init__(self, t_min: Optional[float] = None,
                 t_max: Optional[float] = None) -> None:
        self.t_min = t_min
        self.t_max = t_max
        self._t: List[float] = []
        self._kind: List[int] = []
        self._vm: List[int] = []
        self._pool: List[int] = []
        self._host: List[int] = []
        self._a: List[float] = []
        self._b: List[float] = []
        self._aux: List[int] = []
        self._kind_ids: Dict[str, int] = {}
        self._kinds: List[str] = []
        self._aux_ids: Dict[str, int] = {}
        self._auxs: List[str] = []

    # -------------------------------------------------------------- emit
    def emit(self, t: float, kind: str, vm: int = -1, pool: int = -1,
             host: int = -1, a: float = 0.0, b: float = 0.0,
             aux: Optional[str] = None) -> None:
        if self.t_min is not None and t < self.t_min:
            return
        if self.t_max is not None and t >= self.t_max:
            return
        k = self._kind_ids.get(kind)
        if k is None:
            k = self._kind_ids[kind] = len(self._kinds)
            self._kinds.append(kind)
        if aux is None:
            x = -1
        else:
            x = self._aux_ids.get(aux)
            if x is None:
                x = self._aux_ids[aux] = len(self._auxs)
                self._auxs.append(aux)
        self._t.append(t)
        self._kind.append(k)
        self._vm.append(vm)
        self._pool.append(pool)
        self._host.append(host)
        self._a.append(a)
        self._b.append(b)
        self._aux.append(x)

    def __len__(self) -> int:
        return len(self._t)

    # ------------------------------------------------------------- views
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Dense columns for vectorized queries: ``t`` / ``a`` / ``b`` as
        float64, ``kind`` / ``pool`` / ``host`` / ``aux`` as int32, ``vm``
        as int64, plus the ``kinds`` / ``auxs`` string tables."""
        return {
            "t": np.asarray(self._t, dtype=np.float64),
            "kind": np.asarray(self._kind, dtype=np.int32),
            "vm": np.asarray(self._vm, dtype=np.int64),
            "pool": np.asarray(self._pool, dtype=np.int32),
            "host": np.asarray(self._host, dtype=np.int32),
            "a": np.asarray(self._a, dtype=np.float64),
            "b": np.asarray(self._b, dtype=np.float64),
            "aux": np.asarray(self._aux, dtype=np.int32),
            "kinds": np.asarray(self._kinds, dtype=object),
            "auxs": np.asarray(self._auxs, dtype=object),
        }

    def kind_id(self, kind: str) -> int:
        """The interned id of ``kind`` in this log, or -1 if the run never
        emitted it (so ``arrays['kind'] == -1`` matches nothing)."""
        return self._kind_ids.get(kind, -1)

    def aux_id(self, aux: str) -> int:
        """The interned id of ``aux``, or -1 if never emitted (-1 is also
        the column value for records with no aux — match kinds first)."""
        return self._aux_ids.get(aux, -1)

    def records(self) -> Iterator[Record]:
        """Normalized record tuples in emit order — the diffable view."""
        kinds, auxs = self._kinds, self._auxs
        for i in range(len(self._t)):
            x = self._aux[i]
            yield (self._t[i], kinds[self._kind[i]], self._vm[i],
                   self._pool[i], self._host[i], self._a[i], self._b[i],
                   auxs[x] if x >= 0 else None)

    # ---------------------------------------------------------------- I/O
    def save(self, path: str, manifest: Optional[dict] = None) -> str:
        """Write the log to ``path`` — ``.npz`` selects the compact binary
        format, anything else NDJSON."""
        d = os.path.dirname(path)
        if d:
            os.makedirs(d, exist_ok=True)
        if path.endswith(".npz"):
            return self.save_npz(path, manifest=manifest)
        return self.write_ndjson(path, manifest=manifest)

    def write_ndjson(self, path: str,
                     manifest: Optional[dict] = None) -> str:
        header = {"type": "header", "schema": SCHEMA,
                  "version": SCHEMA_VERSION, "n": len(self._t),
                  "kinds": list(self._kinds), "auxs": list(self._auxs)}
        if manifest is not None:
            header["manifest"] = manifest
        with open(path, "w") as f:
            f.write(json.dumps(header, sort_keys=True) + "\n")
            for i in range(len(self._t)):
                x = self._aux[i]
                f.write(json.dumps(
                    {"t": self._t[i], "k": self._kinds[self._kind[i]],
                     "vm": self._vm[i], "pool": self._pool[i],
                     "host": self._host[i], "a": self._a[i],
                     "b": self._b[i],
                     "x": self._auxs[x] if x >= 0 else None}) + "\n")
        return path

    def save_npz(self, path: str, manifest: Optional[dict] = None) -> str:
        arrays = self.to_arrays()
        arrays["kinds"] = arrays["kinds"].astype(str)
        arrays["auxs"] = arrays["auxs"].astype(str)
        meta = {"schema": SCHEMA, "version": SCHEMA_VERSION}
        if manifest is not None:
            meta["manifest"] = manifest
        np.savez_compressed(path, meta=json.dumps(meta, sort_keys=True),
                            **arrays)
        return path


# ---------------------------------------------------------------------------
# loading
# ---------------------------------------------------------------------------
def load_event_log(path: str) -> EventLog:
    """Rebuild an :class:`EventLog` from either on-disk format (the
    analytics / report entry point; for memory-bounded diffing of NDJSON
    logs stream :func:`iter_event_records` instead)."""
    log = EventLog()
    for t, kind, vm, pool, host, a, b, aux in iter_event_records(path):
        log.emit(t, kind, vm=vm, pool=pool, host=host, a=a, b=b, aux=aux)
    return log


def read_manifest(path: str) -> Optional[dict]:
    """The manifest block a log was saved with, or None."""
    if path.endswith(".npz"):
        with np.load(path, allow_pickle=False) as z:
            return json.loads(str(z["meta"])).get("manifest")
    with open(path) as f:
        return json.loads(f.readline()).get("manifest")


def iter_event_records(path: str) -> Iterator[Record]:
    """Stream normalized records from an on-disk log.  NDJSON logs are read
    line-by-line (O(1) memory — the diff's streaming mode); npz logs load
    their columns once and iterate."""
    if path.endswith(".npz"):
        with np.load(path, allow_pickle=False) as z:
            cols = {k: z[k] for k in
                    ("t", "kind", "vm", "pool", "host", "a", "b", "aux")}
            kinds = [str(s) for s in z["kinds"]]
            auxs = [str(s) for s in z["auxs"]]
        for i in range(cols["t"].size):
            x = int(cols["aux"][i])
            yield (float(cols["t"][i]), kinds[int(cols["kind"][i])],
                   int(cols["vm"][i]), int(cols["pool"][i]),
                   int(cols["host"][i]), float(cols["a"][i]),
                   float(cols["b"][i]), auxs[x] if x >= 0 else None)
        return
    with open(path) as f:
        header = json.loads(f.readline())
        if header.get("schema") != SCHEMA:
            raise ValueError(f"{path}: not a {SCHEMA} NDJSON file "
                             f"(header schema {header.get('schema')!r})")
        for line in f:
            if not line.strip():
                continue
            d = json.loads(line)
            yield (d["t"], d["k"], d["vm"], d["pool"], d["host"],
                   d["a"], d["b"], d.get("x"))


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def validate_event_log(src) -> List[str]:
    """Schema checks for a log (an :class:`EventLog` or a saved path);
    returns a list of problems — empty means valid (the
    :func:`repro.obs.export.validate_chrome_trace` idiom).

    Checks: header schema/version (paths), every kind in
    :data:`EVENT_KINDS`, non-decreasing sim time, well-typed ids, finite
    payloads."""
    problems: List[str] = []
    if isinstance(src, str):
        if src.endswith(".npz"):
            try:
                with np.load(src, allow_pickle=False) as z:
                    meta = json.loads(str(z["meta"]))
            except (OSError, KeyError, ValueError) as e:
                return [f"unreadable npz log: {e}"]
        else:
            try:
                with open(src) as f:
                    meta = json.loads(f.readline())
            except (OSError, ValueError) as e:
                return [f"unreadable NDJSON log: {e}"]
        if meta.get("schema") != SCHEMA:
            problems.append(f"header schema is {meta.get('schema')!r}, "
                            f"expected {SCHEMA!r}")
        if meta.get("version") != SCHEMA_VERSION:
            problems.append(f"header version is {meta.get('version')!r}, "
                            f"expected {SCHEMA_VERSION}")
        records = iter_event_records(src)
    else:
        records = src.records()
    known = {k.value for k in LogEventKind}
    last_t = float("-inf")
    bad_kinds = set()
    for i, (t, kind, vm, pool, host, a, b, aux) in enumerate(records):
        if kind not in known and kind not in bad_kinds:
            bad_kinds.add(kind)
            problems.append(f"record {i}: unknown event kind {kind!r}")
        if not isinstance(t, (int, float)) or not np.isfinite(t):
            problems.append(f"record {i}: non-finite time {t!r}")
        elif t < last_t:
            problems.append(f"record {i}: time goes backwards "
                            f"({t} < {last_t})")
        else:
            last_t = t
        for name, v in (("vm", vm), ("pool", pool), ("host", host)):
            if not isinstance(v, (int, np.integer)):
                problems.append(f"record {i}: {name} id {v!r} is not an int")
        for name, v in (("a", a), ("b", b)):
            if not isinstance(v, (int, float)) or not np.isfinite(v):
                problems.append(f"record {i}: payload {name}={v!r} "
                                f"is not finite")
        if aux is not None and not isinstance(aux, str):
            problems.append(f"record {i}: aux {aux!r} is not a string")
        if len(problems) >= 50:
            problems.append("... (validation stopped at 50 problems)")
            break
    return problems


def write_event_log(log: EventLog, path: str,
                    manifest: Optional[dict] = None) -> str:
    """Module-level alias of :meth:`EventLog.save` (CLI symmetry with
    ``write_chrome_trace`` / ``write_profile``)."""
    return log.save(path, manifest=manifest)
