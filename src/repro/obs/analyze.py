"""Vectorized post-hoc queries over an event log (ISSUE 8).

The paper's deliverable includes "analytical insights into … market risk";
these are the queries that produce them, all running on the dense columns
of :meth:`repro.obs.eventlog.EventLog.to_arrays` (numpy ``searchsorted`` /
``cumsum`` / ``unique`` — no per-event Python loops):

* :func:`interruption_intensity` / :func:`storm_intervals` — rolling-window
  interruption rate and the intervals where it exceeds a threshold (the
  "interruption storm" detector).
* :func:`pool_risk_series` — per-pool market-risk time series at tick
  resolution: clearing price, wave victim counts, live occupancy, and the
  bid danger margin (mean admitted bid minus price — how close the
  resident cohort sits to the interruption boundary).
* :func:`vm_lifecycle` — one VM's full event timeline, reconstructed.
* :func:`cohort_summary` — per-VM aggregates rolled up across the cohort.

Every function accepts an :class:`~repro.obs.eventlog.EventLog` or a saved
log path.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from .eventlog import EventLog, load_event_log

LogLike = Union[EventLog, str]

#: event kinds that mean "a VM started occupying a host in this pool" /
#: "… stopped"; migrate-complete counts only when it landed (aux "ok")
_ARRIVALS = ("start", "resume")
_DEPARTURES = ("interrupt", "migrate-start")


def _log(src: LogLike) -> EventLog:
    return load_event_log(src) if isinstance(src, str) else src


def _kind_mask(arr: Dict[str, np.ndarray], log: EventLog,
               *kinds: str) -> np.ndarray:
    m = np.zeros(arr["kind"].size, dtype=bool)
    for k in kinds:
        m |= arr["kind"] == log.kind_id(k)
    return m


# ---------------------------------------------------------------------------
# interruption storms
# ---------------------------------------------------------------------------
def interruption_intensity(src: LogLike, window: float = 600.0
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Rolling interruption rate: for each interruption event at time t,
    the count of interruptions in ``(t - window, t]`` divided by the
    window (events/s).  Returns ``(times, intensity)`` — one point per
    interruption, which is exactly where the rate function changes."""
    log = _log(src)
    arr = log.to_arrays()
    t = arr["t"][_kind_mask(arr, log, "interrupt")]
    if t.size == 0:
        return np.zeros(0), np.zeros(0)
    # events are time-ordered; count via two searchsorted cursors
    lo = np.searchsorted(t, t - window, side="left")
    hi = np.arange(1, t.size + 1)
    return t, (hi - lo) / window


def storm_intervals(src: LogLike, window: float = 600.0,
                    threshold: float = 0.05,
                    min_gap: Optional[float] = None) -> List[dict]:
    """Intervals where the rolling interruption intensity is at or above
    ``threshold`` (events/s).  Consecutive above-threshold points closer
    than ``min_gap`` (default: ``window``) merge into one storm.  Each
    storm dict carries ``t0``/``t1``, its event count, and the peak
    intensity — the detector that turns a log into "storms hit at t=3600
    and t=6000"."""
    t, inten = interruption_intensity(src, window=window)
    hot = inten >= threshold
    if not hot.any():
        return []
    gap = window if min_gap is None else min_gap
    ht, hi_ = t[hot], inten[hot]
    # split where consecutive hot points are further apart than the gap
    breaks = np.flatnonzero(np.diff(ht) > gap) + 1
    storms = []
    for seg_t, seg_i in zip(np.split(ht, breaks), np.split(hi_, breaks)):
        storms.append({
            "t0": float(seg_t[0]), "t1": float(seg_t[-1]),
            "events": int(seg_t.size),
            "peak_intensity": float(seg_i.max()),
        })
    return storms


# ---------------------------------------------------------------------------
# per-pool market risk
# ---------------------------------------------------------------------------
def pool_risk_series(src: LogLike, pool: int) -> Dict[str, np.ndarray]:
    """Per-tick market-risk series for one pool.

    Returns ``t`` (the pool's price-tick times) and, aligned to it:
    ``price`` (clearing price), ``victims`` (wave victims in the tick
    interval ending at each t), ``occupancy`` (VMs resident in the pool —
    arrivals minus departures, cumulative), ``mean_bid`` (running mean of
    the bids admitted into the pool so far — an approximation of the
    resident cohort's bid level), and ``danger_margin`` (``mean_bid -
    price``: how much headroom the cohort has before the next wave; the
    margin going negative is the wave firing)."""
    log = _log(src)
    arr = log.to_arrays()
    in_pool = arr["pool"] == pool
    tick = _kind_mask(arr, log, "price-tick") & in_pool
    t = arr["t"][tick]
    price = arr["a"][tick]
    out: Dict[str, np.ndarray] = {"t": t, "price": price}
    # wave victims, bucketed into the tick interval they landed in
    wv = _kind_mask(arr, log, "wave") & in_pool
    victims = np.zeros(t.size)
    if t.size and wv.any():
        idx = np.clip(np.searchsorted(t, arr["t"][wv], side="left"),
                      0, t.size - 1)
        np.add.at(victims, idx, arr["b"][wv])
    out["victims"] = victims
    # occupancy: +1 at arrivals into the pool, -1 at departures; sampled
    # at tick boundaries (events at exactly t count — ticks run first)
    arrive = (_kind_mask(arr, log, *_ARRIVALS) & in_pool)
    mc = _kind_mask(arr, log, "migrate-complete") & in_pool
    if mc.any():
        aux_ok = log.aux_id("ok")
        if aux_ok >= 0:
            arrive |= mc & (arr["aux"] == aux_ok)
    depart = _kind_mask(arr, log, *_DEPARTURES) & in_pool
    depart |= (_kind_mask(arr, log, "finish") & in_pool)
    delta_t = np.concatenate([arr["t"][arrive], arr["t"][depart]])
    delta_v = np.concatenate([np.ones(int(arrive.sum())),
                              -np.ones(int(depart.sum()))])
    order = np.argsort(delta_t, kind="stable")
    occ_t, occ_v = delta_t[order], np.cumsum(delta_v[order])
    if t.size and occ_t.size:
        pos = np.searchsorted(occ_t, t, side="right") - 1
        out["occupancy"] = np.where(pos >= 0, occ_v[np.maximum(pos, 0)], 0.0)
    else:
        out["occupancy"] = np.zeros(t.size)
    # running mean of admitted bids (start/resume events carry the bid in a)
    bid_ev = _kind_mask(arr, log, *_ARRIVALS) & in_pool
    bt, bv = arr["t"][bid_ev], arr["a"][bid_ev]
    if t.size and bt.size:
        n = np.searchsorted(bt, t, side="right")
        csum = np.concatenate([[0.0], np.cumsum(bv)])
        mean_bid = np.where(n > 0, csum[n] / np.maximum(n, 1), np.nan)
    else:
        mean_bid = np.full(t.size, np.nan)
    out["mean_bid"] = mean_bid
    out["danger_margin"] = mean_bid - price
    return out


def victim_rate(src: LogLike, pool: Optional[int] = None) -> float:
    """Wave victims per tick (one pool, or the whole market)."""
    log = _log(src)
    arr = log.to_arrays()
    sel = np.ones(arr["kind"].size, dtype=bool) if pool is None \
        else arr["pool"] == pool
    ticks = int((_kind_mask(arr, log, "price-tick") & sel).sum())
    victims = float(arr["b"][_kind_mask(arr, log, "wave") & sel].sum())
    return victims / max(ticks, 1)


# ---------------------------------------------------------------------------
# serving scenario (PR 10)
# ---------------------------------------------------------------------------
def serve_series(src: LogLike,
                 window: float = 1800.0) -> Optional[Dict[str, np.ndarray]]:
    """Serving-scenario chart series, or ``None`` when the log carries no
    serve events (so non-serve consumers can branch cheaply).

    Returns serve-tick-aligned arrays — ``t`` / ``rate`` (the demand-curve
    arrival rate each tick integrated) / ``depth`` (global queue depth) /
    ``live`` (VMs holding an active request scheduler) — plus
    ``p95`` (trailing-``window`` p95 completion latency sampled at
    the same ticks; NaN before the first completion) and
    ``scale_t``/``scale_units`` (the autoscaler's target steps; empty
    when no autoscaler acted)."""
    log = _log(src)
    if log.kind_id("serve-sample") < 0 and log.kind_id("request-arrive") < 0:
        return None
    arr = log.to_arrays()
    out: Dict[str, np.ndarray] = {}
    sample = _kind_mask(arr, log, "serve-sample")
    arrive = _kind_mask(arr, log, "request-arrive")
    out["t"] = arr["t"][sample]
    out["depth"] = arr["a"][sample]
    out["live"] = arr["b"][sample]
    out["rate_t"] = arr["t"][arrive]
    out["rate"] = arr["b"][arrive]
    # trailing-window p95 latency, sampled at the serve ticks (one
    # percentile per tick over the completions inside (t-window, t])
    done = _kind_mask(arr, log, "request-done")
    dt, lat = arr["t"][done], arr["a"][done]
    t = out["t"]
    p95 = np.full(t.size, np.nan)
    if dt.size and t.size:
        lo = np.searchsorted(dt, t - window, side="left")
        hi = np.searchsorted(dt, t, side="right")
        for i, (l, h) in enumerate(zip(lo, hi)):
            if h > l:
                p95[i] = float(np.percentile(lat[l:h], 95.0))
    out["p95"] = p95
    scale = _kind_mask(arr, log, "autoscale")
    out["scale_t"] = arr["t"][scale]
    out["scale_units"] = arr["a"][scale]
    return out


# ---------------------------------------------------------------------------
# per-VM lifecycles / cohort rollup
# ---------------------------------------------------------------------------
def vm_lifecycle(src: LogLike, vm_id: int) -> List[dict]:
    """One VM's event timeline: ``[{t, kind, pool, host, a, b, aux}, …]``
    in emit order — submit → start → interrupt → hibernate → resume → …"""
    log = _log(src)
    arr = log.to_arrays()
    rows = np.flatnonzero(arr["vm"] == vm_id)
    kinds, auxs = arr["kinds"], arr["auxs"]
    return [{
        "t": float(arr["t"][i]), "kind": str(kinds[arr["kind"][i]]),
        "pool": int(arr["pool"][i]), "host": int(arr["host"][i]),
        "a": float(arr["a"][i]), "b": float(arr["b"][i]),
        "aux": str(auxs[arr["aux"][i]]) if arr["aux"][i] >= 0 else None,
    } for i in rows]


def cohort_summary(src: LogLike) -> dict:
    """Cohort-level rollup of the per-VM timelines: VM count, final-state
    histogram (each VM's last lifecycle event), interruption / migration
    counts per VM (total, max, mean) — the "per-VM lifecycle" answer at
    fleet scale, computed with one ``np.unique`` pass."""
    log = _log(src)
    arr = log.to_arrays()
    life = _kind_mask(arr, log, "submit", "start", "resume", "finish",
                      "fail", "interrupt", "hibernate", "terminate")
    vm = arr["vm"][life]
    if vm.size == 0:
        return {"n_vms": 0, "final_states": {}, "interruptions": {},
                "migrations": {}}
    kind = arr["kind"][life]
    uniq, inverse = np.unique(vm, return_inverse=True)
    # final state: the last lifecycle event of each VM (emit order = time
    # order, so the highest row index per VM wins)
    last = np.zeros(uniq.size, dtype=np.int64)
    np.maximum.at(last, inverse, np.arange(vm.size))
    final_kinds = kind[last]
    kinds_table = arr["kinds"]
    final_states: Dict[str, int] = {}
    for k, n in zip(*np.unique(final_kinds, return_counts=True)):
        final_states[str(kinds_table[k])] = int(n)

    def _per_vm(kind_name: str) -> dict:
        m = _kind_mask(arr, log, kind_name)
        counts = np.zeros(uniq.size)
        if m.any():
            idx = np.searchsorted(uniq, arr["vm"][m])
            ok = (idx < uniq.size)
            ok[ok] &= uniq[idx[ok]] == arr["vm"][m][ok]
            np.add.at(counts, idx[ok], 1)
        return {"total": int(counts.sum()), "max": int(counts.max()),
                "mean": round(float(counts.mean()), 4)}

    return {
        "n_vms": int(uniq.size),
        "final_states": final_states,
        "interruptions": _per_vm("interrupt"),
        "migrations": _per_vm("migrate-start"),
    }
