"""Run manifests: make every committed metrics/report JSON self-describing.

A manifest answers "what produced this file?" months later: the seed(s),
the full spec dict plus a content hash (so two artifacts are comparable
at a glance), the git SHA if the tree is a checkout, the versions of the
packages whose numerics matter, and the wall-clock duration of the run.

Everything degrades gracefully: no git, no jax, no installed-package
metadata — the corresponding fields are simply ``null``.  The manifest is
*additive* metadata, deliberately excluded from determinism comparisons
(the sweep runner's byte-identity tests run with ``manifest=False``).
"""
from __future__ import annotations

import hashlib
import json
import platform
import subprocess
import sys
from typing import Any, Dict, Optional

MANIFEST_VERSION = 1


def spec_hash(spec_dict: Optional[dict]) -> Optional[str]:
    """Content hash of a spec's canonical JSON (sorted keys)."""
    if spec_dict is None:
        return None
    blob = json.dumps(spec_dict, sort_keys=True, separators=(",", ":"))
    return hashlib.sha256(blob.encode("utf-8")).hexdigest()[:16]


def git_sha(cwd: Optional[str] = None) -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=cwd, capture_output=True,
            text=True, timeout=5)
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return None


def _pkg_version(name: str) -> Optional[str]:
    try:
        from importlib import metadata
        return metadata.version(name)
    except Exception:
        return None


def package_versions() -> Dict[str, Optional[str]]:
    return {
        "python": platform.python_version(),
        "numpy": _pkg_version("numpy"),
        "jax": _pkg_version("jax"),
    }


def run_manifest(spec_dict: Optional[dict] = None,
                 seed: Any = None,
                 duration_s: Optional[float] = None,
                 extra: Optional[dict] = None) -> dict:
    """Assemble the manifest block attached to metrics/report JSON."""
    m: Dict[str, Any] = {
        "manifest_version": MANIFEST_VERSION,
        "seed": seed,
        "spec": spec_dict,
        "spec_hash": spec_hash(spec_dict),
        "git_sha": git_sha(),
        "versions": package_versions(),
        "platform": platform.platform(),
        "argv": list(sys.argv),
        "duration_s": (round(duration_s, 6)
                       if duration_s is not None else None),
    }
    if extra:
        m.update(extra)
    return m
