"""Chrome trace-event JSON export for :class:`repro.obs.tracer.Tracer`.

Emits the Trace Event Format understood by ``chrome://tracing`` and
Perfetto (legacy JSON ingestion): a ``traceEvents`` array of ``"X"``
complete spans, ``"i"`` instants, ``"C"`` counter samples, and ``"M"``
metadata records naming the tracks.

Two clocks, two track groups: the same spans are emitted once under
**pid 1 ("wall-time")** with real wall-clock ``ts``/``dur`` (microseconds
since the tracer epoch) and once under **pid 2 ("sim-time")** with
``ts = sim_t * 1e6`` so the viewer's timeline doubles as the simulated
clock — on the sim-time track each span's wall duration is carried in
``args.wall_ms`` instead of ``dur`` (sim events are logically
instantaneous).  Within each group, one tid per span category keeps
subsystems on separate rows.

``validate_chrome_trace`` is the schema check the test-suite applies to
every emitted file; keeping it next to the writer means the two cannot
drift apart.
"""
from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

PID_WALL = 1
PID_SIM = 2

_PROCESS_NAMES = {PID_WALL: "wall-time", PID_SIM: "sim-time"}


def _category_tids(tracer) -> Dict[str, int]:
    """Stable category -> tid assignment in first-seen order."""
    tids: Dict[str, int] = {}
    for rec in tracer.spans:
        tids.setdefault(rec[0], len(tids) + 1)
    for rec in tracer.instants:
        tids.setdefault(rec[0], len(tids) + 1)
    if tracer.counters.series:
        tids.setdefault("counters", len(tids) + 1)
    return tids


def chrome_trace(tracer, manifest: Optional[dict] = None) -> dict:
    """Render a Tracer's records as a Chrome trace-event document."""
    tids = _category_tids(tracer)
    events: List[dict] = []

    for pid, pname in _PROCESS_NAMES.items():
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name", "args": {"name": pname}})
        for cat, tid in tids.items():
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name", "args": {"name": cat}})

    for cat, name, t0, dur, sim_t, self_dur, args in tracer.spans:
        tid = tids[cat]
        wall_args = dict(args) if args else {}
        wall_args["sim_t"] = round(sim_t, 6)
        wall_args["self_us"] = round(self_dur * 1e6, 3)
        events.append({"ph": "X", "pid": PID_WALL, "tid": tid, "cat": cat,
                       "name": name, "ts": round(t0 * 1e6, 3),
                       "dur": round(dur * 1e6, 3), "args": wall_args})
        sim_args = dict(args) if args else {}
        sim_args["wall_ms"] = round(dur * 1e3, 6)
        events.append({"ph": "X", "pid": PID_SIM, "tid": tid, "cat": cat,
                       "name": name, "ts": round(sim_t * 1e6, 3),
                       "dur": 0, "args": sim_args})

    for cat, name, wall, sim_t, args in tracer.instants:
        tid = tids[cat]
        base = {"ph": "i", "tid": tid, "cat": cat, "name": name,
                "s": "t", "args": dict(args) if args else {}}
        events.append({**base, "pid": PID_WALL, "ts": round(wall * 1e6, 3)})
        events.append({**base, "pid": PID_SIM, "ts": round(sim_t * 1e6, 3)})

    ctid = tids.get("counters", 0)
    for sim_t, wall, snap in tracer.counters.series:
        for key in sorted(snap):
            base = {"ph": "C", "tid": ctid, "name": key,
                    "args": {"value": snap[key]}}
            events.append({**base, "pid": PID_WALL,
                           "ts": round(wall * 1e6, 3)})
            events.append({**base, "pid": PID_SIM,
                           "ts": round(sim_t * 1e6, 3)})

    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    if manifest is not None:
        doc["otherData"] = manifest
    return doc


def write_chrome_trace(tracer, path: str,
                       manifest: Optional[dict] = None) -> dict:
    doc = chrome_trace(tracer, manifest)
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, separators=(",", ":"))
    return doc


_REQUIRED_BY_PH = {
    "X": ("pid", "tid", "name", "cat", "ts", "dur"),
    "i": ("pid", "tid", "name", "cat", "ts"),
    "C": ("pid", "tid", "name", "ts", "args"),
    "M": ("pid", "tid", "name", "args"),
}


def validate_chrome_trace(doc: dict) -> List[str]:
    """Return a list of schema problems (empty == valid).

    Checks the invariants chrome://tracing / Perfetto actually rely on:
    known phase types, required per-phase fields, numeric non-negative
    timestamps/durations, and that every (pid, tid) used by an event has
    metadata naming it.
    """
    problems: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    named_tracks = set()
    for ev in events:
        if ev.get("ph") == "M" and ev.get("name") == "process_name":
            named_tracks.add((ev.get("pid"), None))
        if ev.get("ph") == "M" and ev.get("name") == "thread_name":
            named_tracks.add((ev.get("pid"), ev.get("tid")))
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        req = _REQUIRED_BY_PH.get(ph)
        if req is None:
            problems.append(f"event {i}: unknown ph {ph!r}")
            continue
        for field in req:
            if field not in ev:
                problems.append(f"event {i} (ph={ph}): missing {field!r}")
        for field in ("ts", "dur"):
            if field in ev and ph != "M":
                val = ev[field]
                if not isinstance(val, (int, float)) or val < 0:
                    problems.append(
                        f"event {i} (ph={ph}): bad {field}={val!r}")
        if ph == "C":
            args = ev.get("args")
            if not isinstance(args, dict) or not all(
                    isinstance(v, (int, float)) for v in args.values()):
                problems.append(f"event {i}: counter args not numeric")
        if ph in ("X", "i", "C"):
            pid = ev.get("pid")
            if (pid, None) not in named_tracks:
                problems.append(f"event {i}: pid {pid!r} has no "
                                "process_name metadata")
    return problems
