"""Runtime determinism sanitizer — the dynamic twin of ``tools/detlint``.

``sanitized()`` monkeypatches the wall-clock readers (``time.time``,
``time.monotonic``, ``time.perf_counter`` and their ``_ns`` variants), the
stdlib ``random`` module-level functions, and the legacy ``np.random``
module-level functions to raise :class:`SanitizerViolation` for the
duration of a ``with`` block.  Running one fixed-seed simulation inside
the block verifies *at runtime* what the ``no-wallclock`` and
``no-global-rng`` lint rules claim statically: nothing on the sim path
reads a clock or touches hidden global RNG state.

Scope and limits:

* Module-level function replacement only — code that bound a clock at
  import/class-definition time (e.g. :class:`repro.obs.tracer.Tracer`'s
  default ``clock=time.perf_counter``) keeps its captured reference.
  That is deliberate: obs/ is *allowed* to read clocks; the sanitizer
  polices call-time lookups on the sim path.
* ``datetime.datetime.now`` is a method on a C type and cannot be
  patched; the static ``no-wallclock`` rule covers it.
* Seeded ``np.random.default_rng(...)`` Generators are untouched — their
  methods live on the Generator instance, not the module.

Everything is restored in a ``finally``, so a violation (or any other
exception) cannot leak patched state into the caller.
"""

from __future__ import annotations

import contextlib
import random
import time
from typing import Iterator, List, Tuple

import numpy as np

__all__ = ["SanitizerViolation", "sanitized", "TIME_ATTRS", "RANDOM_ATTRS",
           "NP_RANDOM_ATTRS"]


class SanitizerViolation(RuntimeError):
    """A forbidden wall-clock or global-RNG call executed inside a
    ``sanitized()`` scope."""


TIME_ATTRS = (
    "time", "time_ns",
    "monotonic", "monotonic_ns",
    "perf_counter", "perf_counter_ns",
    "process_time", "process_time_ns",
)

#: stdlib random module-level functions (all share one hidden global state)
RANDOM_ATTRS = (
    "random", "uniform", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "gauss", "normalvariate", "expovariate",
    "betavariate", "gammavariate", "lognormvariate", "paretovariate",
    "weibullvariate", "triangular", "vonmisesvariate", "getrandbits",
    "seed",
)

#: legacy numpy module-level RNG entry points (hidden global RandomState)
NP_RANDOM_ATTRS = (
    "random", "rand", "randn", "randint", "random_sample", "ranf",
    "sample", "choice", "shuffle", "permutation", "uniform", "normal",
    "standard_normal", "exponential", "poisson", "binomial", "beta",
    "gamma", "lognormal", "pareto", "weibull", "seed", "random_integers",
)


def _raiser(module_name: str, attr: str):
    full = f"{module_name}.{attr}"

    def _forbidden(*args, **kwargs):
        raise SanitizerViolation(
            f"{full}() called inside a sanitized sim scope — sim code must "
            "be a pure function of (spec, seed); thread a seeded "
            "np.random.default_rng Generator / take times from the event "
            "queue instead"
        )

    _forbidden.__name__ = f"forbidden_{attr}"
    _forbidden.__qualname__ = _forbidden.__name__
    return _forbidden


@contextlib.contextmanager
def sanitized() -> Iterator[None]:
    """Forbid wall-clock and global-RNG calls for the duration of the block."""
    saved: List[Tuple[object, str, object]] = []

    def patch(module, module_name: str, attrs) -> None:
        for attr in attrs:
            original = getattr(module, attr, None)
            if original is None:
                continue
            saved.append((module, attr, original))
            setattr(module, attr, _raiser(module_name, attr))

    patch(time, "time", TIME_ATTRS)
    patch(random, "random", RANDOM_ATTRS)
    patch(np.random, "np.random", NP_RANDOM_ATTRS)
    try:
        yield
    finally:
        for module, attr, original in reversed(saved):
            setattr(module, attr, original)
