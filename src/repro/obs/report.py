"""Self-contained static HTML run reports (ISSUE 8).

One ``.html`` file per run (or per sweep report), rendered from an event
log with inline SVG charts — zero new dependencies, no external assets, so
the file is a durable committed/CI artifact that opens anywhere.

Run reports show the PR 7 manifest header (seed, spec hash, git SHA),
headline stat tiles, and three chart rows: per-pool clearing prices, the
rolling interruption intensity with detected storm bands, and per-pool
occupancy (the fleet-capacity view).  Sweep reports render the aggregate
mean ± CI table plus a bar chart per headline metric.

Entry points: :func:`render_report` / :func:`render_sweep_report` return
HTML strings; :func:`write_html_report` dispatches on the input (event log
vs sweep report dict) and writes the file.
"""
from __future__ import annotations

import html
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .analyze import (
    interruption_intensity,
    pool_risk_series,
    serve_series,
    storm_intervals,
)
from .eventlog import EventLog, load_event_log

_PALETTE = ("#2563eb", "#dc2626", "#16a34a", "#d97706", "#7c3aed",
            "#0891b2", "#be185d", "#4d7c0f")

_CSS = """
body{font-family:system-ui,sans-serif;margin:24px;color:#1f2937;
     max-width:1080px}
h1{font-size:20px;margin-bottom:4px} h2{font-size:15px;margin:24px 0 6px}
.manifest{font-size:12px;color:#6b7280;border-collapse:collapse}
.manifest td{padding:1px 12px 1px 0}
.tiles{display:flex;gap:12px;flex-wrap:wrap;margin:16px 0}
.tile{border:1px solid #e5e7eb;border-radius:8px;padding:8px 14px}
.tile .v{font-size:20px;font-weight:600}
.tile .k{font-size:11px;color:#6b7280;text-transform:uppercase}
table.agg{border-collapse:collapse;font-size:12px}
table.agg th,table.agg td{border:1px solid #e5e7eb;padding:3px 8px;
                          text-align:right}
table.agg th{background:#f9fafb}
.legend{font-size:11px;color:#6b7280;margin:2px 0 10px}
.legend span{margin-right:14px}
svg{background:#fcfcfd;border:1px solid #e5e7eb;border-radius:6px}
"""


def _esc(s) -> str:
    return html.escape(str(s))


def _axis_ticks(lo: float, hi: float, n: int = 4) -> List[float]:
    if hi <= lo:
        return [lo]
    return [lo + (hi - lo) * i / n for i in range(n + 1)]


def _svg_line_chart(series: Sequence[Tuple[str, np.ndarray, np.ndarray]],
                    width: int = 980, height: int = 220,
                    y_label: str = "", bands: Sequence[Tuple[float, float]]
                    = ()) -> str:
    """A multi-series SVG polyline chart.  ``series`` is ``(label, xs,
    ys)`` triples sharing one x/y scale; ``bands`` draws shaded x-axis
    intervals (storm windows) behind the lines."""
    pad_l, pad_r, pad_t, pad_b = 56, 12, 10, 26
    pw, ph = width - pad_l - pad_r, height - pad_t - pad_b
    xs_all = [xs for _, xs, _ in series if len(xs)]
    ys_all = [ys for _, _, ys in series if len(ys)]
    if not xs_all:
        return f'<svg width="{width}" height="{height}"><text x="12" ' \
               f'y="24" font-size="12">(no data)</text></svg>'
    x_lo = min(float(np.nanmin(x)) for x in xs_all)
    x_hi = max(float(np.nanmax(x)) for x in xs_all)
    y_lo = min(0.0, min(float(np.nanmin(y)) for y in ys_all))
    y_hi = max(float(np.nanmax(y)) for y in ys_all)
    if x_hi <= x_lo:
        x_hi = x_lo + 1.0
    if y_hi <= y_lo:
        y_hi = y_lo + 1.0

    def X(v):
        return pad_l + (v - x_lo) / (x_hi - x_lo) * pw

    def Y(v):
        return pad_t + ph - (v - y_lo) / (y_hi - y_lo) * ph

    parts = [f'<svg width="{width}" height="{height}" '
             f'viewBox="0 0 {width} {height}" '
             f'xmlns="http://www.w3.org/2000/svg">']
    for b0, b1 in bands:
        parts.append(
            f'<rect x="{X(b0):.1f}" y="{pad_t}" '
            f'width="{max(X(b1) - X(b0), 2.0):.1f}" height="{ph}" '
            f'fill="#fee2e2" opacity="0.8"/>')
    for tv in _axis_ticks(y_lo, y_hi):
        y = Y(tv)
        parts.append(f'<line x1="{pad_l}" y1="{y:.1f}" '
                     f'x2="{width - pad_r}" y2="{y:.1f}" '
                     f'stroke="#eef0f3"/>')
        parts.append(f'<text x="{pad_l - 6}" y="{y + 3:.1f}" '
                     f'font-size="10" fill="#6b7280" '
                     f'text-anchor="end">{tv:.3g}</text>')
    for tv in _axis_ticks(x_lo, x_hi, 6):
        x = X(tv)
        parts.append(f'<text x="{x:.1f}" y="{height - 8}" font-size="10" '
                     f'fill="#6b7280" text-anchor="middle">{tv:.4g}</text>')
    for i, (_label, xs, ys) in enumerate(series):
        xs = np.asarray(xs, float)
        ys = np.asarray(ys, float)
        keep = np.isfinite(xs) & np.isfinite(ys)
        xs, ys = xs[keep], ys[keep]
        if xs.size == 0:
            continue
        pts = " ".join(f"{X(x):.1f},{Y(y):.1f}" for x, y in zip(xs, ys))
        color = _PALETTE[i % len(_PALETTE)]
        parts.append(f'<polyline points="{pts}" fill="none" '
                     f'stroke="{color}" stroke-width="1.4"/>')
    if y_label:
        parts.append(f'<text x="4" y="{pad_t + 10}" font-size="10" '
                     f'fill="#6b7280">{_esc(y_label)}</text>')
    parts.append("</svg>")
    return "".join(parts)


def _legend(labels: Sequence[str]) -> str:
    spans = "".join(
        f'<span style="color:{_PALETTE[i % len(_PALETTE)]}">&#9632; '
        f'{_esc(lb)}</span>' for i, lb in enumerate(labels))
    return f'<div class="legend">{spans}</div>'


def _svg_bar_chart(labels: Sequence[str], means: Sequence[float],
                   errs: Sequence[float], width: int = 980,
                   height: int = 180) -> str:
    pad_l, pad_r, pad_t, pad_b = 56, 12, 10, 54
    pw, ph = width - pad_l - pad_r, height - pad_t - pad_b
    hi = max([m + e for m, e in zip(means, errs)] + [1e-9])
    parts = [f'<svg width="{width}" height="{height}" '
             f'viewBox="0 0 {width} {height}" '
             f'xmlns="http://www.w3.org/2000/svg">']
    n = max(len(labels), 1)
    bw = min(48.0, pw / n * 0.6)
    for i, (lb, m, e) in enumerate(zip(labels, means, errs)):
        cx = pad_l + pw * (i + 0.5) / n
        h = ph * m / hi
        y = pad_t + ph - h
        color = _PALETTE[i % len(_PALETTE)]
        parts.append(f'<rect x="{cx - bw / 2:.1f}" y="{y:.1f}" '
                     f'width="{bw:.1f}" height="{h:.1f}" '
                     f'fill="{color}" opacity="0.85"/>')
        if e > 0:
            e_px = ph * e / hi
            parts.append(f'<line x1="{cx:.1f}" y1="{y - e_px:.1f}" '
                         f'x2="{cx:.1f}" y2="{min(y + e_px, pad_t + ph):.1f}"'
                         f' stroke="#374151" stroke-width="1.2"/>')
        parts.append(f'<text x="{cx:.1f}" y="{y - 4 if h else y - 4:.1f}" '
                     f'font-size="10" text-anchor="middle">{m:.3g}</text>')
        parts.append(
            f'<text x="{cx:.1f}" y="{height - 40}" font-size="10" '
            f'fill="#6b7280" text-anchor="middle" '
            f'transform="rotate(18 {cx:.1f} {height - 40})">'
            f'{_esc(lb)}</text>')
    parts.append("</svg>")
    return "".join(parts)


def _manifest_table(manifest: Optional[dict]) -> str:
    if not manifest:
        return ""
    keys = ("seed", "spec_sha256", "git_sha", "created", "duration_s")
    rows = "".join(
        f"<tr><td>{_esc(k)}</td><td><code>{_esc(manifest[k])}</code></td>"
        f"</tr>" for k in keys if k in manifest)
    return f'<table class="manifest">{rows}</table>'


def _tiles(stats: Dict[str, object]) -> str:
    return '<div class="tiles">' + "".join(
        f'<div class="tile"><div class="v">{_esc(v)}</div>'
        f'<div class="k">{_esc(k)}</div></div>'
        for k, v in stats.items()) + "</div>"


# ---------------------------------------------------------------------------
# run report
# ---------------------------------------------------------------------------
def render_report(src: Union[EventLog, str],
                  manifest: Optional[dict] = None,
                  title: str = "Run report") -> str:
    """One run's HTML report from its event log: manifest header, stat
    tiles, price / interruption-intensity / occupancy charts."""
    log = load_event_log(src) if isinstance(src, str) else src
    arr = log.to_arrays()
    kinds = {str(k): log.kind_id(str(k)) for k in arr["kinds"]}

    def count(kind: str) -> int:
        return int((arr["kind"] == kinds[kind]).sum()) if kind in kinds \
            else 0

    pools = sorted(int(p) for p in np.unique(
        arr["pool"][arr["pool"] >= 0])) if len(log) else []
    stats = {
        "events": len(log),
        "interruptions": count("interrupt"),
        "waves": count("wave"),
        "migrations": count("migrate-start"),
        "fleet launches": count("fleet-launch"),
        "faults": count("fault"),
    }
    storms = storm_intervals(log)
    bands = [(s["t0"], s["t1"]) for s in storms]
    body = [f"<h1>{_esc(title)}</h1>", _manifest_table(manifest),
            _tiles(stats)]
    risk = {p: pool_risk_series(log, p) for p in pools}
    if any(r["t"].size for r in risk.values()):
        body.append("<h2>Clearing price per pool</h2>")
        body.append(_legend([f"pool {p}" for p in pools]))
        body.append(_svg_line_chart(
            [(f"pool {p}", risk[p]["t"], risk[p]["price"]) for p in pools],
            y_label="$/h", bands=bands))
        body.append("<h2>Bid danger margin per pool "
                    "(mean admitted bid &minus; price)</h2>")
        body.append(_svg_line_chart(
            [(f"pool {p}", risk[p]["t"], risk[p]["danger_margin"])
             for p in pools], y_label="$/h"))
    it, iv = interruption_intensity(log)
    body.append("<h2>Interruption intensity (rolling)"
                + (f" — {len(storms)} storm(s) shaded" if storms else "")
                + "</h2>")
    body.append(_svg_line_chart([("intensity", it, iv)],
                                y_label="events/s", bands=bands))
    if any(r["t"].size for r in risk.values()):
        body.append("<h2>Pool occupancy (resident VMs)</h2>")
        body.append(_legend([f"pool {p}" for p in pools]))
        body.append(_svg_line_chart(
            [(f"pool {p}", risk[p]["t"], risk[p]["occupancy"])
             for p in pools], y_label="VMs", bands=bands))
    # serving scenario (PR 10): rendered only when the run emitted serve
    # events, so every non-serve report stays byte-identical
    sv = serve_series(log)
    if sv is not None:
        body.append("<h2>Serving: arrival rate</h2>")
        body.append(_svg_line_chart(
            [("rate", sv["rate_t"], sv["rate"])], y_label="req/s",
            bands=bands))
        body.append("<h2>Serving: queue depth</h2>")
        body.append(_svg_line_chart(
            [("depth", sv["t"], sv["depth"])], y_label="requests",
            bands=bands))
        body.append("<h2>Serving: p95 latency (trailing window)</h2>")
        body.append(_svg_line_chart(
            [("p95", sv["t"], sv["p95"])], y_label="s", bands=bands))
        body.append("<h2>Serving: capacity — autoscaler target vs live</h2>")
        body.append(_legend(["target units", "live units"]))
        body.append(_svg_line_chart(
            [("target units", sv["scale_t"], sv["scale_units"]),
             ("live units", sv["t"], sv["live"])], y_label="units",
            bands=bands))
    return (f"<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body>{''.join(body)}</body></html>")


# ---------------------------------------------------------------------------
# sweep report
# ---------------------------------------------------------------------------
_SWEEP_METRICS = ("interruptions", "max_interruption_time",
                  "realized_spot_cost", "time_below_target_s")


def _cell_label(cell: dict) -> str:
    parts = [str(cell.get("regime")), cell.get("policy", ""),
             cell.get("migration", "")]
    fl = cell.get("fleet")
    if "fleet" in cell:
        parts.append(fl["strategy"] if fl else "per-vm")
    return "/".join(p for p in parts if p)


def render_sweep_report(report: dict,
                        title: Optional[str] = None) -> str:
    """Sweep-report HTML: the aggregate mean ± CI table plus one bar chart
    (mean with CI whiskers) per headline metric present in the cells."""
    cells = report.get("cells", [])
    title = title or f"Sweep report: {report.get('name', '?')}"
    labels = [_cell_label(c) for c in cells]
    metric_keys: List[str] = []
    for m in _SWEEP_METRICS:
        if any(m in c.get("metrics", {}) for c in cells):
            metric_keys.append(m)
    body = [f"<h1>{_esc(title)}</h1>",
            _manifest_table(report.get("manifest")),
            _tiles({"cells": len(cells),
                    "runs": report.get("n_runs", "?"),
                    "horizon": report.get("horizon", "?")})]
    if cells:
        all_keys = sorted({k for c in cells for k in c.get("metrics", {})})
        head = "".join(f"<th>{_esc(k)}</th>" for k in all_keys)
        rows = []
        for lb, c in zip(labels, cells):
            tds = []
            for k in all_keys:
                mk = c["metrics"].get(k)
                tds.append(
                    f"<td>{mk['mean']:.3g}&#177;{mk['ci95']:.2g}</td>"
                    if mk else "<td>-</td>")
            rows.append(f"<tr><th>{_esc(lb)}</th>{''.join(tds)}</tr>")
        body.append("<h2>Aggregate metrics (mean &#177; 95% CI)</h2>")
        body.append(f'<table class="agg"><tr><th>cell</th>{head}</tr>'
                    f'{"".join(rows)}</table>')
    for m in metric_keys:
        means = [c["metrics"].get(m, {}).get("mean", 0.0) for c in cells]
        errs = [c["metrics"].get(m, {}).get("ci95", 0.0) for c in cells]
        body.append(f"<h2>{_esc(m)}</h2>")
        body.append(_svg_bar_chart(labels, means, errs))
    return (f"<!doctype html><html><head><meta charset='utf-8'>"
            f"<title>{_esc(title)}</title><style>{_CSS}</style></head>"
            f"<body>{''.join(body)}</body></html>")


def write_html_report(src, path: str, manifest: Optional[dict] = None,
                      title: Optional[str] = None) -> str:
    """Render + write a report: an :class:`EventLog` (or saved log path)
    produces a run report; a sweep-report dict (has ``"cells"``) produces
    the sweep variant."""
    if isinstance(src, dict) and "cells" in src:
        doc = render_sweep_report(src, title=title)
    else:
        doc = render_report(src, manifest=manifest,
                            title=title or "Run report")
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        f.write(doc)
    return path


def _json_default(o):  # pragma: no cover - defensive
    return str(o)


def report_summary_json(src: Union[EventLog, str]) -> str:
    """The run report's headline numbers as JSON (storms + cohort tiles) —
    a machine-readable sidecar for CI assertions."""
    log = load_event_log(src) if isinstance(src, str) else src
    return json.dumps({"events": len(log),
                       "storms": storm_intervals(log)},
                      sort_keys=True, default=_json_default)
