"""Zero-dependency runtime telemetry: tracing, counters, profiles, manifests.

Public surface:

* :class:`Tracer` / :data:`NULL_TRACER` — span/instant/counter recorder
  and its inert default (``repro.obs.tracer``).
* :func:`write_chrome_trace` / :func:`validate_chrome_trace` — Chrome
  trace-event JSON export for Perfetto / chrome://tracing
  (``repro.obs.export``).
* :func:`profile_table` / :func:`write_profile` /
  :func:`format_profile_table` — per-subsystem self/total wall-time
  breakdown (``repro.obs.profile``).
* :func:`run_manifest` / :func:`spec_hash` — self-describing metadata
  blocks for committed artifacts (``repro.obs.manifest``).
"""
from .tracer import NULL_TRACER, Counters, NullTracer, Tracer
from .export import chrome_trace, validate_chrome_trace, write_chrome_trace
from .profile import (format_profile_table, profile_report, profile_table,
                      write_profile)
from .manifest import run_manifest, spec_hash

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Counters",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "profile_table", "profile_report", "write_profile",
    "format_profile_table",
    "run_manifest", "spec_hash",
]
