"""Zero-dependency runtime telemetry: tracing, counters, profiles, manifests,
and the event flight recorder.

Public surface:

* :class:`Tracer` / :data:`NULL_TRACER` — span/instant/counter recorder
  and its inert default (``repro.obs.tracer``).
* :class:`EventLog` / :data:`NULL_RECORDER` — append-only structured log
  of every lifecycle/market event and its inert default
  (``repro.obs.eventlog``).
* :func:`first_divergence` / :func:`bisect_divergence` — first-divergence
  run diffing over two event logs (``repro.obs.diff``).
* :func:`pool_risk_series` / :func:`storm_intervals` /
  :func:`cohort_summary` — vectorized post-hoc market-risk analytics over
  a recorded log (``repro.obs.analyze``).
* :func:`write_html_report` — self-contained static HTML run/sweep report
  (``repro.obs.report``).
* :func:`write_chrome_trace` / :func:`validate_chrome_trace` — Chrome
  trace-event JSON export for Perfetto / chrome://tracing
  (``repro.obs.export``).
* :func:`profile_table` / :func:`write_profile` /
  :func:`format_profile_table` — per-subsystem self/total wall-time
  breakdown (``repro.obs.profile``).
* :func:`run_manifest` / :func:`spec_hash` — self-describing metadata
  blocks for committed artifacts (``repro.obs.manifest``).
"""
from .tracer import NULL_TRACER, Counters, NullTracer, Tracer
from .eventlog import (EVENT_KINDS, NULL_RECORDER, EventLog, LogEventKind,
                       NullRecorder, iter_event_records, load_event_log,
                       read_manifest, validate_event_log, write_event_log)
from .diff import (Divergence, bisect_divergence, first_divergence,
                   format_divergence)
from .analyze import (cohort_summary, interruption_intensity,
                      pool_risk_series, serve_series, storm_intervals,
                      victim_rate, vm_lifecycle)
from .report import (render_report, render_sweep_report, report_summary_json,
                     write_html_report)
from .export import chrome_trace, validate_chrome_trace, write_chrome_trace
from .profile import (format_profile_table, profile_report, profile_table,
                      write_profile)
from .manifest import run_manifest, spec_hash
from .sanitize import SanitizerViolation, sanitized

__all__ = [
    "Tracer", "NullTracer", "NULL_TRACER", "Counters",
    "EventLog", "NullRecorder", "NULL_RECORDER", "EVENT_KINDS", "LogEventKind",
    "load_event_log", "iter_event_records", "read_manifest",
    "validate_event_log", "write_event_log",
    "Divergence", "first_divergence", "bisect_divergence",
    "format_divergence",
    "interruption_intensity", "storm_intervals", "pool_risk_series",
    "victim_rate", "vm_lifecycle", "cohort_summary", "serve_series",
    "render_report", "render_sweep_report", "write_html_report",
    "report_summary_json",
    "chrome_trace", "write_chrome_trace", "validate_chrome_trace",
    "profile_table", "profile_report", "write_profile",
    "format_profile_table",
    "run_manifest", "spec_hash",
    "SanitizerViolation", "sanitized",
]
