"""Runtime tracing primitives: spans, instants, and live counters.

The simulator's observability layer (ISSUE 7) is built on three record
kinds, all produced by one :class:`Tracer`:

* **spans** — a wall-clock interval around one unit of engine work (an
  event dispatch, a market-tick phase, a planner scoring pass), stamped
  with the simulation time at which it ran.  Spans nest: the tracer keeps
  a stack, so each record carries its *self* time (total minus children) —
  the per-subsystem profile table falls out of one dict aggregation.
* **instants** — zero-duration markers (an interruption wave landing, a
  fleet fallback rung firing).
* **counters** — monotonically growing named integers (events dispatched,
  interruptions by cause, waves, migrations, fallback-rung hits) plus
  sampled gauges (queue depth, registry size), snapshotted into a
  timeseries on a configurable sim-time cadence.

Overhead contract: the disabled path must cost (almost) nothing.  Every
instrumentation site in the engine guards on ``tracer.enabled`` — a single
attribute load + branch — and the simulator's hot event loop selects an
entirely *untraced* loop body when observability is off, so a disabled run
executes byte-for-byte the same per-event code as a build with no tracer
at all (regression-tested: metrics JSON equality, ``tests/obs``).  The
:data:`NULL_TRACER` singleton is the default everywhere; sites never need
a ``None`` check.

Nothing in this module draws randomness or mutates engine state: attaching
a (fully enabled) tracer is observation-only, so traced and untraced runs
of the same spec + seed produce identical metrics.
"""
from __future__ import annotations

import math
import time
from typing import Any, Callable, Dict, List, Optional, Tuple


class Counters:
    """Low-overhead named counters + snapshot timeseries.

    ``inc``/``add`` are plain dict updates (no locks — the simulator is
    single-threaded); ``snapshot`` copies the live values, merges sampled
    gauges, and appends to :attr:`series` as ``(sim_t, wall_s, values)``.
    """

    __slots__ = ("values", "series")

    def __init__(self) -> None:
        self.values: Dict[str, float] = {}
        self.series: List[Tuple[float, float, Dict[str, float]]] = []

    def inc(self, key: str, n: int = 1) -> None:
        v = self.values
        v[key] = v.get(key, 0) + n

    def set(self, key: str, value: float) -> None:
        """Set a gauge-style value (last write wins)."""
        self.values[key] = value

    def get(self, key: str, default: float = 0) -> float:
        return self.values.get(key, default)

    def snapshot(self, sim_t: float, wall_s: float,
                 gauges: Optional[Dict[str, float]] = None
                 ) -> Dict[str, float]:
        snap = dict(self.values)
        if gauges:
            snap.update(gauges)
        self.series.append((sim_t, wall_s, snap))
        return snap


class NullTracer:
    """Inert tracer: ``enabled`` is False and every method is a no-op.

    Instrumentation sites hold a reference to this singleton by default, so
    the fast-path check is one attribute load (``tr.enabled``) with no
    ``None`` branch.  Kept deliberately method-complete: code may call any
    tracer method without checking ``enabled`` first on cold paths.
    """

    enabled = False
    counters = Counters()          # shared sink; never snapshotted
    on_snapshot: Optional[Callable] = None

    def begin(self, cat: str, name: str) -> None:
        pass

    def end(self, sim_t: float, args: Optional[dict] = None) -> None:
        pass

    def instant(self, cat: str, name: str, sim_t: float,
                args: Optional[dict] = None) -> None:
        pass

    def counters_due(self, sim_t: float) -> bool:
        return False

    def snapshot(self, sim_t: float,
                 gauges: Optional[Dict[str, float]] = None) -> dict:
        return {}

    def unwind(self, sim_t: float, args: Optional[dict] = None) -> int:
        return 0


#: the default tracer everywhere a ``tracer`` attribute exists
NULL_TRACER = NullTracer()


class Tracer:
    """Span/instant/counter recorder with nesting-aware self-time.

    ``keep_records=False`` (profile- or counters-only modes) still times
    spans but does not retain per-span records — memory stays O(distinct
    span names) even on multi-hundred-thousand-event runs, which is what
    lets the profiling mode run at trace scale.

    Record layouts (all tuples, exported by :mod:`repro.obs.export`):

    * ``spans``:    ``(cat, name, t0_s, dur_s, sim_t, self_s, args)`` with
      ``t0_s`` relative to the tracer epoch.
    * ``instants``: ``(cat, name, wall_s, sim_t, args)``.
    * ``counters.series``: ``(sim_t, wall_s, {key: value})``.
    """

    enabled = True

    def __init__(self, keep_records: bool = True, profile: bool = False,
                 counters_every: Optional[float] = None,
                 clock: Callable[[], float] = time.perf_counter) -> None:
        if counters_every is not None and not counters_every > 0:
            raise ValueError(
                f"counters_every must be > 0 or None (got {counters_every!r})")
        self.keep_records = bool(keep_records)
        self.profile_enabled = bool(profile)
        self.counters_every = counters_every
        self.clock = clock
        self.epoch = clock()
        self.spans: List[tuple] = []
        self.instants: List[tuple] = []
        self.counters = Counters()
        #: optional live-progress hook: called as ``fn(sim_t, snapshot)``
        #: after every counter snapshot (the CLI's live line)
        self.on_snapshot: Optional[Callable[[float, dict], None]] = None
        self._stack: List[list] = []       # [cat, name, t0, child_dur]
        self._profile: Dict[Tuple[str, str], list] = {}  # -> [n, total, self]
        self._next_snap = 0.0 if counters_every is not None else None

    # ------------------------------------------------------------- spans
    def begin(self, cat: str, name: str) -> None:
        self._stack.append([cat, name, self.clock(), 0.0])

    def end(self, sim_t: float, args: Optional[dict] = None) -> None:
        t1 = self.clock()
        cat, name, t0, child = self._stack.pop()
        dur = t1 - t0
        if self._stack:
            self._stack[-1][3] += dur     # accumulate into the parent
        self_dur = dur - child
        if self.keep_records:
            self.spans.append(
                (cat, name, t0 - self.epoch, dur, sim_t, self_dur, args))
        if self.profile_enabled:
            p = self._profile.get((cat, name))
            if p is None:
                self._profile[(cat, name)] = [1, dur, self_dur]
            else:
                p[0] += 1
                p[1] += dur
                p[2] += self_dur

    def instant(self, cat: str, name: str, sim_t: float,
                args: Optional[dict] = None) -> None:
        if self.keep_records:
            self.instants.append(
                (cat, name, self.clock() - self.epoch, sim_t, args))

    def unwind(self, sim_t: float, args: Optional[dict] = None) -> int:
        """Close every open span (an exception propagated mid-span).

        Each open frame is ended normally — durations stay exact, child
        times still accumulate into parents — with ``args`` (default
        ``{"aborted": True}``) marking the abnormal close, so the span
        stack stays well-nested and a truncated trace still exports as
        schema-valid Chrome JSON.  Returns the number of spans closed."""
        if args is None:
            args = {"aborted": True}
        n = 0
        while self._stack:
            self.end(sim_t, args)
            n += 1
        return n

    # ----------------------------------------------------------- counters
    def counters_due(self, sim_t: float) -> bool:
        ns = self._next_snap
        return ns is not None and sim_t >= ns

    def snapshot(self, sim_t: float,
                 gauges: Optional[Dict[str, float]] = None) -> dict:
        snap = self.counters.snapshot(sim_t, self.clock() - self.epoch,
                                      gauges)
        if self._next_snap is not None:
            every = self.counters_every
            # cadence anchored at t=0: next boundary strictly after sim_t
            self._next_snap = (math.floor(sim_t / every) + 1.0) * every
        if self.on_snapshot is not None:
            self.on_snapshot(sim_t, snap)
        return snap

    # ---------------------------------------------------------- reporting
    def wall_elapsed(self) -> float:
        return self.clock() - self.epoch

    def profile(self) -> Dict[Tuple[str, str], list]:
        """``(cat, name) -> [count, total_s, self_s]`` aggregate (live
        reference; copy before mutating)."""
        return self._profile

    def deterministic_view(self) -> dict:
        """The seed-reproducible portion of the trace: everything except
        wall-clock times.  Two runs of the same spec + seed must produce
        identical views (regression-tested)."""
        return {
            "spans": [(c, n, round(sim_t, 9), args)
                      for c, n, _t0, _dur, sim_t, _self, args in self.spans],
            "instants": [(c, n, round(sim_t, 9), args)
                         for c, n, _wall, sim_t, args in self.instants],
            "counter_series": [(round(sim_t, 9), snap)
                               for sim_t, _wall, snap in
                               self.counters.series],
            "counters": dict(self.counters.values),
        }
