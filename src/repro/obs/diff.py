"""First-divergence finder over two event logs (ISSUE 8).

The repo's correctness methodology compares end-of-run metrics JSONs —
which says *that* two runs diverged, never *where*.  This module answers
"where": stream two event logs (two seeds, two code paths, vectorized vs
scalar oracle) and report the first differing record with its sim time,
both payloads, and the shared context window preceding it.  ROADMAP
direction 1 (the fused device-side core) adopts this as its bit-identity
debugging tool: when the fused loop diverges from the Python oracle at
trace scale, the first divergent event names the subsystem and tick.

Two modes:

* :func:`first_divergence` — exact streaming comparison.  Accepts
  :class:`~repro.obs.eventlog.EventLog` objects, saved log paths (NDJSON
  streams line-by-line, O(1) memory), or any record iterables.
* :func:`bisect_divergence` — windowed-rerun bisection for runs too big to
  log whole: the caller reruns both simulations with a windowed recorder
  (``EventLog(t_min, t_max)``) per probe, and the binary search narrows
  the divergence to a ``min_window``-sized interval.  Correctness rests on
  the bit-identity invariant itself: both runs are identical *before* the
  first divergence time T, so any window starting at or before T captures
  the same prefix from both runs and preserves the first divergent record.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Iterable, List, Optional, Tuple, Union

from .eventlog import EventLog, Record, iter_event_records

LogSource = Union[EventLog, str, Iterable[Record]]


@dataclass
class Divergence:
    """The first point two event streams disagree.

    ``record_a`` / ``record_b`` are the differing records (None when one
    stream simply ended — the other side's record carries the sim time).
    ``context`` holds the last shared records before the divergence, newest
    last."""

    index: int
    record_a: Optional[Record]
    record_b: Optional[Record]
    context: List[Record] = field(default_factory=list)

    @property
    def time(self) -> Optional[float]:
        """Sim time of the divergence (the earlier side when both exist)."""
        ts = [r[0] for r in (self.record_a, self.record_b) if r is not None]
        return min(ts) if ts else None


def _records(src: LogSource):
    if isinstance(src, str):
        return iter_event_records(src)
    if isinstance(src, EventLog):
        return src.records()
    return iter(src)


def first_divergence(a: LogSource, b: LogSource,
                     context: int = 5) -> Optional[Divergence]:
    """The first record where streams ``a`` and ``b`` differ, or None when
    they are identical.  Comparison is exact tuple equality — NDJSON round-
    trips floats exactly, so "equal" here means bit-identical payloads."""
    it_a, it_b = _records(a), _records(b)
    ring: deque = deque(maxlen=context) if context > 0 else deque(maxlen=1)
    _END = object()
    i = 0
    while True:
        ra = next(it_a, _END)
        rb = next(it_b, _END)
        if ra is _END and rb is _END:
            return None
        if ra is _END or rb is _END or ra != rb:
            return Divergence(
                index=i,
                record_a=None if ra is _END else ra,
                record_b=None if rb is _END else rb,
                context=list(ring) if context > 0 else [])
        if context > 0:
            ring.append(ra)
        i += 1


def bisect_divergence(
    make_logs: Callable[[float, float], Tuple[LogSource, LogSource]],
    t_end: float, min_window: float = 600.0, context: int = 5,
) -> Tuple[Optional[Divergence], Tuple[float, float]]:
    """Locate a divergence by windowed reruns instead of one full log.

    ``make_logs(t0, t1)`` must rerun *both* simulations from scratch,
    recording only events in ``[t0, t1)`` (pass ``EventLog(t_min=t0,
    t_max=t1)`` as each run's recorder), and return the two logs.  The
    search keeps the invariant "the first divergence lies in ``[lo, hi)``":
    if the probe of the lower half diverges, the divergence (and therefore
    the *first* divergence, since prefixes are shared) is there; otherwise
    it is in the upper half — whose window then starts at ``mid <= T``, so
    the shared-prefix alignment still holds.  Returns the divergence found
    in the final window (with context) and the window itself; ``(None,
    window)`` means the runs never diverged in ``[0, t_end)``.

    Probe cost: O(log(t_end / min_window)) paired reruns, each holding at
    most one window of events in memory."""
    lo, hi = 0.0, float(t_end)
    while hi - lo > min_window:
        mid = 0.5 * (lo + hi)
        a, b = make_logs(lo, mid)
        if first_divergence(a, b, context=0) is not None:
            hi = mid
        else:
            lo = mid
    a, b = make_logs(lo, hi)
    return first_divergence(a, b, context=context), (lo, hi)


# ---------------------------------------------------------------------------
# reporting
# ---------------------------------------------------------------------------
def _fmt_record(r: Optional[Record]) -> str:
    if r is None:
        return "<stream ended>"
    t, kind, vm, pool, host, a, b, aux = r
    parts = [f"t={t:.6g}", kind]
    if vm >= 0:
        parts.append(f"vm={vm}")
    if pool >= 0:
        parts.append(f"pool={pool}")
    if host >= 0:
        parts.append(f"host={host}")
    if a != 0.0:
        parts.append(f"a={a!r}")
    if b != 0.0:
        parts.append(f"b={b!r}")
    if aux is not None:
        parts.append(f"aux={aux}")
    return "  ".join(parts)


def format_divergence(div: Optional[Divergence],
                      label_a: str = "A", label_b: str = "B") -> str:
    """Human-readable divergence report (the ``--diff`` CLI's output)."""
    if div is None:
        return "event logs are identical (zero divergence)"
    lines = [f"first divergence at record #{div.index}"
             + (f" (sim t={div.time:.6g}s)" if div.time is not None else "")]
    if div.context:
        lines.append(f"  last {len(div.context)} shared event(s):")
        lines.extend(f"    {_fmt_record(r)}" for r in div.context)
    lines.append(f"  {label_a}: {_fmt_record(div.record_a)}")
    lines.append(f"  {label_b}: {_fmt_record(div.record_b)}")
    return "\n".join(lines)
