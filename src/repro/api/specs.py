"""The declarative spec tree — one serializable description of "a run".

Frozen dataclasses describing everything a simulation run needs, validated
at *construction* (unknown names, bad pool counts, migration-without-engine
all fail before any event loop starts), with lossless ``to_dict`` /
``from_dict`` / JSON round-trips so scenarios are shareable files and
CI-gateable artifacts:

* :class:`BidSpec`        — bid strategy name + params (``BID_REGISTRY``).
* :class:`PolicySpec`     — allocation policy name + params
  (``POLICY_REGISTRY``).
* :class:`MigrationSpec`  — migration policy name + params
  (``MIGRATION_REGISTRY``).
* :class:`RebidSpec`      — adaptive re-bid bump range (RebidOnResume).
* :class:`ScenarioSpec`   — workload + market regime + pools + tick +
  horizon (``WORKLOAD_REGISTRY``; ``regime=None`` = no market engine).
* :class:`FleetSpec`      — spot-fleet strategy + FleetConfig params
  (``FLEET_STRATEGY_REGISTRY``).
* :class:`FaultSpec`      — fault-injection scenario name + params
  (``FAULT_REGISTRY``).
* :class:`ObsSpec`        — observability switches (tracing / profiling /
  counter snapshots, ``repro.obs``).
* :class:`RunSpec`        — scenario × policy × migration × rebid × fleet ×
  faults × obs: the unit :func:`repro.api.build` materializes.
* :class:`ExperimentSpec` — scenario + policy/migration/regime/fleet grid +
  seed list: the unit :func:`repro.api.sweep.run_experiment` fans out.

Specs carry *names and parameters*, never live objects — stateful
components (engines, planners, policies) are materialized fresh per run by
the builder, so two runs can never accidentally share state.
"""
from __future__ import annotations

import dataclasses
import inspect
import json
from collections import abc
from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional, Tuple

from ..core.allocation import POLICY_REGISTRY
from ..core.simulator import SimConfig
from ..market.bids import BID_REGISTRY
from ..market.migration import (
    MIGRATION_POLICIES,
    MIGRATION_REGISTRY,
    MigrationConfig,
)
from ..market.faults import FAULT_REGISTRY, make_fault_injector
from ..market.fleet import (
    FLEET_STRATEGY_REGISTRY,
    FleetConfig,
    validate_fleet_config,
)
from ..market.pools import REGIMES
from ..serve.autoscale import (
    AUTOSCALE_REGISTRY,
    AutoscaleConfig,
    validate_autoscale_config,
)
from ..serve.service import ServeConfig, validate_serve_config
from .workloads import WORKLOAD_REGISTRY


def _spec_error(msg: str) -> ValueError:
    return ValueError(f"invalid spec: {msg}")


def _check_param_keys(params: Mapping[str, Any], allowed, what: str) -> None:
    unknown = sorted(set(params) - set(allowed))
    if unknown:
        raise _spec_error(
            f"unknown {what} parameter(s) {unknown} "
            f"(known: {', '.join(sorted(allowed))})")


def _factory_param_names(factory) -> Optional[Tuple[str, ...]]:
    """Keyword-parameter names a factory accepts, or None when it takes
    ``**kwargs`` (then key validation is deferred to build time)."""
    try:
        sig = inspect.signature(factory)
    except (TypeError, ValueError):  # builtins / C callables
        return None
    names = []
    for p in sig.parameters.values():
        if p.kind is inspect.Parameter.VAR_KEYWORD:
            return None
        if p.name != "self" and p.kind in (
                inspect.Parameter.POSITIONAL_OR_KEYWORD,
                inspect.Parameter.KEYWORD_ONLY):
            names.append(p.name)
    return tuple(names)


def _set(obj, name: str, value) -> None:
    object.__setattr__(obj, name, value)  # frozen-dataclass field fixup


class _SpecBase:
    """Shared JSON plumbing for every spec dataclass."""

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str):
        return cls.from_dict(json.loads(text))

    def replace(self, **changes):
        """``dataclasses.replace`` shorthand (re-runs validation)."""
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BidSpec(_SpecBase):
    """Bid strategy for the workload's spot VMs (engine runs only)."""

    strategy: str = "randomized"
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        factory = BID_REGISTRY.get(self.strategy)  # raises on unknown name
        _set(self, "params", dict(self.params))
        allowed = _factory_param_names(factory)
        if allowed is not None:
            # pool_cfg-derived defaults the builder may inject are implicit
            _check_param_keys(self.params, set(allowed),
                              f"bid strategy {self.strategy!r}")

    def to_dict(self) -> dict:
        return {"strategy": self.strategy, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "BidSpec":
        return cls(strategy=d.get("strategy", "randomized"),
                   params=d.get("params", {}))


@dataclass(frozen=True)
class PolicySpec(_SpecBase):
    """Allocation policy by registry name."""

    name: str
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        factory = POLICY_REGISTRY.get(self.name)
        _set(self, "params", dict(self.params))
        allowed = _factory_param_names(factory)
        if allowed is not None:
            _check_param_keys(self.params, set(allowed),
                              f"allocation policy {self.name!r}")

    def to_dict(self) -> dict:
        return {"name": self.name, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PolicySpec":
        return cls(name=d["name"], params=d.get("params", {}))


@dataclass(frozen=True)
class MigrationSpec(_SpecBase):
    """Proactive migration policy by registry name (``"none"`` = off)."""

    policy: str = "none"
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        MIGRATION_REGISTRY.get(self.policy)
        _set(self, "params", dict(self.params))
        if self.policy in MIGRATION_POLICIES:
            allowed = {f.name for f in dataclasses.fields(MigrationConfig)
                       } - {"policy"}
            _check_param_keys(self.params, allowed,
                              f"migration policy {self.policy!r}")

    @property
    def enabled(self) -> bool:
        return self.policy != "none"

    def to_dict(self) -> dict:
        return {"policy": self.policy, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "MigrationSpec":
        return cls(policy=d.get("policy", "none"), params=d.get("params", {}))


@dataclass(frozen=True)
class RebidSpec(_SpecBase):
    """Adaptive re-bidding on hibernation (RebidOnResume); the builder
    supplies the on-demand cap and seed."""

    bump_lo: float = 1.05
    bump_hi: float = 1.30

    def __post_init__(self):
        if not (0.0 < self.bump_lo <= self.bump_hi):
            raise _spec_error(
                f"rebid bump range needs 0 < bump_lo <= bump_hi "
                f"(got [{self.bump_lo}, {self.bump_hi}])")

    def to_dict(self) -> dict:
        return {"bump_lo": self.bump_lo, "bump_hi": self.bump_hi}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RebidSpec":
        return cls(bump_lo=d.get("bump_lo", 1.05),
                   bump_hi=d.get("bump_hi", 1.30))


@dataclass(frozen=True)
class FleetSpec(_SpecBase):
    """Spot-fleet manager: diversification strategy by registry name +
    :class:`~repro.market.fleet.FleetConfig` parameters (target capacity,
    pool weights, fallback ladder, backoff).  Validated at construction;
    pool-count-dependent checks (weight length, ``pool:<k>`` rungs) re-run
    inside :class:`RunSpec`, where ``n_pools`` is known."""

    strategy: str = "diversified"
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        FLEET_STRATEGY_REGISTRY.get(self.strategy)  # raises on unknown name
        _set(self, "params", dict(self.params))
        allowed = {f.name for f in dataclasses.fields(FleetConfig)
                   } - {"strategy"}
        _check_param_keys(self.params, allowed,
                          f"fleet strategy {self.strategy!r}")
        try:
            self.config()
        except ValueError as e:
            raise _spec_error(str(e)) from None

    def config(self, n_pools: Optional[int] = None) -> FleetConfig:
        """Materialize (and validate) the FleetConfig; with ``n_pools`` the
        pool-dependent checks run too."""
        p = dict(self.params)
        if "ladder" in p:
            p["ladder"] = tuple((str(r), int(b)) for r, b in p["ladder"])
        if p.get("pool_weights") is not None:
            p["pool_weights"] = tuple(float(x) for x in p["pool_weights"])
        cfg = FleetConfig(strategy=self.strategy, **p)
        validate_fleet_config(cfg, n_pools)
        return cfg

    def to_dict(self) -> dict:
        return {"strategy": self.strategy, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FleetSpec":
        return cls(strategy=d.get("strategy", "diversified"),
                   params=d.get("params", {}))


@dataclass(frozen=True)
class FaultSpec(_SpecBase):
    """Market fault injection: scenario by registry name + generator
    parameters.  The builder compiles it into a fresh seeded
    :class:`~repro.market.faults.FaultInjector` per run."""

    scenario: str = "storm"
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        factory = FAULT_REGISTRY.get(self.scenario)  # raises on unknown name
        _set(self, "params", dict(self.params))
        allowed = _factory_param_names(factory)
        if allowed is not None:
            _check_param_keys(
                self.params,
                set(allowed) - {"n_pools", "horizon", "tick_interval",
                                "seed"},
                f"fault scenario {self.scenario!r}")

    def validate_events(self, n_pools: int, horizon: Optional[float],
                        tick_interval: float) -> None:
        """Compile the schedule once (seed 0) so bad events — unknown pools,
        negative times, out-of-range magnitudes — fail at spec construction,
        not mid-sweep in a worker."""
        try:
            make_fault_injector(self.scenario, n_pools, horizon,
                                tick_interval, 0, **self.params)
        except (ValueError, TypeError) as e:
            raise _spec_error(str(e)) from None

    def to_dict(self) -> dict:
        return {"scenario": self.scenario, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "FaultSpec":
        return cls(scenario=d.get("scenario", "storm"),
                   params=d.get("params", {}))


@dataclass(frozen=True)
class ServeSpec(_SpecBase):
    """Traffic-driven serving layer:
    :class:`~repro.serve.service.ServeConfig` parameters (tick cadence,
    per-VM slots, decode throughput, SLO latency/objective).  The demand
    curve itself comes from the scenario's workload (``serve-diurnal`` /
    ``serve-bursty``), so the same ServeSpec composes with any demand
    shape."""

    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        _set(self, "params", dict(self.params))
        allowed = {f.name for f in dataclasses.fields(ServeConfig)}
        _check_param_keys(self.params, allowed, "serve")
        try:
            self.config()
        except ValueError as e:
            raise _spec_error(str(e)) from None

    def config(self) -> ServeConfig:
        cfg = ServeConfig(**dict(self.params))
        validate_serve_config(cfg)
        return cfg

    def to_dict(self) -> dict:
        return {"params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ServeSpec":
        return cls(params=d.get("params", {}))


@dataclass(frozen=True)
class AutoscaleSpec(_SpecBase):
    """Closed-loop autoscaler: policy by registry name
    (:data:`~repro.serve.autoscale.AUTOSCALE_REGISTRY`) +
    :class:`~repro.serve.autoscale.AutoscaleConfig` parameters (cadence,
    unit bounds, hysteresis, cooldown).  Drives
    ``FleetManager.set_target_units`` — requires both a serve spec (the
    signals) and a fleet spec (the lever)."""

    policy: str = "target-tracking"
    params: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        AUTOSCALE_REGISTRY.get(self.policy)  # raises on unknown name
        _set(self, "params", dict(self.params))
        allowed = {f.name for f in dataclasses.fields(AutoscaleConfig)}
        _check_param_keys(self.params, allowed,
                          f"autoscale policy {self.policy!r}")
        try:
            self.config()
        except ValueError as e:
            raise _spec_error(str(e)) from None

    def config(self) -> AutoscaleConfig:
        cfg = AutoscaleConfig(**dict(self.params))
        validate_autoscale_config(cfg)
        return cfg

    def to_dict(self) -> dict:
        return {"policy": self.policy, "params": dict(self.params)}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "AutoscaleSpec":
        return cls(policy=d.get("policy", "target-tracking"),
                   params=d.get("params", {}))


@dataclass(frozen=True)
class ObsSpec(_SpecBase):
    """Observability: tracing / profiling / counter snapshots
    (``repro.obs``).  All three are independent switches on one
    :class:`~repro.obs.tracer.Tracer`: ``trace`` retains span/instant
    records for Chrome-trace export, ``profile`` aggregates span wall-times
    into the per-subsystem self/total table, and ``counters_every``
    snapshots the counter registry every N simulated seconds.  The default
    spec is fully off and builds no tracer at all — byte-identical metrics
    to a pre-observability run.

    ``events`` is a fourth, independent switch: it attaches an
    :class:`~repro.obs.eventlog.EventLog` flight recorder (structured
    lifecycle/market event log) without building a tracer — an events-only
    spec still runs the plain untraced event loop."""

    trace: bool = False
    profile: bool = False
    #: counter-snapshot cadence in simulated seconds; None = off
    counters_every: Optional[float] = None
    #: record the structured event log (``repro.obs.eventlog``)
    events: bool = False

    def __post_init__(self):
        _set(self, "trace", bool(self.trace))
        _set(self, "profile", bool(self.profile))
        _set(self, "events", bool(self.events))
        if self.counters_every is not None:
            try:
                _set(self, "counters_every", float(self.counters_every))
            except (TypeError, ValueError):
                raise _spec_error(
                    f"counters_every must be a number or None "
                    f"(got {self.counters_every!r})") from None
            if not self.counters_every > 0:
                raise _spec_error(
                    f"counters_every must be > 0 or None "
                    f"(got {self.counters_every!r})")

    @property
    def enabled(self) -> bool:
        return (self.trace or self.profile
                or self.counters_every is not None or self.events)

    def to_dict(self) -> dict:
        return {"trace": self.trace, "profile": self.profile,
                "counters_every": self.counters_every,
                "events": self.events}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ObsSpec":
        return cls(trace=d.get("trace", False),
                   profile=d.get("profile", False),
                   counters_every=d.get("counters_every"),
                   events=d.get("events", False))


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ScenarioSpec(_SpecBase):
    """Workload + market regime + pools + tick + horizon — everything about
    the *world* a policy runs in (nothing about which policy runs)."""

    workload: str = "market"
    workload_params: Mapping[str, Any] = field(default_factory=dict)
    #: price regime (``repro.market.pools.REGIMES``); None = no market engine
    regime: Optional[str] = None
    n_pools: int = 4
    tick_interval: float = 60.0
    #: derive per-pool volatility from the synthetic Spot-Advisor dataset
    from_advisor: bool = True
    #: bid strategy for spot VMs (engine runs only)
    bid: Optional[BidSpec] = None
    #: extra :class:`~repro.core.simulator.SimConfig` fields
    #: (e.g. ``interruption_selector``)
    sim_params: Mapping[str, Any] = field(default_factory=dict)
    #: simulated horizon (s); None = the workload's default
    horizon: Optional[float] = None

    def __post_init__(self):
        entry = WORKLOAD_REGISTRY.get(self.workload)  # raises on unknown
        _set(self, "workload_params", dict(self.workload_params))
        _set(self, "sim_params", dict(self.sim_params))
        if isinstance(self.bid, Mapping):
            _set(self, "bid", BidSpec.from_dict(self.bid))
        if self.regime is not None and self.regime not in REGIMES:
            raise _spec_error(
                f"unknown regime {self.regime!r} (known: {', '.join(REGIMES)};"
                f" None disables the market engine)")
        if not (isinstance(self.n_pools, int) and self.n_pools >= 1):
            raise _spec_error(f"n_pools must be an int >= 1 "
                              f"(got {self.n_pools!r})")
        if not self.tick_interval > 0:
            raise _spec_error(f"tick_interval must be > 0 "
                              f"(got {self.tick_interval!r})")
        if self.horizon is not None and not self.horizon > 0:
            raise _spec_error(f"horizon must be > 0 or None "
                              f"(got {self.horizon!r})")
        reserved = set(getattr(entry, "reserved_params", ()) or ())
        overlap = sorted(reserved & set(self.workload_params))
        if overlap:
            raise _spec_error(
                f"workload_params {overlap} are supplied by the builder "
                f"(per-run seed / scenario fields) — remove them")
        cfg_cls = getattr(entry, "config_cls", None)
        if cfg_cls is not None and dataclasses.is_dataclass(cfg_cls):
            allowed = {f.name for f in dataclasses.fields(cfg_cls)} - reserved
            _check_param_keys(self.workload_params, allowed,
                              f"workload {self.workload!r}")
        _check_param_keys(
            self.sim_params,
            {f.name for f in dataclasses.fields(SimConfig)}
            - {"record_timeline"},
            "sim")
        if getattr(entry, "requires_market", False) and self.regime is None:
            raise _spec_error(
                f"workload {self.workload!r} requires a market regime "
                f"(set regime to one of {', '.join(REGIMES)})")
        if self.bid is not None:
            if self.regime is None:
                raise _spec_error(
                    "a bid strategy needs a market engine — set regime, or "
                    "drop the bid spec")
            if not getattr(entry, "supports_bids", True):
                raise _spec_error(
                    f"workload {self.workload!r} does not support bid "
                    f"assignment (VMs carry their own bids)")

    @property
    def has_market(self) -> bool:
        return self.regime is not None

    def to_dict(self) -> dict:
        return {
            "workload": self.workload,
            "workload_params": dict(self.workload_params),
            "regime": self.regime,
            "n_pools": self.n_pools,
            "tick_interval": self.tick_interval,
            "from_advisor": self.from_advisor,
            "bid": self.bid.to_dict() if self.bid is not None else None,
            "sim_params": dict(self.sim_params),
            "horizon": self.horizon,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ScenarioSpec":
        bid = d.get("bid")
        return cls(
            workload=d.get("workload", "market"),
            workload_params=d.get("workload_params", {}),
            regime=d.get("regime"),
            n_pools=d.get("n_pools", 4),
            tick_interval=d.get("tick_interval", 60.0),
            from_advisor=d.get("from_advisor", True),
            bid=BidSpec.from_dict(bid) if bid is not None else None,
            sim_params=d.get("sim_params", {}),
            horizon=d.get("horizon"),
        )


@dataclass(frozen=True)
class RunSpec(_SpecBase):
    """One concrete run: scenario × allocation policy × migration × rebid.
    :func:`repro.api.build` materializes it into a fresh simulator."""

    scenario: ScenarioSpec
    policy: PolicySpec
    migration: MigrationSpec = field(default_factory=MigrationSpec)
    rebid: Optional[RebidSpec] = None
    fleet: Optional[FleetSpec] = None
    faults: Optional[FaultSpec] = None
    #: traffic-driven serving layer; None = no request traffic
    serve: Optional[ServeSpec] = None
    #: closed-loop autoscaler (needs serve + fleet); None = fixed capacity
    autoscale: Optional[AutoscaleSpec] = None
    #: observability (tracing/profiling/counters); None = fully off
    obs: Optional[ObsSpec] = None

    def __post_init__(self):
        for name, typ in (("scenario", ScenarioSpec), ("policy", PolicySpec),
                          ("migration", MigrationSpec)):
            val = getattr(self, name)
            if isinstance(val, Mapping):
                _set(self, name, typ.from_dict(val))
            elif not isinstance(getattr(self, name), typ):
                raise _spec_error(f"{name} must be a {typ.__name__}")
        for name, typ in (("rebid", RebidSpec), ("fleet", FleetSpec),
                          ("faults", FaultSpec), ("serve", ServeSpec),
                          ("autoscale", AutoscaleSpec), ("obs", ObsSpec)):
            val = getattr(self, name)
            if isinstance(val, Mapping):
                _set(self, name, typ.from_dict(val))
            elif val is not None and not isinstance(val, typ):
                raise _spec_error(f"{name} must be a {typ.__name__} or None")
        if self.migration.enabled and not self.scenario.has_market:
            raise _spec_error(
                f"migration policy {self.migration.policy!r} requires a "
                f"market engine (prices drive the scoring) — set "
                f"scenario.regime, or use migration 'none'")
        if self.rebid is not None and not self.scenario.has_market:
            raise _spec_error(
                "adaptive re-bidding requires a market engine — set "
                "scenario.regime, or drop the rebid spec")
        if self.fleet is not None:
            if not self.scenario.has_market:
                raise _spec_error(
                    "a fleet manager requires a market engine — set "
                    "scenario.regime, or drop the fleet spec")
            try:
                # pool-count-dependent checks: weight length, pool:<k> rungs
                self.fleet.config(self.scenario.n_pools)
            except ValueError as e:
                raise _spec_error(str(e)) from None
        if self.faults is not None:
            if not self.scenario.has_market:
                raise _spec_error(
                    "fault injection requires a market engine — set "
                    "scenario.regime, or drop the faults spec")
            self.faults.validate_events(self.scenario.n_pools,
                                        self.scenario.horizon,
                                        self.scenario.tick_interval)
        wl = WORKLOAD_REGISTRY.get(self.scenario.workload)
        if self.serve is not None:
            if not getattr(wl, "provides_demand", False):
                raise _spec_error(
                    f"a serve spec needs a demand-providing workload "
                    f"(workload {self.scenario.workload!r} installs no "
                    f"request-rate curve — use serve-diurnal/serve-bursty)")
        elif getattr(wl, "provides_demand", False):
            raise _spec_error(
                f"workload {self.scenario.workload!r} generates request "
                f"demand — add a serve spec to consume it")
        if self.autoscale is not None:
            if self.serve is None:
                raise _spec_error(
                    "an autoscaler needs a serve spec — its signals are the "
                    "serving layer's demand/queue/latency estimates")
            if self.fleet is None:
                raise _spec_error(
                    "an autoscaler needs a fleet spec — "
                    "FleetManager.set_target_units is its actuation lever")

    def to_dict(self) -> dict:
        return {
            "scenario": self.scenario.to_dict(),
            "policy": self.policy.to_dict(),
            "migration": self.migration.to_dict(),
            "rebid": self.rebid.to_dict() if self.rebid is not None else None,
            "fleet": self.fleet.to_dict() if self.fleet is not None else None,
            "faults": (self.faults.to_dict()
                       if self.faults is not None else None),
            "serve": (self.serve.to_dict()
                      if self.serve is not None else None),
            "autoscale": (self.autoscale.to_dict()
                          if self.autoscale is not None else None),
            "obs": self.obs.to_dict() if self.obs is not None else None,
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "RunSpec":
        rebid = d.get("rebid")
        fleet = d.get("fleet")
        faults = d.get("faults")
        serve = d.get("serve")
        autoscale = d.get("autoscale")
        obs = d.get("obs")
        return cls(
            scenario=ScenarioSpec.from_dict(d["scenario"]),
            policy=PolicySpec.from_dict(d["policy"]),
            migration=MigrationSpec.from_dict(d.get("migration", {})),
            rebid=RebidSpec.from_dict(rebid) if rebid is not None else None,
            fleet=FleetSpec.from_dict(fleet) if fleet is not None else None,
            faults=(FaultSpec.from_dict(faults)
                    if faults is not None else None),
            serve=(ServeSpec.from_dict(serve)
                   if serve is not None else None),
            autoscale=(AutoscaleSpec.from_dict(autoscale)
                       if autoscale is not None else None),
            obs=ObsSpec.from_dict(obs) if obs is not None else None,
        )


# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class ExperimentSpec(_SpecBase):
    """A scenario × (regime × policy × migration × bid × workload-param)
    grid swept over seeds — the sweep runner's input, and the one file that
    describes a whole comparison experiment.

    ``bids`` and ``workload_grid`` are optional extra grid axes: ``bids``
    fans the scenario over bid strategies (engine scenarios only), and
    ``workload_grid`` fans named workload parameters over value ladders
    (e.g. ``{"fleet_scale": [1.0, 1.7, 3.4]}`` for a scaling study).  Both
    default to inert (one cell per regime × policy × migration, exactly the
    PR 4 grid)."""

    scenario: ScenarioSpec
    policies: Tuple[PolicySpec, ...]
    seeds: Tuple[int, ...]
    migrations: Tuple[MigrationSpec, ...] = (MigrationSpec(),)
    #: fan the scenario over these regimes (None = use ``scenario.regime``)
    regimes: Optional[Tuple[str, ...]] = None
    #: fan the scenario over these bid strategies (None = ``scenario.bid``)
    bids: Optional[Tuple[BidSpec, ...]] = None
    #: fan named workload parameters over value ladders; the cross product
    #: of all listed values joins the grid
    workload_grid: Mapping[str, Tuple] = field(default_factory=dict)
    rebid: Optional[RebidSpec] = None
    #: fan the grid over fleet managers; entries may be None (the per-VM
    #: baseline cell).  None (the default) = no fleet axis at all (inert)
    fleets: Optional[Tuple[Optional["FleetSpec"], ...]] = None
    #: fault injection applied to *every* cell (same seeded schedule per
    #: seed, so cells stay comparable); None = no faults
    faults: Optional[FaultSpec] = None
    #: serving layer applied to *every* cell (the demand curve comes from
    #: the scenario's workload); None = no request traffic
    serve: Optional["ServeSpec"] = None
    #: fan the grid over autoscalers; entries may be None (the fixed-
    #: capacity baseline cell).  None (the default) = no autoscale axis
    autoscales: Optional[Tuple[Optional["AutoscaleSpec"], ...]] = None
    name: str = "experiment"

    def __post_init__(self):
        _set(self, "policies", tuple(
            PolicySpec.from_dict(p) if isinstance(p, Mapping) else p
            for p in self.policies))
        _set(self, "migrations", tuple(
            MigrationSpec.from_dict(m) if isinstance(m, Mapping) else m
            for m in self.migrations))
        _set(self, "seeds", tuple(self.seeds))
        if isinstance(self.scenario, Mapping):
            _set(self, "scenario", ScenarioSpec.from_dict(self.scenario))
        if isinstance(self.rebid, Mapping):
            _set(self, "rebid", RebidSpec.from_dict(self.rebid))
        if isinstance(self.faults, Mapping):
            _set(self, "faults", FaultSpec.from_dict(self.faults))
        if self.faults is not None and not isinstance(self.faults, FaultSpec):
            raise _spec_error("faults must be a FaultSpec or None")
        if isinstance(self.serve, Mapping):
            _set(self, "serve", ServeSpec.from_dict(self.serve))
        if self.serve is not None and not isinstance(self.serve, ServeSpec):
            raise _spec_error("serve must be a ServeSpec or None")
        if self.autoscales is not None:
            _set(self, "autoscales", tuple(
                AutoscaleSpec.from_dict(a) if isinstance(a, Mapping) else a
                for a in self.autoscales))
            if not self.autoscales:
                raise _spec_error("autoscales cannot be empty — use None "
                                  "for no autoscale axis, or include a None "
                                  "entry for the fixed-capacity baseline")
            if not all(a is None or isinstance(a, AutoscaleSpec)
                       for a in self.autoscales):
                raise _spec_error(
                    "autoscales must all be AutoscaleSpec or None")
        if self.fleets is not None:
            _set(self, "fleets", tuple(
                FleetSpec.from_dict(f) if isinstance(f, Mapping) else f
                for f in self.fleets))
            if not self.fleets:
                raise _spec_error("fleets cannot be empty — use None for no "
                                  "fleet axis, or include a None entry for "
                                  "the per-VM baseline")
            if not all(f is None or isinstance(f, FleetSpec)
                       for f in self.fleets):
                raise _spec_error("fleets must all be FleetSpec or None")
        if not isinstance(self.scenario, ScenarioSpec):
            raise _spec_error("scenario must be a ScenarioSpec")
        if not all(isinstance(p, PolicySpec) for p in self.policies):
            raise _spec_error("policies must all be PolicySpec")
        if not all(isinstance(m, MigrationSpec) for m in self.migrations):
            raise _spec_error("migrations must all be MigrationSpec")
        if self.rebid is not None and not isinstance(self.rebid, RebidSpec):
            raise _spec_error("rebid must be a RebidSpec or None")
        if self.regimes is not None:
            _set(self, "regimes", tuple(self.regimes))
        if self.bids is not None:
            _set(self, "bids", tuple(
                BidSpec.from_dict(b) if isinstance(b, Mapping) else b
                for b in self.bids))
            if not self.bids:
                raise _spec_error("bids cannot be empty — use None to "
                                  "inherit scenario.bid")
            if not all(isinstance(b, BidSpec) for b in self.bids):
                raise _spec_error("bids must all be BidSpec")
        grid = {}
        for key, vals in dict(self.workload_grid).items():
            if isinstance(vals, (str, bytes)) or not isinstance(
                    vals, abc.Sequence):
                raise _spec_error(
                    f"workload_grid[{key!r}] must be a list/tuple of values "
                    f"(got {vals!r})")
            grid[str(key)] = tuple(vals)
        _set(self, "workload_grid", grid)
        for key, vals in self.workload_grid.items():
            if not vals:
                raise _spec_error(
                    f"workload_grid[{key!r}] cannot be empty")
            if key in self.scenario.workload_params:
                raise _spec_error(
                    f"workload_grid key {key!r} also appears in "
                    f"scenario.workload_params — list it in exactly one "
                    f"place")
        if not self.policies:
            raise _spec_error("an experiment needs at least one policy")
        if not self.migrations:
            raise _spec_error("migrations cannot be empty — use the default "
                              "(MigrationSpec('none'),)")
        if not self.seeds:
            raise _spec_error("an experiment needs at least one seed")
        if len(set(self.seeds)) != len(self.seeds):
            raise _spec_error(f"duplicate seeds: {list(self.seeds)}")
        if not all(isinstance(s, int) for s in self.seeds):
            raise _spec_error(f"seeds must be ints (got {list(self.seeds)})")
        if self.regimes is not None:
            if not self.regimes:
                raise _spec_error("regimes cannot be empty — use None to "
                                  "inherit scenario.regime")
            for r in self.regimes:
                if r is not None and r not in REGIMES:
                    raise _spec_error(f"unknown regime {r!r} in regimes "
                                      f"(known: {', '.join(REGIMES)})")
        # every grid cell is validated eagerly: a bad combination (e.g.
        # migration over a regime-less scenario, a bid axis without an
        # engine, an unknown workload_grid key) fails at construction,
        # not in a worker process mid-sweep
        self.cells()

    # -- grid ---------------------------------------------------------------
    def workload_combos(self) -> Tuple[Mapping[str, Any], ...]:
        """The cross product of ``workload_grid`` value ladders as parameter
        dicts, in axis-declaration order (``({},)`` when the grid is
        inert)."""
        if not self.workload_grid:
            return ({},)
        keys = list(self.workload_grid)
        combos: list = [{}]
        for key in keys:
            combos = [{**c, key: v} for c in combos
                      for v in self.workload_grid[key]]
        return tuple(combos)

    def cells(self) -> Tuple[RunSpec, ...]:
        """The (regime × policy × migration × bid × workload-combo × fleet)
        grid as RunSpecs, in report order (new axes nest innermost, so the
        PR 4 ordering is preserved when they are inert)."""
        regimes = (self.regimes if self.regimes is not None
                   else (self.scenario.regime,))
        bid_axis = self.bids if self.bids is not None else (None,)
        fleet_axis = self.fleets if self.fleets is not None else (None,)
        autoscale_axis = (self.autoscales if self.autoscales is not None
                          else (None,))
        combos = self.workload_combos()
        out = []
        for regime in regimes:
            base = (self.scenario if regime == self.scenario.regime
                    else self.scenario.replace(regime=regime))
            for policy in self.policies:
                for migration in self.migrations:
                    for bid in bid_axis:
                        s_bid = base if bid is None else base.replace(bid=bid)
                        for combo in combos:
                            scenario = (s_bid if not combo else s_bid.replace(
                                workload_params={**s_bid.workload_params,
                                                 **combo}))
                            for fleet in fleet_axis:
                                for autoscale in autoscale_axis:
                                    out.append(RunSpec(
                                        scenario=scenario, policy=policy,
                                        migration=migration,
                                        rebid=self.rebid, fleet=fleet,
                                        faults=self.faults,
                                        serve=self.serve,
                                        autoscale=autoscale))
        return tuple(out)

    def runs(self):
        """Yields ``(cell_index, run_spec, seed)`` for the full grid × seed
        fan-out."""
        for i, cell in enumerate(self.cells()):
            for seed in self.seeds:
                yield i, cell, seed

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "scenario": self.scenario.to_dict(),
            "policies": [p.to_dict() for p in self.policies],
            "migrations": [m.to_dict() for m in self.migrations],
            "regimes": list(self.regimes) if self.regimes is not None
            else None,
            "bids": ([b.to_dict() for b in self.bids]
                     if self.bids is not None else None),
            "workload_grid": {k: list(v)
                              for k, v in self.workload_grid.items()},
            "seeds": list(self.seeds),
            "rebid": self.rebid.to_dict() if self.rebid is not None else None,
            "fleets": ([f.to_dict() if f is not None else None
                        for f in self.fleets]
                       if self.fleets is not None else None),
            "faults": (self.faults.to_dict()
                       if self.faults is not None else None),
            "serve": (self.serve.to_dict()
                      if self.serve is not None else None),
            "autoscales": ([a.to_dict() if a is not None else None
                            for a in self.autoscales]
                           if self.autoscales is not None else None),
        }

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ExperimentSpec":
        rebid = d.get("rebid")
        regimes = d.get("regimes")
        bids = d.get("bids")
        fleets = d.get("fleets")
        faults = d.get("faults")
        serve = d.get("serve")
        autoscales = d.get("autoscales")
        return cls(
            name=d.get("name", "experiment"),
            scenario=ScenarioSpec.from_dict(d["scenario"]),
            policies=tuple(PolicySpec.from_dict(p) for p in d["policies"]),
            migrations=tuple(MigrationSpec.from_dict(m)
                             for m in d.get("migrations", [{}])),
            regimes=tuple(regimes) if regimes is not None else None,
            bids=(tuple(BidSpec.from_dict(b) for b in bids)
                  if bids is not None else None),
            workload_grid=d.get("workload_grid", {}),
            seeds=tuple(int(s) for s in d["seeds"]),
            rebid=RebidSpec.from_dict(rebid) if rebid is not None else None,
            fleets=(tuple(FleetSpec.from_dict(f) if f is not None else None
                          for f in fleets)
                    if fleets is not None else None),
            faults=(FaultSpec.from_dict(faults)
                    if faults is not None else None),
            serve=(ServeSpec.from_dict(serve)
                   if serve is not None else None),
            autoscales=(tuple(AutoscaleSpec.from_dict(a)
                              if a is not None else None
                              for a in autoscales)
                        if autoscales is not None else None),
        )

    @classmethod
    def load(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1) + "\n")
