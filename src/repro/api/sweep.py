"""Seed-swept experiment runner: ExperimentSpec → aggregate report.

Fans an :class:`~repro.api.specs.ExperimentSpec` out over its
(regime × policy × migration) grid × seeds with multiprocessing, then
aggregates every numeric metric per grid cell into mean ± 95% CI
(Student-t half-width over the seed sample).  The report is a single JSON
document and is *deterministic*: rows carry no wall-clock fields, jobs are
dispatched and re-assembled in grid order, and aggregate floats are rounded
— two runs of the same spec produce byte-identical reports, so the report
itself is a CI-gateable artifact.

This is the ROADMAP's "seed-swept evaluation harness": tail statistics like
max interruption duration are noisy at a single seed; comparative claims
(HLEM-VMP vs First-Fit, gradient-aware migration vs none) become
mean ± CI over >= 20 seeds per cell, from one spec file:

    exp = ExperimentSpec.load("examples/specs/migration_sweep.json")
    report = run_experiment(exp)
    write_report(report, "results/migration_sweep.json")
"""
from __future__ import annotations

import json
import math
import multiprocessing
import os
from typing import Dict, List, Optional

from .build import resolve_horizon, run_one
from .specs import ExperimentSpec, RunSpec

#: two-sided 95% Student-t critical values by degrees of freedom (n - 1);
#: beyond the table the normal limit 1.96 is used
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 11: 2.201, 12: 2.179,
        13: 2.160, 14: 2.145, 15: 2.131, 16: 2.120, 17: 2.110, 18: 2.101,
        19: 2.093, 20: 2.086, 21: 2.080, 22: 2.074, 23: 2.069, 24: 2.064,
        25: 2.060, 26: 2.056, 27: 2.052, 28: 2.048, 29: 2.045, 30: 2.042}

_ID_KEYS = ("policy", "regime", "migration", "seed")


def t_crit95(df: int) -> float:
    if df < 1:
        return float("nan")
    if df in _T95:
        return _T95[df]
    # beyond the table: closed-form approximation t ~ 1.96 + 2.4/df
    # (within ~0.2% of the true quantile for df > 30, continuous at the
    # table boundary, converging to the normal limit)
    return 1.96 + 2.4 / df


def mean_ci95(values: List[float]) -> Dict[str, float]:
    """Mean and 95% CI half-width (t-distribution) of a seed sample."""
    n = len(values)
    mean = sum(values) / n
    if n < 2:
        return {"mean": round(mean, 6), "ci95": 0.0,
                "min": round(min(values), 6), "max": round(max(values), 6),
                "n": n}
    var = sum((v - mean) ** 2 for v in values) / (n - 1)
    half = t_crit95(n - 1) * math.sqrt(var / n)
    return {"mean": round(mean, 6), "ci95": round(half, 6),
            "min": round(min(values), 6), "max": round(max(values), 6),
            "n": n}


def aggregate_rows(rows: List[dict]) -> Dict[str, Dict[str, float]]:
    """mean ± CI for every numeric metric shared by the cell's rows."""
    out: Dict[str, Dict[str, float]] = {}
    for key in rows[0]:
        if key in _ID_KEYS:
            continue
        vals = [r[key] for r in rows]
        if all(isinstance(v, (int, float)) and not isinstance(v, bool)
               for v in vals):
            out[key] = mean_ci95([float(v) for v in vals])
    return out


def _run_job(job) -> dict:
    spec_dict, seed, until = job
    return run_one(RunSpec.from_dict(spec_dict), seed, until=until)


def run_experiment(exp: ExperimentSpec, processes: Optional[int] = None,
                   until: Optional[float] = None,
                   progress: bool = False) -> dict:
    """Run the full grid × seed fan-out and aggregate per cell.

    ``processes``: worker count for the multiprocessing pool; ``0`` or ``1``
    runs serially in-process (reports are identical either way — rows are
    re-assembled in grid order).  ``until`` overrides every run's horizon
    (e.g. for smoke sweeps)."""
    cells = exp.cells()
    # flat job list in grid-major order (cell 0's seeds, cell 1's seeds, …)
    jobs = [(cell.to_dict(), seed, until)
            for cell in cells for seed in exp.seeds]
    if processes is None:
        processes = min(os.cpu_count() or 1, len(jobs))
    if processes > 1 and len(jobs) > 1:
        # prefer fork so registry entries added at runtime (e.g. a custom
        # policy registered in the caller's __main__) survive into workers;
        # under spawn, custom plugins must be registered at import time of
        # an importable module
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError:  # fork unavailable (e.g. Windows)
            ctx = multiprocessing.get_context()
        with ctx.Pool(processes) as pool:
            rows = []
            # imap preserves job order, so the report stays deterministic
            for k, row in enumerate(pool.imap(_run_job, jobs, chunksize=1)):
                rows.append(row)
                if progress:
                    print(f"# sweep {k + 1}/{len(jobs)}", flush=True)
    else:
        rows = []
        for k, job in enumerate(jobs):
            rows.append(_run_job(job))
            if progress:
                print(f"# sweep {k + 1}/{len(jobs)}", flush=True)

    n_seeds = len(exp.seeds)
    report_cells = []
    for i, cell in enumerate(cells):
        cell_rows = rows[i * n_seeds:(i + 1) * n_seeds]
        report_cells.append({
            "regime": cell.scenario.regime,
            "policy": cell.policy.name,
            "migration": cell.migration.policy,
            "n_seeds": n_seeds,
            "metrics": aggregate_rows(cell_rows),
            "rows": cell_rows,
        })
    horizon = until if until is not None else resolve_horizon(exp.scenario)
    return {
        "name": exp.name,
        "experiment": exp.to_dict(),
        "horizon": horizon,
        "n_runs": len(jobs),
        "cells": report_cells,
    }


def write_report(report: dict, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    return path


def format_report(report: dict) -> str:
    """Human-readable mean ± CI table (the sweep CLI's default output)."""
    lines = [
        f"sweep: {report['name']}  "
        f"({report['n_runs']} runs, {report['cells'][0]['n_seeds']} seeds "
        f"per cell, horizon={report['horizon']})",
        f"{'regime':11s} {'policy':18s} {'migration':15s} "
        f"{'interruptions':>20s} {'max_intr_s':>18s} {'migr':>12s} "
        f"{'spot_cost':>17s}",
    ]
    for c in report["cells"]:
        m = c["metrics"]

        def pm(key: str, digits: int = 1) -> str:
            if key not in m:
                return "-"
            return (f"{m[key]['mean']:.{digits}f}"
                    f"±{m[key]['ci95']:.{digits}f}")

        lines.append(
            f"{str(c['regime']):11s} {c['policy']:18s} "
            f"{c['migration']:15s} {pm('interruptions'):>20s} "
            f"{pm('max_interruption_time'):>18s} {pm('migrations'):>12s} "
            f"{pm('realized_spot_cost', 3):>17s}")
    return "\n".join(lines)
