"""Seed-swept experiment runner: ExperimentSpec → aggregate report.

Fans an :class:`~repro.api.specs.ExperimentSpec` out over its
(regime × policy × migration) grid × seeds with multiprocessing, then
aggregates every numeric metric per grid cell into mean ± 95% CI
(Student-t half-width over the seed sample).  The report is a single JSON
document and is *deterministic*: rows carry no wall-clock fields, jobs are
dispatched and re-assembled in grid order, and aggregate floats are rounded
— two runs of the same spec produce byte-identical reports, so the report
itself is a CI-gateable artifact.

This is the ROADMAP's "seed-swept evaluation harness": tail statistics like
max interruption duration are noisy at a single seed; comparative claims
(HLEM-VMP vs First-Fit, gradient-aware migration vs none) become
mean ± CI over >= 20 seeds per cell, from one spec file:

    exp = ExperimentSpec.load("examples/specs/migration_sweep.json")
    report = run_experiment(exp)
    write_report(report, "results/migration_sweep.json")
"""
from __future__ import annotations

import json
import math
import multiprocessing
import os
import sys
import time
from typing import Dict, List, Optional

from .build import resolve_horizon, run_one
from ..obs.manifest import run_manifest
from .specs import ExperimentSpec, RunSpec

#: two-sided 95% Student-t critical values by degrees of freedom (n - 1);
#: beyond the table the normal limit 1.96 is used
_T95 = {1: 12.706, 2: 4.303, 3: 3.182, 4: 2.776, 5: 2.571, 6: 2.447,
        7: 2.365, 8: 2.306, 9: 2.262, 10: 2.228, 11: 2.201, 12: 2.179,
        13: 2.160, 14: 2.145, 15: 2.131, 16: 2.120, 17: 2.110, 18: 2.101,
        19: 2.093, 20: 2.086, 21: 2.080, 22: 2.074, 23: 2.069, 24: 2.064,
        25: 2.060, 26: 2.056, 27: 2.052, 28: 2.048, 29: 2.045, 30: 2.042}

_ID_KEYS = ("policy", "regime", "migration", "seed")


def t_crit95(df: int) -> float:
    if df < 1:
        return float("nan")
    if df in _T95:
        return _T95[df]
    # beyond the table: closed-form approximation t ~ 1.96 + 2.4/df
    # (within ~0.2% of the true quantile for df > 30, continuous at the
    # table boundary, converging to the normal limit)
    return 1.96 + 2.4 / df


def mean_ci95(values: List[float]) -> Dict[str, float]:
    """Mean and 95% CI half-width (t-distribution) of a seed sample."""
    n = len(values)
    mean = sum(values) / n
    if n < 2:
        return {"mean": round(mean, 6), "ci95": 0.0,
                "min": round(min(values), 6), "max": round(max(values), 6),
                "n": n}
    var = sum((v - mean) ** 2 for v in values) / (n - 1)
    half = t_crit95(n - 1) * math.sqrt(var / n)
    return {"mean": round(mean, 6), "ci95": round(half, 6),
            "min": round(min(values), 6), "max": round(max(values), 6),
            "n": n}


def aggregate_rows(rows: List[dict]) -> Dict[str, Dict[str, float]]:
    """mean ± CI for every numeric metric shared by the cell's rows."""
    out: Dict[str, Dict[str, float]] = {}
    for key in rows[0]:
        if key in _ID_KEYS:
            continue
        vals = [r[key] for r in rows]
        if all(isinstance(v, (int, float)) and not isinstance(v, bool)
               for v in vals):
            out[key] = mean_ci95([float(v) for v in vals])
    return out


def _run_job(job) -> dict:
    spec_dict, seed, until = job
    return run_one(RunSpec.from_dict(spec_dict), seed, until=until)


def _report_cell(exp: ExperimentSpec, cell: RunSpec,
                 cell_rows: List[dict]) -> dict:
    out = {
        "regime": cell.scenario.regime,
        "policy": cell.policy.name,
        "migration": cell.migration.policy,
        "n_seeds": len(exp.seeds),
        "metrics": aggregate_rows(cell_rows),
        "rows": cell_rows,
    }
    # extra grid axes identify their cells; inert axes add no keys, so
    # PR 4-era reports stay byte-identical
    if exp.bids is not None:
        # full spec, not just the strategy name — two BidSpecs may share a
        # strategy and differ only in params
        out["bid"] = (cell.scenario.bid.to_dict()
                      if cell.scenario.bid is not None else None)
    if exp.workload_grid:
        out["workload_params"] = {
            k: cell.scenario.workload_params[k] for k in exp.workload_grid}
    if exp.fleets is not None:
        # full spec (None = the per-VM baseline cell) — two FleetSpecs may
        # share a strategy and differ only in ladder/weights params
        out["fleet"] = (cell.fleet.to_dict()
                        if cell.fleet is not None else None)
    if exp.autoscales is not None:
        # full spec (None = the fixed-capacity baseline cell) — two
        # AutoscaleSpecs may share a policy and differ only in params
        out["autoscale"] = (cell.autoscale.to_dict()
                            if cell.autoscale is not None else None)
    return out


def _assemble_report(exp: ExperimentSpec, horizon, n_runs: int,
                     report_cells: List[dict]) -> dict:
    return {
        "name": exp.name,
        "experiment": exp.to_dict(),
        "horizon": horizon,
        "n_runs": n_runs,
        "cells": report_cells,
    }


def _load_resume_cells(path: str, exp: ExperimentSpec,
                       horizon) -> List[dict]:
    """Completed report cells from a partial (or final) report at ``path``,
    when it matches this experiment + horizon; ``[]`` otherwise.  Partial
    files only ever contain whole cells, appended in grid order, so the
    loaded list is always a reusable prefix of the grid."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return []
    same = (doc.get("experiment") == json.loads(json.dumps(exp.to_dict()))
            and doc.get("horizon") == horizon)
    return list(doc.get("cells", [])) if same else []


def _atomic_write(doc: dict, path: str) -> str:
    """Write ``doc`` as JSON via a temp file + ``os.replace``, so readers
    (and a crash-resumed rerun) never see a half-written report."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path


def run_experiment(exp: ExperimentSpec, processes: Optional[int] = None,
                   until: Optional[float] = None,
                   progress: bool = False,
                   report_path: Optional[str] = None,
                   resume: bool = True,
                   manifest: bool = False) -> dict:
    """Run the full grid × seed fan-out and aggregate per cell.

    ``processes``: worker count for the multiprocessing pool; ``0`` or ``1``
    runs serially in-process (reports are identical either way — rows are
    re-assembled in grid order).  ``until`` overrides every run's horizon
    (e.g. for smoke sweeps).

    ``report_path``: incremental report writing — the report JSON is
    re-written (atomic temp-file + rename) after **every completed cell**,
    with ``"partial": true`` until the grid is done, so long 100+-seed
    sweeps are inspectable mid-run.  With ``resume=True`` (default) an
    existing report at that path whose experiment + horizon match is
    treated as a crash checkpoint: its completed cells are reused verbatim
    and only the remaining cells run — the finished report is byte-identical
    to an uninterrupted run.

    ``progress``: per-job progress lines on **stderr** (stdout stays pure
    for ``--json`` consumers) with per-cell wall time and a simple ETA
    extrapolated from this session's completed jobs.

    ``manifest``: attach a :func:`repro.obs.manifest.run_manifest` block
    (spec hash, git SHA, package versions, wall duration) to the report.
    Off by default — the manifest carries wall-clock fields, and the
    *default* report is byte-deterministic (two runs of the same spec are
    identical artifacts; the determinism tests rely on it).  The CLI turns
    it on for every report it writes.  Resume ignores the block."""
    t_session = time.perf_counter()  # detlint: disable=no-wallclock — stderr ETA only, never in the report
    cells = exp.cells()
    n_seeds = len(exp.seeds)
    horizon = until if until is not None else resolve_horizon(exp.scenario)
    report_cells: List[dict] = []
    if report_path and resume:
        report_cells = _load_resume_cells(report_path, exp, horizon)[
            : len(cells)]
    n_done = len(report_cells)
    if n_done:
        # always announce reuse (stderr, so --json stdout stays pure):
        # resumed cells reflect the code that produced the checkpoint —
        # pass resume=False (CLI: --fresh) after changing the simulator
        print(f"# sweep resume: {n_done}/{len(cells)} cells reused from "
              f"{report_path}", file=sys.stderr, flush=True)
    n_runs = len(cells) * n_seeds
    # flat job list for the remaining cells, in grid-major order
    # (cell k's seeds, cell k+1's seeds, …)
    jobs = [(cell.to_dict(), seed, until)
            for cell in cells[n_done:] for seed in exp.seeds]

    pending: List[dict] = []
    done_jobs = n_done * n_seeds
    session_jobs = 0                      # jobs actually run this session
    t_cell = time.perf_counter()          # detlint: disable=no-wallclock — stderr ETA only, never in the report

    def _collect(row: dict) -> None:
        nonlocal done_jobs, session_jobs, t_cell
        pending.append(row)
        done_jobs += 1
        session_jobs += 1
        if progress:
            # ETA from this session's throughput only — resumed cells were
            # free and must not make the estimate optimistic
            elapsed = time.perf_counter() - t_session  # detlint: disable=no-wallclock — stderr ETA only
            rate = elapsed / session_jobs
            eta = rate * (n_runs - done_jobs)
            print(f"# sweep {done_jobs}/{n_runs}  "
                  f"avg {rate:.2f}s/run  eta {eta:.0f}s",
                  file=sys.stderr, flush=True)
        if len(pending) == n_seeds:       # one whole cell completed
            report_cells.append(
                _report_cell(exp, cells[len(report_cells)], pending[:]))
            pending.clear()
            now = time.perf_counter()  # detlint: disable=no-wallclock — stderr ETA only
            if progress:
                print(f"# sweep cell {len(report_cells)}/{len(cells)} "
                      f"done in {now - t_cell:.2f}s",
                      file=sys.stderr, flush=True)
            t_cell = now
            if report_path and len(report_cells) < len(cells):
                partial = _assemble_report(exp, horizon, n_runs,
                                           report_cells)
                partial["partial"] = True
                _atomic_write(partial, report_path)

    if processes is None:
        processes = min(os.cpu_count() or 1, max(len(jobs), 1))
    if processes > 1 and len(jobs) > 1:
        # prefer fork so registry entries added at runtime (e.g. a custom
        # policy registered in the caller's __main__) survive into workers;
        # under spawn, custom plugins must be registered at import time of
        # an importable module
        try:
            ctx = multiprocessing.get_context("fork")
        except ValueError:  # fork unavailable (e.g. Windows)
            ctx = multiprocessing.get_context()
        with ctx.Pool(processes) as pool:
            # imap preserves job order, so the report stays deterministic
            # and cells complete strictly in grid order
            for row in pool.imap(_run_job, jobs, chunksize=1):
                _collect(row)
    else:
        for job in jobs:
            _collect(_run_job(job))

    report = _assemble_report(exp, horizon, n_runs, report_cells)
    if manifest:
        report["manifest"] = run_manifest(
            spec_dict=exp.to_dict(), seed=list(exp.seeds),
            duration_s=time.perf_counter() - t_session,  # detlint: disable=no-wallclock — manifest is opt-in wall metadata
            extra={"resumed_cells": n_done})
    if report_path:
        _atomic_write(report, report_path)
    return report


def write_report(report: dict, path: str) -> str:
    return _atomic_write(report, path)


def format_report(report: dict) -> str:
    """Human-readable mean ± CI table (the sweep CLI's default output)."""
    fleet_axis = any("fleet" in c for c in report["cells"])
    lines = [
        f"sweep: {report['name']}  "
        f"({report['n_runs']} runs, {report['cells'][0]['n_seeds']} seeds "
        f"per cell, horizon={report['horizon']})",
        f"{'regime':11s} {'policy':18s} {'migration':15s} "
        + (f"{'fleet':12s} " if fleet_axis else "")
        + f"{'interruptions':>20s} {'max_intr_s':>18s} {'migr':>12s} "
        f"{'spot_cost':>17s}"
        + (f" {'below_tgt_s':>18s} {'recovery_s':>16s}" if fleet_axis
           else ""),
    ]
    for c in report["cells"]:
        m = c["metrics"]

        def pm(key: str, digits: int = 1) -> str:
            if key not in m:
                return "-"
            return (f"{m[key]['mean']:.{digits}f}"
                    f"±{m[key]['ci95']:.{digits}f}")

        fl = ""
        if fleet_axis:
            spec = c.get("fleet")
            fl = f"{spec['strategy'] if spec else 'per-vm':12s} "
        lines.append(
            f"{str(c['regime']):11s} {c['policy']:18s} "
            f"{c['migration']:15s} {fl}{pm('interruptions'):>20s} "
            f"{pm('max_interruption_time'):>18s} {pm('migrations'):>12s} "
            f"{pm('realized_spot_cost', 3):>17s}"
            + (f" {pm('time_below_target_s'):>18s} "
               f"{pm('mean_recovery_s'):>16s}" if fleet_axis else ""))
    return "\n".join(lines)
