"""One import point for every plugin registry the scenario API validates
against.

The registries themselves live next to the families they describe (so core
stays importable without the API layer); this module re-exports them plus
the registration decorators:

* :data:`POLICY_REGISTRY` / ``register_policy`` — allocation policies.
* :data:`BID_REGISTRY` / ``register_bid_strategy`` — bid strategies.
* :data:`MIGRATION_REGISTRY` / ``register_migration_policy`` — migration
  policies.
* :data:`PRICE_PROCESS_REGISTRY` / ``register_price_process`` — pool price
  processes.
* :data:`WORKLOAD_REGISTRY` / ``register_workload`` — workload generators.
* :data:`AUTOSCALE_REGISTRY` / ``register_autoscale_policy`` — autoscaler
  policies.
"""
from ..core.registry import Registry
from ..core.allocation import POLICY_REGISTRY, register_policy
from ..market.bids import BID_REGISTRY, register_bid_strategy
from ..market.migration import MIGRATION_REGISTRY, register_migration_policy
from ..market.price_process import (
    PRICE_PROCESS_REGISTRY,
    register_price_process,
)
from ..serve.autoscale import AUTOSCALE_REGISTRY, register_autoscale_policy
from .workloads import WORKLOAD_REGISTRY, WorkloadDef, register_workload

__all__ = [
    "Registry",
    "POLICY_REGISTRY", "register_policy",
    "BID_REGISTRY", "register_bid_strategy",
    "MIGRATION_REGISTRY", "register_migration_policy",
    "PRICE_PROCESS_REGISTRY", "register_price_process",
    "WORKLOAD_REGISTRY", "WorkloadDef", "register_workload",
    "AUTOSCALE_REGISTRY", "register_autoscale_policy",
]
