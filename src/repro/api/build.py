"""Materialize specs into fresh simulators — the one way to construct runs.

Engines, planners, and policies are *stateful* (price-process RNGs, cost
integrals, planner cooldowns): reusing one across runs silently corrupts
results.  The builder therefore constructs every component fresh from the
spec's names + params on each call; a :class:`~repro.api.specs.RunSpec` can
be built any number of times and every build is independent.

``build(spec, seed)`` returns a populated, ready-to-``run()`` simulator;
``run_one(spec, seed)`` additionally runs it to the spec's horizon and
collects the standard metrics row (the sweep runner's per-seed unit).  Both
are bit-identical to the historical hand-wired construction at fixed seed
(regression-tested in ``tests/api/test_api_build.py``).
"""
from __future__ import annotations

from typing import Optional

from ..core.simulator import MarketSimulator, SimConfig
from ..core.allocation import make_policy
from ..market.bids import RebidOnResume
from ..market.engine import MarketEngine
from ..market.faults import make_fault_injector
from ..market.fleet import make_fleet_manager
from ..market.migration import make_migration_planner
from ..market.pools import make_market
from ..market.pricing import realized_cost_stats
from ..obs.eventlog import EventLog
from ..obs.tracer import Tracer
from ..serve.autoscale import make_autoscaler
from ..serve.service import make_serve_manager
from ..serve.slo import serve_stats
from .specs import ObsSpec, RunSpec, ScenarioSpec
from .workloads import WORKLOAD_REGISTRY


def build_tracer(obs: Optional[ObsSpec]) -> Optional[Tracer]:
    """A fresh :class:`~repro.obs.tracer.Tracer` for an :class:`ObsSpec`,
    or None when none of the tracer switches are on (the simulator then
    runs the plain untraced loop — an events-only spec records the flight
    log without ever building a tracer).  ``keep_records`` follows
    ``trace`` — profile- or counters-only modes still time spans but retain
    no per-span records, so memory stays bounded at trace scale."""
    if obs is None or not (obs.trace or obs.profile
                           or obs.counters_every is not None):
        return None
    return Tracer(keep_records=obs.trace, profile=obs.profile,
                  counters_every=obs.counters_every)


def build_event_log(obs: Optional[ObsSpec]) -> Optional[EventLog]:
    """A fresh :class:`~repro.obs.eventlog.EventLog` flight recorder when
    the spec asks for one (``obs.events``), else None — emit sites then
    keep their inert ``NULL_RECORDER`` default."""
    if obs is None or not obs.events:
        return None
    return EventLog()


def build_engine(scenario: ScenarioSpec, seed: int) -> Optional[MarketEngine]:
    """A fresh market engine for the scenario's regime (None when the
    scenario has no market)."""
    if scenario.regime is None:
        return None
    return MarketEngine(make_market(
        scenario.regime, n_pools=scenario.n_pools, seed=seed,
        tick_interval=scenario.tick_interval,
        from_advisor=scenario.from_advisor))


def build(spec: RunSpec, seed: int) -> MarketSimulator:
    """Materialize a :class:`RunSpec` into a populated simulator.

    Every stateful component (engine, planner, rebid hook, policy) is
    constructed fresh; hosts and VMs come from the scenario's registered
    workload.  Call ``sim.run(until=...)`` (or use :func:`run_one`) to
    execute."""
    scenario = spec.scenario
    engine = build_engine(scenario, seed)
    # mirror the historical wiring exactly: with an engine a planner is
    # always attached ("none" never plans — the bit-identity baseline);
    # without one the simulator runs planner-less
    migration = (make_migration_planner(spec.migration.policy,
                                        **dict(spec.migration.params))
                 if engine is not None else None)
    rebid = None
    if spec.rebid is not None:
        rebid = RebidOnResume(
            bump_lo=spec.rebid.bump_lo, bump_hi=spec.rebid.bump_hi,
            on_demand_rate=engine.config.pools[0].on_demand_rate, seed=seed)
    # fleet managers and fault injectors are stateful (slot arrays, fired
    # flags, pre-drawn stochastic schedules) — always fresh per build
    fleet = None
    if spec.fleet is not None:
        fleet = make_fleet_manager(scenario.n_pools,
                                   spec.fleet.config(scenario.n_pools))
    faults = None
    if spec.faults is not None:
        faults = make_fault_injector(
            spec.faults.scenario, scenario.n_pools,
            resolve_horizon(scenario), scenario.tick_interval, seed,
            **dict(spec.faults.params))
    # serve managers carry the request queue + per-VM scheduler map (and
    # the autoscaler its cooldown clock) — always fresh per build
    serve = None
    if spec.serve is not None:
        autoscaler = None
        if spec.autoscale is not None:
            autoscaler = make_autoscaler(spec.autoscale.policy,
                                         spec.autoscale.config())
        serve = make_serve_manager(spec.serve.config(),
                                   autoscaler=autoscaler, seed=seed)
    obs = build_tracer(spec.obs)
    events = build_event_log(spec.obs)
    sim = MarketSimulator(
        policy=make_policy(spec.policy.name, **dict(spec.policy.params)),
        config=SimConfig(record_timeline=False, **dict(scenario.sim_params)),
        engine=engine, migration=migration, rebid=rebid,
        fleet=fleet, faults=faults, serve=serve, obs=obs, events=events)
    if obs is not None:
        # one tracer per run, shared by every subsystem so spans nest and
        # counters land in a single registry; components are fresh per
        # build, so instance-level attachment cannot leak across runs
        sim.policy.tracer = obs
        if engine is not None:
            engine.tracer = obs
        if migration is not None:
            migration.tracer = obs
        if fleet is not None:
            fleet.tracer = obs
        if serve is not None:
            serve.tracer = obs
    if events is not None:
        # one flight recorder per run, shared by every emit site — the
        # same attach pattern as the tracer (fresh components, no leaks)
        if engine is not None:
            engine.events = events
        if migration is not None:
            migration.events = events
        if fleet is not None:
            fleet.events = events
        if faults is not None:
            faults.events_log = events
        if serve is not None:
            serve.events = events
    WORKLOAD_REGISTRY.get(scenario.workload)(sim, scenario, seed)
    return sim


def resolve_horizon(scenario: ScenarioSpec) -> Optional[float]:
    """The spec's horizon, falling back to the workload's default (None =
    run to completion)."""
    if scenario.horizon is not None:
        return scenario.horizon
    return WORKLOAD_REGISTRY.get(scenario.workload).default_horizon


def run_one(spec: RunSpec, seed: int,
            until: Optional[float] = None) -> dict:
    """Build + run one spec at one seed and collect the metrics row.

    The row is wall-clock-free and deterministic at fixed (spec, seed) —
    sweep reports built from it are reproducible artifacts."""
    sim = build(spec, seed)
    horizon = until if until is not None else resolve_horizon(spec.scenario)
    metrics = sim.run(until=horizon)
    return collect_row(sim, metrics, spec, seed)


def collect_row(sim: MarketSimulator, metrics, spec: RunSpec,
                seed: int) -> dict:
    """The standard per-run metrics row (identical key set to the historical
    ``market_sim.run_market`` rows for engine runs)."""
    s = metrics.spot_stats(sim.vms)
    row = {
        "policy": spec.policy.name,
        "regime": spec.scenario.regime,
        "migration": spec.migration.policy,
        "seed": seed,
    }
    if sim.engine is None:
        row.update(s)
        row.update(allocations=metrics.allocations,
                   resubmissions=metrics.resubmissions)
        return row
    ms = metrics.market_stats()
    migs = metrics.migration_stats(sim.vms, sim.engine)
    cost = realized_cost_stats(sim.vms.values(), sim.engine, sim.pool)
    row.update({
        "interruptions": s["interruptions"],
        "price_interruptions": ms["price_interruptions"],
        "waves": ms["waves"],
        "max_wave_size": ms["max_wave_size"],
        "avg_interruption_time": s["avg_interruption_time"],
        "max_interruption_time": s["max_interruption_time"],
        "spot_finished": s["spot_finished"],
        "spot_terminated": s["spot_terminated"],
        "migrations": migs["completed"],
        "migrations_failed": migs["failed"],
        "migration_downtime_s": migs["downtime_s"],
        "predicted_saving": round(migs["predicted_saving"], 2),
        "realized_saving": round(migs["realized_saving"], 2),
        "realized_spot_cost": round(cost["spot_cost"], 4),
        "savings_pct": round(cost["savings_pct"], 1),
        "wasted_cost": round(cost["wasted_cost"], 4),
        "allocations": metrics.allocations,
    })
    rs = None
    if sim.fleet is not None:
        rs = metrics.resilience_stats(sim.vms, sim.engine, sim.pool)
        row.update({
            "time_below_target_s": round(rs["time_below_target"], 1),
            "time_below_frac": round(rs["time_below_frac"], 4),
            "shortfall_area": round(rs["shortfall_area"], 1),
            "mean_recovery_s": round(rs["mean_recovery_s"], 1),
            "max_recovery_s": round(rs["max_recovery_s"], 1),
            "faults_fired": rs["faults_fired"],
            "fleet_launches": rs["fleet_launches"],
            "od_spill_launches": rs["od_spill_launches"],
            "fleet_slots_retired": rs["slots_retired"],
            "fleet_spot_cost": round(rs["fleet_spot_cost"], 4),
            "od_spill_cost": round(rs["od_spill_cost"], 4),
        })
    if sim.serve is not None:
        scfg = sim.serve.config
        horizon = resolve_horizon(spec.scenario)
        cost = (rs["fleet_spot_cost"] + rs["od_spill_cost"]
                if rs is not None else None)
        ss = serve_stats(metrics, slo_latency=scfg.slo_latency_s,
                         slo_objective=scfg.slo_objective,
                         window=scfg.window_s,
                         horizon=horizon if horizon is not None else sim.now,
                         cost=cost)
        row.update({k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in ss.items()})
    return row
