"""Workload generators as scenario-API plugins.

Each entry in :data:`WORKLOAD_REGISTRY` is a :class:`WorkloadDef`: a
``populate(sim, scenario, seed)`` function that fills an empty
:class:`~repro.core.simulator.MarketSimulator` (hosts + submitted VMs +
bid assignment), plus the metadata the spec layer validates against
(``config_cls`` for ``workload_params`` key checking, bid/market support,
the workload's default horizon).

Built-ins:

* ``synthetic`` — the paper's §VII-E comparison scenario
  (:func:`repro.core.workload.synthetic_scenario`); hosts are striped over
  the market's pools when an engine is attached.
* ``market``    — the regional-demand-hump market scenario
  (:func:`repro.core.workload.market_scenario`); requires a market regime.
* ``trace``     — Google-Cluster-Trace-style machine/task events
  (:func:`repro.market.trace.generate_trace` + ``wire_trace``).

Custom workloads register a plain populate function:

    @register_workload("my-workload")
    def _populate(sim, scenario, seed):
        sim.add_host(...); sim.submit(...)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

from ..core.registry import Registry
from ..core.types import resources
from ..core.workload import (
    MarketScenarioConfig,
    ScenarioConfig,
    market_scenario,
    synthetic_scenario,
)
from ..market.bids import assign_bids, make_bid_strategy
from ..market.trace import TraceConfig, generate_trace, wire_trace
from ..serve.demand import make_bursty, make_diurnal

WORKLOAD_REGISTRY = Registry("workload")


@dataclass
class WorkloadDef:
    """One pluggable workload: the populate function plus spec-validation
    metadata."""

    populate: Callable  # (sim, scenario_spec, seed) -> None
    #: config dataclass the workload's ``workload_params`` feed (None skips
    #: the unknown-key check for custom workloads)
    config_cls: Optional[type] = None
    #: horizon used when the spec leaves ``horizon=None`` (None = run to
    #: completion)
    default_horizon: Optional[float] = None
    #: whether ``ScenarioSpec.bid`` applies (trace VMs keep bid = inf)
    supports_bids: bool = True
    #: whether the workload only makes sense under a market regime
    requires_market: bool = False
    #: config keys the builder supplies itself — rejected in
    #: ``workload_params`` at spec construction
    reserved_params: tuple = ("seed",)
    #: whether the workload installs a request-demand curve on
    #: ``sim.serve`` (serving scenarios require one of these)
    provides_demand: bool = False

    def __call__(self, sim, scenario, seed: int) -> None:
        self.populate(sim, scenario, seed)


def register_workload(name: str, config_cls: Optional[type] = None,
                      default_horizon: Optional[float] = None,
                      supports_bids: bool = True,
                      requires_market: bool = False,
                      reserved_params: tuple = ("seed",),
                      provides_demand: bool = False) -> Callable:
    """Decorator registering a populate function as a workload."""
    def _wrap(fn: Callable) -> Callable:
        WORKLOAD_REGISTRY.register(name, WorkloadDef(
            populate=fn, config_cls=config_cls,
            default_horizon=default_horizon, supports_bids=supports_bids,
            requires_market=requires_market, reserved_params=reserved_params,
            provides_demand=provides_demand))
        return fn
    return _wrap


def _assign_spec_bids(sim, scenario, vms, seed: int) -> None:
    """Stamp bids per the scenario's BidSpec (engine runs only; identical
    draws to the hand-wired ``assign_bids`` path)."""
    if scenario.bid is None or sim.engine is None:
        return
    strat = make_bid_strategy(
        scenario.bid.strategy, pool_cfg=sim.engine.config.pools[0],
        seed=seed, **dict(scenario.bid.params))
    assign_bids(vms, strat, seed=seed)


@register_workload("synthetic", config_cls=ScenarioConfig,
                   default_horizon=3000.0)
def _populate_synthetic(sim, scenario, seed: int) -> None:
    cfg = ScenarioConfig(seed=seed, **dict(scenario.workload_params))
    hosts, vms = synthetic_scenario(cfg)
    _assign_spec_bids(sim, scenario, vms, seed)
    stripe = sim.engine is not None
    for i, cap in enumerate(hosts):
        sim.add_host(cap, pool=(i % scenario.n_pools) if stripe else 0)
    for v in vms:
        sim.submit(v)


@register_workload("market", config_cls=MarketScenarioConfig,
                   default_horizon=14400.0, requires_market=True,
                   reserved_params=("seed", "n_pools"))
def _populate_market(sim, scenario, seed: int) -> None:
    cfg = MarketScenarioConfig(seed=seed, n_pools=scenario.n_pools,
                               **dict(scenario.workload_params))
    hosts, pool_ids, vms = market_scenario(cfg)
    _assign_spec_bids(sim, scenario, vms, seed)
    for cap, pid in zip(hosts, pool_ids):
        sim.add_host(cap, pool=pid)
    for v in vms:
        sim.submit(v)


@register_workload("trace", config_cls=TraceConfig, supports_bids=False)
def _populate_trace(sim, scenario, seed: int) -> None:
    cfg = TraceConfig(seed=seed, **dict(scenario.workload_params))
    wire_trace(sim, generate_trace(cfg), cfg)


# ---------------------------------------------------------------------------
# traffic-driven serving workloads: hosts + a demand curve, no VMs — the
# fleet supplies capacity, the serve layer turns the curve into requests
# ---------------------------------------------------------------------------
@dataclass
class DiurnalDemandConfig:
    """Serving scenario infrastructure + diurnal request-rate curve."""

    n_hosts: int = 12
    host_cpu: float = 16.0
    host_ram: float = 65536.0
    base_rate: float = 0.2       # requests/s at the mean
    amplitude: float = 0.15      # sinusoidal swing (requests/s)
    period: float = 86400.0      # one day
    phase: float = 0.0
    seed: int = 0


@dataclass
class BurstyDemandConfig:
    """Serving scenario infrastructure + self-similar bursty curve."""

    n_hosts: int = 12
    host_cpu: float = 16.0
    host_ram: float = 65536.0
    base_rate: float = 0.15
    spike_every: float = 1800.0  # mean inter-spike gap (s)
    spike_mag: float = 0.5       # Pareto magnitude scale (requests/s)
    spike_alpha: float = 1.6     # Pareto tail index (heavy tail < 2)
    spike_duration: float = 300.0
    seed: int = 0


def _serve_hosts(sim, scenario, n_hosts: int, cpu: float, ram: float) -> None:
    for i in range(int(n_hosts)):
        sim.add_host(resources(cpu, ram, 1000.0, 1 << 20),
                     pool=i % scenario.n_pools)


@register_workload("serve-diurnal", config_cls=DiurnalDemandConfig,
                   default_horizon=86400.0, supports_bids=False,
                   requires_market=True, provides_demand=True)
def _populate_serve_diurnal(sim, scenario, seed: int) -> None:
    cfg = DiurnalDemandConfig(seed=seed, **dict(scenario.workload_params))
    _serve_hosts(sim, scenario, cfg.n_hosts, cfg.host_cpu, cfg.host_ram)
    if sim.serve is not None:
        sim.serve.set_demand(make_diurnal(
            base_rate=cfg.base_rate, amplitude=cfg.amplitude,
            period=cfg.period, phase=cfg.phase))


@register_workload("serve-bursty", config_cls=BurstyDemandConfig,
                   default_horizon=86400.0, supports_bids=False,
                   requires_market=True, provides_demand=True)
def _populate_serve_bursty(sim, scenario, seed: int) -> None:
    cfg = BurstyDemandConfig(seed=seed, **dict(scenario.workload_params))
    _serve_hosts(sim, scenario, cfg.n_hosts, cfg.host_cpu, cfg.host_ram)
    if sim.serve is not None:
        horizon = scenario.horizon if scenario.horizon is not None else 86400.0
        sim.serve.set_demand(make_bursty(
            base_rate=cfg.base_rate, spike_every=cfg.spike_every,
            spike_mag=cfg.spike_mag, spike_alpha=cfg.spike_alpha,
            spike_duration=cfg.spike_duration, horizon=horizon,
            seed=cfg.seed))
