"""repro.api — the declarative scenario/experiment layer.

One spec tree describes a run; registries make every component pluggable;
the builder materializes fresh simulators; the sweep runner turns an
``ExperimentSpec`` into a seed-swept mean ± CI report:

    from repro.api import (ExperimentSpec, MigrationSpec, PolicySpec,
                           RunSpec, ScenarioSpec, build, run_experiment)

    spec = RunSpec(
        scenario=ScenarioSpec(workload="market", regime="volatile",
                              bid={"strategy": "randomized",
                                   "params": {"lo": 0.45}}),
        policy=PolicySpec("hlem-vmp-adjusted", {"alpha": -0.5}),
        migration=MigrationSpec("gradient-aware"))
    sim = build(spec, seed=0)          # fresh components, ready to run
    metrics = sim.run(until=14400.0)

    exp = ExperimentSpec(scenario=spec.scenario,
                         policies=(spec.policy,),
                         migrations=(MigrationSpec("none"),
                                     MigrationSpec("gradient-aware")),
                         regimes=("volatile", "correlated"),
                         seeds=tuple(range(20)))
    report = run_experiment(exp)       # multiprocessing fan-out, mean ± CI

Specs JSON round-trip losslessly (``to_dict``/``from_dict``/``to_json``/
``ExperimentSpec.load``), so experiments live in files — see
``examples/specs/``.
"""
from .registry import (
    AUTOSCALE_REGISTRY,
    BID_REGISTRY,
    MIGRATION_REGISTRY,
    POLICY_REGISTRY,
    PRICE_PROCESS_REGISTRY,
    Registry,
    WORKLOAD_REGISTRY,
    WorkloadDef,
    register_autoscale_policy,
    register_bid_strategy,
    register_migration_policy,
    register_policy,
    register_price_process,
    register_workload,
)
from .specs import (
    AutoscaleSpec,
    BidSpec,
    ExperimentSpec,
    FaultSpec,
    FleetSpec,
    MigrationSpec,
    ObsSpec,
    PolicySpec,
    RebidSpec,
    RunSpec,
    ScenarioSpec,
    ServeSpec,
)
from .build import (build, build_engine, build_tracer, collect_row,
                    resolve_horizon, run_one)
from .sweep import (
    aggregate_rows,
    format_report,
    mean_ci95,
    run_experiment,
    write_report,
)

import types as _types

__all__ = [k for k, v in list(globals().items())
           if not k.startswith("_") and not isinstance(v, _types.ModuleType)]
