"""Sharded optimizers: AdamW and Adafactor (pure JAX, no optax).

Optimizer states inherit the parameter shardings (ZeRO-3 style): the spec
tree for states is derived from the param spec tree, so the dry-run can build
in_shardings for the full train state without materializing anything.

Moment dtypes are configurable — trillion-parameter configs (kimi-k2) use
Adafactor (factored second moment) because fp32 Adam moments alone would
exceed 512 x 16 GB HBM; see DESIGN.md.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: Params
    v: Params


class AdafactorState(NamedTuple):
    step: jax.Array
    # per-leaf: for matrices, (row, col) factored second moments; for vectors
    # an unfactored accumulator (stored in `row`, col is a (1,) placeholder).
    row: Params
    col: Params


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------
def adamw_init(params: Params, moment_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def adamw_update(params: Params, grads: Params, state: AdamWState, *,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 ) -> Tuple[Params, AdamWState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - jnp.power(b1, t)
    c2 = 1.0 - jnp.power(b2, t)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m2 / c1
        vh = v2 / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        p2 = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return p2, m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(step, new_m, new_v)


def adamw_specs(param_specs: Params) -> Any:
    """State spec tree matching adamw_init structure."""
    return AdamWState(step=(), m=param_specs, v=param_specs)


# ---------------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ---------------------------------------------------------------------------
def _factored(p) -> bool:
    return p.ndim >= 2


def adafactor_init(params: Params) -> AdafactorState:
    def row_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-1], jnp.float32)
        return jnp.zeros(p.shape, jnp.float32)

    def col_init(p):
        if _factored(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
        return jnp.zeros((1,), jnp.float32)

    return AdafactorState(
        step=jnp.zeros((), jnp.int32),
        row=jax.tree.map(row_init, params),
        col=jax.tree.map(col_init, params),
    )


def adafactor_update(params: Params, grads: Params, state: AdafactorState, *,
                     lr: jax.Array, decay: float = 0.8, eps: float = 1e-30,
                     clip_threshold: float = 1.0, weight_decay: float = 0.0,
                     ) -> Tuple[Params, AdafactorState]:
    step = state.step + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - jnp.power(t, -decay)   # t^-0.8 schedule, as in the paper

    def upd(p, g, r, c):
        gf = g.astype(jnp.float32)
        g2 = gf * gf + eps
        if _factored(p):
            r2 = beta * r + (1 - beta) * g2.mean(axis=-1)
            c2 = beta * c + (1 - beta) * g2.mean(axis=-2)
            rmean = r2.mean(axis=-1, keepdims=True)
            vhat = (r2 / jnp.maximum(rmean, eps))[..., None] * c2[..., None, :]
            u = gf / jnp.sqrt(jnp.maximum(vhat, eps))
        else:
            r2 = beta * r + (1 - beta) * g2
            c2 = c
            u = gf / jnp.sqrt(jnp.maximum(r2, eps))
        # update clipping (RMS(u) <= clip_threshold)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        p2 = p.astype(jnp.float32) - lr * u
        if weight_decay:
            p2 = p2 - lr * weight_decay * p.astype(jnp.float32)
        return p2.astype(p.dtype), r2, c2

    out = jax.tree.map(upd, params, grads, state.row, state.col)
    new_p = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_c = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdafactorState(step, new_r, new_c)


def adafactor_specs(param_specs: Params, params_shape: Params) -> Any:
    """Spec tree: row drops the last logical axis, col drops the second-last."""
    def row_spec(names, shp):
        if len(shp.shape) >= 2:
            return tuple(names[:-1])
        return tuple(names)

    def col_spec(names, shp):
        if len(shp.shape) >= 2:
            return tuple(names[:-2]) + (names[-1],)
        return (None,)

    is_names = lambda t: isinstance(t, tuple) and all(
        n is None or isinstance(n, str) for n in t)
    row = jax.tree.map(row_spec, param_specs, params_shape, is_leaf=is_names)
    col = jax.tree.map(col_spec, param_specs, params_shape, is_leaf=is_names)
    return AdafactorState(step=(), row=row, col=col)


# ---------------------------------------------------------------------------
# uniform front-end
# ---------------------------------------------------------------------------
def make_optimizer(name: str, **defaults):
    """Returns (init_fn, update_fn, specs_fn(param_specs, param_shapes))."""
    if name == "adamw":
        return (adamw_init,
                functools.partial(adamw_update, **defaults),
                lambda specs, shapes: adamw_specs(specs))
    if name == "adafactor":
        return (adafactor_init,
                functools.partial(adafactor_update, **defaults),
                adafactor_specs)
    raise ValueError(name)


def lr_schedule(step: jax.Array, *, peak: float = 3e-4, warmup: int = 100,
                total: int = 10_000, min_ratio: float = 0.1) -> jax.Array:
    """Linear warmup + cosine decay."""
    t = step.astype(jnp.float32)
    warm = t / jnp.maximum(warmup, 1)
    frac = jnp.clip((t - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return peak * jnp.where(t < warmup, warm, cos)
