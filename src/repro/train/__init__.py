from .data import DataConfig, SyntheticDataset
from .optimizer import (
    AdafactorState,
    AdamWState,
    adafactor_init,
    adafactor_update,
    adamw_init,
    adamw_update,
    lr_schedule,
    make_optimizer,
)
from .train_step import (
    TrainState,
    cross_entropy,
    init_train_state,
    make_eval_step,
    make_loss_fn,
    make_train_step,
    train_state_specs,
)

__all__ = [k for k in dir() if not k.startswith("_")]
