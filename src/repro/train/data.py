"""Deterministic synthetic data pipeline.

Produces reproducible token streams (text) or frame/patch embeddings
(audio/vlm backbones) with a host-side iterator that supports
checkpoint/restore of its cursor — required for exactly-once data consumption
across preemption/restart (the data cursor is part of the checkpoint).

The synthetic text stream is a mixture of Zipfian unigrams and a repeated
n-gram process so that a model can actually reduce loss on it (used by the
end-to-end example to show real learning under preemptions).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from ..models.config import ArchConfig


@dataclass
class DataConfig:
    batch: int = 8
    seq_len: int = 128
    seed: int = 0
    zipf_a: float = 1.3
    copy_period: int = 16    # repeat period -> learnable structure


class SyntheticDataset:
    """Stateful, checkpointable batch iterator."""

    def __init__(self, cfg: ArchConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        self.step = 0

    # -- checkpointable cursor ------------------------------------------------
    def state_dict(self) -> Dict:
        return {"step": self.step, "seed": self.dcfg.seed}

    def load_state_dict(self, st: Dict) -> None:
        assert st["seed"] == self.dcfg.seed, "dataset seed mismatch"
        self.step = int(st["step"])

    # -- batch generation ------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.dcfg.seed, step))

    def next_batch(self) -> Dict[str, np.ndarray]:
        b = self.make_batch(self.step)
        self.step += 1
        return b

    def make_batch(self, step: int) -> Dict[str, np.ndarray]:
        d, v = self.dcfg, self.cfg.vocab
        rng = self._rng(step)
        # zipf unigrams clipped to vocab
        base = rng.zipf(d.zipf_a, size=(d.batch, d.seq_len + 1))
        base = np.minimum(base - 1, v - 1).astype(np.int32)
        # overwrite half of each row with a periodic pattern (learnable)
        period = d.copy_period
        pattern = rng.integers(0, v, size=(d.batch, period))
        reps = -(-(d.seq_len + 1) // period)
        tiled = np.tile(pattern, (1, reps))[:, : d.seq_len + 1]
        use_pattern = rng.random((d.batch, 1)) < 0.5
        seq = np.where(use_pattern, tiled, base)
        out = {
            "tokens": seq[:, :-1],
            "labels": seq[:, 1:].astype(np.int32),
        }
        if self.cfg.modality != "text":
            # backbone consumes precomputed frontend embeddings
            emb = rng.normal(0, 1, (d.batch, d.seq_len, self.cfg.d_model))
            out["tokens"] = emb.astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.next_batch()
