"""Training step: next-token cross-entropy, gradient accumulation, optimizer
apply — assembled so that ``jax.jit(make_train_step(cfg), in_shardings=...)``
is the single unit the launcher lowers/compiles for the dry-run and runs for
real training.
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..models.model import forward, init_params, param_specs
from ..models.sharding import constrain, constrain_tree, current_mesh
from .optimizer import lr_schedule, make_optimizer

Params = Any


class TrainState(NamedTuple):
    params: Params
    opt: Any
    step: jax.Array
    rng: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean next-token CE in fp32. logits (B,S,V), labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def make_loss_fn(cfg: ArchConfig, impl: str = "xla"):
    def loss_fn(params, batch):
        logits = forward(cfg, params, batch["tokens"], impl=impl)
        return cross_entropy(logits, batch["labels"], batch.get("mask"))
    return loss_fn


def _opt_kwargs(cfg: ArchConfig) -> dict:
    if cfg.optimizer == "adamw":
        return {"moment_dtype": jnp.dtype(cfg.moment_dtype)}
    return {}


def init_train_state(cfg: ArchConfig, key: jax.Array) -> TrainState:
    params = init_params(cfg, key)
    opt_init, _, _ = make_optimizer(cfg.optimizer)
    if cfg.optimizer == "adamw":
        import functools as _ft
        opt_init = _ft.partial(opt_init, **_opt_kwargs(cfg))
    return TrainState(
        params=params,
        opt=opt_init(params),
        step=jnp.zeros((), jnp.int32),
        rng=key,
    )


def train_state_specs(cfg: ArchConfig):
    """Logical-axis tree for TrainState (dry-run in_shardings)."""
    p_specs = param_specs(cfg)
    _, _, opt_specs_fn = make_optimizer(cfg.optimizer)
    p_shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0)))
    return TrainState(
        params=p_specs,
        opt=opt_specs_fn(p_specs, p_shapes),
        step=(),
        rng=(),  # PRNG key: replicated (empty tuple == fully-replicated spec)
    )


def make_train_step(cfg: ArchConfig, *, impl: str = "xla",
                    lr_kwargs: Optional[dict] = None,
                    grad_accum: Optional[int] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    ``grad_accum`` > 1 splits the batch into microbatches scanned
    sequentially, accumulating fp32 gradients — the standard lever to fit
    large-model activations (llama3-405b train_4k uses 4).
    """
    loss_fn = make_loss_fn(cfg, impl)
    _, opt_update, _ = make_optimizer(cfg.optimizer)
    accum = grad_accum or cfg.grad_accum
    lr_kw = lr_kwargs or {}

    def train_step(state: TrainState, batch) -> Tuple[TrainState, dict]:
        if accum <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(state.params, batch)
        else:
            acc_dt = jnp.dtype(cfg.accum_dtype)
            p_specs = param_specs(cfg)

            def micro(carry, mb):
                loss_sum, gacc = carry
                l, g = jax.value_and_grad(loss_fn)(state.params, mb)
                gacc = jax.tree.map(
                    lambda a, gi: a + gi.astype(acc_dt), gacc, g)
                # pin the accumulator to the parameter shardings: without
                # this the scan carry is unconstrained and GSPMD all-reduces
                # full per-layer weight-gradient tuples every microbatch
                # instead of reduce-scattering to the ZeRO-3 shard
                # (EXPERIMENTS.md §Perf Cell C iter 3: ~2 TB/device/step on
                # kimi-k2)
                gacc = constrain_tree(gacc, p_specs)
                return (loss_sum + l, gacc), None

            def split(x):
                b = x.shape[0]
                return x.reshape(accum, b // accum, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), state.params)
            (loss_sum, grads), _ = jax.lax.scan(micro, (0.0, g0), mbs)
            loss = loss_sum / accum
            grads = jax.tree.map(lambda g: (g / accum), grads)

        grads = jax.tree.map(lambda g, p: g.astype(p.dtype) if g.dtype != p.dtype
                             else g, grads, state.params)
        lr = lr_schedule(state.step, **lr_kw)
        new_params, new_opt = opt_update(state.params, grads, state.opt, lr=lr)
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        return TrainState(new_params, new_opt, state.step + 1, state.rng), metrics

    return train_step


def make_eval_step(cfg: ArchConfig, impl: str = "xla"):
    loss_fn = make_loss_fn(cfg, impl)

    def eval_step(params, batch):
        return loss_fn(params, batch)

    return eval_step
