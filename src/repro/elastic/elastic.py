"""Elastic data-parallel training driven by the spot market simulator.

The integration that makes the paper's technique a first-class feature of the
trainer: worker VMs hosting mesh slices are *spot instances* in a
:class:`repro.core.MarketSimulator`; interruptions (capacity reclaimed for
on-demand load) shrink the data-parallel axis after an emergency checkpoint
inside the warning window, resumptions grow it back — the training-side
mirror of the paper's HIBERNATE/resume lifecycle (Fig. 4).

Global batch is invariant across rescales (per-replica batch is re-derived),
so the loss trajectory is comparable to the uninterrupted run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from ..core import (
    HlemVmpAdjusted,
    MarketSimulator,
    SimConfig,
    make_on_demand,
    make_spot,
    resources,
)
from ..models.config import ArchConfig
from ..models.sharding import attach, tree_shardings, use_mesh
from ..train.data import DataConfig, SyntheticDataset
from ..train.train_step import (
    TrainState,
    init_train_state,
    make_train_step,
    train_state_specs,
)
from .checkpoint import CheckpointManager

Params = Any


# ---------------------------------------------------------------------------
# worker availability from the market simulator
# ---------------------------------------------------------------------------
@dataclass
class AvailabilityEvent:
    time: float
    available: int          # number of live workers after this event
    kind: str               # "interrupt" | "resume" | "start"


def simulate_worker_availability(
    n_workers: int,
    horizon: float,
    seed: int = 0,
    contention: float = 1.5,
    policy=None,
) -> List[AvailabilityEvent]:
    """Run a small spot market where our training workers are spot VMs and a
    background on-demand load creates contention. Returns the availability
    timeline of the worker fleet."""
    rng = np.random.default_rng(seed)
    sim = MarketSimulator(
        policy=policy or HlemVmpAdjusted(),
        config=SimConfig(record_timeline=False, warning_time=2.0))
    n_hosts = max(2, n_workers)
    for _ in range(n_hosts):
        sim.add_host(resources(8, 32_768, 10_000, 400_000))

    worker_demand = resources(4, 16_384, 4_000, 100_000)
    workers = []
    for i in range(n_workers):
        vm = make_spot(i, worker_demand, duration=horizon * 10,
                       min_running_time=5.0,
                       hibernation_timeout=horizon * 10,
                       waiting_timeout=horizon * 10)
        workers.append(vm)
        sim.submit(vm)

    # background on-demand churn
    vid = n_workers
    t = 0.0
    while t < horizon:
        t += float(rng.exponential(horizon / (6.0 * contention)))
        if t >= horizon:
            break
        cpu = float(rng.choice([4, 8]))
        dur = float(rng.uniform(horizon * 0.05, horizon * 0.2))
        sim.submit(make_on_demand(vid, resources(cpu, cpu * 4_096, 2_000,
                                                 50_000),
                                  dur, waiting_timeout=dur, submit_time=t))
        vid += 1

    events: List[AvailabilityEvent] = []
    live = {i: False for i in range(n_workers)}

    def on_alloc(sim, time, vm, host, resumed, **kw):
        if vm.id in live:
            live[vm.id] = True
            events.append(AvailabilityEvent(
                time, sum(live.values()), "resume" if resumed else "start"))

    def on_interrupt(sim, time, vm, kind, **kw):
        if vm.id in live:
            live[vm.id] = False
            events.append(AvailabilityEvent(time, sum(live.values()),
                                            "interrupt"))

    sim.on("vm_allocated", on_alloc)
    sim.on("vm_interrupted", on_interrupt)
    sim.run(until=horizon)
    return events


# ---------------------------------------------------------------------------
# elastic trainer
# ---------------------------------------------------------------------------
def _pow2_floor(n: int) -> int:
    p = 1
    while p * 2 <= n:
        p *= 2
    return p


def build_mesh(n_data: int, n_model: int = 1) -> Mesh:
    devs = np.array(jax.devices()[: n_data * n_model])
    assert devs.size == n_data * n_model, (
        f"need {n_data * n_model} devices, have {len(jax.devices())}")
    return Mesh(devs.reshape(n_data, n_model), ("data", "model"))


@dataclass
class ElasticReport:
    steps_run: int = 0
    rescales: int = 0
    emergency_saves: int = 0
    restores: int = 0
    losses: List[float] = field(default_factory=list)
    mesh_history: List[Tuple[int, int]] = field(default_factory=list)


class ElasticTrainer:
    """Trains under a worker-availability timeline with checkpoint/rescale."""

    def __init__(self, cfg: ArchConfig, dcfg: DataConfig, ckpt_dir: str,
                 max_workers: int, impl: str = "xla", seed: int = 0):
        self.cfg = cfg
        self.dcfg = dcfg
        self.max_workers = max_workers
        self.impl = impl
        self.seed = seed
        self.ckpt = CheckpointManager(ckpt_dir, keep_n=2, async_save=False)
        self.dataset = SyntheticDataset(cfg, dcfg)
        self.mesh: Optional[Mesh] = None
        self.state: Optional[TrainState] = None
        self._step_fn = None
        self.n_data = 0
        self.report = ElasticReport()

    # -- (re)configuration ---------------------------------------------------
    def _specs(self):
        return train_state_specs(self.cfg)

    def configure(self, n_workers: int) -> None:
        """(Re)build mesh for n_workers and restore/initialize state on it."""
        n_data = max(1, _pow2_floor(min(n_workers, self.max_workers)))
        if n_data == self.n_data and self.state is not None:
            return
        prev_state_exists = self.state is not None or \
            self.ckpt.latest_step() is not None
        self.n_data = n_data
        self.mesh = build_mesh(n_data)
        with use_mesh(self.mesh):
            shardings = tree_shardings(self._specs())
            if prev_state_exists:
                template = jax.eval_shape(
                    lambda: init_train_state(self.cfg,
                                             jax.random.PRNGKey(self.seed)))
                self.state, meta = self.ckpt.restore(template,
                                                     shardings=shardings)
                if "data_step" in meta:
                    self.dataset.load_state_dict(
                        {"step": meta["data_step"], "seed": self.dcfg.seed})
                self.report.restores += 1
            else:
                state = init_train_state(self.cfg,
                                         jax.random.PRNGKey(self.seed))
                self.state = jax.device_put(state, shardings)
            self._step_fn = jax.jit(
                make_train_step(self.cfg, impl=self.impl),
                donate_argnums=(0,))
        self.report.rescales += 1
        self.report.mesh_history.append((int(self.state.step), n_data))

    # -- event handlers --------------------------------------------------------
    def on_warning(self) -> None:
        """Spot interruption warning: emergency checkpoint."""
        self.ckpt.save_on_warning(
            self.state, int(self.state.step),
            {"data_step": self.dataset.step})
        self.report.emergency_saves += 1

    # -- training -------------------------------------------------------------
    def run_steps(self, n: int, checkpoint_every: int = 50) -> None:
        assert self.state is not None, "configure() first"
        with use_mesh(self.mesh):
            for _ in range(n):
                batch_np = self.dataset.next_batch()
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                self.state, metrics = self._step_fn(self.state, batch)
                self.report.steps_run += 1
                self.report.losses.append(float(metrics["loss"]))
                step = int(self.state.step)
                if checkpoint_every and step % checkpoint_every == 0:
                    self.ckpt.save(self.state, step,
                                   {"data_step": self.dataset.step})

    def train_elastic(self, total_steps: int,
                      events: List[AvailabilityEvent],
                      steps_per_sim_unit: float = 1.0,
                      min_workers: int = 1) -> ElasticReport:
        """Interleave training with the availability timeline."""
        timeline = sorted(events, key=lambda e: e.time)
        idx = 0
        current = self.max_workers
        self.configure(current)
        while self.report.steps_run < total_steps:
            next_change = (timeline[idx].time * steps_per_sim_unit
                           if idx < len(timeline) else float("inf"))
            target = min(total_steps,
                         int(next_change) if next_change != float("inf")
                         else total_steps)
            chunk = max(0, target - self.report.steps_run)
            if chunk:
                self.run_steps(chunk)
            if idx < len(timeline) and self.report.steps_run < total_steps:
                ev = timeline[idx]
                idx += 1
                new_workers = max(min_workers, ev.available)
                if ev.kind == "interrupt":
                    self.on_warning()          # save within warning window
                if _pow2_floor(new_workers) != self.n_data:
                    # final sync checkpoint then re-mesh + restore
                    self.ckpt.save(self.state, int(self.state.step),
                                   {"data_step": self.dataset.step},
                                   block=True)
                    self.state = None
                    self.configure(new_workers)
        return self.report
