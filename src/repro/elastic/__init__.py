from .checkpoint import CheckpointManager
from .compression import (
    compress_tree,
    compressed_grad_combine,
    decompress_tree,
    dequantize_int8,
    init_error_feedback,
    quantize_int8,
)
from .elastic import (
    AvailabilityEvent,
    ElasticReport,
    ElasticTrainer,
    build_mesh,
    simulate_worker_availability,
)
from .placement import ClusterScheduler, JobSpec, SLICE_V5E_256
from .straggler import StragglerDetector, masked_grad_mean

__all__ = [k for k in dir() if not k.startswith("_")]
