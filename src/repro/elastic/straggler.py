"""Straggler mitigation.

At multi-pod scale the slowest replica sets step time.  Two mechanisms:

1. **Deadline-masked gradient combine** (implemented, jit-compatible): each
   data-parallel replica contributes its microbatch gradient with an
   ``arrived`` mask; the global gradient is the weighted mean over arrived
   replicas only (missing contributions are dropped and the mean re-scaled —
   "backup-worker" semantics without the backups).  The host runtime decides
   the mask from per-replica heartbeats/deadlines; the combine itself is a
   masked psum usable under jit.

2. **Straggler detection** (host-side): an EWMA of per-host step times flags
   hosts slower than ``threshold`` x the fleet median; the elastic layer then
   treats a persistent straggler exactly like a spot interruption — the
   market simulator's HIBERNATE path — and re-meshes without it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def masked_grad_mean(stacked_grads: Params, arrived: jax.Array) -> Params:
    """stacked_grads: tree with leading replica axis R; arrived: (R,) bool.
    Mean over arrived replicas (weight 0 for missing, rescaled)."""
    w = arrived.astype(jnp.float32)
    denom = jnp.maximum(w.sum(), 1.0)

    def one(g):
        gf = g.astype(jnp.float32)
        wshape = (g.shape[0],) + (1,) * (g.ndim - 1)
        return (gf * w.reshape(wshape)).sum(axis=0) / denom

    return jax.tree.map(one, stacked_grads)


@dataclass
class StragglerDetector:
    """EWMA step-time tracker; flags hosts persistently above threshold x
    median."""
    alpha: float = 0.3
    threshold: float = 1.8
    patience: int = 3
    ewma: Dict[int, float] = field(default_factory=dict)
    strikes: Dict[int, int] = field(default_factory=dict)

    def observe(self, host: int, step_time: float) -> None:
        prev = self.ewma.get(host)
        self.ewma[host] = (step_time if prev is None
                           else self.alpha * step_time + (1 - self.alpha) * prev)

    def stragglers(self) -> List[int]:
        if len(self.ewma) < 2:
            return []
        med = float(np.median(list(self.ewma.values())))
        out = []
        for host, t in self.ewma.items():
            if t > self.threshold * med:
                self.strikes[host] = self.strikes.get(host, 0) + 1
            else:
                self.strikes[host] = 0
            if self.strikes.get(host, 0) >= self.patience:
                out.append(host)
        return out
