"""Fault-tolerant checkpointing.

* Atomic: checkpoints are written to ``step_N.tmp`` and renamed only when
  complete — a preemption mid-write never corrupts the latest checkpoint.
* Async: a background thread serializes host copies so the training loop
  resumes immediately (the TPU→host copy is the only synchronous part).
* Emergency: ``save_on_warning`` is designed to be registered as a market-
  simulator ``vm_interrupted`` listener (or a real SIGTERM handler); it
  performs a synchronous save inside the spot warning window (2 min on AWS,
  30 s on GCP — the paper's "warning time" parameter).
* Carries arbitrary metadata (data-iterator cursor, mesh shape) so restart
  resumes exactly-once data consumption and can elastically re-mesh.

At real scale each host writes only its addressable shards; here (single
process) we gather to host numpy. The directory layout and atomicity protocol
are the production ones.
"""
from __future__ import annotations

import json
import os
import queue
import re
import shutil
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, directory: str, keep_n: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_n = keep_n
        self.async_save = async_save
        os.makedirs(directory, exist_ok=True)
        self._q: "queue.Queue" = queue.Queue()
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if async_save:
            self._worker = threading.Thread(target=self._drain, daemon=True)
            self._worker.start()

    # ------------------------------------------------------------------ save
    def save(self, state: Any, step: int, meta: Optional[Dict] = None,
             block: bool = False) -> None:
        """Snapshot to host, then write (async unless block=True)."""
        if self._error:
            raise RuntimeError("async checkpoint worker failed") \
                from self._error
        leaves, treedef = jax.tree.flatten(state)
        host_leaves = [np.asarray(l) for l in leaves]  # device->host copy
        payload = (host_leaves, step, dict(meta or {}))
        if self.async_save and not block:
            self._q.put(payload)
        else:
            self._write(*payload)

    def save_on_warning(self, state: Any, step: int,
                        meta: Optional[Dict] = None) -> None:
        """Synchronous emergency save (called inside the warning window)."""
        self.save(state, step, dict(meta or {}, emergency=True), block=True)

    def wait(self) -> None:
        """Block until all queued async saves hit disk."""
        self._q.join()
        if self._error:
            raise RuntimeError("async checkpoint worker failed") \
                from self._error

    def _drain(self) -> None:
        while True:
            payload = self._q.get()
            try:
                self._write(*payload)
            except BaseException as e:  # surfaced on next save()/wait()
                self._error = e
            finally:
                self._q.task_done()

    def _write(self, host_leaves: List[np.ndarray], step: int,
               meta: Dict) -> None:
        final = os.path.join(self.dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        meta = dict(meta, step=step, n_leaves=len(host_leaves),
                    written_at=time.time())
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep_n] if self.keep_n > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            m = _STEP_RE.match(name)
            if m and os.path.exists(os.path.join(self.dir, name, "meta.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Any, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Restore into ``template``'s tree structure; optionally place leaves
        with ``shardings`` (a matching tree of NamedShardings) — used by the
        elastic rescale path to load onto a *different* mesh."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(d, "arrays.npz"))
        leaves, treedef = jax.tree.flatten(template)
        assert meta["n_leaves"] == len(leaves), (
            f"checkpoint has {meta['n_leaves']} leaves, template "
            f"{len(leaves)} — architecture/optimizer mismatch")
        host = [npz[f"leaf_{i}"] for i in range(len(leaves))]
        if shardings is not None:
            shard_leaves = jax.tree.leaves(shardings)
            restored = [jax.device_put(h, s)
                        for h, s in zip(host, shard_leaves)]
        else:
            restored = [
                jax.device_put(h.astype(l.dtype) if hasattr(l, "dtype") and
                               h.dtype != l.dtype else h)
                for h, l in zip(host, leaves)]
        return jax.tree.unflatten(treedef, restored), meta
