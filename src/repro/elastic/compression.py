"""Gradient compression for cross-pod all-reduce.

The pod axis crosses the slowest links (inter-pod DCN/ICI), so the gradient
all-reduce over "pod" is the natural compression point.  We implement int8
uniform quantization with **error feedback** (the quantization residual is
carried and added to the next step's gradient), which provably preserves
SGD convergence (Karimireddy et al., 2019).

``compressed_psum_pod`` quantizes, all-reduces over the pod axis only (the
intra-pod reduction stays full precision via GSPMD), and dequantizes — all
jit-compatible and sharding-transparent.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8 quantization. Returns (q, scale)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Params, ef: Params) -> Tuple[Params, Params, Params]:
    """Quantize a gradient tree with error feedback.

    Returns (q_tree, scale_tree, new_ef): grads' = Q(grads + ef);
    new_ef = (grads + ef) - dequant(grads')."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return q, s, corrected - deq

    out = jax.tree.map(one, grads, ef)
    istup = lambda x: isinstance(x, tuple)
    q = jax.tree.map(lambda o: o[0], out, is_leaf=istup)
    s = jax.tree.map(lambda o: o[1], out, is_leaf=istup)
    new_ef = jax.tree.map(lambda o: o[2], out, is_leaf=istup)
    return q, s, new_ef


def decompress_tree(q: Params, s: Params, like: Params) -> Params:
    return jax.tree.map(
        lambda qq, ss, l: dequantize_int8(qq, ss, l.dtype), q, s, like)


def init_error_feedback(params: Params) -> Params:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_grad_combine(grads: Params, ef: Params) -> Tuple[Params, Params]:
    """Round-trip a gradient tree through int8 (+EF).  In a multi-pod program
    the all-reduce over "pod" happens *between* compress and decompress; XLA
    then moves 1/4 of the bytes across the pod links.  On a single mesh this
    is the identity-with-quantization-noise operator used by the tests to
    bound the EF residual."""
    q, s, new_ef = compress_tree(grads, ef)
    out = decompress_tree(q, s, grads)
    return out, new_ef
