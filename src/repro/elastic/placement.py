"""HLEM-VMP as the launcher's job→slice placement policy.

The paper's allocation algorithm, applied at cluster level: training/serving
jobs (with HBM, chip, ICI-bandwidth and host-RAM demands) are placed onto pod
slices exactly like VMs onto hosts — including spot-job preemption when a
reserved (on-demand) job needs capacity, entropy-weighted load balancing
across slices, and the adjusted variant's spot-load spreading that reduces
how many preemptible jobs a single slice loss can kill.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional

import numpy as np

from ..core import (
    HlemVmpAdjusted,
    InterruptionBehavior,
    MarketSimulator,
    SimConfig,
    Vm,
    VmState,
    make_on_demand,
    make_spot,
    resources,
)

# resource dims reinterad at cluster level:
#   cpu -> chips, ram -> HBM GB, bw -> ICI GB/s, storage -> host RAM GB
SLICE_V5E_256 = resources(256, 256 * 16, 256 * 100, 256 * 48)


@dataclass
class JobSpec:
    name: str
    chips: int
    hbm_gb: float
    ici_gbps: float
    host_ram_gb: float
    duration_h: float
    preemptible: bool = True

    def demand(self) -> np.ndarray:
        return resources(self.chips, self.hbm_gb, self.ici_gbps,
                         self.host_ram_gb)


class ClusterScheduler:
    """Thin adapter: jobs as VMs, pod slices as hosts, HLEM-VMP placement."""

    def __init__(self, n_slices: int, slice_capacity: np.ndarray = SLICE_V5E_256,
                 alpha: float = -0.5, warning_s: float = 120.0):
        self.sim = MarketSimulator(
            policy=HlemVmpAdjusted(alpha=alpha),
            config=SimConfig(warning_time=warning_s,
                             interruption_selector="best_fit_remaining"))
        self.slice_ids = [self.sim.add_host(slice_capacity.copy())
                          for _ in range(n_slices)]
        self._jobs: Dict[str, Vm] = {}
        self._next = 0

    def submit(self, job: JobSpec, at: float = 0.0) -> int:
        vid = self._next
        self._next += 1
        if job.preemptible:
            vm = make_spot(vid, job.demand(), job.duration_h * 3600,
                           behavior=InterruptionBehavior.HIBERNATE,
                           min_running_time=600.0,
                           hibernation_timeout=24 * 3600.0,
                           waiting_timeout=24 * 3600.0, submit_time=at)
        else:
            vm = make_on_demand(vid, job.demand(), job.duration_h * 3600,
                                waiting_timeout=24 * 3600.0, submit_time=at)
        self._jobs[job.name] = vm
        self.sim.submit(vm)
        return vid

    def run(self, until_h: float):
        return self.sim.run(until=until_h * 3600.0)

    def placement(self) -> Dict[str, int]:
        return {name: vm.host for name, vm in self._jobs.items()}

    def states(self) -> Dict[str, str]:
        return {name: vm.state.value for name, vm in self._jobs.items()}
