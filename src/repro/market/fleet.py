"""Spot-fleet manager with fallback ladders (paper-motivated resilience layer).

The paper's resilience story is per-VM (hibernate, resume, re-bid); real
spot systems instead hold a *fleet* at a target capacity across diversified
pools and degrade gracefully when the market misbehaves.  This module adds
that layer:

* :class:`FleetConfig` — target capacity, per-pool weights, diversification
  strategy, and a configurable **fallback ladder** with per-rung retry
  budgets and exponential backoff.
* :class:`FleetManager` — a slot state machine driven once per PRICE_TICK:
  each slot of ``unit_cpu`` capacity is observed (the dense market registry
  answers "what is still running" in one vectorized pass), shortfall is
  detected, and dead slots are replenished — fresh slots through the
  strategy's residual-capacity apportionment, interrupted slots through the
  ladder: retry same pool → cheaper pool → on-demand fallback → queue work →
  scale down.
* :func:`plan_replenish` — the vectorized apportionment planner, with
  :func:`plan_replenish_ref` as the per-pool Python oracle it is
  regression-tested (and benchmarked) against; likewise
  :func:`fleet_pool_capacity` / :func:`fleet_pool_capacity_ref` for the
  registry liveness scan.

Strategies register in :data:`FLEET_STRATEGY_REGISTRY`
(``@register_fleet_strategy("name")``), so ``FleetSpec`` can sweep
fleet-vs-per-VM baselines by name, PR 4 registry style.

Everything is deterministic: no RNG anywhere in the manager — identical
ticks produce identical launches, which is what makes the chaos-determinism
tests (two-run bit-identity under injected faults) possible.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.registry import Registry
from ..core.types import (InterruptionBehavior, VmState, make_on_demand,
                          make_spot, resources)
from ..obs.eventlog import NULL_RECORDER
from ..obs.tracer import NULL_TRACER

_EPS = 1e-9

#: fallback-ladder rung names, in canonical escalation order; a rung may
#: also be ``"pool:<k>"`` — retry pinned to pool ``k``
LADDER_RUNGS = ("same-pool", "cheaper-pool", "on-demand", "queue",
                "scale-down")

#: string-keyed registry of diversification strategies — apportionment
#: functions ``(need, cur_units, cap_units, weights, prices) -> counts``
FLEET_STRATEGY_REGISTRY = Registry("fleet strategy")
register_fleet_strategy = FLEET_STRATEGY_REGISTRY.register

#: slot states a fleet VM counts as "up" in the capacity sample (INTERRUPTING
#: and MIGRATING VMs still hold and execute on their capacity)
_UP_STATES = (VmState.RUNNING, VmState.INTERRUPTING, VmState.MIGRATING)


@dataclass(frozen=True)
class FleetConfig:
    """Configuration of one spot fleet (the ``FleetSpec`` payload).

    ``target_capacity`` CPU units are held as ``ceil(target/unit_cpu)``
    slots of ``unit_cpu`` × ``unit_ram`` each.  Spot launches bid
    ``bid_fraction`` × the pool's on-demand rate.  ``pool_weights`` steers
    the diversification (None = uniform); the ladder's per-rung budgets and
    the exponential backoff (``base × mult^(k-1)``, capped) pace replacement
    attempts so storms don't thrash the allocator.  An on-demand fallback
    runs for ``od_lease`` seconds, then the slot returns to spot."""
    strategy: str = "diversified"
    target_capacity: float = 64.0
    unit_cpu: float = 2.0
    unit_ram: float = 2048.0
    bid_fraction: float = 0.6
    pool_weights: Optional[Tuple[float, ...]] = None
    ladder: Tuple[Tuple[str, int], ...] = (
        ("same-pool", 2), ("cheaper-pool", 2), ("on-demand", 1),
        ("queue", 2), ("scale-down", 1))
    backoff_base: float = 60.0
    backoff_mult: float = 2.0
    backoff_cap: float = 960.0
    od_lease: float = 1800.0


def _rung_pool(rung: str) -> Optional[int]:
    """The pinned pool id of a ``"pool:<k>"`` rung, else None."""
    if rung.startswith("pool:"):
        try:
            return int(rung[5:])
        except ValueError:
            return None
    return None


def validate_fleet_config(cfg: FleetConfig,
                          n_pools: Optional[int] = None) -> None:
    """Fail-fast validation (construction-time, PR 4 error style).  With
    ``n_pools`` known, also checks weight length and pinned-rung pool ids."""
    if not cfg.target_capacity > 0:
        raise ValueError(
            f"fleet target_capacity must be > 0 (got {cfg.target_capacity!r})")
    if not cfg.unit_cpu > 0:
        raise ValueError(f"fleet unit_cpu must be > 0 (got {cfg.unit_cpu!r})")
    if not cfg.bid_fraction > 0:
        raise ValueError(
            f"fleet bid_fraction must be > 0 (got {cfg.bid_fraction!r})")
    if cfg.pool_weights is not None:
        w = [float(x) for x in cfg.pool_weights]
        if any(x < 0 for x in w):
            raise ValueError(
                f"conflicting fleet pool_weights {tuple(w)}: negative weight")
        if not any(x > 0 for x in w):
            raise ValueError(
                f"conflicting fleet pool_weights {tuple(w)}: all zero — no "
                "pool can receive capacity")
        if n_pools is not None and len(w) != n_pools:
            raise ValueError(
                f"fleet pool_weights has {len(w)} entries for {n_pools} "
                "pools")
    if not cfg.ladder:
        raise ValueError("fleet fallback ladder must have at least one rung")
    for entry in cfg.ladder:
        rung, budget = entry
        pinned = _rung_pool(rung)
        if rung not in LADDER_RUNGS and pinned is None:
            raise ValueError(
                f"unknown fallback rung {rung!r} "
                f"(known: {', '.join(LADDER_RUNGS)}, or 'pool:<k>')")
        if pinned is not None and pinned < 0:
            raise ValueError(f"fallback rung {rung!r} names a negative pool")
        if pinned is not None and n_pools is not None and pinned >= n_pools:
            raise ValueError(
                f"fallback rung {rung!r} names unknown pool {pinned} "
                f"(known pools: 0..{n_pools - 1})")
        if int(budget) < 1:
            raise ValueError(
                f"fallback rung {rung!r} retry budget must be >= 1 "
                f"(got {budget!r})")
    if not cfg.backoff_base > 0:
        raise ValueError(
            f"fleet backoff_base must be > 0 (got {cfg.backoff_base!r})")
    if not cfg.backoff_mult >= 1.0:
        raise ValueError(
            f"fleet backoff_mult must be >= 1 (got {cfg.backoff_mult!r})")
    if not cfg.backoff_cap >= cfg.backoff_base:
        raise ValueError(
            f"fleet backoff_cap must be >= backoff_base "
            f"(got {cfg.backoff_cap!r} < {cfg.backoff_base!r})")
    if not cfg.od_lease > 0:
        raise ValueError(f"fleet od_lease must be > 0 (got {cfg.od_lease!r})")


# ---------------------------------------------------------------------------
# registry liveness scan (vectorized + Python oracle) — benchmarked pair
# ---------------------------------------------------------------------------
def fleet_pool_capacity(registry: Dict[str, np.ndarray],
                        fleet_vids: np.ndarray,
                        n_pools: int) -> Tuple[np.ndarray, np.ndarray]:
    """(units, cpu) per pool held by the fleet's rows of the dense RUNNING-
    spot registry: one sorted-membership test + two bincounts, no per-VM
    walk.  ``fleet_vids`` must be sorted unique (the manager's live slot
    ids)."""
    vids = registry["vid"]
    if vids.size == 0 or fleet_vids.size == 0:
        return np.zeros(n_pools, dtype=np.int64), np.zeros(n_pools)
    mask = np.isin(vids, fleet_vids, assume_unique=True)
    pools = registry["pool"][mask]
    units = np.bincount(pools, minlength=n_pools).astype(np.int64)
    cpu = np.bincount(pools, weights=registry["cpu"][mask],
                      minlength=n_pools)
    return units, cpu


def fleet_pool_capacity_ref(registry: Dict[str, np.ndarray],
                            fleet_vids: np.ndarray,
                            n_pools: int) -> Tuple[np.ndarray, np.ndarray]:
    """Per-row Python oracle of :func:`fleet_pool_capacity` — accumulates in
    registry row order, matching ``bincount`` bit for bit."""
    fset = {int(v) for v in fleet_vids}
    units = [0] * n_pools
    cpu = [0.0] * n_pools
    for i in range(registry["vid"].size):
        if int(registry["vid"][i]) in fset:
            p = int(registry["pool"][i])
            units[p] += 1
            cpu[p] += float(registry["cpu"][i])
    return np.asarray(units, dtype=np.int64), np.asarray(cpu)


# ---------------------------------------------------------------------------
# replenish planning (vectorized + Python oracle) — benchmarked pair
# ---------------------------------------------------------------------------
def _admissible_caps(prices, bids, free_cpu, weights,
                     unit_cpu: float) -> np.ndarray:
    """(n_pools,) int64 units each pool can admit right now: price must
    clear the fleet's bid, free CPU bounds the count, zero-weight pools are
    excluded from planning entirely."""
    adm = ((prices <= bids + _EPS) & (free_cpu >= unit_cpu - _EPS)
           & (weights > 0.0))
    return np.where(adm, np.floor(free_cpu / unit_cpu).astype(np.int64), 0)


@register_fleet_strategy("diversified")
def _diversified(need: int, cur_units, cap_units, weights, prices):
    """Residual-capacity apportionment (clusterman-style): target the
    weight-proportional split of ``current + need`` units, allocate the
    positive residuals by largest remainder (price then pool id break
    ties), round-robin any cap-limited leftover."""
    n = weights.size
    counts = np.zeros(n, dtype=np.int64)
    if need <= 0 or not cap_units.any():
        return counts
    total = float(np.sum(cur_units)) + float(need)
    wsum = float(np.sum(weights))
    desired = weights * (total / wsum)
    residual = np.maximum(desired - cur_units, 0.0)
    residual = np.where(cap_units > 0, residual, 0.0)
    rsum = float(np.sum(residual))
    if rsum <= 0.0:
        # balanced already (or residual pools inadmissible): cheapest first
        return _fill_by_price(need, cap_units, prices)
    shares = residual * (float(need) / rsum)
    floors = np.floor(shares)
    counts[:] = np.minimum(floors.astype(np.int64), cap_units)
    frac = shares - floors
    order = np.lexsort((np.arange(n), prices, -frac))
    rem = need - int(counts.sum())
    while rem > 0:
        progress = False
        for p in order:
            if rem == 0:
                break
            if counts[p] < cap_units[p]:
                counts[p] += 1
                rem -= 1
                progress = True
        if not progress:
            break
    return counts


def _fill_by_price(need: int, cap_units, prices) -> np.ndarray:
    n = prices.size
    counts = np.zeros(n, dtype=np.int64)
    order = np.lexsort((np.arange(n), prices))
    rem = need
    for p in order:
        take = min(rem, int(cap_units[p]))
        counts[p] = take
        rem -= take
        if rem == 0:
            break
    return counts


@register_fleet_strategy("lowest-price")
def _lowest_price(need: int, cur_units, cap_units, weights, prices):
    """Fill the cheapest admissible pool first, spilling to the next by
    price (pool id breaks ties) — maximal savings, minimal diversification."""
    if need <= 0:
        return np.zeros(weights.size, dtype=np.int64)
    return _fill_by_price(need, cap_units, prices)


@register_fleet_strategy("single-pool")
def _single_pool(need: int, cur_units, cap_units, weights, prices):
    """Everything in the highest-weight pool (first on ties) — the
    undiversified baseline the resilience sweep compares against."""
    n = weights.size
    counts = np.zeros(n, dtype=np.int64)
    if need <= 0:
        return counts
    best = int(np.argmax(weights))
    counts[best] = min(need, int(cap_units[best]))
    return counts


def plan_replenish(need: int, cur_units, weights, prices, bids, free_cpu,
                   unit_cpu: float, strategy: str = "diversified"
                   ) -> np.ndarray:
    """(n_pools,) int64 launch counts covering ``need`` replacement slots.
    Admissibility (price clears the bid, free CPU holds a unit, weight > 0)
    caps each pool; the registered ``strategy`` apportions within the caps.
    May total less than ``need`` when capacity is short — unserved slots
    retry next tick."""
    cur_units = np.asarray(cur_units, dtype=np.int64)
    weights = np.asarray(weights, dtype=np.float64)
    prices = np.asarray(prices, dtype=np.float64)
    bids = np.asarray(bids, dtype=np.float64)
    free_cpu = np.asarray(free_cpu, dtype=np.float64)
    cap_units = _admissible_caps(prices, bids, free_cpu, weights, unit_cpu)
    fn = FLEET_STRATEGY_REGISTRY.get(strategy)
    return fn(int(need), cur_units, cap_units, weights, prices)


def plan_replenish_ref(need: int, cur_units, weights, prices, bids,
                       free_cpu, unit_cpu: float,
                       strategy: str = "diversified") -> np.ndarray:
    """Per-pool Python oracle of :func:`plan_replenish`: identical decisions
    bit for bit.  Shared scalar reductions go through ``float(np.sum(...))``
    (pairwise summation differs from a sequential Python sum in the last
    ulp); the per-pool arithmetic is plain scalar IEEE, matching numpy's
    elementwise kernels exactly."""
    n = len(prices)
    need = int(need)
    cap_units = [0] * n
    for p in range(n):
        if (float(prices[p]) <= float(bids[p]) + _EPS
                and float(free_cpu[p]) >= unit_cpu - _EPS
                and float(weights[p]) > 0.0):
            cap_units[p] = int(math.floor(float(free_cpu[p]) / unit_cpu))
    counts = [0] * n

    def fill_by_price(rem):
        for p in sorted(range(n), key=lambda q: (float(prices[q]), q)):
            take = min(rem, cap_units[p])
            counts[p] = take
            rem -= take
            if rem == 0:
                break
        return counts

    if strategy == "single-pool":
        if need <= 0:
            return np.asarray(counts, dtype=np.int64)
        best = 0
        for p in range(1, n):
            if float(weights[p]) > float(weights[best]):
                best = p
        counts[best] = min(need, cap_units[best])
        return np.asarray(counts, dtype=np.int64)
    if strategy == "lowest-price":
        if need > 0:
            fill_by_price(need)
        return np.asarray(counts, dtype=np.int64)
    if strategy != "diversified":
        raise ValueError(f"no reference walk for strategy {strategy!r}")
    if need <= 0 or not any(cap_units):
        return np.asarray(counts, dtype=np.int64)
    total = float(np.sum(np.asarray(cur_units, dtype=np.int64))) + float(need)
    wsum = float(np.sum(np.asarray(weights, dtype=np.float64)))
    desired = [float(weights[p]) * (total / wsum) for p in range(n)]
    residual = [max(desired[p] - float(cur_units[p]), 0.0) if cap_units[p] > 0
                else 0.0 for p in range(n)]
    rsum = float(np.sum(np.asarray(residual, dtype=np.float64)))
    if rsum <= 0.0:
        fill_by_price(need)
        return np.asarray(counts, dtype=np.int64)
    shares = [residual[p] * (float(need) / rsum) for p in range(n)]
    floors = [math.floor(shares[p]) for p in range(n)]
    for p in range(n):
        counts[p] = min(int(floors[p]), cap_units[p])
    frac = [shares[p] - floors[p] for p in range(n)]
    order = sorted(range(n), key=lambda q: (-frac[q], float(prices[q]), q))
    rem = need - sum(counts)
    while rem > 0:
        progress = False
        for p in order:
            if rem == 0:
                break
            if counts[p] < cap_units[p]:
                counts[p] += 1
                rem -= 1
                progress = True
        if not progress:
            break
    return np.asarray(counts, dtype=np.int64)


# ---------------------------------------------------------------------------
# the manager
# ---------------------------------------------------------------------------
class FleetManager:
    """Holds ``ceil(target/unit)`` capacity slots and keeps them filled.

    Driven once per PRICE_TICK by the simulator (post-wave, post-flush,
    post-planner).  Each slot is empty, or owns one VM (spot, or on-demand
    while riding the ``"on-demand"`` rung).  Slot lifecycle:

    * fresh (never ran / od lease ended) — batched through the strategy's
      apportionment, retried every tick while inadmissible (no backoff);
    * healthy — its VM reached RUNNING; ladder state is reset;
    * episode — its VM died after running: the slot walks the fallback
      ladder, one attempt per due tick, per-rung retry budgets, exponential
      backoff between attempts; an exhausted ladder (or the ``scale-down``
      rung) retires the slot and lowers the effective target.

    Fleet VMs are non-persistent TERMINATE spot requests: a failed placement
    FAILs immediately (observed next tick as a consumed attempt) and an
    interrupted slot is *replaced*, never hibernated — replacement is the
    fleet's whole job.  Stateful across one run; use a fresh manager per
    simulation, like the engine."""

    #: telemetry hook (``repro.obs``); the build layer swaps in the live
    #: tracer — rung hits and launches feed the counter registry
    tracer = NULL_TRACER
    #: event recorder — rung/launch/retire records for the flight log
    events = NULL_RECORDER

    def __init__(self, config: FleetConfig, n_pools: int):
        validate_fleet_config(config, n_pools)
        FLEET_STRATEGY_REGISTRY.get(config.strategy)   # fail fast
        self.config = config
        self.n_pools = int(n_pools)
        if config.pool_weights is not None:
            self.weights = np.asarray(config.pool_weights, dtype=np.float64)
        else:
            self.weights = np.ones(self.n_pools, dtype=np.float64)
        self.n_slots = int(math.ceil(config.target_capacity
                                     / config.unit_cpu))
        self._ladder = tuple((str(r), int(b)) for r, b in config.ladder)
        n = self.n_slots
        self.slot_vid = np.full(n, -1, dtype=np.int64)
        self.slot_pool = np.full(n, -1, dtype=np.int64)   # home pool
        self.slot_rung = np.full(n, -1, dtype=np.int64)   # -1 = fresh/healthy
        self.slot_tries = np.zeros(n, dtype=np.int64)     # used at this rung
        self.slot_fail = np.zeros(n, dtype=np.int64)      # backoff exponent
        self.slot_next = np.zeros(n)                      # earliest attempt
        self.slot_retired = np.zeros(n, dtype=bool)
        self.slot_od = np.zeros(n, dtype=bool)
        self.slot_ran = np.zeros(n, dtype=bool)           # incarnation ran?
        #: slots taken out of service by the autoscaler (scale-in); unlike
        #: ladder retirement they are reversible — scale-out reuses them
        self.slot_shed = np.zeros(n, dtype=bool)
        #: unit target the autoscaler last requested (PR 10 capacity
        #: interface); starts at the provisioned slot count
        self.target_units = n
        # retarget bookkeeping: None until set_target_units first runs, so
        # an autoscaler-less fleet keeps the PR 6 effective-target formula
        # (and its metrics) bit for bit
        self._units_override: Optional[int] = None
        self._retired_base = 0

    # ------------------------------------------------------------- queries
    def wants_tick(self) -> bool:
        """Any in-service (unretired, unshed) slot left?  Keeps a bounded
        run's PRICE_TICK chain alive through backoff waits when nothing
        else is running."""
        return bool(np.any(~self.slot_retired & ~self.slot_shed))

    def effective_target(self) -> float:
        """Target CPU after scale-down: retired slots lower the bar (the
        fleet *chose* to shrink; shortfall metrics measure against what it
        still promises).  Once the autoscaler has retargeted, the promise
        is its requested units minus any retirements since."""
        retired = float(np.count_nonzero(self.slot_retired))
        unit = self.config.unit_cpu
        if self._units_override is None:
            return self.config.target_capacity - retired * unit
        return (float(self._units_override) * unit
                - (retired - float(self._retired_base)) * unit)

    def _backoff(self, fails: int) -> float:
        cfg = self.config
        return min(cfg.backoff_cap,
                   cfg.backoff_base * cfg.backoff_mult ** (fails - 1))

    # ----------------------------------------- dynamic capacity (autoscale)
    def set_target_units(self, sim, n: int, now: float) -> None:
        """Retarget the fleet to ``n`` unit slots — the autoscaler's lever.

        Scale-out un-sheds parked slots first (they re-enter the fresh
        apportionment next tick), then grows the slot arrays.  Scale-in
        sheds empty slots first, then decommissions live RUNNING /
        INTERRUPTING VMs highest-index first (their work drains through the
        ordinary finish path); WAITING / MIGRATING slots are left alone —
        best effort, the next evaluation retries.  Ladder-retired slots
        never come back."""
        n = int(n)
        cur = int(np.count_nonzero(~self.slot_retired & ~self.slot_shed))
        self.target_units = n
        self._units_override = n
        self._retired_base = int(np.count_nonzero(self.slot_retired))
        if n > cur:
            need = n - cur
            parked = np.flatnonzero(self.slot_shed & ~self.slot_retired)
            for s in parked[:need]:
                self._reset_slot(int(s), now)
            need -= min(need, int(parked.size))
            if need > 0:
                self._grow_slots(need, now)
        elif n < cur:
            rem = cur - n
            in_service = [s for s in range(self.n_slots - 1, -1, -1)
                          if not self.slot_retired[s]
                          and not self.slot_shed[s]]
            empty = [s for s in in_service if self.slot_vid[s] < 0]
            live = [s for s in in_service if self.slot_vid[s] >= 0]
            for s in empty + live:
                if rem == 0:
                    break
                vid = int(self.slot_vid[s])
                if vid >= 0:
                    vm = sim.vms[vid]
                    if vm.state not in (VmState.RUNNING,
                                        VmState.INTERRUPTING):
                        continue    # in flight — not safely shedable now
                    sim.decommission(vm)
                self.slot_shed[s] = True
                self.slot_vid[s] = -1
                self.slot_od[s] = False
                self.slot_ran[s] = False
                self.slot_rung[s] = -1
                self.slot_tries[s] = 0
                self.slot_fail[s] = 0
                rem -= 1

    def _reset_slot(self, s: int, now: float) -> None:
        """Return a shed slot to service as a fresh spot slot."""
        self.slot_shed[s] = False
        self.slot_vid[s] = -1
        self.slot_pool[s] = -1
        self.slot_rung[s] = -1
        self.slot_tries[s] = 0
        self.slot_fail[s] = 0
        self.slot_next[s] = now
        self.slot_od[s] = False
        self.slot_ran[s] = False

    def _grow_slots(self, k: int, now: float) -> None:
        """Append ``k`` fresh in-service slots to every state array."""
        self.slot_vid = np.concatenate(
            [self.slot_vid, np.full(k, -1, dtype=np.int64)])
        self.slot_pool = np.concatenate(
            [self.slot_pool, np.full(k, -1, dtype=np.int64)])
        self.slot_rung = np.concatenate(
            [self.slot_rung, np.full(k, -1, dtype=np.int64)])
        self.slot_tries = np.concatenate(
            [self.slot_tries, np.zeros(k, dtype=np.int64)])
        self.slot_fail = np.concatenate(
            [self.slot_fail, np.zeros(k, dtype=np.int64)])
        self.slot_next = np.concatenate(
            [self.slot_next, np.full(k, float(now), dtype=np.float64)])
        self.slot_retired = np.concatenate(
            [self.slot_retired, np.zeros(k, dtype=bool)])
        self.slot_od = np.concatenate(
            [self.slot_od, np.zeros(k, dtype=bool)])
        self.slot_ran = np.concatenate(
            [self.slot_ran, np.zeros(k, dtype=bool)])
        self.slot_shed = np.concatenate(
            [self.slot_shed, np.zeros(k, dtype=bool)])
        self.n_slots += k

    # ---------------------------------------------------------------- tick
    def on_tick(self, sim, now: float) -> None:
        cfg = self.config
        m = sim.metrics
        vms = sim.vms
        # -- observe every slot; update the state machine ------------------
        up_cpu = 0.0
        for s in range(self.n_slots):
            if self.slot_retired[s] or self.slot_shed[s]:
                continue
            vid = int(self.slot_vid[s])
            if vid < 0:
                continue
            vm = vms[vid]
            st = vm.state
            if st in _UP_STATES:
                up_cpu += float(vm.demand[0])
                if not self.slot_ran[s] or self.slot_rung[s] >= 0:
                    # the attempt landed: healthy, ladder state resets
                    self.slot_ran[s] = True
                    self.slot_rung[s] = -1
                    self.slot_tries[s] = 0
                    self.slot_fail[s] = 0
                continue
            if st is VmState.WAITING:
                continue    # in flight — neither up nor dead yet
            # dead: FINISHED / TERMINATED / FAILED
            if st is VmState.FINISHED and self.slot_od[s]:
                # on-demand lease ran its course: back to a fresh spot slot
                self.slot_vid[s] = -1
                self.slot_od[s] = False
                self.slot_ran[s] = False
                self.slot_rung[s] = -1
                self.slot_tries[s] = 0
                self.slot_fail[s] = 0
                self.slot_next[s] = now
                continue
            if self.slot_ran[s]:
                # was up, got reclaimed → open a fallback episode
                if vm.pool >= 0:
                    self.slot_pool[s] = int(vm.pool)
                self.slot_vid[s] = -1
                self.slot_od[s] = False
                self.slot_ran[s] = False
                self.slot_rung[s] = 0
                self.slot_tries[s] = 0
                self.slot_fail[s] = 0
                self.slot_next[s] = now
            else:
                # the launch attempt failed at placement; the try was
                # consumed at launch — wait out its backoff
                self.slot_vid[s] = -1
                self.slot_od[s] = False
        m.fleet_samples.append((now, up_cpu, self.effective_target()))
        # -- market snapshot for this tick's planning ----------------------
        eng = sim.engine
        prices = eng.prices
        bids = cfg.bid_fraction * eng.od_rates
        free_cpu = sim.pool.pool_free_cpu().astype(np.float64).copy()
        live_spot = self.slot_vid[(self.slot_vid >= 0) & ~self.slot_od]
        cur_units, _ = fleet_pool_capacity(
            sim.pool.market_registry(), np.sort(live_spot), self.n_pools)
        # -- fresh slots: batched strategy apportionment -------------------
        due = [s for s in range(self.n_slots)
               if not self.slot_retired[s] and not self.slot_shed[s]
               and self.slot_vid[s] < 0 and self.slot_next[s] <= now + _EPS]
        fresh = [s for s in due if self.slot_rung[s] < 0]
        if fresh:
            counts = plan_replenish(len(fresh), cur_units, self.weights,
                                    prices, bids, free_cpu, cfg.unit_cpu,
                                    cfg.strategy)
            targets = [p for p in range(self.n_pools)
                       for _ in range(int(counts[p]))]
            # zip truncates: slots beyond admissible capacity stay fresh
            # and re-enter the apportionment next tick
            for s, p in zip(fresh, targets):
                m.fallback_counts["launch"] = (
                    m.fallback_counts.get("launch", 0) + 1)
                if self.tracer.enabled:
                    self.tracer.counters.inc("fleet/rung/launch")
                if self.events.enabled:
                    self.events.emit(now, "fleet-rung", pool=int(p),
                                     a=float(s), aux="launch")
                self._launch_spot(sim, s, p, now, bids, free_cpu)
        # -- episode slots: one ladder attempt each ------------------------
        for s in due:
            if self.slot_rung[s] < 0 or self.slot_vid[s] >= 0:
                continue
            while (self.slot_rung[s] < len(self._ladder)
                   and self.slot_tries[s]
                   >= self._ladder[int(self.slot_rung[s])][1]):
                self.slot_rung[s] += 1
                self.slot_tries[s] = 0
            if self.slot_rung[s] >= len(self._ladder):
                self._retire(sim, s, now)
                continue
            self._attempt(sim, s, now, prices, bids, free_cpu)

    # ------------------------------------------------------------- actions
    def _attempt(self, sim, s: int, now: float, prices, bids,
                 free_cpu) -> None:
        """One fallback-ladder attempt for episode slot ``s``; always
        consumes a try and arms the backoff (success is only known next
        tick, when the slot's VM is observed RUNNING)."""
        cfg = self.config
        m = sim.metrics
        rung = self._ladder[int(self.slot_rung[s])][0]
        m.fallback_counts[rung] = m.fallback_counts.get(rung, 0) + 1
        if self.tracer.enabled:
            self.tracer.counters.inc("fleet/rung/" + rung)
            self.tracer.instant("fleet", "rung/" + rung, now,
                                {"slot": int(s)})
        if self.events.enabled:
            self.events.emit(now, "fleet-rung", a=float(s), aux=rung)
        if rung == "scale-down":
            self._retire(sim, s, now)
            return
        if rung != "queue":
            pinned = _rung_pool(rung)
            if rung == "on-demand":
                p = self._od_pool(free_cpu)
                if p >= 0:
                    self._launch_od(sim, s, p, now, free_cpu)
            else:
                home = int(self.slot_pool[s])
                if rung == "same-pool":
                    p = home if home >= 0 else 0
                    if not self._admissible(p, prices, bids, free_cpu):
                        p = -1
                elif pinned is not None:
                    p = pinned
                    if not self._admissible(p, prices, bids, free_cpu):
                        p = -1
                else:   # cheaper-pool
                    p = self._cheapest_other(home, prices, bids, free_cpu)
                if p >= 0:
                    self._launch_spot(sim, s, p, now, bids, free_cpu)
            # an inadmissible rung submits nothing — the try still counts
        self.slot_tries[s] += 1
        self.slot_fail[s] += 1
        self.slot_next[s] = now + self._backoff(int(self.slot_fail[s]))

    def _admissible(self, p: int, prices, bids, free_cpu) -> bool:
        return (float(prices[p]) <= float(bids[p]) + _EPS
                and float(free_cpu[p]) >= self.config.unit_cpu - _EPS)

    def _cheapest_other(self, home: int, prices, bids, free_cpu) -> int:
        best = -1
        for p in range(self.n_pools):
            if p == home or not self._admissible(p, prices, bids, free_cpu):
                continue
            if best < 0 or float(prices[p]) < float(prices[best]) - _EPS:
                best = p
        return best

    def _od_pool(self, free_cpu) -> int:
        """On-demand fallback pool: most free CPU (lowest id on ties) that
        can hold a unit — on-demand ignores price admission by definition."""
        best = -1
        for p in range(self.n_pools):
            if float(free_cpu[p]) < self.config.unit_cpu - _EPS:
                continue
            if best < 0 or float(free_cpu[p]) > float(free_cpu[best]) + _EPS:
                best = p
        return best

    def _launch_spot(self, sim, s: int, p: int, now: float, bids,
                     free_cpu) -> None:
        cfg = self.config
        vid = sim.new_vm_id()
        vm = make_spot(
            vid, resources(cfg.unit_cpu, cfg.unit_ram, 10.0, 1024.0),
            duration=float("inf"),
            behavior=InterruptionBehavior.TERMINATE, persistent=False,
            submit_time=now, bid=float(bids[p]), pool=int(p))
        sim.submit(vm)
        self.slot_vid[s] = vid
        self.slot_pool[s] = int(p)
        self.slot_od[s] = False
        self.slot_ran[s] = False
        free_cpu[p] -= cfg.unit_cpu     # same-tick launches share the budget
        sim.metrics.fleet_launches += 1
        sim.metrics.fleet_spot_ids.append(vid)
        if self.events.enabled:
            self.events.emit(now, "fleet-launch", vm=vid, pool=int(p),
                             a=float(bids[p]), b=float(s), aux="spot")

    def _launch_od(self, sim, s: int, p: int, now: float,
                   free_cpu) -> None:
        cfg = self.config
        vid = sim.new_vm_id()
        vm = make_on_demand(
            vid, resources(cfg.unit_cpu, cfg.unit_ram, 10.0, 1024.0),
            duration=cfg.od_lease, persistent=False,
            submit_time=now, pool=int(p))
        sim.submit(vm)
        self.slot_vid[s] = vid
        self.slot_pool[s] = int(p)
        self.slot_od[s] = True
        self.slot_ran[s] = False
        free_cpu[p] -= cfg.unit_cpu
        sim.metrics.od_spill_launches += 1
        sim.metrics.fleet_od_ids.append(vid)
        if self.events.enabled:
            self.events.emit(now, "fleet-launch", vm=vid, pool=int(p),
                             b=float(s), aux="od")

    def _retire(self, sim, s: int, now: float) -> None:
        """Scale down: give the slot up for good and lower the effective
        target — graceful degradation instead of thrash."""
        self.slot_retired[s] = True
        self.slot_vid[s] = -1
        sim.metrics.fleet_slots_retired += 1
        if self.events.enabled:
            self.events.emit(now, "fleet-retire", a=float(s))


def make_fleet_manager(n_pools: int, config: Optional[FleetConfig] = None,
                       **kwargs) -> FleetManager:
    """Build a manager from a config (or config kwargs); unknown strategy
    names fail fast with the known list, PR 4 registry style."""
    cfg = config if config is not None else FleetConfig(**kwargs)
    return FleetManager(cfg, n_pools)
